"""Analyzer driver: file walking, call-graph construction, waivers, report.

Two passes over the analyzed fileset:

1. parse every file, index its functions (qualname, decorators, simple-name
   call edges) and compute the hot-path closure — every function reachable
   from a ``@hot_path``-decorated root by following call edges, matched by
   simple name across the fileset (coarse by design: over-approximation
   costs a waiver, under-approximation misses a bug);
2. run each rule module over each file with the shared context.

Waivers are in-source comments (``# analyze: waive[RULE]: reason``) on the
offending line or the line directly above; ``--strict`` additionally fails
on *stale* waivers so justifications cannot outlive the code they excuse.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

WAIVER_RE = re.compile(
    r"#\s*analyze:\s*waive\[([A-Za-z0-9_,\s]+)\]\s*:\s*(\S.*)")
BARE_WAIVER_RE = re.compile(r"#\s*analyze:\s*waive\[([A-Za-z0-9_,\s]+)\]\s*(?::\s*)?$")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    waived: bool = False
    waive_reason: str = ""

    def format(self) -> str:
        tag = f" (waived: {self.waive_reason})" if self.waived else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


@dataclasses.dataclass
class Waiver:
    path: str
    line: int
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


@dataclasses.dataclass
class FunctionInfo:
    """One function/method definition, as the rules see it."""

    qualname: str
    node: ast.AST  # FunctionDef | AsyncFunctionDef
    decorators: Tuple[str, ...]  # dotted decorator names
    calls: Set[str] = dataclasses.field(default_factory=set)  # simple names
    nested: bool = False  # defined inside another function (not importable)

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def is_hot_root(self) -> bool:
        return any(d == "hot_path" or d.endswith(".hot_path")
                   for d in self.decorators)


@dataclasses.dataclass
class ModuleInfo:
    path: str
    source: str
    tree: ast.Module
    functions: List[FunctionInfo]

    @property
    def lines(self) -> List[str]:
        return self.source.splitlines()


class Context:
    """Shared analysis state: all modules + the hot-path closure."""

    def __init__(self, modules: Sequence[ModuleInfo]):
        self.modules = list(modules)
        self.hot: Set[Tuple[str, str]] = set()  # (path, qualname)
        self._compute_hot_closure()

    def is_hot(self, module: ModuleInfo, fn: FunctionInfo) -> bool:
        return (module.path, fn.qualname) in self.hot

    def _compute_hot_closure(self) -> None:
        by_name: Dict[str, List[Tuple[str, FunctionInfo]]] = {}
        by_module: Dict[Tuple[str, str], List[Tuple[str, FunctionInfo]]] = {}
        for m in self.modules:
            for fn in m.functions:
                # Nested defs are only callable from their enclosing scope, so
                # they are never valid *cross-module* call targets.
                if not fn.nested:
                    by_name.setdefault(fn.name, []).append((m.path, fn))
                by_module.setdefault((m.path, fn.name), []).append((m.path, fn))
        work: List[Tuple[str, FunctionInfo]] = [
            (m.path, fn) for m in self.modules for fn in m.functions
            if fn.is_hot_root]
        seen: Set[Tuple[str, str]] = set()
        while work:
            path, fn = work.pop()
            key = (path, fn.qualname)
            if key in seen:
                continue
            seen.add(key)
            for callee in fn.calls:
                # Same-module definitions shadow same-named functions
                # elsewhere (nested helpers, methods like ``__init__``):
                # only fall back to the global by-name match when the
                # caller's module has no definition of that name.
                targets = by_module.get((path, callee)) or by_name.get(callee, ())
                for tgt in targets:
                    work.append(tgt)
        self.hot = seen


# Call-graph edges through these roots would alias external functions onto
# same-named repo defs (``np.stack`` is not ``models.params.stack``).
EXTERNAL_ROOTS = {
    "np", "numpy", "jnp", "jax", "lax", "ast", "os", "sys", "math", "time",
    "re", "json", "zlib", "dataclasses", "collections", "functools",
    "itertools", "contextlib", "logging", "pathlib", "typing", "pytest",
}


def _external_call(dotted: str) -> bool:
    return "." in dotted and dotted.split(".", 1)[0] in EXTERNAL_ROOTS


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.random.uniform`` for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


class _FunctionIndexer(ast.NodeVisitor):
    def __init__(self) -> None:
        self.stack: List[str] = []
        self.kinds: List[str] = []  # "class" | "function", parallel to stack
        self.functions: List[FunctionInfo] = []

    def _visit_def(self, node) -> None:
        qual = ".".join(self.stack + [node.name])
        decos = tuple(
            d for d in (dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
                        for dec in node.decorator_list)
            if d is not None)
        calls: Set[str] = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = call_name(sub)
                if name is not None and not _external_call(name):
                    calls.add(name.rsplit(".", 1)[-1])
        nested = "function" in self.kinds
        self.functions.append(FunctionInfo(qual, node, decos, calls, nested=nested))
        self.stack.append(node.name)
        self.kinds.append("function")
        self.generic_visit(node)
        self.kinds.pop()
        self.stack.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_def(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_def(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.stack.append(node.name)
        self.kinds.append("class")
        self.generic_visit(node)
        self.kinds.pop()
        self.stack.pop()


def parse_module(path: str, source: Optional[str] = None) -> ModuleInfo:
    src = Path(path).read_text() if source is None else source
    tree = ast.parse(src, filename=path)
    idx = _FunctionIndexer()
    idx.visit(tree)
    return ModuleInfo(path=path, source=src, tree=tree, functions=idx.functions)


def collect_waivers(module: ModuleInfo) -> List[Waiver]:
    out: List[Waiver] = []
    for i, line in enumerate(module.lines, start=1):
        m = WAIVER_RE.search(line)
        reason = None
        if m:
            reason = m.group(2).strip()
        else:
            m = BARE_WAIVER_RE.search(line)
            if m:
                reason = ""  # missing reason: waiver counts as unexplained
        if m:
            rules = tuple(r.strip().upper() for r in m.group(1).split(",") if r.strip())
            out.append(Waiver(module.path, i, rules, reason or ""))
    return out


def _rule_modules():
    from tools.analyze.rules import ALL_RULES

    return ALL_RULES


def analyze_modules(modules: Sequence[ModuleInfo]) -> Tuple[List[Finding], List[Waiver]]:
    ctx = Context(modules)
    findings: List[Finding] = []
    waivers: List[Waiver] = []
    for m in modules:
        mod_waivers = collect_waivers(m)
        mod_findings: List[Finding] = []
        for rule in _rule_modules():
            mod_findings.extend(rule.check(m, ctx))
        # Nested defs are visited standalone AND inside their enclosing
        # function's walk; keep one finding per (rule, line).
        dedup: Dict[Tuple[str, int], Finding] = {}
        for f in mod_findings:
            dedup.setdefault((f.rule, f.line), f)
        mod_findings = list(dedup.values())
        # A waiver on the finding's line or the line above covers it; a
        # waiver with an empty reason never explains anything.  Same-line
        # waivers match first so consecutive flagged lines don't cascade
        # onto each other's comments.
        for f in mod_findings:
            for offset in (0, 1):
                w = next((w for w in mod_waivers
                          if f.rule in w.rules and w.line == f.line - offset
                          and w.reason), None)
                if w is not None:
                    f.waived, f.waive_reason = True, w.reason
                    w.used = True
                    break
        findings.extend(mod_findings)
        waivers.extend(mod_waivers)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, waivers


def analyze_source(source: str, path: str = "<memory>") -> List[Finding]:
    """Analyze one in-memory module (the fixture-test entry point)."""
    return analyze_modules([parse_module(path, source)])[0]


def iter_py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(str(f) for f in sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(str(path))
    return out


def analyze_paths(paths: Sequence[str]) -> Tuple[List[Finding], List[Waiver]]:
    return analyze_modules([parse_module(f) for f in iter_py_files(paths)])


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="Hot-path invariant linter (see tools/analyze/__init__.py).")
    ap.add_argument("paths", nargs="+", help="files or directories to analyze")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale or reasonless waivers")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress waived findings in the report")
    args = ap.parse_args(argv)

    findings, waivers = analyze_paths(args.paths)
    unwaived = [f for f in findings if not f.waived]
    stale = [w for w in waivers if not w.used]
    reasonless = [w for w in waivers if not w.reason]

    for f in findings:
        if f.waived and args.quiet:
            continue
        print(f.format())
    if args.strict:
        for w in stale:
            print(f"{w.path}:{w.line}: STALE-WAIVER: waive[{','.join(w.rules)}] "
                  f"matches no finding")
        for w in reasonless:
            print(f"{w.path}:{w.line}: WAIVER-NO-REASON: waive[{','.join(w.rules)}] "
                  f"has no justification")

    n_waived = sum(1 for f in findings if f.waived)
    print(f"analyze: {len(findings)} finding(s) "
          f"({n_waived} waived, {len(unwaived)} unexplained), "
          f"{len(stale)} stale waiver(s)")
    if unwaived:
        return 1
    if args.strict and (stale or reasonless):
        return 1
    return 0
