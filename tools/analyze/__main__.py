"""``python -m tools.analyze src/ [--strict]`` — see tools/analyze/__init__.py."""
import sys

from tools.analyze.driver import main

if __name__ == "__main__":
    sys.exit(main())
