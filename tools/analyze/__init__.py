"""repro-analyze: hot-path invariant linter for the serving engine.

An AST-based static-analysis pass (stdlib ``ast`` only — no third-party
lint framework) whose rules are derived from real bugs this repo fixed by
hand in earlier PRs.  Run it as::

    python -m tools.analyze src/            # report, exit 1 on unwaived findings
    python -m tools.analyze src/ --strict   # additionally fail on stale waivers

Rules
-----
KEY01   PRNG key reuse: the same key object flowing into two consumers
        without an intervening ``split``/``fold_in`` (the PR 7
        ``select_attribute`` AQR-key bug).
PAD01   Shape hazards: dynamic-shaped array constructors on hot paths that
        bypass the shared pow2 helpers (retrace bombs).
SYNC01  Host-device sync on hot paths: ``.item()`` / ``float()`` / ``int()``
        / ``np.asarray`` on device-derived values inside functions reachable
        from the ``@hot_path`` roots.
CACHE01 Cache-key completeness: table-keyed caches must key on ``uid`` AND
        ``version``; signature-derived keys must exclude threshold values.
DTYPE01 64-bit literals/promotions under x64-disabled jax (the PR 1
        ``ones_like`` class).
CMP01   Comparator/tie-break totality on index-lookup paths: order-dependent
        ``max``/sorts without a deterministic tie-break key, and
        subsumption-style threshold comparisons that ignore operator
        strictness (the PR 3 ``subsumes`` ``>`` vs ``>=`` bug).

Waivers: a finding is explained away in-source with::

    offending_line()  # analyze: waive[RULE]: reason

(or the comment alone on the line directly above).  ``--strict`` also
rejects waivers that no longer match a finding, so justifications cannot
outlive the code they excuse.
"""
from tools.analyze.driver import Finding, analyze_paths, analyze_source, main

__all__ = ["Finding", "analyze_paths", "analyze_source", "main"]
