"""CACHE01 — cache-key completeness.

Every cross-query cache in this repo keys on table lineage: a bare object
id or name-based key serves stale state after ``append``/``delete`` mints a
new version, and an id-based key resurrects on id reuse.  Conversely,
*threshold values* must stay OUT of signature-derived keys — the AQR and
selection caches exist precisely because queries differing only in HAVING
thresholds share one pass; leaking ``having.value`` into the key silently
disables the sharing (and leaking it into an index predicate key would
split entries that must compare).

The rule checks every declared key-builder (functions whose name contains
``cache_key``, plus the explicitly registered schemas below) against its
schema:

* ``require``: attribute reads that MUST appear (default: ``uid`` AND
  ``version`` — one without the other is the classic incomplete key);
* threshold exclusion: no ``<having>.value`` reads and no
  ``astuple(x.having)`` / ``astuple(x.outer_having)`` (astuple embeds the
  threshold value wholesale).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from tools.analyze.driver import Context, Finding, ModuleInfo, call_name, dotted_name

RULE = "CACHE01"

# Declared schemas: function name -> required attribute reads.  Any other
# function whose name contains "cache_key" gets the default schema.
SCHEMAS: Dict[str, Tuple[str, ...]] = {
    "aqr_cache_key": ("uid", "version"),
    "selection_cache_key": ("uid", "version"),
}
DEFAULT_REQUIRE: Tuple[str, ...] = ("uid", "version")

HAVING_NAMES = ("having", "outer_having")


def _attr_reads(fn_node: ast.AST) -> set:
    return {sub.attr for sub in ast.walk(fn_node) if isinstance(sub, ast.Attribute)}


def _having_value_read(fn_node: ast.AST) -> Optional[int]:
    """Line of a ``<...>.having.value`` / ``<...>.outer_having.value`` read."""
    for sub in ast.walk(fn_node):
        if isinstance(sub, ast.Attribute) and sub.attr == "value":
            base = sub.value
            if isinstance(base, ast.Attribute) and base.attr in HAVING_NAMES:
                return sub.lineno
            if isinstance(base, ast.Name) and base.id in HAVING_NAMES:
                return sub.lineno
    return None


def _having_astuple(fn_node: ast.AST) -> Optional[int]:
    for sub in ast.walk(fn_node):
        if isinstance(sub, ast.Call):
            name = call_name(sub)
            if name is None or name.rsplit(".", 1)[-1] != "astuple":
                continue
            for arg in sub.args:
                dn = dotted_name(arg)
                if dn is not None and dn.rsplit(".", 1)[-1] in HAVING_NAMES:
                    return sub.lineno
                # astuple(x.having) guarded by a conditional still embeds
                # the value; the IfExp form `astuple(h) if h else None` with
                # h bound to a having is beyond one-level resolution.
    return None


def check(module: ModuleInfo, ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for fn in module.functions:
        name = fn.name
        if name in SCHEMAS:
            require = SCHEMAS[name]
        elif "cache_key" in name:
            require = DEFAULT_REQUIRE
        else:
            continue
        reads = _attr_reads(fn.node)
        missing = [a for a in require if a not in reads]
        if missing:
            out.append(Finding(
                RULE, module.path, fn.node.lineno,
                f"cache key builder {name!r} omits {'/'.join(missing)} — a "
                f"table-keyed cache must key on uid AND version or it serves "
                f"stale state after mutations"))
        line = _having_value_read(fn.node) or _having_astuple(fn.node)
        if line is not None:
            out.append(Finding(
                RULE, module.path, line,
                f"cache key builder {name!r} embeds a HAVING threshold "
                f"value — signature-derived keys must be "
                f"threshold-independent (ops only) so same-template queries "
                f"share one pass"))
    return out
