"""KEY01 — PRNG key reuse.

The bug class (PR 7, ``select_attribute``): one PRNG key object flowing into
two ``jax.random.*`` consumers (or two key-consuming repo functions) without
an intervening ``split``/``fold_in``.  Two passes drawing from the same key
produce *correlated* randomness — the AQR pass and the estimate pass ranked
candidates off correlated draws until the fold_in fix.

Analysis: per function, path-sensitive consumption counting.

* Key variables: parameters named like keys (``key``, ``k_s``, ``*_key``,
  ``rng``) and locals assigned from ``PRNGKey``/``split``/``fold_in`` (or
  any call whose name ends with ``key``).
* A call consuming a key var as an argument counts once — unless the callee
  is a deriver (``split``/``fold_in``/``PRNGKey``), which is how new keys
  are minted.
* Reassignment resets the count.  ``if``/``else`` branches count
  independently (a key consumed once in each arm is used once per path).
* Consumption inside a loop or comprehension whose key is not re-derived
  each iteration is an immediate finding: every iteration draws the same
  randomness.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set

from tools.analyze.driver import Context, Finding, FunctionInfo, ModuleInfo, call_name

RULE = "KEY01"

KEY_PARAM_RE = re.compile(r"^(key|rng|k|k_[a-z0-9_]+|[a-z0-9_]*_key)$")
DERIVERS = {"split", "fold_in", "PRNGKey", "key"}  # jax.random.key too


def _is_deriver(call: ast.Call) -> bool:
    name = call_name(call)
    if name is None:
        return False
    return name.rsplit(".", 1)[-1] in DERIVERS


def _non_key_annotation(arg: ast.arg) -> bool:
    """A key-looking parameter annotated as a plain host type (``key: int``
    registration ids, ``k: str`` cache keys) is not a PRNG key."""
    ann = arg.annotation
    if ann is None:
        return False
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        text = ann.value
    else:
        try:
            text = ast.unparse(ann)
        except Exception:  # pragma: no cover - malformed annotation
            return False
    return not any(tok in text for tok in ("Array", "array", "PRNGKey", "Key"))


def _terminates(stmts: List[ast.stmt]) -> bool:
    """True when control cannot fall through this statement list."""
    if not stmts:
        return False
    last = stmts[-1]
    if isinstance(last, (ast.Return, ast.Raise, ast.Continue, ast.Break)):
        return True
    if isinstance(last, ast.If):
        return bool(last.orelse) and _terminates(last.body) and _terminates(last.orelse)
    return False


def _assigned_names(target: ast.AST) -> List[str]:
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(_assigned_names(elt))
        return out
    return []


class _FnAnalysis:
    def __init__(self, module: ModuleInfo, fn: FunctionInfo):
        self.module = module
        self.fn = fn
        self.findings: List[Finding] = []
        self.key_vars: Set[str] = set()
        args = fn.node.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if KEY_PARAM_RE.match(a.arg) and not _non_key_annotation(a):
                self.key_vars.add(a.arg)
        for sub in ast.walk(fn.node):
            if isinstance(sub, ast.Assign) and isinstance(sub.value, ast.Call) \
                    and _is_deriver(sub.value):
                for t in sub.targets:
                    self.key_vars.update(_assigned_names(t))

    def _flag(self, var: str, line: int, why: str) -> None:
        self.findings.append(Finding(
            RULE, self.module.path, line,
            f"PRNG key {var!r} {why} — derive a fresh key with "
            f"jax.random.split/fold_in instead"))

    # -- expression-level consumption ---------------------------------------
    def _consume_expr(self, expr: ast.AST, counts: Dict[str, int],
                      loop_vars: Optional[Set[str]] = None) -> None:
        for sub in ast.walk(expr):
            if isinstance(sub, (ast.ListComp, ast.SetComp, ast.DictComp,
                                ast.GeneratorExp)):
                self._consume_comp(sub, counts)
            elif isinstance(sub, ast.Call):
                deriver = _is_deriver(sub)
                consumed_here: Set[str] = set()
                for arg in list(sub.args) + [kw.value for kw in sub.keywords]:
                    if isinstance(arg, ast.Name) and arg.id in self.key_vars:
                        if deriver:
                            continue
                        var = arg.id
                        if loop_vars is not None and var in loop_vars:
                            self._flag(var, sub.lineno,
                                       "consumed inside a loop without "
                                       "per-iteration derivation")
                            loop_vars.discard(var)  # one finding per var
                            continue
                        counts[var] = counts.get(var, 0) + 1
                        if counts[var] == 2 and var not in consumed_here:
                            self._flag(var, sub.lineno,
                                       "consumed by a second consumer "
                                       "without split/fold_in")
                        consumed_here.add(var)

    def _consume_comp(self, comp: ast.AST, counts: Dict[str, int]) -> None:
        targets: Set[str] = set()
        for gen in comp.generators:  # type: ignore[attr-defined]
            targets.update(_assigned_names(gen.target))
        live = {v for v in self.key_vars if v not in targets}
        self._consume_expr_nodes_in_comp(comp, counts, live)

    def _consume_expr_nodes_in_comp(self, comp, counts, live: Set[str]) -> None:
        for sub in ast.walk(comp):
            if isinstance(sub, ast.Call) and not _is_deriver(sub):
                for arg in list(sub.args) + [kw.value for kw in sub.keywords]:
                    if isinstance(arg, ast.Name) and arg.id in live:
                        self._flag(arg.id, sub.lineno,
                                   "consumed inside a comprehension without "
                                   "per-iteration derivation")
                        live.discard(arg.id)

    # -- statement-level walk -----------------------------------------------
    def run(self) -> List[Finding]:
        self._walk(self.fn.node.body, {})
        return self.findings

    def _walk(self, stmts, counts: Dict[str, int]) -> Dict[str, int]:
        for stmt in stmts:
            counts = self._stmt(stmt, counts)
        return counts

    def _stmt(self, stmt: ast.AST, counts: Dict[str, int]) -> Dict[str, int]:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return counts  # nested defs are analyzed as their own functions
        if isinstance(stmt, ast.Assign):
            self._consume_expr(stmt.value, counts)
            for t in stmt.targets:
                for name in _assigned_names(t):
                    if name in self.key_vars:
                        counts[name] = 0  # rebound: fresh object
            return counts
        if isinstance(stmt, ast.AugAssign):
            self._consume_expr(stmt.value, counts)
            return counts
        if isinstance(stmt, ast.If):
            self._consume_expr(stmt.test, counts)
            after_body = self._walk(stmt.body, dict(counts))
            after_else = self._walk(stmt.orelse, dict(counts))
            # A branch that terminates (guard-clause return/raise/...) never
            # reaches the code after the If — its counts don't merge.
            if _terminates(stmt.body):
                return after_else
            if stmt.orelse and _terminates(stmt.orelse):
                return after_body
            merged = dict(counts)
            for v in set(after_body) | set(after_else):
                merged[v] = max(after_body.get(v, 0), after_else.get(v, 0))
            return merged
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            return self._loop(stmt, counts)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._consume_expr(item.context_expr, counts)
            return self._walk(stmt.body, counts)
        if isinstance(stmt, ast.Try):
            counts = self._walk(stmt.body, counts)
            for h in stmt.handlers:
                counts = self._walk(h.body, dict(counts))
            counts = self._walk(stmt.orelse, counts)
            return self._walk(stmt.finalbody, counts)
        # Return / Expr / Assert / Raise / ...: count any consumption inside.
        for value in ast.iter_child_nodes(stmt):
            if isinstance(value, ast.expr):
                self._consume_expr(value, counts)
        return counts

    def _loop(self, stmt, counts: Dict[str, int]) -> Dict[str, int]:
        # Vars re-derived each iteration: the for-target (when iterating a
        # deriver, e.g. ``for k in jax.random.split(key, n)``) and anything
        # assigned from a deriver call inside the body.
        rebound: Set[str] = set()
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._consume_expr(stmt.iter, counts)
            if isinstance(stmt.iter, ast.Call) or any(
                    isinstance(s, ast.Call) and _is_deriver(s)
                    for s in ast.walk(stmt.iter)):
                rebound.update(_assigned_names(stmt.target))
        else:
            self._consume_expr(stmt.test, counts)
        for sub in ast.walk(stmt):
            if isinstance(sub, ast.Assign) and isinstance(sub.value, ast.Call) \
                    and _is_deriver(sub.value):
                for t in sub.targets:
                    rebound.update(_assigned_names(t))
        loop_vars = {v for v in self.key_vars if v not in rebound}
        body_counts = dict(counts)
        for s in stmt.body:
            body_counts = self._stmt_with_loopvars(s, body_counts, loop_vars)
        return self._walk(stmt.orelse, counts)

    def _stmt_with_loopvars(self, stmt, counts, loop_vars: Set[str]):
        # Same as _stmt but expression consumption knows which vars are
        # loop-carried (consuming one = per-iteration reuse = finding).
        if isinstance(stmt, ast.Assign):
            self._consume_expr(stmt.value, counts, loop_vars)
            for t in stmt.targets:
                for name in _assigned_names(t):
                    if name in self.key_vars:
                        counts[name] = 0
                        loop_vars.discard(name)
            return counts
        if isinstance(stmt, ast.If):
            self._consume_expr(stmt.test, counts, loop_vars)
            b = dict(counts)
            for s in stmt.body:
                b = self._stmt_with_loopvars(s, b, loop_vars)
            e = dict(counts)
            for s in stmt.orelse:
                e = self._stmt_with_loopvars(s, e, loop_vars)
            if _terminates(stmt.body):
                return e
            if stmt.orelse and _terminates(stmt.orelse):
                return b
            merged = dict(counts)
            for v in set(b) | set(e):
                merged[v] = max(b.get(v, 0), e.get(v, 0))
            return merged
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            return self._loop(stmt, counts)
        for value in ast.iter_child_nodes(stmt):
            if isinstance(value, ast.expr):
                self._consume_expr(value, counts, loop_vars)
        return counts


def check(module: ModuleInfo, ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for fn in module.functions:
        analysis = _FnAnalysis(module, fn)
        if analysis.key_vars:
            out.extend(analysis.run())
    return out
