"""CMP01 — comparator/tie-break totality on index-lookup paths.

Two bug classes, both shipped and fixed by hand before this pass existed:

* **Order-dependent selection** (PR 7, ``SketchIndex.lookup_entry``): a
  ``max``/``min``/``sorted`` over index entries or candidates whose key
  does not totally order them lets insertion order break ties — batched
  admission inserts a wave's sketches in a different order than sequential
  replay, so probes served *different* entries and bookkeeping diverged.
  The fix is an explicit deterministic tie-break tuple; this rule demands
  one syntactically: selections over entry/candidate collections must pass
  a ``key=`` whose lambda returns a tuple.

* **Subsumption strictness** (PR 3, ``subsumes``): comparing HAVING
  thresholds with ``<=``/``>=`` while ignoring operator strictness treated
  ``agg > tau`` and ``agg >= tau`` as interchangeable at equal thresholds —
  silent wrong results on reuse (the boundary groups' provenance was never
  captured).  Any function named like a subsumption/domination test that
  compares ``.value`` attributes but never reads ``.op`` repeats that bug.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from tools.analyze.driver import Context, Finding, ModuleInfo, call_name

RULE = "CMP01"

ORDERED_COLLECTION_HINTS = ("entries", "entry", "cand", "candidates", "sizes",
                            "estimates", "ranking")
SUBSUME_HINTS = ("subsum", "dominat")


def _mentions_hint(expr: ast.AST) -> bool:
    for sub in ast.walk(expr):
        name = None
        if isinstance(sub, ast.Name):
            name = sub.id
        elif isinstance(sub, ast.Attribute):
            name = sub.attr
        if name is not None and any(h in name.lower()
                                    for h in ORDERED_COLLECTION_HINTS):
            return True
    return False


def _key_is_total(kw: Optional[ast.keyword]) -> bool:
    """A key that syntactically ends in a tuple is an explicit tie-break."""
    if kw is None:
        return False
    v = kw.value
    if isinstance(v, ast.Lambda):
        body = v.body
        return isinstance(body, (ast.Tuple, ast.List))
    return False  # sizes.get etc.: cannot prove totality


def _check_selections(module: ModuleInfo, fn_node: ast.AST, out: List[Finding]) -> None:
    for sub in ast.walk(fn_node):
        if not isinstance(sub, ast.Call):
            continue
        name = call_name(sub)
        if name is None:
            continue
        last = name.rsplit(".", 1)[-1]
        if last not in ("max", "min", "sorted", "sort"):
            continue
        iterable: Optional[ast.AST]
        if last == "sort" and isinstance(sub.func, ast.Attribute):
            iterable = sub.func.value
        elif sub.args:
            iterable = sub.args[0]
        else:
            continue
        if last in ("max", "min") and len(sub.args) > 1:
            continue  # max(a, b) over scalars, not a collection pick
        if not _mentions_hint(iterable):
            continue
        kw = next((k for k in sub.keywords if k.arg == "key"), None)
        if not _key_is_total(kw):
            out.append(Finding(
                RULE, module.path, sub.lineno,
                f"{last}() over an entry/candidate collection without an "
                f"explicit tuple tie-break key — equal primary keys fall "
                f"back to iteration/insertion order, which batched and "
                f"sequential execution do not share"))


def _check_subsumption(module: ModuleInfo, fn, out: List[Finding]) -> None:
    if not any(h in fn.name.lower() for h in SUBSUME_HINTS):
        return
    reads_op = any(isinstance(s, ast.Attribute) and s.attr == "op"
                   for s in ast.walk(fn.node))
    if reads_op:
        return
    for sub in ast.walk(fn.node):
        if not isinstance(sub, ast.Compare):
            continue
        exprs = [sub.left] + list(sub.comparators)
        value_reads = sum(
            1 for e in exprs for a in ast.walk(e)
            if isinstance(a, ast.Attribute) and a.attr in ("value", "threshold"))
        if value_reads >= 1 and any(
                isinstance(op, (ast.LtE, ast.GtE, ast.Lt, ast.Gt))
                for op in sub.ops):
            out.append(Finding(
                RULE, module.path, sub.lineno,
                f"{fn.name}() compares thresholds without consulting "
                f"operator strictness (.op) — '>' and '>=' captured sketches "
                f"differ at the boundary, so threshold dominance alone is "
                f"not containment (the PR 3 subsumes bug)"))
            return


def check(module: ModuleInfo, ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for fn in module.functions:
        _check_selections(module, fn.node, out)
        _check_subsumption(module, fn, out)
    return out
