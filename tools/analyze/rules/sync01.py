"""SYNC01 — host-device synchronization on hot paths.

``.item()``, ``float(x)`` / ``int(x)`` and ``np.asarray(x)`` on a device
value block the host on the device stream.  One sync at a deliberate merge
point is a design decision (and gets a waiver saying so); a sync smeared
into a per-item loop or a function that runs per query is the difference
between the fused one-launch hot path and the host-loop it replaced.

Scope: functions in the hot-path closure (``@hot_path`` roots + the
call-graph walk from them, matched by simple name across the fileset).

Device-derived values are tracked per function: a local assigned from a
``jnp.*`` / ``jax.*`` call (or from another device-derived local) is
device-derived; flagged sync forms are

* ``<anything>.item()`` — always a sync;
* ``float(e)`` / ``int(e)`` where ``e`` mentions a device-derived value;
* ``np.asarray(e)`` / ``np.array(e)`` likewise.
"""
from __future__ import annotations

import ast
from typing import List, Set

from tools.analyze.driver import Context, Finding, ModuleInfo, call_name

RULE = "SYNC01"

SYNC_BUILTINS = {"float", "int"}
SYNC_NP = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}


def _device_rooted_call(call: ast.Call) -> bool:
    name = call_name(call)
    return name is not None and name.split(".", 1)[0] in ("jnp", "jax")


def _is_host_copy(value: ast.AST) -> bool:
    """``np.asarray(...)`` (or a tuple of them) materializes HOST copies:
    the transfer is flagged at that line; downstream float()/int() on the
    bound names are free."""
    if isinstance(value, ast.Call):
        return call_name(value) in SYNC_NP
    if isinstance(value, (ast.Tuple, ast.List)) and value.elts:
        return all(_is_host_copy(e) for e in value.elts)
    return False


def _device_locals(fn_node: ast.AST) -> Set[str]:
    """Two ordered passes over assignments: a value mentioning a jnp/jax
    call (or a device-derived name) marks its targets device; re-binding a
    name to a host copy un-marks it.  The second pass covers loop-carried
    flows; the result approximates the state at the *last* binding, which is
    what the sync checks below care about."""
    assigns = []
    for sub in ast.walk(fn_node):
        if isinstance(sub, ast.Assign):
            assigns.append((sub.lineno, sub.targets, sub.value))
        elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
            assigns.append((sub.lineno, [sub.target], sub.value))
    assigns.sort(key=lambda a: a[0])
    device: Set[str] = set()
    for _ in range(2):
        for _, targets, value in assigns:
            if _is_host_copy(value):
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            device.discard(n.id)
                continue
            mentions_device = any(
                (isinstance(s, ast.Call) and _device_rooted_call(s))
                or (isinstance(s, ast.Name) and s.id in device)
                for s in ast.walk(value))
            if not mentions_device:
                continue
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        device.add(n.id)
    return device


def _is_static_metadata(expr: ast.AST) -> bool:
    """``int(x.shape[0])`` / ``float(x.ndim)`` read static trace-time
    metadata, not device data — no sync."""
    return any(isinstance(s, ast.Attribute) and s.attr in ("shape", "ndim")
               for s in ast.walk(expr))


def _mentions_device(expr: ast.AST, device: Set[str]) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Name) and sub.id in device:
            return True
        if isinstance(sub, ast.Call) and _device_rooted_call(sub):
            return True
    return False


def check(module: ModuleInfo, ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for fn in module.functions:
        if not (fn.is_hot_root or ctx.is_hot(module, fn)):
            continue
        device = _device_locals(fn.node)
        for sub in ast.walk(fn.node):
            if not isinstance(sub, ast.Call):
                continue
            name = call_name(sub)
            if isinstance(sub.func, ast.Attribute) and sub.func.attr == "item" \
                    and not sub.args \
                    and _mentions_device(sub.func.value, device):
                out.append(Finding(
                    RULE, module.path, sub.lineno,
                    "hot-path .item() forces a device->host sync"))
                continue
            if name in SYNC_BUILTINS and len(sub.args) == 1 \
                    and not _is_static_metadata(sub.args[0]) \
                    and _mentions_device(sub.args[0], device):
                out.append(Finding(
                    RULE, module.path, sub.lineno,
                    f"hot-path {name}() on a device value forces a "
                    f"device->host sync"))
                continue
            if name in SYNC_NP and sub.args \
                    and _mentions_device(sub.args[0], device):
                out.append(Finding(
                    RULE, module.path, sub.lineno,
                    f"hot-path {name}() on a device value forces a "
                    f"device->host transfer"))
    return out
