"""DTYPE01 — 64-bit dtypes under x64-disabled jax.

This repo runs jax with the default ``jax_enable_x64=False``: any int64 /
float64 reaching a jax constructor is silently truncated to 32 bits.  The
historical exemplar (fixed in PR 1): ``jnp.ones_like`` on a host numpy
array — numpy's default integer is int64 on linux, ``ones_like`` copies the
dtype, and jax then truncates it with only a one-time warning, so weight
vectors quietly became int32 while the surrounding math assumed wider.

Flags:

* ``jnp.int64`` / ``jnp.float64`` / ``jnp.uint64`` attribute reads, and
  64-bit dtype string/attribute arguments (``dtype=np.int64``,
  ``dtype="float64"``) in jnp/jax-rooted calls — the dtype cannot survive;
* ``jnp.{ones,zeros,full}_like`` / ``jnp.asarray`` applied directly to an
  ``np.``-rooted expression — the host array's platform-dependent 64-bit
  dtype is inherited and then truncated; convert explicitly instead.
"""
from __future__ import annotations

import ast
from typing import List

from tools.analyze.driver import Context, Finding, ModuleInfo, call_name, dotted_name

RULE = "DTYPE01"

WIDE = {"int64", "float64", "uint64"}
LIKE = {"ones_like", "zeros_like", "full_like", "asarray"}


def _np_rooted(expr: ast.AST) -> bool:
    if isinstance(expr, ast.Call):
        name = call_name(expr)
    else:
        name = dotted_name(expr)
    return name is not None and name.split(".", 1)[0] in ("np", "numpy")


def check(module: ModuleInfo, ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    # jnp.int64 and friends, anywhere: under x64-disabled these are traps.
    for sub in ast.walk(module.tree):
        if isinstance(sub, ast.Attribute) and sub.attr in WIDE:
            root = dotted_name(sub)
            if root is not None and root.split(".", 1)[0] == "jnp":
                out.append(Finding(
                    RULE, module.path, sub.lineno,
                    f"{root}: 64-bit jax dtype under x64-disabled — "
                    f"silently truncated to 32 bits"))
    for sub in ast.walk(module.tree):
        if not isinstance(sub, ast.Call):
            continue
        name = call_name(sub)
        if name is None:
            continue
        parts = name.split(".")
        jax_rooted = parts[0] in ("jnp", "jax")
        # dtype=np.int64 / dtype="float64" flowing into a jax call.
        if jax_rooted:
            for kw in sub.keywords:
                if kw.arg != "dtype":
                    continue
                dn = dotted_name(kw.value)
                if dn is not None and dn.rsplit(".", 1)[-1] in WIDE \
                        and not dn.startswith("jnp."):
                    out.append(Finding(
                        RULE, module.path, kw.value.lineno,
                        f"{name}(dtype={dn}): 64-bit dtype under "
                        f"x64-disabled jax — silently truncated"))
                if isinstance(kw.value, ast.Constant) and kw.value.value in WIDE:
                    out.append(Finding(
                        RULE, module.path, kw.value.lineno,
                        f"{name}(dtype={kw.value.value!r}): 64-bit dtype "
                        f"under x64-disabled jax — silently truncated"))
        # jnp.ones_like(np.<...>): dtype inherited from a host array.
        if jax_rooted and parts[-1] in LIKE and sub.args \
                and _np_rooted(sub.args[0]) \
                and not any(kw.arg == "dtype" for kw in sub.keywords):
            out.append(Finding(
                RULE, module.path, sub.lineno,
                f"{name}() on a host numpy value inherits a "
                f"platform-dependent (often 64-bit) dtype that x64-disabled "
                f"jax truncates — pass dtype= explicitly (the PR 1 "
                f"ones_like bug class)"))
    return out
