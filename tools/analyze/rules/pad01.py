"""PAD01 — shape hazards on hot paths (retrace bombs).

Every hot-path launch in this repo is shape-stable by construction: row
counts, fragment axes, pair counts, group axes, shard and query axes are
all pow2-quantized before they reach an array constructor, so a steady
workload stays inside a small set of compiled size classes.  A constructor
whose size derives from raw data (``len(rows)``, ``n + 1``, a bare count)
compiles a fresh XLA program per distinct size — the retrace bombs the
``TRACE_COUNTS`` tests exist to catch at runtime; this rule catches them at
review time.

In hot-path functions (``@hot_path`` roots + call-graph closure), the size
argument of ``jnp/np.{zeros,ones,full,empty}`` must be

* a literal (or tuple of literals), or
* inherited from an existing array's ``.shape`` (no new size class), or
* routed through a pow2 helper — any call whose name contains ``pow2`` —
  directly or through one level of local assignment.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from tools.analyze.driver import Context, Finding, ModuleInfo, call_name, dotted_name

RULE = "PAD01"

CONSTRUCTORS = {"zeros", "ones", "full", "empty"}


def _is_constant_shape(expr: ast.AST) -> bool:
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, (ast.Tuple, ast.List)):
        return all(_is_constant_shape(e) for e in expr.elts)
    if isinstance(expr, ast.UnaryOp):
        return _is_constant_shape(expr.operand)
    return False


def _has_pow2_marker(expr: ast.AST) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call):
            name = call_name(sub)
            if name is not None and "pow2" in name.lower():
                return True
    return False


def _is_shape_inherited(expr: ast.AST) -> bool:
    """``x.shape`` / ``x.shape[0]`` / ``x.size`` reuse an existing array's
    size class — no new compilation.  ``num_rows`` / ``num_samples`` are the
    repo's ColumnTable/SampleSet row-count properties: they mirror the
    backing arrays' leading dim (pow2-padded upstream for sketch instances),
    so a constructor sized to them inherits an existing class too."""
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Attribute) and sub.attr in (
                "shape", "size", "num_rows", "num_samples"):
            return True
    return False


def _local_assignments(fn_node: ast.AST) -> Dict[str, List[ast.AST]]:
    out: Dict[str, List[ast.AST]] = {}
    for sub in ast.walk(fn_node):
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                if isinstance(t, ast.Name):
                    out.setdefault(t.id, []).append(sub.value)
        elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
            if isinstance(sub.target, ast.Name):
                out.setdefault(sub.target.id, []).append(sub.value)
    return out


def _shape_ok(expr: ast.AST, assigns: Dict[str, List[ast.AST]]) -> bool:
    if _is_constant_shape(expr) or _has_pow2_marker(expr) or _is_shape_inherited(expr):
        return True
    # Resolve names one level through local assignments: a size computed as
    # ``n_pad = _next_pow2(n)`` then used as ``jnp.zeros(n_pad)`` is fine.
    names = [s.id for s in ast.walk(expr) if isinstance(s, ast.Name)]
    if not names:
        return False
    for name in names:
        exprs = assigns.get(name)
        if not exprs:
            return False  # parameter or outer value: unknown provenance
        if not all(_is_constant_shape(e) or _has_pow2_marker(e)
                   or _is_shape_inherited(e) for e in exprs):
            return False
    return True


def check(module: ModuleInfo, ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for fn in module.functions:
        if not (fn.is_hot_root or ctx.is_hot(module, fn)):
            continue
        assigns = _local_assignments(fn.node)
        for sub in ast.walk(fn.node):
            if not isinstance(sub, ast.Call):
                continue
            name = call_name(sub)
            if name is None:
                continue
            parts = name.split(".")
            if parts[-1] not in CONSTRUCTORS or len(parts) < 2:
                continue
            # Host numpy constructors don't compile anything; only device
            # (jnp/jax) constructors mint XLA size classes.
            if parts[0] not in ("jnp", "jax"):
                continue
            if not sub.args:
                continue
            shape = sub.args[0]
            if parts[-1] == "full" and len(sub.args) >= 2:
                pass  # first arg is still the shape
            if not _shape_ok(shape, assigns):
                out.append(Finding(
                    RULE, module.path, sub.lineno,
                    f"hot-path {name}(...) with a data-dependent size that "
                    f"bypasses the pow2 helpers — every distinct size "
                    f"compiles a fresh XLA program"))
    return out
