"""Rule registry: each rule module exposes ``RULE`` (its id) and
``check(module: ModuleInfo, ctx: Context) -> List[Finding]``."""
from tools.analyze.rules import cache01, cmp01, dtype01, key01, pad01, sync01

ALL_RULES = (key01, pad01, sync01, cache01, dtype01, cmp01)

__all__ = ["ALL_RULES"]
