"""Executor correctness vs a numpy brute-force oracle for all templates."""
import numpy as np
import pytest

from repro.core import Aggregate, Database, Having, JoinSpec, Predicate, Query, execute, provenance_mask
from repro.core.datasets import make_crimes, make_tpch


@pytest.fixture(scope="module")
def crimes_db():
    return Database({"crimes": make_crimes(5_000, seed=11)})


@pytest.fixture(scope="module")
def tpch_db():
    return make_tpch(8_000, seed=12)


def brute_force_agh(db, q):
    t = db[q.table].to_numpy()
    n = len(next(iter(t.values())))
    where = np.ones(n, bool)
    if q.where:
        ops = {">": np.greater, ">=": np.greater_equal, "<": np.less,
               "<=": np.less_equal, "=": np.equal}
        where = ops[q.where.op](t[q.where.attr], q.where.value)
    groups = {}
    for i in range(n):
        key = tuple(float(t[a][i]) for a in q.groupby)
        groups.setdefault(key, []).append(i)
    out = {}
    for key, idx in groups.items():
        idx = [i for i in idx if where[i]]
        if not idx:
            continue
        if q.agg.fn == "count":
            v = float(len(idx))
        elif q.agg.fn == "sum":
            v = float(sum(t[q.agg.attr][i] for i in idx))
        else:
            v = float(np.mean([t[q.agg.attr][i] for i in idx]))
        if q.having is None or eval(f"v {q.having.op.replace('=','==') if q.having.op=='=' else q.having.op} {q.having.value}"):
            out[key] = v
    return out


@pytest.mark.parametrize("fn,attr", [("sum", "records"), ("avg", "records"), ("count", None)])
@pytest.mark.parametrize("with_where", [False, True])
def test_agh_matches_bruteforce(crimes_db, fn, attr, with_where):
    q = Query(
        table="crimes",
        groupby=("district", "year"),
        agg=Aggregate(fn, attr),
        where=Predicate("month", "<=", 6) if with_where else None,
        having=Having(">", 30.0) if fn != "avg" else Having(">", 18.0),
    )
    got = {tuple(float(q2[i]) for q2 in [execute(q, crimes_db).group_values[a] for a in sorted(execute(q, crimes_db).group_values)]): None for i in []}
    res = execute(q, crimes_db)
    got = {}
    attrs = list(q.groupby)
    for i in range(len(res.values)):
        key = tuple(float(res.group_values[a][i]) for a in attrs)
        got[key] = float(res.values[i])
    want = brute_force_agh(crimes_db, q)
    assert set(got) == set(want)
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-4)


def test_join_template(tpch_db):
    q = Query(
        table="lineitem",
        groupby=("l_suppkey",),
        agg=Aggregate("sum", "l_quantity"),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
        having=Having(">", 100.0),
    )
    res = execute(q, tpch_db)
    # oracle: manual join (all lineitems match since orders cover the range)
    li = tpch_db["lineitem"].to_numpy()
    ok = np.asarray(tpch_db["orders"]["o_orderkey"])
    match = np.isin(li["l_orderkey"], ok)
    sums = {}
    for sk, qy, m in zip(li["l_suppkey"], li["l_quantity"], match):
        if m:
            sums[float(sk)] = sums.get(float(sk), 0.0) + float(qy)
    want = {k: v for k, v in sums.items() if v > 100.0}
    got = dict(zip(map(float, res.group_values["l_suppkey"]), map(float, res.values)))
    assert got == pytest.approx(want, rel=1e-4)


def test_nested_template(crimes_db):
    q = Query(
        table="crimes",
        groupby=("district", "year"),
        agg=Aggregate("sum", "records"),
        having=Having(">", 20.0),
        outer_groupby=("district",),
        outer_agg=Aggregate("sum", None),
        outer_having=Having(">", 100.0),
    )
    res = execute(q, crimes_db)
    assert q.template == "Q-AAGH"
    # oracle
    inner = brute_force_agh(crimes_db, Query("crimes", ("district", "year"), Aggregate("sum", "records"), having=Having(">", 20.0)))
    outer = {}
    for (d, y), v in inner.items():
        outer[d] = outer.get(d, 0.0) + v
    want = {k: v for k, v in outer.items() if v > 100.0}
    got = dict(zip(map(float, res.group_values["district"]), map(float, res.values)))
    assert got == pytest.approx(want, rel=1e-4)


def test_provenance_is_sufficient(crimes_db):
    """Q(P(Q,D)) == Q(D): the lineage really is a sufficient subset."""
    q = Query("crimes", ("district", "month"), Aggregate("sum", "records"), having=Having(">", 50.0))
    prov = provenance_mask(q, crimes_db)
    sub = Database({"crimes": crimes_db["crimes"].select(prov)})
    assert execute(q, sub).canonical() == execute(q, crimes_db).canonical()
