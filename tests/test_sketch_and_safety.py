"""Sketch capture / application / safety / index-reuse behaviour."""
import numpy as np
import pytest

from repro.core import (
    Aggregate, Database, Having, Predicate, Query, SketchIndex, apply_sketch,
    capture_sketch, equi_depth_ranges, execute, execute_with_sketch,
    is_safe_sketch, prefilter_candidates, safe_attributes, subsumes,
)
from repro.core.datasets import make_crimes


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(20_000, seed=3)})


@pytest.fixture(scope="module")
def q():
    # Threshold at the ~85th percentile of group sums so the sketch actually
    # skips fragments on a 20k-row table.
    return Query(
        table="crimes",
        groupby=("district", "year"),
        agg=Aggregate("sum", "records"),
        having=Having(">", 400.0),
    )


@pytest.mark.parametrize("attr", ["district", "year", "month", "records", "beat"])
def test_sketch_is_safe_on_any_attr(db, q, attr):
    """SUM >= 0 with HAVING '>' is upward monotone: all attrs safe (Sec. 4.3)."""
    ranges = equi_depth_ranges(db["crimes"], attr, 50)
    sk = capture_sketch(q, db, ranges)
    assert is_safe_sketch(q, db, sk)
    assert 0.0 < sk.selectivity <= 1.0


def test_sketch_covers_provenance(db, q):
    from repro.core import provenance_mask, sketch_keep_mask

    ranges = equi_depth_ranges(db["crimes"], "beat", 50)
    sk = capture_sketch(q, db, ranges)
    prov = provenance_mask(q, db)
    keep = np.asarray(sketch_keep_mask(sk, db["crimes"]))
    assert (keep | ~prov).all()  # every provenance row kept


def test_avg_having_restricts_safety(db):
    q_avg = Query("crimes", ("district",), Aggregate("avg", "records"), having=Having(">", 5.0))
    safe = safe_attributes(q_avg, db)
    assert set(safe) == {"district"}  # only GB attrs safe for AVG


def test_prefilter_keeps_gb_attrs(db, q):
    cands = prefilter_candidates(q, db, ("district", "year", "month", "beat"), 100)
    assert "district" in cands and "year" in cands  # GB attrs exempt
    assert "month" not in cands  # 12 distinct < 100 ranges, not a GB attr
    assert "beat" in cands  # enough distinct values


def test_index_reuse_subsumption(db, q):
    idx = SketchIndex()
    sk = capture_sketch(q, db, equi_depth_ranges(db["crimes"], "district", 25))
    idx.insert(q, sk)
    # Higher threshold => subset provenance => reusable.
    import dataclasses

    q_higher = dataclasses.replace(q, having=Having(">", q.having.value + 200.0))
    assert subsumes(q, q_higher)
    assert idx.lookup(q_higher) is not None
    # Lower threshold needs MORE data: not reusable.
    q_lower = dataclasses.replace(q, having=Having(">", q.having.value - 300.0))
    assert not subsumes(q, q_lower)
    assert idx.lookup(q_lower) is None
    # Different group-by: not reusable.
    q_other = dataclasses.replace(q, groupby=("month",))
    assert idx.lookup(q_other) is None
    # Reused sketch still yields exact results.
    res = execute_with_sketch(q_higher, db, idx.lookup(q_higher))
    assert res.canonical() == execute(q_higher, db).canonical()


def test_apply_sketch_shrinks_db(db):
    # A 99th-percentile threshold leaves a handful of groups => the sketch
    # must actually skip fragments.
    import dataclasses

    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.99))))
    sk = capture_sketch(qs, db, equi_depth_ranges(db["crimes"], "beat", 50))
    db2 = apply_sketch(sk, db)
    # Instances are pow2-padded (masked tail) so reuse execution hits an
    # already-compiled shape: logical rows == size_rows, physical rows are
    # the next power of two.
    from repro.core.table import PAD_VALID

    inst = db2["crimes"]
    assert int(np.asarray(inst[PAD_VALID]).sum()) == sk.size_rows
    assert inst.num_rows == 1 << (sk.size_rows - 1).bit_length()
    assert inst.num_rows < db["crimes"].num_rows


# -- low-cardinality group-by attributes (satellite regression) ----------------
# GB attrs are exempt from the distinct-count pre-filter, so an attribute with
# fewer distinct values than n_ranges reaches ``equi_depth_ranges``: the
# deduplicated bounds collapse to a few fat, value-aligned fragments.  Every
# path downstream (capture, application, estimation, maintenance, the engine)
# must handle the degenerate partition.

def _lowcard_db(n=6_000, n_distinct=3, seed=11):
    from repro.core.table import from_numpy

    rng = np.random.default_rng(seed)
    return Database({"t": from_numpy("t", {
        "g": rng.integers(0, n_distinct, n).astype(np.float32),
        "v": rng.random(n).astype(np.float32),
    })})


def _lowcard_q(tau=600.0):
    return Query("t", ("g",), Aggregate("count", None), having=Having(">", tau))


def test_lowcard_gb_ranges_dedupe_and_value_align():
    db2 = _lowcard_db()
    ranges = equi_depth_ranges(db2["t"], "g", 10)
    # 3 distinct values -> at most 2 interior bounds survive dedupe.
    assert ranges.n_ranges <= 3 + 1
    assert np.all(np.diff(ranges.bounds) > 0)  # strictly increasing
    # Value-aligned: every row of one group value lands in one fragment.
    col = np.asarray(db2["t"]["g"])
    frag = np.asarray(ranges.bucketize(col))
    for v in np.unique(col):
        assert len(np.unique(frag[col == v])) == 1


def test_lowcard_gb_capture_apply_execute():
    db2 = _lowcard_db()
    q2 = _lowcard_q(tau=2100.0)  # ~one of three groups passes
    ranges = equi_depth_ranges(db2["t"], "g", 10)
    sk = capture_sketch(q2, db2, ranges)
    assert is_safe_sketch(q2, db2, sk)
    res = execute_with_sketch(q2, db2, sk)
    assert res.canonical() == execute(q2, db2).canonical()
    # The fat-fragment partition still skips: non-passing groups' fragments
    # are not covered when the threshold splits the groups.
    if 0 < int(np.asarray(sk.bits).sum()) < sk.ranges.n_ranges:
        assert sk.selectivity < 1.0


def test_lowcard_gb_estimate_path():
    """The padded estimator accepts a candidate whose deduped n_ranges is far
    below the requested count (ragged fragment axis)."""
    import jax

    from repro.aqp.sampling import SampleCache
    from repro.aqp.size_estimation import EstimationConfig, estimate_size_batched

    db2 = _lowcard_db()
    q2 = _lowcard_q(tau=2100.0)
    key = jax.random.PRNGKey(0)
    samples = SampleCache().get_or_create(key, db2["t"], ("g",), 0.2)
    ranges = equi_depth_ranges(db2["t"], "g", 10)
    ests = estimate_size_batched(key, q2, db2, {"g": ranges}, samples,
                                 EstimationConfig())
    est = ests["g"]
    assert est.est_bits.shape[0] == ranges.n_ranges
    assert 0.0 <= est.est_selectivity <= 1.0


def test_lowcard_gb_engine_end_to_end_with_maintenance():
    """Engine admission + repeat hit + append/repair over the degenerate
    partition: results stay exact throughout."""
    from repro.core.engine import PBDSEngine

    db2 = _lowcard_db()
    q2 = _lowcard_q(tau=1000.0)
    eng = PBDSEngine(db2, strategy="CB-OPT-GB", n_ranges=10, theta=0.2, seed=0,
                     min_selectivity_gain=2.0)
    res, info = eng.run(q2)
    assert info.created
    assert res.canonical() == execute(q2, db2).canonical()
    _, info2 = eng.run(q2)
    assert info2.reused
    # Mutate: append rows biased into one group, then re-run -> repair path.
    fact = eng.db["t"]
    batch = {"g": np.full(500, 1.0, np.float32),
             "v": np.linspace(0, 1, 500, dtype=np.float32)}
    eng.append_rows("t", batch)
    res3, info3 = eng.run(q2)
    assert info3.reused and info3.repaired
    assert res3.canonical() == execute(q2, eng.db).canonical()
