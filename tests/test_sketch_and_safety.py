"""Sketch capture / application / safety / index-reuse behaviour."""
import numpy as np
import pytest

from repro.core import (
    Aggregate, Database, Having, Predicate, Query, SketchIndex, apply_sketch,
    capture_sketch, equi_depth_ranges, execute, execute_with_sketch,
    is_safe_sketch, prefilter_candidates, safe_attributes, subsumes,
)
from repro.core.datasets import make_crimes


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(20_000, seed=3)})


@pytest.fixture(scope="module")
def q():
    # Threshold at the ~85th percentile of group sums so the sketch actually
    # skips fragments on a 20k-row table.
    return Query(
        table="crimes",
        groupby=("district", "year"),
        agg=Aggregate("sum", "records"),
        having=Having(">", 400.0),
    )


@pytest.mark.parametrize("attr", ["district", "year", "month", "records", "beat"])
def test_sketch_is_safe_on_any_attr(db, q, attr):
    """SUM >= 0 with HAVING '>' is upward monotone: all attrs safe (Sec. 4.3)."""
    ranges = equi_depth_ranges(db["crimes"], attr, 50)
    sk = capture_sketch(q, db, ranges)
    assert is_safe_sketch(q, db, sk)
    assert 0.0 < sk.selectivity <= 1.0


def test_sketch_covers_provenance(db, q):
    from repro.core import provenance_mask, sketch_keep_mask

    ranges = equi_depth_ranges(db["crimes"], "beat", 50)
    sk = capture_sketch(q, db, ranges)
    prov = provenance_mask(q, db)
    keep = np.asarray(sketch_keep_mask(sk, db["crimes"]))
    assert (keep | ~prov).all()  # every provenance row kept


def test_avg_having_restricts_safety(db):
    q_avg = Query("crimes", ("district",), Aggregate("avg", "records"), having=Having(">", 5.0))
    safe = safe_attributes(q_avg, db)
    assert set(safe) == {"district"}  # only GB attrs safe for AVG


def test_prefilter_keeps_gb_attrs(db, q):
    cands = prefilter_candidates(q, db, ("district", "year", "month", "beat"), 100)
    assert "district" in cands and "year" in cands  # GB attrs exempt
    assert "month" not in cands  # 12 distinct < 100 ranges, not a GB attr
    assert "beat" in cands  # enough distinct values


def test_index_reuse_subsumption(db, q):
    idx = SketchIndex()
    sk = capture_sketch(q, db, equi_depth_ranges(db["crimes"], "district", 25))
    idx.insert(q, sk)
    # Higher threshold => subset provenance => reusable.
    import dataclasses

    q_higher = dataclasses.replace(q, having=Having(">", q.having.value + 200.0))
    assert subsumes(q, q_higher)
    assert idx.lookup(q_higher) is not None
    # Lower threshold needs MORE data: not reusable.
    q_lower = dataclasses.replace(q, having=Having(">", q.having.value - 300.0))
    assert not subsumes(q, q_lower)
    assert idx.lookup(q_lower) is None
    # Different group-by: not reusable.
    q_other = dataclasses.replace(q, groupby=("month",))
    assert idx.lookup(q_other) is None
    # Reused sketch still yields exact results.
    res = execute_with_sketch(q_higher, db, idx.lookup(q_higher))
    assert res.canonical() == execute(q_higher, db).canonical()


def test_apply_sketch_shrinks_db(db):
    # A 99th-percentile threshold leaves a handful of groups => the sketch
    # must actually skip fragments.
    import dataclasses

    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.99))))
    sk = capture_sketch(qs, db, equi_depth_ranges(db["crimes"], "beat", 50))
    db2 = apply_sketch(sk, db)
    # Instances are pow2-padded (masked tail) so reuse execution hits an
    # already-compiled shape: logical rows == size_rows, physical rows are
    # the next power of two.
    from repro.core.table import PAD_VALID

    inst = db2["crimes"]
    assert int(np.asarray(inst[PAD_VALID]).sum()) == sk.size_rows
    assert inst.num_rows == 1 << (sk.size_rows - 1).bit_length()
    assert inst.num_rows < db["crimes"].num_rows
