"""Coordinator failover: replicated metadata, epoch-fenced takeover, chaos.

What must hold:

  * a coordinator kill promotes the warm standby from replicated metadata
    alone — index hits stay hits (no re-capture), shard state never moves
    (no full-table reship), and serving results are bit-identical;
  * a partitioned old coordinator is provably *fenced*: its ops raise
    ``StaleEpochError`` at the shard, on both transports;
  * the seeded chaos differential stays bit-identical with coordinator
    faults mixed into the schedule (loopback and real subprocess shards,
    all four workload templates);
  * stale checkpoints are counted and surfaced, never silent, and recovery
    delta-replays back to parity (satellite 2);
  * the ServerPool survives a respawn storm and the top-up/shutdown race
    without deadlock or orphans (satellite 3).
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Database,
    Having,
    Query,
    ReplicationError,
    ReplicationRecord,
    ShardedEngine,
    StaleEpochError,
    execute,
)
from repro.core.replication import MetadataStore
from repro.core.standby import FailoverCoordinator, replica_factory
from repro.core.datasets import make_crimes, make_tpch
from repro.runtime.chaos import (
    COORD,
    COORD_FAULT_KINDS,
    ChaosEvent,
    differential,
    random_ops,
    random_schedule,
)


# ---------------------------------------------------------------------------
# Shared workload helpers (same shapes as tests/test_chaos.py)
# ---------------------------------------------------------------------------


def _crimes_queries(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = [dataclasses.replace(base,
                              having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.4, 0.7)]
    qs.append(base)
    return qs


def _crimes_rows(rng, n):
    t = make_crimes(n, seed=int(rng.integers(1 << 30)))
    return {a: np.asarray(t[a]) for a in t.schema}


def _engine(db, n_shards=3, **kw):
    args = dict(n_ranges=16, theta=0.1, seed=0, min_selectivity_gain=2.0)
    args.update(kw)
    return ShardedEngine(db, "crimes", "district", n_shards=n_shards, **args)


def _tpch_templates(db):
    """The four workload templates (AGH / AJGH / AAGH / AAJGH)."""
    from repro.core import JoinSpec

    def thresh(q, qt):
        vals = execute(dataclasses.replace(q, having=None, outer_having=None),
                       db).values
        return float(np.quantile(vals, qt))

    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    agh = dataclasses.replace(agh, having=Having(">", thresh(agh, 0.8)))
    ajgh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
                 join=JoinSpec("orders", "l_orderkey", "o_orderkey"))
    ajgh = dataclasses.replace(ajgh, having=Having(">", thresh(ajgh, 0.8)))
    aagh = Query("lineitem", ("l_partkey", "l_suppkey"),
                 Aggregate("sum", "l_quantity"), having=Having(">", 0.0),
                 outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aagh = dataclasses.replace(aagh, outer_having=Having(">", thresh(aagh, 0.8)))
    aajgh = Query("lineitem", ("l_partkey", "l_suppkey"),
                  Aggregate("count", None),
                  join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
                  having=Having(">", 0.0),
                  outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aajgh = dataclasses.replace(
        aajgh, outer_having=Having(">", thresh(aajgh, 0.8)))
    return [agh, ajgh, aagh, aajgh]


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(3000, seed=2)})


def _failover(db, n_shards=3, replica="loopback", **kw):
    return FailoverCoordinator(_engine(db, n_shards, **kw),
                               make_replica=replica_factory(replica))


# ---------------------------------------------------------------------------
# Loopback: takeover semantics
# ---------------------------------------------------------------------------


def test_takeover_keeps_index_hits_no_recapture(db):
    q = _crimes_queries(db)[0]
    fc = _failover(db)
    try:
        expect = execute(q, fc.db).canonical()
        res, _ = fc.run(q)
        assert res.canonical() == expect
        epoch0 = fc.engine.epoch

        fc.inject_coord("coord_kill")
        assert fc.engine.epoch == epoch0 + 1
        assert fc.zombie is None  # a killed coordinator leaves no object
        misses = fc.index.misses
        res, info = fc.run(q)
        assert res.canonical() == expect
        # The replicated registration replayed into a *hit*: reuse without
        # a single new capture on the promoted coordinator.
        assert info.reused and not info.created
        assert fc.index.misses == misses

        # The promoted coordinator is a full coordinator: mutations flow.
        fc.append_rows("crimes", _crimes_rows(np.random.default_rng(7), 250))
        res, _ = fc.run(q)
        assert res.canonical() == execute(q, fc.db).canonical()
    finally:
        fc.shutdown()


def test_partition_fences_zombie_coordinator(db):
    q = _crimes_queries(db)[0]
    fc = _failover(db)
    try:
        fc.run(q)
        fc.inject_coord("coord_partition")
        z = fc.zombie
        assert z is not None and z.epoch + 1 == fc.engine.epoch

        # The fenced-out coordinator's ops are rejected AT THE SHARD — as
        # StaleEpochError, never ShardUnavailableError, so retry/degraded
        # machinery can't quietly absorb a zombie write.
        with pytest.raises(StaleEpochError):
            z.shards[0].catch_up(z.version)
        with pytest.raises(StaleEpochError):
            z.shards[1].ship(z.version + 1, "append",
                             {a: np.asarray(v)[:0] for a, v in
                              _crimes_rows(np.random.default_rng(0), 4).items()})

        # ... while the promoted coordinator serves and chains takeovers.
        res, _ = fc.run(q)
        assert res.canonical() == execute(q, fc.db).canonical()
        fc.inject_coord("coord_kill")
        res, _ = fc.run(q)
        assert res.canonical() == execute(q, fc.db).canonical()
        assert fc.takeovers == 2
    finally:
        fc.shutdown()


def test_chaos_differential_with_coord_faults_loopback(db):
    """Seeded replays mixing coordinator kills/partitions into the shard
    fault schedule: traces must equal the fault-free engine's exactly."""
    qs = _crimes_queries(db)
    for n_shards, seed in ((1, 11), (3, 12), (4, 13)):
        ops = random_ops(seed, 24, qs, _crimes_rows)
        events = random_schedule(seed, 24, n_shards, coord_rate=0.15)
        assert any(e.shard == COORD for e in events), \
            f"seed {seed}: schedule drew no coordinator faults"
        ok, chaotic, clean = differential(
            lambda n=n_shards: _failover(db, n, op_deadline_s=0.02),
            "crimes", ops, events,
            make_clean=lambda n=n_shards: _engine(db, n))
        assert ok, (
            f"n_shards={n_shards} seed={seed}: diverged at op "
            f"{next(i for i, (a, b) in enumerate(zip(chaotic, clean)) if a != b)}")


def test_random_schedule_coord_events_seeded(db):
    a = random_schedule(5, 40, 3, coord_rate=0.2)
    b = random_schedule(5, 40, 3, coord_rate=0.2)
    assert a == b
    coord = [e for e in a if e.shard == COORD]
    assert coord and all(e.kind in COORD_FAULT_KINDS for e in coord)
    # coord_rate=0 keeps legacy schedules byte-identical (no rng drift).
    assert random_schedule(5, 40, 3) == random_schedule(5, 40, 3, coord_rate=0.0)


def test_replication_stream_detects_gaps():
    store = MetadataStore()
    with pytest.raises(ReplicationError):
        store.apply(ReplicationRecord(2, "ckpt", (0, 1)))


def test_replica_loss_degrades_replication_not_serving(db):
    class _DyingReplica:
        def publish(self, rec):
            raise ReplicationError("standby gone")

        def snapshot(self):  # pragma: no cover - never reached
            raise ReplicationError("standby gone")

        def close_replica(self):
            pass

    q = _crimes_queries(db)[0]
    se = _engine(db, 2)
    try:
        se.attach_replica(_DyingReplica())
        assert se.replica_degraded  # bootstrap emit already failed
        se.append_rows("crimes", _crimes_rows(np.random.default_rng(3), 120))
        res, _ = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
    finally:
        se.shutdown()


# ---------------------------------------------------------------------------
# Subprocess: real processes, real standby, peer checkpoints
# ---------------------------------------------------------------------------


def _sub(db, n_shards=3, **kw):
    args = dict(transport="subprocess", op_deadline_s=5.0)
    args.update(kw)
    return _engine(db, n_shards, **args)


@pytest.mark.slow
def test_subprocess_takeover_with_standby_process(db):
    """The standby is a real process: it outlives the coordinator object
    and hands the folded metadata store back over its socket."""
    q = _crimes_queries(db)[0]
    fc = FailoverCoordinator(_sub(db), make_replica=replica_factory("subprocess"))
    try:
        expect = execute(q, fc.db).canonical()
        res, _ = fc.run(q)
        assert res.canonical() == expect

        fc.inject_coord("coord_kill")
        misses = fc.index.misses
        res, info = fc.run(q)
        assert res.canonical() == expect
        assert info.reused and fc.index.misses == misses

        fc.append_rows("crimes", _crimes_rows(np.random.default_rng(9), 200))
        fc.inject_coord("coord_partition")
        with pytest.raises(StaleEpochError):
            fc.zombie.shards[0].catch_up(fc.zombie.version)
        res, _ = fc.run(q)
        assert res.canonical() == execute(q, fc.db).canonical()
    finally:
        fc.shutdown()


@pytest.mark.slow
def test_peer_checkpoint_restores_killed_server(db):
    """A SIGKILLed shard server recovers from its peer's mirrored
    checkpoint: shard-sized state off the peer, not a full-table reship."""
    q = _crimes_queries(db)[0]
    se = _sub(db)
    try:
        se.run(q)
        se.append_rows("crimes", _crimes_rows(np.random.default_rng(4), 300))
        se.shards[1].inject("kill")
        se.shards[1].heal()
        res, _ = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
        assert se.peer_restores >= 1
    finally:
        se.shutdown()


@pytest.mark.slow
def test_stale_checkpoints_counted_and_recovered(db):
    """Satellite 2: a checkpoint that cannot refresh its peer mirror is
    *counted* (engine + RouteInfo), and once the peer heals, recovery
    delta-replays back to exact parity."""
    q = _crimes_queries(db)[0]
    se = _sub(db)
    try:
        se.run(q)
        se.shards[1].inject("kill")  # peer of shard 0
        rng = np.random.default_rng(5)
        for _ in range(3):
            se.append_rows("crimes", _crimes_rows(rng, 120))
        res, _ = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
        assert sum(se.stale_checkpoints) > 0
        assert se.last_route is not None
        assert se.last_route.stale_checkpoints == sum(se.stale_checkpoints)

        se.shards[1].heal()
        res, _ = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
    finally:
        se.shutdown()


@pytest.mark.slow
def test_chaos_differential_subprocess_coord_faults():
    """The acceptance gate: seeded chaos incl. coordinator faults over real
    subprocess shards (1-8), all four workload templates, bit-identical to
    the fault-free single-process fused engine."""
    db = make_tpch(2500, seed=8)
    qs = _tpch_templates(db)

    def rows(rng, n):
        t = make_tpch(4 * n, seed=int(rng.integers(1 << 30)))["lineitem"]
        return {a: np.asarray(t[a])[:n] for a in t.schema}

    def make_engine(n, replica):
        return FailoverCoordinator(
            ShardedEngine(db, "lineitem", "l_suppkey", n_shards=n,
                          n_ranges=16, theta=0.1, seed=0,
                          min_selectivity_gain=1.0, transport="subprocess",
                          op_deadline_s=5.0),
            make_replica=replica_factory(replica))

    def make_clean(n):
        return ShardedEngine(db, "lineitem", "l_suppkey", n_shards=n,
                             n_ranges=16, theta=0.1, seed=0,
                             min_selectivity_gain=1.0)

    for n_shards, seed, replica in ((1, 31, "loopback"),
                                    (4, 32, "subprocess"),
                                    (8, 33, "loopback")):
        ops = random_ops(seed, 10, qs, rows, p_query=0.5, p_batch=0.2,
                         p_append=0.2)
        events = random_schedule(seed, 10, n_shards, coord_rate=0.25)
        assert any(e.shard == COORD for e in events), \
            f"seed {seed}: no coordinator faults drawn"
        ok, chaotic, clean = differential(
            lambda n=n_shards, r=replica: make_engine(n, r),
            "lineitem", ops, events,
            make_clean=lambda n=n_shards: make_clean(n))
        assert ok, (
            f"n_shards={n_shards} seed={seed}: diverged at op "
            f"{next(i for i, (a, b) in enumerate(zip(chaotic, clean)) if a != b)}")


# ---------------------------------------------------------------------------
# Satellite 3: ServerPool respawn storm + shutdown race
# ---------------------------------------------------------------------------


def _pid_alive(pid):
    try:
        os.kill(pid, 0)
        return True
    except ProcessLookupError:
        return False


@pytest.mark.slow
def test_respawn_storm_degrades_to_cold_spawn(db):
    """Kills faster than the background top-up can replenish spares: heal
    must fall through to a cold spawn — never deadlock, never orphan."""
    from repro.core.shard_rpc import POOL

    q = _crimes_queries(db)[0]
    se = _sub(db, 2)
    killed = []
    try:
        se.run(q)
        for _ in range(4):
            for s in se.shards:
                killed.append(s.pid)
                s.inject("kill")
            # Drain every warm spare so the next heal cold-spawns.
            with POOL._lock:
                spares = list(POOL._spares)
                POOL._spares.clear()
            for sp in spares:
                POOL.discard(sp)
            for s in se.shards:
                s.heal()
            res, _ = se.run(q)
            assert res.canonical() == execute(q, se.db).canonical()
    finally:
        se.shutdown()
    assert all(not _pid_alive(p) for p in killed)
    # Everything the pool ever spawned is either tracked or dead — a storm
    # must not leak an untracked server.
    with POOL._lock:
        tracked = {sp.proc.pid for sp in POOL._all}
    assert all(p in tracked or not _pid_alive(p) for p in killed)


@pytest.mark.slow
def test_pool_top_up_races_shutdown_without_orphans(db):
    """shutdown_all racing the background fill thread: the closed window
    kills any spawn that lands mid-shutdown instead of leaking it."""
    from repro.core.shard_rpc import POOL

    for _ in range(3):
        # Kick a background top-up, then immediately drain-and-reopen.
        with POOL._lock:
            POOL._spares.clear()
        POOL._top_up_async()
        before = {sp.proc.pid for sp in list(POOL._all)}
        POOL.shutdown_all()
        for pid in before:
            assert not _pid_alive(pid)
    assert not POOL._closed  # reopened for the next tenant

    # close_pool() is terminal: a post-close spawn attempt raises instead of
    # leaking, and shutdown_all reopens for the rest of the suite.
    POOL.close_pool()
    from repro.core.shard_rpc import ShardUnavailableError

    with pytest.raises(ShardUnavailableError):
        POOL._spawn()
    POOL.shutdown_all()
    assert not POOL._closed

    # The pool still works end-to-end after the storm.
    se = _sub(db, 2)
    try:
        q = _crimes_queries(db)[0]
        res, _ = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
    finally:
        se.shutdown()
