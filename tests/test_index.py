"""SketchIndex storage policy: subsumption retrieval and recency pruning."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Database,
    Having,
    Predicate,
    Query,
    RangeSet,
    SketchIndex,
    execute,
    subsumes,
)
from repro.core.datasets import make_crimes
from repro.core.engine import PBDSEngine
from repro.core.sketch import ProvenanceSketch


def _q(gb=("a",), tau=10.0, op=">", where=None):
    return Query("t", gb, Aggregate("sum", "v"), having=Having(op, tau), where=where)


def _sk(size_rows=10):
    return ProvenanceSketch("t", RangeSet("a", np.array([1.0, 2.0])),
                           bits=np.array([True, False, True]),
                           size_rows=size_rows, total_rows=100)


def test_subsumes_threshold_domination():
    q1 = _q(tau=10.0)
    assert subsumes(q1, _q(tau=10.0))
    assert subsumes(q1, _q(tau=25.0))  # stricter HAVING => less provenance
    assert not subsumes(q1, _q(tau=5.0))  # q2 needs rows q1's sketch may skip
    # >= with the same threshold asks for at least as much provenance as >.
    assert subsumes(_q(tau=10.0, op=">="), _q(tau=10.0))


def test_subsumes_mixed_ops_at_equal_threshold():
    """Regression: a `>`-captured sketch must NOT serve `>=` at the same tau —
    groups with agg == tau are in q2's provenance but not in the sketch."""
    assert not subsumes(_q(tau=10.0, op=">"), _q(tau=10.0, op=">="))
    # The safe direction: `>=`-captured provenance is a superset of `>`'s.
    assert subsumes(_q(tau=10.0, op=">="), _q(tau=10.0, op=">"))
    assert subsumes(_q(tau=10.0, op=">="), _q(tau=10.0, op=">="))
    assert subsumes(_q(tau=10.0, op=">"), _q(tau=10.0, op=">"))
    # Strict domination restores subsumption for the mixed pair.
    assert subsumes(_q(tau=10.0, op=">"), _q(tau=10.0 + 1e-6, op=">="))
    # Same rule on the *outer* HAVING of nested templates.
    def _nested(op, tau):
        q = _q(tau=0.0)
        return dataclasses.replace(
            q, outer_groupby=("a",), outer_agg=Aggregate("sum", None),
            outer_having=Having(op, tau))
    assert not subsumes(_nested(">", 7.0), _nested(">=", 7.0))
    assert subsumes(_nested(">=", 7.0), _nested(">", 7.0))


def test_equal_threshold_mixed_op_lookup_misses_index():
    """End-to-end: the index refuses the unsafe `>` -> `>=` equal-tau hit."""
    idx = SketchIndex()
    idx.insert(_q(tau=10.0, op=">"), _sk())
    assert idx.lookup(_q(tau=10.0, op=">=")) is None
    assert idx.misses == 1
    assert idx.lookup(_q(tau=10.0, op=">")) is not None


def test_subsumes_requires_matching_structure():
    q1 = _q()
    assert not subsumes(q1, _q(gb=("b",)))
    assert not subsumes(q1, _q(where=Predicate("b", ">", 0.0)))
    assert not subsumes(_q(where=Predicate("b", ">", 0.0)),
                        _q(where=Predicate("b", ">", 1.0)))
    # Non-monotone HAVING ops only subsume on exact equality.
    assert subsumes(_q(op="<", tau=3.0), _q(op="<", tau=3.0))
    assert not subsumes(_q(op="<", tau=3.0), _q(op="<", tau=4.0))


def test_lookup_prefers_smallest_subsuming_sketch():
    idx = SketchIndex()
    idx.insert(_q(tau=10.0), _sk(size_rows=50))
    idx.insert(_q(tau=12.0), _sk(size_rows=20))
    e = idx.lookup_entry(_q(tau=30.0))
    assert e is not None and e.sketch.size_rows == 20
    assert idx.hits == 1 and idx.misses == 0


def test_prune_keeps_most_recently_hit_entries():
    idx = SketchIndex()
    queries = [_q(gb=gb, tau=5.0) for gb in (("a",), ("b",), ("c",), ("d",))]
    for q in queries:
        idx.insert(q, _sk())
    # Hit them in a known order: c, then a (a is most recent).
    assert idx.lookup(queries[2]) is not None
    assert idx.lookup(queries[0]) is not None
    evicted = idx.prune(2)
    assert evicted == 2 and len(idx) == 2
    kept = {e.query.groupby for e in idx.entries()}
    assert kept == {("a",), ("c",)}
    # The never-hit entries are gone; lookups for them now miss.
    assert idx.lookup(queries[1]) is None
    assert idx.lookup(queries[3]) is None


def test_prune_noop_under_capacity():
    idx = SketchIndex()
    idx.insert(_q(), _sk())
    assert idx.prune(5) == 0 and len(idx) == 1


def test_subsumed_query_reuses_wider_sketch_and_pruned_entry_recaptures():
    """End-to-end: a subsumed query reuses the stored (wider) sketch; after a
    prune evicts it, the next run re-captures cleanly and stays exact."""
    db = Database({"crimes": make_crimes(15_000, seed=21)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    q_wide = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.7))))
    q_narrow = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.9))))

    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.1, seed=0,
                     min_selectivity_gain=2.0)
    _, info = eng.run(q_wide)
    assert info.created
    res, info = eng.run(q_narrow)
    assert info.reused  # subsumed by the wider sketch, never captured
    assert res.canonical() == execute(q_narrow, db).canonical()
    assert len(eng.index) == 1

    assert eng.index.prune(0) == 1 and len(eng.index) == 0
    res2, info2 = eng.run(q_narrow)
    assert info2.created and not info2.reused
    assert res2.canonical() == execute(q_narrow, db).canonical()


def test_lookup_tie_break_is_insertion_order_independent():
    """Satellite regression: equal-size sketches must be served from the same
    entry whatever order they were inserted in.  Batched admission can insert
    a wave's sketches in a different order than a sequential replay, so
    insertion-position ties would diverge ``uses``/``last_hit`` bookkeeping
    (and hence prune decisions) between the two paths."""
    qa, qb = _q(tau=10.0), _q(tau=12.0)  # both subsume tau>=30 probes
    probe = _q(tau=30.0)
    idx1, idx2 = SketchIndex(), SketchIndex()
    idx1.insert(qa, _sk(size_rows=20))
    idx1.insert(qb, _sk(size_rows=20))
    idx2.insert(qb, _sk(size_rows=20))
    idx2.insert(qa, _sk(size_rows=20))
    e1, e2 = idx1.lookup_entry(probe), idx2.lookup_entry(probe)
    # The tighter-threshold capture (tau=12) wins the size tie in both.
    assert e1.query.having.value == e2.query.having.value == 12.0
    # Bookkeeping landed on the same logical entry in both indexes.
    assert e1.uses == e2.uses == 1


def test_lookup_tie_break_prefers_tighter_outer_threshold():
    """Ties on (size, inner threshold) break on the outer HAVING threshold."""
    import dataclasses as dc

    def _qq(t1, t2):
        q = _q(tau=t1)
        return dc.replace(q, outer_groupby=("a",),
                          outer_agg=Aggregate("sum", None),
                          outer_having=Having(">", t2))

    probe = _qq(30.0, 9.0)
    for order in ((5.0, 8.0), (8.0, 5.0)):
        idx = SketchIndex()
        for t2 in order:
            idx.insert(_qq(10.0, t2), _sk(size_rows=20))
        e = idx.lookup_entry(probe)
        assert e.query.outer_having.value == 8.0, order
