"""Composite (multi-attribute) sketches — the beyond-paper extension."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Catalog,
    Database,
    Having,
    Query,
    capture_sketch,
    equi_depth_ranges,
    execute,
    execute_with_sketch,
)
from repro.core.datasets import make_crimes
from repro.core.multisketch import (
    CompositeRanges,
    capture_composite,
    composite_ranges,
    execute_with_composite,
    select_composite_gb,
)


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(15_000, seed=31)})


@pytest.fixture(scope="module")
def q(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    tau = float(np.quantile(execute(base, db).values, 0.9))
    import dataclasses

    return dataclasses.replace(base, having=Having(">", tau))


def test_composite_sketch_safe(db, q):
    cr = composite_ranges(db["crimes"], ("district", "year"), 100)
    sk = capture_composite(q, db, cr)
    assert execute_with_composite(q, db, sk).canonical() == execute(q, db).canonical()
    assert 0.0 < sk.selectivity <= 1.0


def test_composite_never_larger_than_singles(db, q):
    """A GB-pair partition refines both singles => selectivity can only drop."""
    cr = composite_ranges(db["crimes"], ("district", "year"), 100)
    comp = capture_composite(q, db, cr)
    for attr in ("district", "year"):
        single = capture_sketch(q, db, equi_depth_ranges(db["crimes"], attr, 100))
        # composite uses ~sqrt budget per attr, so compare against same-ranges
        # singles built from the composite's own parts:
        part = [p for p in cr.parts if p.attr == attr][0]
        single_same = capture_sketch(q, db, part)
        assert comp.selectivity <= single_same.selectivity + 1e-9


def test_composite_bucketize_is_cross_product(db):
    cr = composite_ranges(db["crimes"], ("district", "year"), 64)
    b = np.asarray(cr.bucketize(db["crimes"]))
    assert b.min() >= 0 and b.max() < cr.n_ranges
    b0 = np.asarray(cr.parts[0].bucketize(db["crimes"]["district"]))
    b1 = np.asarray(cr.parts[1].bucketize(db["crimes"]["year"]))
    np.testing.assert_array_equal(b, b0 * cr.parts[1].n_ranges + b1)


def test_composite_parity_with_single_attribute_path(db):
    """On a 2-attribute workload every query answered through the composite
    path matches both the single-attribute sketch path and NO-PS execution."""
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    wl = [dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.5, 0.75, 0.9)]
    wl.append(Query("crimes", ("district", "year"), Aggregate("count", None),
                    having=Having(">", float(np.quantile(
                        execute(Query("crimes", ("district", "year"),
                                      Aggregate("count", None)), db).values, 0.8)))))
    cat = Catalog()
    cr = composite_ranges(db["crimes"], ("district", "year"), 100)
    for q in wl:
        want = execute(q, db).canonical()
        comp = capture_composite(q, db, cr, catalog=cat)
        assert execute_with_composite(q, db, comp, catalog=cat).canonical() == want
        for attr in ("district", "year"):
            single = capture_sketch(
                q, db, equi_depth_ranges(db["crimes"], attr, 100), catalog=cat)
            assert execute_with_sketch(q, db, single, catalog=cat).canonical() == want


def test_composite_path_goes_through_catalog(db, q):
    """Repeated composite capture/application over one partition reuses the
    catalog's bucketization, fragment sizes, and sketch instance."""
    cat = Catalog()
    cr = composite_ranges(db["crimes"], ("district", "year"), 64)
    sk = capture_composite(q, db, cr, catalog=cat)
    execute_with_composite(q, db, sk, catalog=cat)
    stats1 = dict(cat.stats)
    assert stats1.get("bucketize", 0) >= 1  # composite bucket built once
    sk2 = capture_composite(q, db, cr, catalog=cat)
    execute_with_composite(q, db, sk2, catalog=cat)
    execute_with_composite(q, db, sk, catalog=cat)
    stats2 = dict(cat.stats)
    # No new full bucketize / fragment-size passes; instances reused.
    assert stats2.get("bucketize", 0) == stats1.get("bucketize", 0)
    assert stats2.get("fragment_sizes", 0) == stats1.get("fragment_sizes", 0)
    assert stats2.get("bucketize_hit", 0) > stats1.get("bucketize_hit", 0)
    assert stats2.get("instance_hit", 0) > stats1.get("instance_hit", 0)
    np.testing.assert_array_equal(sk.bits, sk2.bits)


def test_composite_batched_estimation_matches_per_candidate_loop(db, q):
    """Composite candidates routed through estimate_size_batched's vmapped
    incidence pass agree with the single-candidate reference loop."""
    from repro.aqp.sampling import stratified_reservoir_sample
    from repro.aqp.size_estimation import (
        approximate_query_result,
        estimate_size,
        estimate_size_batched,
    )

    key = jax.random.PRNGKey(3)
    fact = db["crimes"]
    samples = stratified_reservoir_sample(key, fact, ("district", "year"), 0.1)
    aqr = approximate_query_result(key, q, db, samples)
    cands = {
        ("district",): composite_ranges(fact, ("district",), 64),
        ("year",): composite_ranges(fact, ("year",), 64),
        ("district", "year"): composite_ranges(fact, ("district", "year"), 64),
        # A non-GB attribute exercises the sample-row (slow) composite path.
        ("beat", "district"): composite_ranges(fact, ("beat", "district"), 64),
    }
    batched = estimate_size_batched(key, q, db, cands, samples, aqr=aqr)
    for attrs, cr in cands.items():
        ref = estimate_size(key, q, db, cr, samples, aqr=aqr)
        got = batched[attrs]
        np.testing.assert_array_equal(got.est_bits, ref.est_bits)
        assert got.est_rows == pytest.approx(ref.est_rows, rel=1e-5)
        assert got.expected_rows == pytest.approx(ref.expected_rows, rel=1e-4)
        assert got.lo_rows == pytest.approx(ref.lo_rows, rel=1e-4)
        assert got.hi_rows == pytest.approx(ref.hi_rows, rel=1e-4)


def test_cb_opt_gb2_sizes_match_exact_membership(db, q):
    """The batched GB fast path reproduces the old exact full-scan loop:
    size == #rows whose composite fragment is hit by a satisfied group."""
    from repro.aqp.sampling import stratified_reservoir_sample
    from repro.aqp.size_estimation import approximate_query_result

    key = jax.random.PRNGKey(0)
    fact = db["crimes"]
    gb = ("district", "year")
    # Mirror select_composite_gb's internal key discipline: one key per
    # random pass (sampling vs. AQR), split from the caller's key.
    k_s, k_e = jax.random.split(key)
    samples = stratified_reservoir_sample(k_s, fact, gb, 0.1)
    _, satisfied = approximate_query_result(k_e, q, db, samples)
    best, cr_best, sizes = select_composite_gb(key, q, db, 100, theta=0.1)
    total = fact.num_rows
    for attrs in [("district",), ("year",), ("district", "year")]:
        cr = composite_ranges(fact, attrs, 100)
        frag = None
        for r in cr.parts:
            b = np.asarray(r.bucketize(np.asarray(samples.group_values[r.attr])))
            frag = b if frag is None else frag * r.n_ranges + b
        sat_frags = np.unique(frag[np.nonzero(satisfied)[0]])
        bucket = np.asarray(cr.bucketize(fact))
        exact = float(np.isin(bucket, sat_frags).sum()) / total
        assert sizes[attrs] == pytest.approx(exact, rel=1e-6)


def test_cb_opt_gb2_selects_reasonably(db, q):
    key = jax.random.PRNGKey(0)
    best, cr, sizes = select_composite_gb(key, q, db, 100, theta=0.1)
    # exact capture of the chosen candidate should be close to its estimate
    sk = capture_composite(q, db, cr)
    assert abs(sk.selectivity - sizes[best]) < 0.15
    # the winner must be no worse than the worst single by a margin
    singles = {k: v for k, v in sizes.items() if len(k) == 1}
    assert sizes[best] <= min(singles.values()) + 1e-9
