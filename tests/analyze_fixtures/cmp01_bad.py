"""CMP01 positive fixture: the PR 3 subsumes bug (threshold comparison
blind to operator strictness) and order-dependent selections."""


def subsumes_reconstruction(a, b):
    # PR 3: `agg > tau` vs `agg >= tau` treated as interchangeable at equal
    # thresholds — the boundary groups' provenance was never captured.
    if a.table != b.table:
        return False
    return a.having.value <= b.having.value


def pick_entry(entries, sizes):
    best = min(entries, key=sizes.get)  # ties -> insertion order
    ranking = sorted(entries, key=sizes.get)
    return best, ranking
