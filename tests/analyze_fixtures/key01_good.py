"""KEY01 negative fixture: the fixed shapes — split/fold_in per pass,
per-iteration derivation, guard-clause early returns, non-PRNG 'key'
parameters."""
import jax


def select_attribute_fixed(key, q, db, samples):
    k_s, k_e = jax.random.split(key)
    aqr = approximate_query_result(k_s, q, db, samples)
    estimates = estimate_size_batched(jax.random.fold_in(k_e, 1), q, db,
                                      samples, aqr=aqr)
    return aqr, estimates


def loop_fixed(key, items):
    out = []
    for i, item in enumerate(items):
        k_i = jax.random.fold_in(key, i)
        out.append(jax.random.uniform(k_i, (4,)))
    return out


def split_iteration(key, items):
    out = []
    for k in jax.random.split(key, len(items)):
        out.append(jax.random.uniform(k, (4,)))
    return out


def guard_clause(key, stratified, table):
    if not stratified:
        return uniform_sample(key, table)  # early return: exclusive branch
    return reservoir_sample(key, table)


def registration_id(key: int, entries):
    # 'key' here is an integer registration id, not a PRNG key.
    first = entries.get(key)
    second = entries.pop(key)
    return first, second
