"""CACHE01 negative fixture: complete lineage keys, ops-only HAVING."""


def selection_cache_key(strategy, q, table, theta, n_ranges):
    ops = (q.having.op if q.having else None,
           q.outer_having.op if q.outer_having else None)
    return (strategy, table.uid, table.version, theta, n_ranges, ops)


def not_a_key_builder(q):
    # Reading having.value outside a key builder is fine (e.g. executors).
    return q.having.value
