"""CMP01 negative fixture: strictness-aware subsumption (the PR 3 fix
shape) and tuple tie-breaks."""


def subsumes_fixed(a, b):
    if a.table != b.table:
        return False
    if a.having.op == b.having.op:
        return a.having.value <= b.having.value
    # Mixed strictness: '>' at tau covers '>=' at tau only when strictly
    # dominated (boundary groups differ at equality).
    if a.having.op == ">=" and b.having.op == ">":
        return a.having.value <= b.having.value
    return a.having.value < b.having.value


def pick_entry(entries, sizes):
    best = min(entries, key=lambda e: (sizes[e], e))
    ranking = sorted(entries, key=lambda e: (sizes[e], e))
    return best, ranking
