"""PAD01 positive fixture: hot-path constructors sized by raw data —
every distinct size compiles a fresh XLA program (the retrace-bomb class
the pow2 helpers exist to prevent)."""
import jax.numpy as jnp

from repro.runtime.guards import hot_path


@hot_path
def serve(rows, n_groups):
    acc = jnp.zeros(len(rows))  # raw row count: one size class per len
    mask = jnp.ones(n_groups + 1)  # raw parameter arithmetic
    return acc, mask
