"""CACHE01 positive fixture: incomplete lineage keys and threshold leaks."""
import dataclasses


def result_cache_key(q, table):
    # Misses version: serves stale state after append/delete mutations.
    return (q.table, table.uid, q.groupby)


def aqr_cache_key(q, table, theta):
    # Leaks the HAVING threshold: same-template queries stop sharing the
    # pass the cache exists to share.  (Also misses uid/version.)
    return (q.table, q.having.value, theta)


def probe_cache_key(q, table):
    # astuple embeds the threshold value wholesale.
    return (table.uid, table.version, dataclasses.astuple(q.having))
