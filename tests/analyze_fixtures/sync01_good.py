"""SYNC01 negative fixture: shape metadata, host copies, and syncs in
cold functions are all fine."""
import jax.numpy as jnp
import numpy as np

from repro.runtime.guards import hot_path


@hot_path
def serve(table, values):
    n = int(values.shape[0])  # static metadata, not a sync
    dev = jnp.cumsum(table)
    host = np.asarray(dev)  # analyze: waive[SYNC01]: deliberate merge point for the fixture
    scalar = float(host[0])  # host copy: free
    return n, scalar


def cold_merge(table):
    # Not hot: materializing results here is nobody's business.
    return np.asarray(jnp.sum(table))
