"""KEY01 positive fixture: minimized reconstruction of the PR 7
``select_attribute`` bug — one key drawn on by the AQR pass and the
estimate pass, correlating their randomness."""
import jax


def select_attribute_reconstruction(key, q, db, samples):
    # Both passes consume the SAME key: correlated draws ranked candidates
    # off correlated noise until the fold_in fix.
    aqr = approximate_query_result(key, q, db, samples)
    estimates = estimate_size_batched(key, q, db, samples, aqr=aqr)
    return aqr, estimates


def loop_reuse(key, items):
    out = []
    for item in items:
        out.append(jax.random.uniform(key, (4,)))  # same draw every iteration
    return out


def comprehension_reuse(key, items):
    return [jax.random.normal(key, (2,)) for _ in items]
