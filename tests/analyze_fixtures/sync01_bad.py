"""SYNC01 positive fixture: host-device syncs inside hot-path functions —
.item(), float()/int() and np.asarray() on device values."""
import jax.numpy as jnp
import numpy as np

from repro.runtime.guards import hot_path


@hot_path
def serve(table, threshold):
    total = jnp.sum(table)
    if total.item() > threshold:  # sync in the hot path
        return None
    scale = float(jnp.max(table))  # sync
    host = np.asarray(jnp.cumsum(table))  # transfer
    return scale, host


def helper_called_from_hot(vals):
    # In the closure via ``serve_helper`` below even without the decorator.
    s = jnp.dot(vals, vals)
    return int(s)


@hot_path
def serve_helper(vals):
    return helper_called_from_hot(vals)
