"""DTYPE01 negative fixture: explicit 32-bit dtypes, host-side 64-bit
numpy (fine — numpy is not under the x64 flag)."""
import jax.numpy as jnp
import numpy as np


def weights_like(counts):
    return jnp.ones_like(np.bincount(counts), dtype=jnp.float32)


def explicit_narrow(n, arr):
    a = jnp.zeros(n, dtype=jnp.int32)
    b = jnp.asarray(arr, dtype=jnp.float32)
    host = np.zeros(n, dtype=np.int64)  # host numpy: 64-bit is fine
    return a, b, host
