"""PAD01 negative fixture: literal shapes, pow2-routed sizes, inherited
shapes, host-side numpy, and non-hot functions are all fine."""
import jax.numpy as jnp
import numpy as np

from repro.runtime.guards import hot_path


def _next_pow2(n):
    return 1 << max(0, (n - 1)).bit_length()


@hot_path
def serve(rows, n_groups, arr, table):
    literal = jnp.zeros(64)
    padded = jnp.zeros(_next_pow2(len(rows)))
    n_pad = _next_pow2(n_groups)
    via_local = jnp.ones(n_pad)
    inherited = jnp.zeros(arr.shape[0])
    row_count = jnp.ones(table.num_rows)  # table row count: existing class
    host = np.zeros(len(rows))  # host numpy compiles nothing
    return literal, padded, via_local, inherited, row_count, host


def cold(rows):
    # Not in the hot closure: data-dependent sizes are fine off-path.
    return jnp.zeros(len(rows))
