"""DTYPE01 positive fixture: 64-bit dtypes under x64-disabled jax,
including the PR 1 ones_like-on-host-array class."""
import jax.numpy as jnp
import numpy as np


def weights_like(counts):
    # The PR 1 bug: host numpy defaults to int64 on linux, ones_like copies
    # it, x64-disabled jax silently truncates to int32.
    return jnp.ones_like(np.bincount(counts))


def explicit_wide(n):
    a = jnp.zeros(n, dtype=np.int64)
    b = jnp.full(n, 1.0, dtype="float64")
    c = jnp.asarray(np.arange(n)).astype(jnp.int64)
    return a, b, c
