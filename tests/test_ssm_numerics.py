"""Chunked-vs-exact numerics for the SSM/recurrent training forms, and
decode-vs-train consistency — the invariants behind the memory fixes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def cfg():
    return get_config("xlstm-350m", smoke=True)


def test_mamba_chunked_equals_unchunked(cfg):
    p = init_params(KEY, ssm.mamba_params(cfg), jnp.float32)
    x = jax.random.normal(KEY, (2, 40, cfg.d_model))
    y_full = ssm.mamba_train(p, cfg, x, chunk=40)
    y_chunk = ssm.mamba_train(p, cfg, x, chunk=8)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_chunk), atol=1e-5)


def test_mamba_decode_matches_train(cfg):
    p = init_params(KEY, ssm.mamba_params(cfg), jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y_train = ssm.mamba_train(p, cfg, x, chunk=16)
    cache = ssm.init_mamba_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(x.shape[1]):
        o, cache = ssm.mamba_decode(p, cfg, x[:, t : t + 1], cache)
        outs.append(o)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train), atol=2e-4, rtol=1e-3)


def test_mlstm_chunked_equals_quadratic(cfg):
    p = init_params(KEY, ssm.mlstm_params(cfg), jnp.float32)
    x = jax.random.normal(KEY, (2, 48, cfg.d_model))
    y_one = ssm.mlstm_train(p, cfg, x, chunk=48)  # single chunk == quadratic
    y_chunked = ssm.mlstm_train(p, cfg, x, chunk=8)
    np.testing.assert_allclose(np.asarray(y_one), np.asarray(y_chunked), atol=1e-4)


def test_mlstm_decode_matches_train(cfg):
    p = init_params(KEY, ssm.mlstm_params(cfg), jnp.float32)
    x = jax.random.normal(KEY, (2, 24, cfg.d_model))
    y_train = ssm.mlstm_train(p, cfg, x, chunk=8)
    cache = ssm.init_mlstm_cache(cfg, 2)
    outs = []
    for t in range(x.shape[1]):
        o, cache = ssm.mlstm_decode(p, cfg, x[:, t : t + 1], cache)
        outs.append(o)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train), atol=2e-4, rtol=1e-3)


def test_slstm_custom_vjp_grads_match_autodiff(cfg):
    """The collective-saving custom VJP must be *exact* (EXPERIMENTS §Perf)."""
    p = init_params(KEY, ssm.slstm_params(cfg), jnp.float32)
    x = jax.random.normal(KEY, (2, 20, cfg.d_model))

    def ref_train(p, x):
        b, s, d = x.shape
        hh, uh = cfg.n_heads, d // cfg.n_heads
        hin = ssm.rmsnorm(p["ln"], x)
        xproj = jnp.einsum("bsd,dg->bsg", hin, p["wx"])

        def step(state, xt):
            h, c, n, m = ssm._slstm_step(p, cfg, xt, state)
            return (h, c, n, m), h

        z = jnp.zeros((b, hh, uh), jnp.float32)
        init = (z, z, z, jnp.full((b, hh, uh), -1e30, jnp.float32))
        _, hs = jax.lax.scan(step, init, jnp.moveaxis(xproj, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
        return x + jnp.einsum("bsd,dg->bsg", hs, p["out"])

    y1 = ssm.slstm_train(p, cfg, x)
    y2 = ref_train(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)

    g1 = jax.grad(lambda p: (ssm.slstm_train(p, cfg, x) ** 2).sum())(p)
    g2 = jax.grad(lambda p: (ref_train(p, x) ** 2).sum())(p)
    f1 = sorted(jax.tree_util.tree_leaves_with_path(g1), key=lambda kv: str(kv[0]))
    f2 = sorted(jax.tree_util.tree_leaves_with_path(g2), key=lambda kv: str(kv[0]))
    for (k1, a), (k2, b) in zip(f1, f2):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-4, rtol=1e-3, err_msg=str(k1)
        )


def test_slstm_decode_matches_train(cfg):
    p = init_params(KEY, ssm.slstm_params(cfg), jnp.float32)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model))
    y_train = ssm.slstm_train(p, cfg, x)
    cache = ssm.init_slstm_cache(cfg, 2)
    outs = []
    for t in range(x.shape[1]):
        o, cache = ssm.slstm_decode(p, cfg, x[:, t : t + 1], cache)
        outs.append(o)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train), atol=2e-4, rtol=1e-3)
