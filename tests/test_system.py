"""End-to-end behaviour tests for the PBDS engine (Fig. 3 workflow).

The central invariant: for EVERY strategy, the engine returns exactly the
same query results as NO-PS execution — sketches only change cost, never
answers.  Plus: index reuse kicks in across a workload, cost-based selection
beats random on selectivity, and the curation pipeline's engine run matches.
"""
import jax
import numpy as np
import pytest

from repro.core import Database, execute
from repro.core.datasets import make_crimes, make_tpch
from repro.core.engine import PBDSEngine
from repro.core.strategies import SelectionConfig
from repro.core.workload import CRIMES_SPEC, TPCH_JOIN_SPEC, generate_workload

STRATEGIES = ("NO-PS", "RAND-ALL", "RAND-GB", "RAND-PK", "RAND-AGG",
              "CB-OPT", "CB-OPT-REL", "CB-OPT-GB", "OPT")


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(15_000, seed=21)})


@pytest.fixture(scope="module")
def workload(db):
    return generate_workload(CRIMES_SPEC, db, 6, seed=21)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_engine_results_exact_for_every_strategy(db, workload, strategy):
    eng = PBDSEngine(db, strategy=strategy, n_ranges=50, theta=0.1, seed=0)
    for q in workload:
        res, info = eng.run(q)
        assert res.canonical() == execute(q, db).canonical(), (strategy, q)


def test_engine_reuses_sketches(db, workload):
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.1, seed=0)
    created = []
    for q in workload:
        _, info = eng.run(q)
        created.append(info.created)
    assert eng.index.hits == 0  # all distinct queries -> all misses
    assert any(created)
    for q, was_created in zip(workload, created):  # replay
        _, info = eng.run(q)
        # every query whose sketch was created must now hit the index
        assert info.reused == was_created or info.reused, q
    assert eng.index.hits >= sum(created)


def test_cost_based_beats_random_on_average(db):
    queries = generate_workload(CRIMES_SPEC, db, 8, seed=33)
    sel = {}
    for strat in ("CB-OPT-GB", "RAND-PK"):
        eng = PBDSEngine(db, strategy=strat, n_ranges=50, theta=0.1, seed=1)
        sels = []
        for q in queries:
            _, info = eng.run(q)
            if info.selectivity is not None:
                sels.append(info.selectivity)
        sel[strat] = np.mean(sels) if sels else 1.0
    assert sel["CB-OPT-GB"] <= sel["RAND-PK"] + 0.05


def test_join_workload_end_to_end():
    db = make_tpch(12_000, seed=22)
    queries = generate_workload(TPCH_JOIN_SPEC, db, 4, seed=22)
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.1, seed=0)
    for q in queries:
        res, _ = eng.run(q)
        assert res.canonical() == execute(q, db).canonical()


def test_engine_skips_useless_sketches(db):
    """A sketch estimated to cover ~the whole table is not created."""
    from repro.core import Aggregate, Having, Query

    q = Query("crimes", ("district",), Aggregate("count", None), having=Having(">", 0.0))
    # Paper-faithful selection: the default reuse-aware config deliberately
    # admits broad sketches when the workload window shows them recurring.
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1,
                     min_selectivity_gain=0.9, seed=0,
                     selection=SelectionConfig.paper_faithful())
    res, info = eng.run(q)
    assert not info.created  # every group passes -> selectivity 1.0 -> skip
    assert res.canonical() == execute(q, db).canonical()
