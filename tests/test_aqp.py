"""AQP layer: stratified sampling, Haas estimators, bootstrap, wander join,
size estimation accuracy."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.aqp.bootstrap import bootstrap_group_means
from repro.aqp.estimators import group_estimates, norm_cdf, pass_probability
from repro.aqp.sampling import SampleCache, stratified_reservoir_sample, uniform_reservoir_sample
from repro.aqp.size_estimation import EstimationConfig, approximate_query_result, estimate_size
from repro.aqp.wander_join import JoinIndex, walk
from repro.core import Aggregate, Database, Having, JoinSpec, Query, capture_sketch, equi_depth_ranges
from repro.core.datasets import make_crimes, make_tpch

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(30_000, seed=5)})


def test_stratified_sample_represents_every_group(db):
    t = db["crimes"]
    s = stratified_reservoir_sample(KEY, t, ("district", "year"), theta=0.05)
    assert s.stratified
    assert (s.sample_sizes >= 1).all()  # every group represented
    assert (s.sample_sizes <= s.group_sizes).all()
    # roughly theta of the table overall (min-1-per-group inflates slightly)
    assert 0.03 < s.num_samples / t.num_rows < 0.15
    # sampled rows really belong to their groups
    d = np.asarray(t["district"])[s.indices]
    assert (d == s.group_values["district"][s.sample_gid]).all()


def test_uniform_fallback_when_too_many_groups(db):
    t = db["crimes"]
    # beat x year x month has ~more groups than 0.1% sample budget
    s = stratified_reservoir_sample(KEY, t, ("beat", "year", "month"), theta=0.001)
    assert not s.stratified


def test_sum_estimator_unbiased(db):
    """Mean of per-group SUM estimates over many sample draws ~ true sums."""
    t = db["crimes"]
    from repro.core.table import encode_groups

    gid, n_groups, _ = encode_groups(t, ("district",))
    vals = np.asarray(t["records"], dtype=np.float64)
    true = np.bincount(gid, weights=vals, minlength=n_groups)
    ests = []
    for i in range(30):
        s = stratified_reservoir_sample(jax.random.PRNGKey(i), t, ("district",), 0.05)
        est = group_estimates(
            "sum", t["records"][np.sort(s.indices)] if False else t.gather(s.indices)["records"],
            np.ones(s.num_samples, bool), s.sample_gid, s.n_groups, s.group_sizes,
        )
        ests.append(est.estimate)
    mean_est = np.mean(ests, axis=0)
    rel = np.abs(mean_est - true) / np.maximum(true, 1)
    # records is zipf-skewed: SUM estimates are high-variance but unbiased;
    # the 30-draw mean should land within ~15% for most groups.
    assert np.median(rel) < 0.15


def test_pass_probability_monotone():
    est = group_estimates(
        "sum",
        jax.numpy.asarray(np.array([10.0, 20.0, 30.0, 40.0], np.float32)),
        jax.numpy.asarray(np.ones(4, bool)),
        np.array([0, 0, 1, 1], np.int32),
        2,
        np.array([10, 10]),
    )
    p_low = pass_probability(est, ">", 50.0)
    p_high = pass_probability(est, ">", 500.0)
    assert (p_low >= p_high).all()
    assert norm_cdf(np.array([0.0]))[0] == pytest.approx(0.5, abs=1e-6)


def test_bootstrap_shrinks_with_group_size():
    rng = np.random.default_rng(0)
    gid = np.repeat([0, 1], [400, 25]).astype(np.int32)
    vals = rng.normal(10, 3, 425).astype(np.float32)
    bs = bootstrap_group_means(KEY, vals, gid, 2, n_resamples=50)
    assert bs.std[0] < bs.std[1]  # bigger stratum -> tighter statistic
    assert bs.mean == pytest.approx(
        [vals[gid == 0].mean(), vals[gid == 1].mean()], abs=1.0
    )


def test_wander_join_walk():
    tpch = make_tpch(5_000, seed=6)
    idx = JoinIndex.build(tpch["orders"], "o_orderkey")
    fact_keys = np.asarray(tpch["lineitem"]["l_orderkey"])[:500]
    rows, fanout = walk(KEY, idx, fact_keys)
    ok = np.asarray(tpch["orders"]["o_orderkey"])
    assert (fanout >= 1).all()  # all orderkeys exist
    assert (ok[rows] == fact_keys).all()  # picked partner matches the key


def test_size_estimation_accuracy(db):
    q = Query("crimes", ("district", "year"), Aggregate("sum", "records"),
              having=Having(">", 100.0))
    s = stratified_reservoir_sample(KEY, db["crimes"], q.groupby, 0.05)
    for attr in ("district", "year"):
        ranges = equi_depth_ranges(db["crimes"], attr, 20)
        est = estimate_size(KEY, q, db, ranges, s)
        actual = capture_sketch(q, db, ranges).size_rows
        rse = abs(est.est_rows - actual) / max(actual, 1)
        assert rse < 0.2, (attr, est.est_rows, actual)
        assert est.lo_rows <= est.hi_rows
        assert 0 <= est.est_selectivity <= 1


def test_join_size_estimation():
    tpch = make_tpch(20_000, seed=7)
    q = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
              join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
              having=Having(">", 50.0))
    s = stratified_reservoir_sample(KEY, tpch["lineitem"], ("l_suppkey",), 0.1)
    ranges = equi_depth_ranges(tpch["lineitem"], "l_suppkey", 20)
    est = estimate_size(KEY, q, tpch, ranges, s)
    actual = capture_sketch(q, tpch, ranges).size_rows
    assert abs(est.est_rows - actual) / max(actual, 1) < 0.35


def test_sample_cache_reuse(db):
    cache = SampleCache()
    s1 = cache.get_or_create(KEY, db["crimes"], ("district",), 0.05)
    s2 = cache.get_or_create(jax.random.PRNGKey(9), db["crimes"], ("district",), 0.05)
    assert s1 is s2 and cache.hits == 1 and cache.misses == 1
    assert s1.reusable_for("crimes", ("district",))
    assert not s1.reusable_for("crimes", ("year",))
