"""AQP layer: stratified sampling, Haas estimators, bootstrap, wander join,
size estimation accuracy."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.aqp.bootstrap import bootstrap_group_means
from repro.aqp.estimators import group_estimates, norm_cdf, pass_probability
from repro.aqp.sampling import SampleCache, stratified_reservoir_sample, uniform_reservoir_sample
from repro.aqp.size_estimation import EstimationConfig, approximate_query_result, estimate_size
from repro.aqp.wander_join import JoinIndex, walk
from repro.core import Aggregate, Database, Having, JoinSpec, Query, capture_sketch, equi_depth_ranges
from repro.core.datasets import make_crimes, make_tpch

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(30_000, seed=5)})


def test_stratified_sample_represents_every_group(db):
    t = db["crimes"]
    s = stratified_reservoir_sample(KEY, t, ("district", "year"), theta=0.05)
    assert s.stratified
    assert (s.sample_sizes >= 1).all()  # every group represented
    assert (s.sample_sizes <= s.group_sizes).all()
    # roughly theta of the table overall (min-1-per-group inflates slightly)
    assert 0.03 < s.num_samples / t.num_rows < 0.15
    # sampled rows really belong to their groups
    d = np.asarray(t["district"])[s.indices]
    assert (d == s.group_values["district"][s.sample_gid]).all()


def test_uniform_fallback_when_too_many_groups(db):
    t = db["crimes"]
    # beat x year x month has ~more groups than 0.1% sample budget
    s = stratified_reservoir_sample(KEY, t, ("beat", "year", "month"), theta=0.001)
    assert not s.stratified


def test_sum_estimator_unbiased(db):
    """Mean of per-group SUM estimates over many sample draws ~ true sums."""
    t = db["crimes"]
    from repro.core.table import encode_groups

    gid, n_groups, _ = encode_groups(t, ("district",))
    vals = np.asarray(t["records"], dtype=np.float64)
    true = np.bincount(gid, weights=vals, minlength=n_groups)
    ests = []
    for i in range(30):
        s = stratified_reservoir_sample(jax.random.PRNGKey(i), t, ("district",), 0.05)
        est = group_estimates(
            "sum", t["records"][np.sort(s.indices)] if False else t.gather(s.indices)["records"],
            np.ones(s.num_samples, bool), s.sample_gid, s.n_groups, s.group_sizes,
        )
        ests.append(est.estimate)
    mean_est = np.mean(ests, axis=0)
    rel = np.abs(mean_est - true) / np.maximum(true, 1)
    # records is zipf-skewed: SUM estimates are high-variance but unbiased;
    # the 30-draw mean should land within ~15% for most groups.
    assert np.median(rel) < 0.15


def test_pass_probability_monotone():
    est = group_estimates(
        "sum",
        jax.numpy.asarray(np.array([10.0, 20.0, 30.0, 40.0], np.float32)),
        jax.numpy.asarray(np.ones(4, bool)),
        np.array([0, 0, 1, 1], np.int32),
        2,
        np.array([10, 10]),
    )
    p_low = pass_probability(est, ">", 50.0)
    p_high = pass_probability(est, ">", 500.0)
    assert (p_low >= p_high).all()
    assert norm_cdf(np.array([0.0]))[0] == pytest.approx(0.5, abs=1e-6)


def test_bootstrap_shrinks_with_group_size():
    rng = np.random.default_rng(0)
    gid = np.repeat([0, 1], [400, 25]).astype(np.int32)
    vals = rng.normal(10, 3, 425).astype(np.float32)
    bs = bootstrap_group_means(KEY, vals, gid, 2, n_resamples=50)
    assert bs.std[0] < bs.std[1]  # bigger stratum -> tighter statistic
    assert bs.mean == pytest.approx(
        [vals[gid == 0].mean(), vals[gid == 1].mean()], abs=1.0
    )


def test_wander_join_walk():
    tpch = make_tpch(5_000, seed=6)
    idx = JoinIndex.build(tpch["orders"], "o_orderkey")
    fact_keys = np.asarray(tpch["lineitem"]["l_orderkey"])[:500]
    rows, fanout = walk(KEY, idx, fact_keys)
    ok = np.asarray(tpch["orders"]["o_orderkey"])
    assert (fanout >= 1).all()  # all orderkeys exist
    assert (ok[rows] == fact_keys).all()  # picked partner matches the key


def test_size_estimation_accuracy(db):
    q = Query("crimes", ("district", "year"), Aggregate("sum", "records"),
              having=Having(">", 100.0))
    s = stratified_reservoir_sample(KEY, db["crimes"], q.groupby, 0.05)
    for attr in ("district", "year"):
        ranges = equi_depth_ranges(db["crimes"], attr, 20)
        est = estimate_size(KEY, q, db, ranges, s)
        actual = capture_sketch(q, db, ranges).size_rows
        rse = abs(est.est_rows - actual) / max(actual, 1)
        assert rse < 0.2, (attr, est.est_rows, actual)
        assert est.lo_rows <= est.hi_rows
        assert 0 <= est.est_selectivity <= 1


def test_join_size_estimation():
    tpch = make_tpch(20_000, seed=7)
    q = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
              join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
              having=Having(">", 50.0))
    s = stratified_reservoir_sample(KEY, tpch["lineitem"], ("l_suppkey",), 0.1)
    ranges = equi_depth_ranges(tpch["lineitem"], "l_suppkey", 20)
    est = estimate_size(KEY, q, tpch, ranges, s)
    actual = capture_sketch(q, tpch, ranges).size_rows
    assert abs(est.est_rows - actual) / max(actual, 1) < 0.35


def test_sample_cache_reuse(db):
    cache = SampleCache()
    s1 = cache.get_or_create(KEY, db["crimes"], ("district",), 0.05)
    s2 = cache.get_or_create(jax.random.PRNGKey(9), db["crimes"], ("district",), 0.05)
    assert s1 is s2 and cache.hits == 1 and cache.misses == 1
    assert s1.reusable_for("crimes", ("district",))
    assert not s1.reusable_for("crimes", ("year",))


def test_aqr_cache_eviction_overflow_and_recompute():
    """Satellite coverage: the max_entries FIFO overflow branch.  Evicted
    passes recompute bit-identically and the hit/miss/eviction counters stay
    consistent with the number of calls."""
    from repro.aqp.sampling import AQRCache

    db = Database({"crimes": make_crimes(8_000, seed=3)})
    fact = db["crimes"]
    cache = AQRCache(max_entries=2)
    scache = SampleCache()
    cfg = EstimationConfig()
    key = jax.random.PRNGKey(0)
    qs = [Query("crimes", (gb,), Aggregate("count", None), having=Having(">", 5.0))
          for gb in ("district", "month", "year")]
    outs = []
    for q in qs:
        samples = scache.get_or_create(key, fact, q.groupby_on_fact(db), 0.2)
        outs.append(cache.get_or_compute(key, q, db, samples, 0.2, cfg))
    assert cache.misses == 3 and cache.hits == 0
    assert cache.evictions == 1 and len(cache._cache) == 2
    # qs[0] was the FIFO victim: recomputing reproduces the identical pass.
    samples0 = scache.get_or_create(key, fact, qs[0].groupby_on_fact(db), 0.2)
    est2, sampled2 = cache.get_or_compute(key, qs[0], db, samples0, 0.2, cfg)
    est1, sampled1 = outs[0]
    np.testing.assert_array_equal(est1.estimate, est2.estimate)
    np.testing.assert_array_equal(est1.sigma, est2.sigma)
    np.testing.assert_array_equal(sampled1, sampled2)
    assert cache.misses == 4 and cache.evictions == 2
    # A hit neither evicts nor recomputes.
    before = dict(cache._cache)
    cache.get_or_compute(key, qs[0], db, samples0, 0.2, cfg)
    assert cache.hits == 1 and cache.evictions == 2
    assert list(cache._cache) == list(before)
    assert cache.hits + cache.misses == 5


def test_aqr_cache_version_churn_invalidation():
    """A mutated table never serves a stale pass (key mismatch by version),
    and ``invalidate`` drops every entry of the table."""
    from repro.aqp.sampling import AQRCache

    db = Database({"crimes": make_crimes(8_000, seed=3)})
    fact = db["crimes"]
    cache = AQRCache(max_entries=8)
    scache = SampleCache()
    cfg = EstimationConfig()
    key = jax.random.PRNGKey(0)
    q = Query("crimes", ("district",), Aggregate("count", None),
              having=Having(">", 5.0))
    samples = scache.get_or_create(key, fact, q.groupby_on_fact(db), 0.2)
    cache.get_or_compute(key, q, db, samples, 0.2, cfg)
    fact2 = fact.append({a: np.asarray(fact[a])[:16] for a in fact.schema})
    db2 = db.with_table(fact2)
    samples2 = scache.get_or_create(key, fact2, q.groupby_on_fact(db2), 0.2)
    cache.get_or_compute(key, q, db2, samples2, 0.2, cfg)
    assert cache.misses == 2 and cache.hits == 0  # no stale serve
    assert len(cache._cache) == 2  # both versions resident until invalidated
    cache.invalidate("crimes")
    assert len(cache._cache) == 0
    cache.get_or_compute(key, q, db2, samples2, 0.2, cfg)
    assert cache.misses == 3  # invalidated entries recompute
