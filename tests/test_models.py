"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, a decode step, and decode-vs-prefill
consistency for the attention path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.optim.adamw import OptConfig
from repro.train.step import TrainSpec, init_train_state, make_train_step, microbatch_reshape

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b, s):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(KEY, (b, cfg.n_frontend_tokens, cfg.frontend_dim))
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(KEY, (b, s, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    spec = TrainSpec(microbatch=2, opt=OptConfig(total_steps=10))
    state = init_train_state(KEY, cfg, spec)
    step = jax.jit(make_train_step(cfg, spec))
    batch = microbatch_reshape(_batch(cfg, 4, 32), 2)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert float(metrics["loss"]) > 0
    # params updated and still finite
    leaf = jax.tree_util.tree_leaves(state["params"])[0]
    assert np.isfinite(np.asarray(leaf, np.float32)).all()
    assert int(state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.concrete_params(KEY, cfg)
    b, t = 2, 16
    cache = lm.init_cache(cfg, b, t, cross_len=t if cfg.is_encdec else 0)
    logits, cache2 = jax.jit(
        lambda p, c, tok, pos: lm.decode_step(p, cfg, c, tok, pos)
    )(params, cache, jnp.zeros((b,), jnp.int32), jnp.array(0, jnp.int32))
    assert logits.shape == (b, cfg.vocab_p)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(cache2)


def test_decode_matches_full_forward_attention():
    """Teacher-forced decode logits == full-sequence forward logits (dense)."""
    cfg = get_config("stablelm-1.6b", smoke=True)
    params = lm.concrete_params(KEY, cfg)
    b, s = 1, 12
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full_logits = lm.prefill(params, cfg, {"tokens": tokens})  # last position
    cache = lm.init_cache(cfg, b, s)
    logits = None
    for i in range(s):
        logits, cache = lm.decode_step(params, cfg, cache, tokens[:, i], jnp.array(i, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits), atol=0.15, rtol=0.05
    )


def test_sliding_window_cache_ring_buffer():
    """gemma3-style local attention: ring buffer gives same logits as full
    cache once positions exceed the window."""
    cfg = get_config("gemma3-27b", smoke=True)
    params = lm.concrete_params(KEY, cfg)
    b, s = 1, 24  # window is 8 in the smoke config
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    cache = lm.init_cache(cfg, b, s)
    for i in range(s):
        logits, cache = lm.decode_step(params, cfg, cache, tokens[:, i], jnp.array(i, jnp.int32))
    full = lm.prefill(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full), atol=0.2, rtol=0.08)


def test_param_count_sane():
    for arch, lo, hi in [
        ("stablelm-1.6b", 1.2e9, 2.2e9),
        ("internlm2-20b", 15e9, 25e9),
        ("qwen1.5-32b", 25e9, 40e9),
        ("gemma3-27b", 20e9, 35e9),
        ("jamba-1.5-large-398b", 300e9, 480e9),
        ("qwen3-moe-30b-a3b", 22e9, 40e9),
        ("xlstm-350m", 0.2e9, 0.6e9),
    ]:
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, f"{n:.3e}")


def test_moe_active_params_less_than_total():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.param_count(active_only=True) < 0.25 * cfg.param_count()


def test_long_context_support_flags():
    assert get_config("xlstm-350m").supports_long_context()
    assert get_config("jamba-1.5-large-398b").supports_long_context()
    assert get_config("gemma3-27b").supports_long_context()
    assert not get_config("stablelm-1.6b").supports_long_context()
    assert not get_config("qwen3-moe-30b-a3b").supports_long_context()
