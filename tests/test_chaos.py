"""Chaos-tolerant sharded serving: failure injection, failover, degraded mode.

Contracts under test (the acceptance criteria of the chaos PR):

  * the chaos differential gate — replay sequences interleaving kill / stall /
    partition / flaky / heal with queries and append/delete mutations must
    produce results EQUAL to the fault-free replay of the same ops (degraded
    substitution is bit-identical, so equality is exact);
  * during faults the engine keeps answering — no exception ever surfaces to
    a caller — and ``RouteInfo`` reports ``degraded`` / ``failed_shards`` /
    ``n_retries`` honestly;
  * recovery of a rejoined shard is checkpoint-adopt + delta-replay +
    maintainer re-registration, never a from-scratch sketch re-capture
    (asserted on the coordinator index miss counter);
  * ``rebalance`` re-places a dead shard's fragments onto survivors via
    ``plan_replacement`` and the re-planned cluster serves exactly;
  * shard inboxes are depth-capped: past the cap ``ship`` raises
    ``BackpressureError``, the coordinator's delta log carries the entries,
    and the next read resyncs the shard.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    BackpressureError,
    Database,
    Having,
    Query,
    ShardedEngine,
    execute,
)
from repro.core.datasets import make_crimes, make_tpch
from repro.runtime.chaos import (
    ChaosEvent,
    ChaosHarness,
    differential,
    random_ops,
    random_schedule,
)


def _crimes_queries(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = [dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.5, 0.8)]
    byear = Query("crimes", ("year",), Aggregate("sum", "records"))
    qs.append(dataclasses.replace(byear, having=Having(
        ">", float(np.quantile(execute(byear, db).values, 0.6)))))
    return qs


def _crimes_rows(rng, n):
    t = make_crimes(n, seed=int(rng.integers(1 << 30)))
    return {a: np.asarray(t[a]) for a in t.schema}


def _engine(db, n_shards=3, **kw):
    args = dict(n_ranges=16, theta=0.1, seed=0, min_selectivity_gain=2.0)
    args.update(kw)
    return ShardedEngine(db, "crimes", "district", n_shards=n_shards, **args)


def _tpch_templates(db):
    from repro.core import JoinSpec

    def thresh(q, qt):
        vals = execute(dataclasses.replace(q, having=None, outer_having=None),
                       db).values
        return float(np.quantile(vals, qt))

    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    agh = dataclasses.replace(agh, having=Having(">", thresh(agh, 0.8)))
    ajgh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
                 join=JoinSpec("orders", "l_orderkey", "o_orderkey"))
    ajgh = dataclasses.replace(ajgh, having=Having(">", thresh(ajgh, 0.8)))
    aagh = Query("lineitem", ("l_partkey", "l_suppkey"),
                 Aggregate("sum", "l_quantity"), having=Having(">", 0.0),
                 outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aagh = dataclasses.replace(aagh, outer_having=Having(">", thresh(aagh, 0.8)))
    aajgh = Query("lineitem", ("l_partkey", "l_suppkey"),
                  Aggregate("count", None),
                  join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
                  having=Having(">", 0.0),
                  outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aajgh = dataclasses.replace(
        aajgh, outer_having=Having(">", thresh(aajgh, 0.8)))
    return [agh, ajgh, aagh, aajgh]


def test_kill_degraded_serve_recover():
    """The canonical chaos arc: kill -> degraded serving -> heal -> recovery
    via checkpoint + delta replay, with no exception and no re-capture."""
    db = Database({"crimes": make_crimes(4000, seed=2)})
    q = _crimes_queries(db)[0]
    se = _engine(db, 3)
    ref, _ = se.run(q)  # capture + register
    single = execute(q, se.db).canonical()
    assert ref.canonical() == single

    se.shards[1].inject("kill")
    # Serving continues through the fault; the route is reported degraded.
    res, info = se.run(q)
    assert res.canonical() == single
    assert info.reused and info.degraded
    assert se.last_route.degraded
    assert 1 in se.last_route.failed_shards
    assert se.health[1] in ("suspect", "dead")

    # Mutations while down: shipped to survivors, logged for the dead shard.
    rows = _crimes_rows(np.random.default_rng(7), 300)
    se.append_rows("crimes", rows)
    expect = execute(q, se.db).canonical()
    res, info = se.run(q)
    assert res.canonical() == expect
    assert info.degraded

    misses_before = se.engine.index.misses
    se.shards[1].heal()
    res, info = se.run(q)  # probe -> adopt checkpoint -> replay -> re-register
    assert res.canonical() == expect
    assert se.health[1] == "healthy"
    assert not info.degraded and not se.last_route.degraded
    assert se.shards[1].version == se.version
    # Recovery is delta-replay + re-registration — NEVER a re-capture.
    assert se.engine.index.misses == misses_before
    # The recovered shard's maintainer agrees with the survivors' protocol:
    # the next serve needs no coordinator substitution.
    res, info = se.run(q)
    assert res.canonical() == expect and not info.degraded


def test_partition_keeps_state_and_flaky_retries():
    db = Database({"crimes": make_crimes(4000, seed=3)})
    q = _crimes_queries(db)[0]
    se = _engine(db, 3)
    se.run(q)
    single = execute(q, se.db).canonical()

    se.shards[0].inject("partition")
    res, info = se.run(q)
    assert res.canonical() == single and info.degraded
    se.shards[0].heal()
    res, info = se.run(q)
    assert res.canonical() == single
    assert se.health[0] == "healthy" and not info.degraded

    # A flaky shard drops one op then self-heals: the retry wrapper absorbs
    # it without degrading the route.
    se.shards[2].inject("flaky", 1)
    res, info = se.run(q)
    assert res.canonical() == single
    assert se.last_route.n_retries >= 1
    assert not info.degraded


def test_stall_past_deadline_routes_around_straggler():
    db = Database({"crimes": make_crimes(4000, seed=4)})
    q = _crimes_queries(db)[0]
    se = _engine(db, 3, op_deadline_s=0.002)
    # Warm the per-op timing baselines (the straggler demotion needs a
    # formed median so one-time compile spikes don't demote).
    for _ in range(10):
        se.run(q)
    single = execute(q, se.db).canonical()
    se.shards[1].inject("stall", 0.05)
    res, _ = se.run(q)  # the stalled catch_up demotes the shard...
    assert res.canonical() == single
    res, info = se.run(q)  # ...and subsequent serves route around it
    assert res.canonical() == single
    assert se.health[1] == "suspect"
    assert info.degraded and 1 in se.last_route.failed_shards
    se.shards[1].heal()
    res, info = se.run(q)
    assert res.canonical() == single
    assert se.health[1] == "healthy" and not info.degraded


def test_rebalance_moves_dead_shards_fragments():
    db = Database({"crimes": make_crimes(4000, seed=5)})
    qs = _crimes_queries(db)
    se = _engine(db, 3)
    for q in qs:
        se.run(q)
    se.shards[2].inject("kill")
    for _ in range(2):  # two failed contacts: suspect, then dead
        se.run(qs[0])
    assert se.health[2] == "dead"

    rebuilt = se.rebalance()
    assert set(rebuilt) <= {0, 1} and rebuilt
    assert not (se.plan.owner == 2).any()  # shard 2 owns nothing now
    for q in qs:
        res, info = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
        # A fully re-placed cluster serves clean: no degraded routes.
        assert not info.degraded
    # Mutations after the re-plan route by the new ownership.
    se.append_rows("crimes", _crimes_rows(np.random.default_rng(11), 200))
    mask = np.random.default_rng(12).random(se.db["crimes"].num_rows) < 0.05
    se.delete_rows("crimes", mask)
    for q in qs:
        res, _ = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
    # The emptied shard may rejoin later: harmless (it owns no fragments).
    se.shards[2].heal()
    res, info = se.run(qs[0])
    assert res.canonical() == execute(qs[0], se.db).canonical()
    assert se.health[2] == "healthy"


def test_inbox_cap_backpressure_and_resync():
    db = Database({"crimes": make_crimes(3000, seed=6)})
    q = _crimes_queries(db)[0]
    se = _engine(db, 2, inbox_cap=2)
    se.run(q)
    rng = np.random.default_rng(13)
    for _ in range(5):  # 5 deltas > cap of 2: ship hits backpressure
        se.append_rows("crimes", _crimes_rows(rng, 50))
    assert all(s.backpressure_hits > 0 for s in se.shards)
    assert all(s.lag <= 2 for s in se.shards)
    with pytest.raises(BackpressureError):
        se.shards[0].ship(99, "append", {})
    # The read path drains the inbox AND replays the logged suffix.
    res, info = se.run(q)
    assert res.canonical() == execute(q, se.db).canonical()
    assert not info.degraded
    assert se.min_watermark() == se.version


def test_sustained_backpressure_log_bounded_and_drains_bit_identical():
    """A shard held at ``inbox_cap`` across MANY mutation batches: the
    coordinator's delta log must stay bounded-but-sufficient — it carries
    exactly the un-checkpointed suffix (pruned back to empty at each
    checkpointing read), and the post-pressure drain replays everything
    bit-identically.  Covers the BackpressureError path well past the
    single-overflow case."""
    db = Database({"crimes": make_crimes(3000, seed=16)})
    q = _crimes_queries(db)[0]
    cap = 2
    se = _engine(db, 2, inbox_cap=cap)
    se.run(q)  # capture + register + first checkpoint
    rng = np.random.default_rng(21)
    n_batches = 20
    for _ in range(n_batches):
        se.append_rows("crimes", _crimes_rows(rng, 40))
    # Sustained pressure: inboxes pinned at the cap the whole run, every
    # overflowed batch counted, nothing applied in between.
    assert all(s.lag <= cap for s in se.shards)
    assert all(s.backpressure_hits >= n_batches - cap for s in se.shards)
    # Bounded-but-sufficient: the log holds exactly the un-checkpointed
    # suffix — one entry per shipped batch since the last read, no more.
    assert all(len(log) == n_batches for log in se._log)

    expect = execute(q, se.db).canonical()
    res, info = se.run(q)  # drain: inbox apply + log-suffix replay
    assert res.canonical() == expect
    assert not info.degraded
    assert se.min_watermark() == se.version
    # The checkpointing read pruned the whole suffix: log growth is capped
    # by read frequency, not by mutation volume.
    assert all(len(log) == 0 for log in se._log)

    # Steady alternation: every wave's log tops out at the wave size and
    # every drain stays bit-identical.
    for _ in range(3):
        for _ in range(5):
            se.append_rows("crimes", _crimes_rows(rng, 40))
        assert all(len(log) <= 5 for log in se._log)
        res, _ = se.run(q)
        assert res.canonical() == execute(q, se.db).canonical()
        assert all(len(log) == 0 for log in se._log)


def test_chaos_differential_crimes():
    """Seeded kill/stall/partition/flaky/heal replays, 1-4 shards: chaotic
    traces must equal the fault-free traces exactly."""
    db = Database({"crimes": make_crimes(3000, seed=7)})
    qs = _crimes_queries(db)
    for n_shards, seed in ((1, 0), (2, 1), (3, 2), (4, 3)):
        ops = random_ops(seed, 14, qs, _crimes_rows)
        events = random_schedule(seed + 50, 14, n_shards)
        ok, chaotic, clean = differential(
            lambda n=n_shards: _engine(db, n, op_deadline_s=0.02),
            "crimes", ops, events)
        assert ok, (
            f"n_shards={n_shards} seed={seed}: chaotic trace diverged at op "
            f"{next(i for i, (a, b) in enumerate(zip(chaotic, clean)) if a != b)}")


def test_chaos_differential_tpch_templates():
    """All four workload templates under scripted chaos on a join schema."""
    db = make_tpch(2500, seed=8)
    qs = _tpch_templates(db)

    def rows(rng, n):
        t = make_tpch(4 * n, seed=int(rng.integers(1 << 30)))["lineitem"]
        return {a: np.asarray(t[a])[:n] for a in t.schema}

    def make_engine():
        return ShardedEngine(db, "lineitem", "l_suppkey", n_shards=3,
                             n_ranges=16, theta=0.1, seed=0,
                             min_selectivity_gain=1.0, op_deadline_s=0.02)

    ops = random_ops(21, 12, qs, rows, p_query=0.5, p_batch=0.2, p_append=0.2)
    events = [
        ChaosEvent(1, 0, "kill"),
        ChaosEvent(3, 2, "partition"),
        ChaosEvent(5, 0, "heal"),
        ChaosEvent(6, 1, "flaky", 2.0),
        ChaosEvent(8, 2, "heal"),
        ChaosEvent(9, 0, "stall", 0.05),
        ChaosEvent(11, 0, "heal"),
    ]
    ok, chaotic, clean = differential(make_engine, "lineitem", ops, events)
    assert ok, ("tpch chaotic trace diverged at op "
                f"{next(i for i, (a, b) in enumerate(zip(chaotic, clean)) if a != b)}")


def test_sharded_coordinator_selection_state_roundtrip():
    """The sharded coordinator checkpoints ONE reuse-aware selection state
    (shards never hold any), and a replacement coordinator restores it."""
    db = Database({"crimes": make_crimes(2000, seed=17)})
    q = _crimes_queries(db)[0]
    se = _engine(db, 2)
    se.run(q)  # one miss -> one workload entry
    state = se.selection_state()
    assert state["workload"]["clock"] == se.engine.workload.clock >= 1

    se2 = _engine(db, 2)
    se2.restore_selection_state(state)
    assert se2.engine.workload.clock == se.engine.workload.clock
    assert ([ (s, repr(p.signature())) for s, p in se2.engine.workload.entries() ]
            == [ (s, repr(p.signature())) for s, p in se.engine.workload.entries() ])
    assert se2.engine.selection_cache.misses == se.engine.selection_cache.misses


def test_random_schedule_is_deterministic_and_heals():
    ev1 = random_schedule(42, 30, 4)
    ev2 = random_schedule(42, 30, 4)
    assert ev1 == ev2
    # Every persistent fault is healed by the end of the schedule.
    state = {}
    for e in ev1:
        if e.kind == "heal":
            state.pop(e.shard, None)
        elif e.kind in ("kill", "stall", "partition"):
            state[e.shard] = e.kind
    assert state == {}


def test_harness_replays_events_at_steps():
    db = Database({"crimes": make_crimes(2000, seed=9)})
    q = _crimes_queries(db)[0]
    se = _engine(db, 2)
    se.run(q)
    h = ChaosHarness([ChaosEvent(1, 0, "kill"), ChaosEvent(2, 0, "heal")])
    trace = h.run(se, "crimes", [("query", q)] * 4)
    assert len(trace) == 4 and len(set(map(str, trace))) == 1
    assert se.health[0] == "healthy"
