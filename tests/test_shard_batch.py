"""Fused SPMD sharded serving + ShardedEngine.run_batch differential suite.

Contracts under test (the acceptance criteria of the SPMD serving PR):

  * fused stacked one-launch serving is *bit-identical* to the per-shard
    host loop and to single-node execution across all four templates,
    including under interleaved appends/deletes;
  * ``ShardedEngine.run_batch(qs)`` is semantically equivalent to
    ``[se.run(q) for q in qs]`` — results, index contents, sketch bits,
    per-shard maintainer state and watermarks;
  * the warm hit path costs exactly ONE fused XLA launch per batch
    (counter-asserted), regardless of how many queries or entries hit;
  * the stacked layout is pow2-quantized on the shard-row, group and query
    axes, so shard-count or registered-sketch-set changes within a padded
    bucket compile nothing new;
  * shard-side registrations evict with the coordinator's recency clock
    (``ShardedEngine.prune`` / ``max_registered``), bounding per-shard
    maintainer + instance memory.
"""
import contextlib
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Database,
    Having,
    JoinSpec,
    Predicate,
    Query,
    ShardedEngine,
    execute,
)
from repro.core import shard as shard_mod
from repro.core.datasets import make_crimes, make_tpch
from repro.runtime.guards import retrace_guard

N_ROWS = 20_000


@contextlib.contextmanager
def count_xla_compiles():
    """Count real backend compilations via the shared retrace guard
    (cached executions emit no event)."""
    with retrace_guard(allowed=None) as watch:
        yield watch.events


def _threshold(q, db, quantile):
    vals = execute(dataclasses.replace(q, having=None, outer_having=None), db).values
    return float(np.quantile(vals, quantile))


def _tpch_template_batches(db, quantiles=(0.55, 0.8, 0.9)):
    """Per template, a batch of queries differing only in HAVING thresholds
    (ascending, so later members hit the first member's sketch)."""
    batches = {}
    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    batches["Q-AGH"] = [
        dataclasses.replace(agh, having=Having(">", _threshold(agh, db, qt)))
        for qt in quantiles
    ]
    ajgh = Query(
        "lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
    )
    batches["Q-AJGH"] = [
        dataclasses.replace(ajgh, having=Having(">", _threshold(ajgh, db, qt)))
        for qt in quantiles
    ]
    aagh = Query(
        "lineitem", ("l_partkey", "l_suppkey"), Aggregate("sum", "l_quantity"),
        having=Having(">", 0.0),
        outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
    )
    batches["Q-AAGH"] = [
        dataclasses.replace(
            aagh, outer_having=Having(">", _threshold(aagh, db, qt)))
        for qt in quantiles
    ]
    aajgh = Query(
        "lineitem", ("l_partkey", "l_suppkey"), Aggregate("count", None),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
        having=Having(">", 0.0),
        outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
    )
    batches["Q-AAJGH"] = [
        dataclasses.replace(
            aajgh, outer_having=Having(">", _threshold(aajgh, db, qt)))
        for qt in quantiles
    ]
    return batches


def _crimes_engines(db, n_shards, **kw):
    args = dict(n_ranges=25, theta=0.1, seed=0, min_selectivity_gain=2.0)
    args.update(kw)
    return (ShardedEngine(db, "crimes", "district", n_shards=n_shards, **args),
            ShardedEngine(db, "crimes", "district", n_shards=n_shards, **args))


def _snapshot(se):
    """Comparable engine state: index sketches, shard maintainer bits,
    registration count, watermark."""
    index = sorted(
        (repr(e.query.signature()), e.sketch.bits.tobytes(),
         e.sketch.size_rows)
        for e in se.engine.index.entries())
    shard_bits = [
        sorted(m.bits().tobytes() for m in shard.maintainers.values())
        for shard in se.shards
    ]
    return {
        "index": index,
        "shard_bits": shard_bits,
        "n_registered": len(se._registered),
        "watermark": se.min_watermark(),
        "version": se.version,
    }


def _assert_outs_equal(outs_b, outs_s, ctx=""):
    assert len(outs_b) == len(outs_s)
    for i, ((rb, ib), (rs, is_)) in enumerate(zip(outs_b, outs_s)):
        assert rb.canonical() == rs.canonical(), f"{ctx}[{i}]"
        assert ib.reused == is_.reused, f"{ctx}[{i}]"
        assert ib.created == is_.created, f"{ctx}[{i}]"


def test_run_batch_matches_sequential_all_templates():
    db = make_tpch(N_ROWS, seed=7)
    for name, batch in _tpch_template_batches(db).items():
        se_b = ShardedEngine(db, "lineitem", "l_suppkey", n_shards=2,
                             n_ranges=32, theta=0.1, seed=0,
                             min_selectivity_gain=2.0)
        se_s = ShardedEngine(db, "lineitem", "l_suppkey", n_shards=2,
                             n_ranges=32, theta=0.1, seed=0,
                             min_selectivity_gain=2.0)
        outs_b = se_b.run_batch(batch)
        outs_s = [se_s.run(q) for q in batch]
        _assert_outs_equal(outs_b, outs_s, name)
        assert _snapshot(se_b) == _snapshot(se_s), name
        # Warm pass: every member is a routed hit now.
        outs_b2 = se_b.run_batch(batch)
        outs_s2 = [se_s.run(q) for q in batch]
        _assert_outs_equal(outs_b2, outs_s2, name + ":warm")
        assert all(ib.reused for _, ib in outs_b2), name
        for (rb, _), q in zip(outs_b2, batch):
            assert rb.canonical() == execute(q, se_b.db).canonical(), name


@pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
def test_run_batch_mixed_hits_and_misses(n_shards):
    db = Database({"crimes": make_crimes(N_ROWS, seed=3)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = [dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.5, 0.7, 0.9)]
    base2 = Query("crimes", ("district",), Aggregate("count", None))
    counts = execute(base2, db).values
    q2 = dataclasses.replace(base2, having=Having(">", float(np.quantile(counts, 0.6))))

    se_b, se_s = _crimes_engines(db, n_shards)
    # Warm one entry so the batch mixes hits with misses (plus a duplicate
    # and an ascending pair that defers a wave).
    se_b.run(qs[0])
    se_s.run(qs[0])
    batch = [qs[1], qs[0], q2, qs[2], qs[1]]
    outs_b = se_b.run_batch(batch)
    outs_s = [se_s.run(q) for q in batch]
    _assert_outs_equal(outs_b, outs_s, f"S={n_shards}")
    assert _snapshot(se_b) == _snapshot(se_s)
    for (rb, _), q in zip(outs_b, batch):
        assert rb.canonical() == execute(q, se_b.db).canonical()


def test_run_batch_interleaved_mutations_and_maintainer_state():
    rng = np.random.default_rng(19)
    db = Database({"crimes": make_crimes(N_ROWS, seed=9)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    queries = [
        dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
        for qt in (0.6, 0.8)
    ]
    # A non-group-local query too: groups span shards, the fused path must
    # serve from the coordinator-maintained bits.
    byear = Query("crimes", ("year",), Aggregate("sum", "records"))
    ysums = execute(byear, db).values
    queries.append(dataclasses.replace(
        byear, having=Having(">", float(np.quantile(ysums, 0.7)))))

    se_b, se_s = _crimes_engines(db, 4)
    se_b.run_batch(queries)
    for q in queries:
        se_s.run(q)
    assert _snapshot(se_b) == _snapshot(se_s)

    n_batches = 0
    for step in range(16):
        op = rng.choice(["append", "delete", "batch"], p=[0.3, 0.25, 0.45])
        if op == "append":
            batch_rows = make_crimes(int(rng.integers(200, 600)),
                                     seed=int(rng.integers(1 << 30)))
            rows = {a: np.asarray(batch_rows[a]) for a in batch_rows.schema}
            se_b.append_rows("crimes", rows)
            se_s.append_rows("crimes", rows)
        elif op == "delete":
            mask = rng.random(se_b.db["crimes"].num_rows) < 0.02
            se_b.delete_rows("crimes", mask)
            se_s.delete_rows("crimes", mask)
        else:
            picks = [queries[int(rng.integers(len(queries)))]
                     for _ in range(int(rng.integers(2, 5)))]
            outs_b = se_b.run_batch(picks)
            outs_s = [se_s.run(q) for q in picks]
            _assert_outs_equal(outs_b, outs_s, f"step{step}")
            for (rb, ib), q in zip(outs_b, picks):
                assert ib.reused, step
                assert rb.canonical() == execute(q, se_b.db).canonical(), step
            # Watermark gate drained every shard before serving.
            assert se_b.min_watermark() == se_b.version
            assert _snapshot(se_b) == _snapshot(se_s), step
            n_batches += 1
    assert n_batches >= 3


def test_fused_equals_host_loop_bitwise():
    """Same engine, both serving paths: values must match bit-for-bit
    (not just canonically) — the stacked merge reproduces the host-loop
    float32 arithmetic exactly inside the integral envelope."""
    db = Database({"crimes": make_crimes(N_ROWS, seed=5)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    q = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.8))))
    qavg = dataclasses.replace(
        base, agg=Aggregate("avg", "records"),
        having=Having(">", float(np.quantile(
            execute(dataclasses.replace(base, agg=Aggregate("avg", "records")),
                    db).values, 0.8))))
    se, _ = _crimes_engines(db, 4)
    for query in (q, qavg):
        se.run(query)
        se.fused = True
        rf, inf_f = se.run(query)
        assert se.last_route.fused and se.last_route.t_launch_s >= 0
        se.fused = False
        rl, inf_l = se.run(query)
        assert not se.last_route.fused
        se.fused = True
        assert inf_f.shards_contacted == inf_l.shards_contacted
        assert inf_f.shards_skipped == inf_l.shards_skipped
        assert sorted(rf.group_values) == sorted(rl.group_values)
        assert np.array_equal(rf.values, rl.values)
        for a in rf.group_values:
            assert np.array_equal(rf.group_values[a], rl.group_values[a])
        single = execute(query, se.db)
        assert rf.canonical() == single.canonical()


def test_hit_batch_costs_one_fused_launch():
    db = Database({"crimes": make_crimes(N_ROWS, seed=11)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = [dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.6, 0.85)]
    base2 = Query("crimes", ("district",), Aggregate("count", None))
    counts = execute(base2, db).values
    q2 = dataclasses.replace(base2, having=Having(">", float(np.quantile(counts, 0.6))))
    se, _ = _crimes_engines(db, 4)
    batch = qs + [q2, qs[0], qs[1]]
    se.run_batch(batch)  # cold: admits + registers
    se.run_batch(batch)  # warms the stacked arrays + compiled shapes
    before = shard_mod.LAUNCH_COUNTS["fused_partials"]
    outs = se.run_batch(batch)  # 5 queries, 3 distinct entries
    assert shard_mod.LAUNCH_COUNTS["fused_partials"] - before == 1
    assert all(ib.reused for _, ib in outs)
    assert se.last_route.fused and se.last_route.n_queries == len(batch)
    # Single-query hits also cost exactly one launch.
    before = shard_mod.LAUNCH_COUNTS["fused_partials"]
    se.run(qs[0])
    assert shard_mod.LAUNCH_COUNTS["fused_partials"] - before == 1


def test_stacked_pow2_quantization_avoids_recompiles():
    """Shard-count and registered-sketch-set changes inside one padded
    bucket (shard-row, group AND query axes pow2-quantized) must compile
    nothing new — mirrors the ``sizes_mat`` test in ``test_catalog.py``."""
    db = Database({"crimes": make_crimes(N_ROWS, seed=13)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    # Low thresholds: the sketch covers (almost) all fragments, so every
    # shard is contacted and the stacked shard axis tracks the shard count.
    q3 = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.1))))
    q4 = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.15))))

    se3, _ = _crimes_engines(db, 3)
    se3.run(q3)
    se3.run(q3)  # warm: compiles the fused size class (s_pad=4, r_pad, g_pad)
    trace_before = shard_mod.TRACE_COUNTS["fused_partials"]

    # 4 shards: s_pad is still 4, per-shard rows shrink within the same
    # pow2 row bucket (20k rows: ceil to 8192 at both 3 and 4 shards), and a
    # second registered sketch with the same group-by lands in the same
    # (r_pad, g_pad) bucket — the fused launch must never retrace for any of
    # them (the stacked *build* may compile one-time gather shapes; the
    # serving launch itself is pinned by the trace counter).
    se4, _ = _crimes_engines(db, 4)
    se4.run(q3)  # cold: capture + registration
    se4.run(q3)  # first fused serve: builds the stack
    se4.run(q4)
    se4.run(q4)
    assert shard_mod.TRACE_COUNTS["fused_partials"] == trace_before, (
        "fused launch retraced inside one pow2 bucket")

    # Steady state: repeated fused serves over both sketches (and a mixed
    # hit batch through the query-axis path, once warmed) compile nothing.
    se4.run_batch([q3, q4])
    with count_xla_compiles() as events:
        se4.run(q3)
        assert se4.last_route.fused
        se4.run(q4)
        se4.run_batch([q3, q4, q3])
    assert len(events) == 0, (
        f"steady-state fused serving compiled {len(events)} programs")
    assert shard_mod.TRACE_COUNTS["fused_partials"] == trace_before


def test_prune_bounds_shard_registrations():
    """Shard-side ``SketchIndex.prune`` wiring: registrations evict with the
    coordinator's recency clock and per-shard state stays bounded."""
    db = Database({"crimes": make_crimes(N_ROWS, seed=17)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    years = np.asarray(db["crimes"]["year"])
    lo, hi = int(years.min()), int(years.max())
    qs = []
    for k, yr in enumerate((lo, lo + 1, lo + 2)):
        b = dataclasses.replace(base, where=Predicate("year", ">=", float(yr)))
        sums = execute(b, db).values
        qs.append(dataclasses.replace(
            b, having=Having(">", float(np.quantile(sums, 0.8)))))
    # Distinct WHERE predicates => distinct index entries (no subsumption).
    se = ShardedEngine(db, "crimes", "district", n_shards=3, n_ranges=25,
                       theta=0.1, seed=0, min_selectivity_gain=2.0,
                       max_registered=2)
    for q in qs:
        se.run(q)
        se.run(q)
    assert len(se.engine.index) == 2
    assert len(se._registered) == 2
    for shard in se.shards:
        assert len(shard.maintainers) <= 2
        assert len(shard._inst) <= 2
    assert len(se.engine.catalog._stacked) <= 2
    # The least-recently-hit sketch (qs[0]) was evicted: next run re-captures.
    _, info = se.run(qs[0])
    assert info.created and not info.reused
    res, info2 = se.run(qs[0])
    assert info2.reused
    assert res.canonical() == execute(qs[0], se.db).canonical()
    # Manual prune to 1 drops shard state for the evicted entries too.
    assert se.prune(1) >= 1
    assert len(se._registered) == 1
    for shard in se.shards:
        assert len(shard.maintainers) <= 1


def test_spmd_mesh_shard_map_path():
    """With a real multi-device mesh (forced host devices), the fused path
    runs through shard_map + psum and stays exact."""
    code = textwrap.dedent("""
        import dataclasses
        import numpy as np
        from repro.core import (Aggregate, Database, Having, Query,
                                ShardedEngine, execute)
        from repro.core import shard as shard_mod
        from repro.core.datasets import make_crimes

        db = Database({"crimes": make_crimes(6_000, seed=3)})
        base = Query("crimes", ("district",), Aggregate("sum", "records"))
        sums = execute(base, db).values
        q = dataclasses.replace(
            base, having=Having(">", float(np.quantile(sums, 0.1))))
        se = ShardedEngine(db, "crimes", "district", n_shards=4, n_ranges=16,
                           theta=0.1, seed=0, min_selectivity_gain=2.0)
        assert se._mesh is not None and se._mesh.devices.size == 4
        se.run(q)
        res, info = se.run(q)
        assert info.reused and se.last_route.fused
        assert shard_mod._SPMD_FNS, "shard_map path was not taken"
        assert res.canonical() == execute(q, se.db).canonical()
        print("SPMD_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SPMD_OK" in proc.stdout
