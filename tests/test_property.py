"""Hypothesis property tests for the system's core invariants:

  1. SAFETY: for any generated table + Q-AGH query + safe attribute, the
     sketch-instrumented query returns exactly the full-data result.
  2. Sketch covers provenance; selectivity in (0, 1]; accurate sketch bits
     equal the brute-force fragment incidence of the provenance.
  3. Size estimation is bounded by the table size and the Frechet interval
     is ordered.
  4. Index subsumption never returns an unsafe sketch.
"""
import dataclasses

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.aqp.sampling import stratified_reservoir_sample
from repro.aqp.size_estimation import estimate_size
from repro.core import (
    Aggregate, Database, Having, Query, capture_sketch, equi_depth_ranges,
    execute, execute_with_sketch, provenance_mask, subsumes,
)
from repro.core.table import from_numpy

SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def table_and_query(draw):
    n = draw(st.integers(min_value=30, max_value=400))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    ncat = draw(st.integers(min_value=2, max_value=12))
    t = from_numpy(
        "t",
        dict(
            a=rng.integers(0, ncat, n).astype(np.int32),
            b=rng.integers(0, ncat * 2, n).astype(np.int32),
            c=rng.integers(0, 50, n).astype(np.int32),
            v=rng.integers(0, 100, n).astype(np.int32),  # non-negative values
        ),
    )
    gb = draw(st.sampled_from([("a",), ("b",), ("a", "b")]))
    fn = draw(st.sampled_from(["sum", "count", "avg"]))
    tau = draw(st.floats(min_value=1.0, max_value=500.0))
    q = Query("t", gb, Aggregate(fn, None if fn == "count" else "v"),
              having=Having(">", tau))
    attr_pool = list(gb) if fn == "avg" else ["a", "b", "c"]
    attr = draw(st.sampled_from(attr_pool))
    n_ranges = draw(st.integers(min_value=2, max_value=20))
    return Database({"t": t}), q, attr, n_ranges


@given(table_and_query())
@settings(**SETTINGS)
def test_sketch_safety_invariant(tq):
    db, q, attr, n_ranges = tq
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    sk = capture_sketch(q, db, ranges)
    assert execute_with_sketch(q, db, sk).canonical() == execute(q, db).canonical()
    assert 0.0 <= sk.selectivity <= 1.0


@given(table_and_query())
@settings(**SETTINGS)
def test_sketch_bits_are_exact_incidence(tq):
    db, q, attr, n_ranges = tq
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    sk = capture_sketch(q, db, ranges)
    prov = provenance_mask(q, db)
    bucket = np.asarray(ranges.bucketize(db["t"][attr]))
    want = np.zeros(ranges.n_ranges, bool)
    for r in bucket[prov]:
        want[r] = True
    np.testing.assert_array_equal(sk.bits, want)


@given(table_and_query())
@settings(**SETTINGS)
def test_size_estimate_bounded(tq):
    db, q, attr, n_ranges = tq
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    s = stratified_reservoir_sample(jax.random.PRNGKey(0), db["t"], q.groupby, 0.3)
    est = estimate_size(jax.random.PRNGKey(1), q, db, ranges, s)
    n = db["t"].num_rows
    assert 0.0 <= est.est_rows <= n + 1e-6
    assert 0.0 <= est.est_selectivity <= 1.0
    assert est.lo_rows <= est.hi_rows + 1e-6
    assert est.expected_rows <= est.hi_rows + 1e-6


@given(table_and_query(), st.floats(min_value=0.0, max_value=300.0))
@settings(**SETTINGS)
def test_subsumption_soundness(tq, delta):
    """If subsumes(q1, q2), the q1 sketch answers q2 exactly."""
    db, q1, attr, n_ranges = tq
    q2 = dataclasses.replace(q1, having=Having(">", q1.having.value + delta))
    if not subsumes(q1, q2):
        pytest.skip("not subsumed (op not monotone)")
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    sk = capture_sketch(q1, db, ranges)
    assert execute_with_sketch(q2, db, sk).canonical() == execute(q2, db).canonical()
