"""Hypothesis property tests for the system's core invariants:

  1. SAFETY: for any generated table + Q-AGH query + safe attribute, the
     sketch-instrumented query returns exactly the full-data result.
  2. Sketch covers provenance; selectivity in (0, 1]; accurate sketch bits
     equal the brute-force fragment incidence of the provenance.
  3. Size estimation is bounded by the table size and the Frechet interval
     is ordered.
  4. Index subsumption never returns an unsafe sketch.
  5. MAINTENANCE: across any append/delete sequence, maintained sketch bits
     are a superset-or-equal of the re-capture oracle's; equal outright for
     monotone-safe aggregates; and equal for every aggregate after
     ``repair()``.  Shrinks on the (ops-sequence, attr) pair.
"""
import dataclasses

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (pip install -r requirements-dev.txt)")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.aqp.sampling import stratified_reservoir_sample
from repro.aqp.size_estimation import estimate_size
from repro.core import (
    Aggregate, Catalog, Database, Having, Query, build_maintainer,
    capture_sketch, equi_depth_ranges, execute, execute_with_sketch,
    monotone_safe, provenance_mask, subsumes,
)
from repro.core.table import from_numpy

SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def table_and_query(draw):
    n = draw(st.integers(min_value=30, max_value=400))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    ncat = draw(st.integers(min_value=2, max_value=12))
    t = from_numpy(
        "t",
        dict(
            a=rng.integers(0, ncat, n).astype(np.int32),
            b=rng.integers(0, ncat * 2, n).astype(np.int32),
            c=rng.integers(0, 50, n).astype(np.int32),
            v=rng.integers(0, 100, n).astype(np.int32),  # non-negative values
        ),
    )
    gb = draw(st.sampled_from([("a",), ("b",), ("a", "b")]))
    fn = draw(st.sampled_from(["sum", "count", "avg"]))
    tau = draw(st.floats(min_value=1.0, max_value=500.0))
    q = Query("t", gb, Aggregate(fn, None if fn == "count" else "v"),
              having=Having(">", tau))
    attr_pool = list(gb) if fn == "avg" else ["a", "b", "c"]
    attr = draw(st.sampled_from(attr_pool))
    n_ranges = draw(st.integers(min_value=2, max_value=20))
    return Database({"t": t}), q, attr, n_ranges


@given(table_and_query())
@settings(**SETTINGS)
def test_sketch_safety_invariant(tq):
    db, q, attr, n_ranges = tq
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    sk = capture_sketch(q, db, ranges)
    assert execute_with_sketch(q, db, sk).canonical() == execute(q, db).canonical()
    assert 0.0 <= sk.selectivity <= 1.0


@given(table_and_query())
@settings(**SETTINGS)
def test_sketch_bits_are_exact_incidence(tq):
    db, q, attr, n_ranges = tq
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    sk = capture_sketch(q, db, ranges)
    prov = provenance_mask(q, db)
    bucket = np.asarray(ranges.bucketize(db["t"][attr]))
    want = np.zeros(ranges.n_ranges, bool)
    for r in bucket[prov]:
        want[r] = True
    np.testing.assert_array_equal(sk.bits, want)


@given(table_and_query())
@settings(**SETTINGS)
def test_size_estimate_bounded(tq):
    db, q, attr, n_ranges = tq
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    s = stratified_reservoir_sample(jax.random.PRNGKey(0), db["t"], q.groupby, 0.3)
    est = estimate_size(jax.random.PRNGKey(1), q, db, ranges, s)
    n = db["t"].num_rows
    assert 0.0 <= est.est_rows <= n + 1e-6
    assert 0.0 <= est.est_selectivity <= 1.0
    assert est.lo_rows <= est.hi_rows + 1e-6
    assert est.expected_rows <= est.hi_rows + 1e-6


def _mut_table(rng, n, ncat):
    return dict(
        a=rng.integers(0, ncat, n).astype(np.int32),
        b=rng.integers(0, ncat * 3, n).astype(np.int32),
        v=rng.integers(0, 60, n).astype(np.int32),  # non-negative, f32-exact
    )


@st.composite
def maintenance_scenario(draw):
    """(initial table, query, sketch attr, ranges, ops) — shrinks on the
    (ops-sequence, attr) pair."""
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    n = draw(st.integers(min_value=40, max_value=250))
    ncat = draw(st.integers(min_value=2, max_value=10))
    fn = draw(st.sampled_from(["sum", "count", "avg"]))
    tau = draw(st.floats(min_value=1.0, max_value=400.0))
    q = Query("t", ("a",), Aggregate(fn, None if fn == "count" else "v"),
              having=Having(">", tau))
    # AVG is only safe on group-by attributes; sum/count are safe everywhere
    # here (non-negative v, upward-monotone HAVING).
    attr = draw(st.sampled_from(["a"] if fn == "avg" else ["a", "b"]))
    n_ranges = draw(st.integers(min_value=2, max_value=12))
    ops = draw(st.lists(
        st.one_of(
            st.tuples(st.just("append"), st.integers(1, 80)),
            st.tuples(st.just("delete"), st.integers(2, 9)),
        ),
        min_size=1, max_size=6))
    return _mut_table(rng, n, ncat), q, attr, n_ranges, ops, seed, ncat


@given(maintenance_scenario())
@settings(**SETTINGS)
def test_maintained_bits_superset_and_exact_after_repair(scenario):
    cols, q, attr, n_ranges, ops, seed, ncat = scenario
    rng = np.random.default_rng(seed + 1)
    t = from_numpy("t", cols)
    db = Database({"t": t})
    ranges = equi_depth_ranges(t, attr, n_ranges)
    cat = Catalog()
    safe = monotone_safe(q, db, cat)
    m = build_maintainer(q, db, ranges, cat)

    for kind, arg in ops:
        if kind == "append":
            batch = _mut_table(rng, arg, ncat)
            t = t.append(batch)
            cols = {k: np.concatenate([cols[k], batch[k]]) for k in cols}
        else:
            mask = np.asarray(t["b"]) % arg == 0
            if mask.all():
                continue
            t = t.delete(mask)
            keep = ~(cols["b"] % arg == 0)
            cols = {k: v[keep] for k, v in cols.items()}
        db = Database({"t": t})
        m.apply(t, db)

        oracle = capture_sketch(q, Database({"t": from_numpy("t", cols)}), ranges,
                                catalog=Catalog())
        got = m.bits()
        assert (got | oracle.bits == got).all(), "maintained bits lost coverage"
        if safe:
            np.testing.assert_array_equal(got, oracle.bits)
        m.repair()
        np.testing.assert_array_equal(m.bits(), oracle.bits)


@given(table_and_query(), st.floats(min_value=0.0, max_value=300.0))
@settings(**SETTINGS)
def test_subsumption_soundness(tq, delta):
    """If subsumes(q1, q2), the q1 sketch answers q2 exactly."""
    db, q1, attr, n_ranges = tq
    q2 = dataclasses.replace(q1, having=Having(">", q1.having.value + delta))
    if not subsumes(q1, q2):
        pytest.skip("not subsumed (op not monotone)")
    ranges = equi_depth_ranges(db["t"], attr, n_ranges)
    sk = capture_sketch(q1, db, ranges)
    assert execute_with_sketch(q2, db, sk).canonical() == execute(q2, db).canonical()
