"""Adversarial subsumption: ``subsumes(q1, q2)`` must imply provenance
containment bit-for-bit against the capture oracle.

The index's reuse rule is only safe when every fragment holding q2-provenance
rows is marked in the sketch captured for q1.  This suite randomizes
``(op, tau)`` pairs — with thresholds drawn from the *actual* group-aggregate
values so exact-boundary equality (agg == tau) occurs constantly — and checks
the implication ``subsumes(q1, q2)  =>  frag(P(q2)) subset-of bits(q1)``
against ``capture_sketch``/``provenance_mask``.  Includes the `>`/`>=`
equal-threshold boundary (the PR's wrong-result-reuse regression) and mixed
outer/inner HAVING chains on the nested templates.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Database,
    Having,
    Query,
    RangeSet,
    capture_sketch,
    equi_depth_ranges,
    execute,
    provenance_mask,
    subsumes,
)
from repro.core.datasets import make_crimes
from repro.core.table import from_numpy


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(8_000, seed=41)})


def _prov_frag_bits(q, db, ranges) -> np.ndarray:
    """The oracle: which fragments hold >= 1 provenance row of ``q``."""
    prov = provenance_mask(q, db)
    bucket = np.asarray(ranges.bucketize(db[q.table][ranges.attr]))
    bits = np.zeros(ranges.n_ranges, dtype=bool)
    bits[bucket[prov]] = True
    return bits


def _check_pair(q1, q2, db, ranges):
    """If the index would reuse q1's sketch for q2, containment must hold."""
    if not subsumes(q1, q2):
        return False
    sk = capture_sketch(q1, db, ranges)
    p2 = _prov_frag_bits(q2, db, ranges)
    missing = p2 & ~sk.bits
    assert not missing.any(), (
        f"unsafe reuse: {q1.having}/{q1.outer_having} claimed to subsume "
        f"{q2.having}/{q2.outer_having} but fragments {np.nonzero(missing)[0]} "
        f"hold q2 provenance outside the stored sketch")
    return True


def test_randomized_agh_pairs_containment(db):
    rng = np.random.default_rng(7)
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    agg_vals = np.unique(execute(base, db).values)
    ranges = equi_depth_ranges(db["crimes"], "district", 20)
    ops = [">", ">=", "<", "<=", "="]
    n_subsumed = 0
    for _ in range(250):
        # Draw taus from the actual aggregate values (boundary equality is
        # the adversarial case) or a perturbation of one.
        taus = []
        for _k in range(2):
            v = float(rng.choice(agg_vals))
            if rng.random() < 0.4:
                v += float(rng.choice([-1.0, 1.0]))
            taus.append(v)
        # Bias toward the monotone ops so the reuse path is hit often; the
        # occasional <, <=, = pairs cover the exact-equality-only rule.
        pool = ops if rng.random() < 0.3 else [">", ">="]
        op1, op2 = rng.choice(pool, size=2)
        q1 = dataclasses.replace(base, having=Having(str(op1), taus[0]))
        q2 = dataclasses.replace(base, having=Having(str(op2), taus[1]))
        n_subsumed += _check_pair(q1, q2, db, ranges)
    # The suite must actually exercise the reuse path, not vacuously pass.
    assert n_subsumed > 30


def test_randomized_nested_pairs_mixed_inner_outer(db):
    """Nested templates: inner and outer HAVING both vary independently."""
    rng = np.random.default_rng(19)
    base = Query(
        "crimes", ("district", "year"), Aggregate("sum", "records"),
        outer_groupby=("district",), outer_agg=Aggregate("sum", None),
    )
    inner_vals = np.unique(execute(
        dataclasses.replace(base, outer_groupby=None, outer_agg=None), db).values)
    outer_vals = np.unique(execute(base, db).values)
    ranges = equi_depth_ranges(db["crimes"], "district", 20)
    n_subsumed = 0
    for _ in range(120):
        def _tau(vals):
            v = float(rng.choice(vals))
            return v + (float(rng.choice([-1.0, 1.0])) if rng.random() < 0.4 else 0.0)
        op_i1, op_i2, op_o1, op_o2 = rng.choice([">", ">="], size=4)
        q1 = dataclasses.replace(base, having=Having(str(op_i1), _tau(inner_vals)),
                                 outer_having=Having(str(op_o1), _tau(outer_vals)))
        q2 = dataclasses.replace(base, having=Having(str(op_i2), _tau(inner_vals)),
                                 outer_having=Having(str(op_o2), _tau(outer_vals)))
        n_subsumed += _check_pair(q1, q2, db, ranges)
    assert n_subsumed > 10


def test_boundary_violation_is_real_not_theoretical():
    """Constructed dataset where the pre-fix rule (`>` serves `>=` at equal
    tau) returns a provably unsafe sketch: the boundary group's fragment is
    missing from the stored bits but holds q2 provenance."""
    table = from_numpy("t", {
        "g": np.array([0, 0, 1, 1, 2, 2], dtype=np.int32),
        "v": np.array([5, 5, 10, 10, 3, 2], dtype=np.int32),
    })
    db = Database({"t": table})
    # Per-group sums: g0 -> 10, g1 -> 20, g2 -> 5.  One fragment per group.
    ranges = RangeSet("g", np.array([0.5, 1.5]))
    q1 = Query("t", ("g",), Aggregate("sum", "v"), having=Having(">", 10.0))
    q2 = Query("t", ("g",), Aggregate("sum", "v"), having=Having(">=", 10.0))
    sk1 = capture_sketch(q1, db, ranges)
    p2 = _prov_frag_bits(q2, db, ranges)
    # q2's provenance needs g0's fragment; q1's sketch does not contain it.
    assert (p2 & ~sk1.bits).any()
    assert not subsumes(q1, q2)  # the fix: equal-tau mixed ops must miss
    # And the safe direction still reuses: g1-only provenance is contained.
    assert subsumes(q2, q1)
    p1 = _prov_frag_bits(q1, db, ranges)
    sk2 = capture_sketch(q2, db, ranges)
    assert not (p1 & ~sk2.bits).any()


def test_subsumption_implies_safe_result_end_to_end(db):
    """Beyond containment: serving q2 from q1's sketch instance returns the
    exact q2 result whenever subsumes says yes (spot-check on real data)."""
    from repro.core import apply_sketch

    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    agg_vals = execute(base, db).values
    tau = float(np.quantile(agg_vals, 0.8))
    ranges = equi_depth_ranges(db["crimes"], "district", 20)
    rng = np.random.default_rng(3)
    q1 = dataclasses.replace(base, having=Having(">", tau))
    sk = capture_sketch(q1, db, ranges)
    for _ in range(20):
        op = str(rng.choice([">", ">="]))
        tau2 = float(rng.choice([tau, tau + 1.0, tau * 1.2,
                                 float(rng.choice(agg_vals))]))
        q2 = dataclasses.replace(base, having=Having(op, tau2))
        if not subsumes(q1, q2):
            continue
        got = execute(q2, apply_sketch(sk, db)).canonical()
        assert got == execute(q2, db).canonical(), (op, tau2)
