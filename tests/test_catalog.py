"""Catalog + fragment-skipping execution path.

Covers the PR's acceptance criteria:
  * clustered (fragment-slice) and unclustered (keep-mask) sketch application
    produce results identical to NO-PS execution on all four templates at
    120k rows;
  * a repeated workload does zero host-side encode_groups / join-argsort
    work on the second pass (catalog call-counting);
  * the batched size estimator agrees with the single-candidate reference.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Catalog,
    Database,
    Having,
    JoinSpec,
    Query,
    apply_sketch,
    capture_sketch,
    equi_depth_ranges,
    execute,
    execute_with_sketch,
)
from repro.core.datasets import make_crimes, make_tpch
from repro.core.engine import PBDSEngine
from repro.core.workload import CRIMES_SPEC, TPCH_JOIN_SPEC, generate_workload

N_ROWS = 120_000


@pytest.fixture(scope="module")
def tpch_db():
    return make_tpch(N_ROWS, seed=7)


def _threshold(q: Query, db: Database, quantile: float) -> float:
    vals = execute(dataclasses.replace(q, having=None, outer_having=None), db).values
    return float(np.quantile(vals, quantile))


def _templates(db: Database):
    """One query per supported template over the 120k-row lineitem table."""
    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    agh = dataclasses.replace(agh, having=Having(">", _threshold(agh, db, 0.8)))

    ajgh = Query(
        "lineitem", ("l_suppkey",), Aggregate("sum", "l_extendedprice"),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
    )
    ajgh = dataclasses.replace(ajgh, having=Having(">", _threshold(ajgh, db, 0.8)))

    aagh = Query(
        "lineitem", ("l_suppkey", "l_partkey"), Aggregate("sum", "l_quantity"),
        having=Having(">", 0.0),
        outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
    )
    aagh = dataclasses.replace(
        aagh, outer_having=Having(">", _threshold(aagh, db, 0.8)))

    aajgh = Query(
        "lineitem", ("l_suppkey", "l_partkey"), Aggregate("sum", "l_quantity"),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
        having=Having(">", 0.0),
        outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
    )
    aajgh = dataclasses.replace(
        aajgh, outer_having=Having(">", _threshold(aajgh, db, 0.8)))
    return [agh, ajgh, aagh, aajgh]


def test_fragment_skipping_exact_all_templates(tpch_db):
    """Sketch-instrumented == NO-PS on every template, clustered + unclustered."""
    ranges = equi_depth_ranges(tpch_db["lineitem"], "l_suppkey", 64)
    clustered_db = tpch_db.with_table(tpch_db["lineitem"].cluster_by(ranges))
    for q in _templates(tpch_db):
        assert q.template in ("Q-AGH", "Q-AJGH", "Q-AAGH", "Q-AAJGH")
        want = execute(q, tpch_db).canonical()
        assert len(want) > 0

        # Unclustered: keep-mask (sketch_filter kernel fallback) path.
        cat_u = Catalog()
        sk_u = capture_sketch(q, tpch_db, ranges, catalog=cat_u)
        got_u = execute_with_sketch(q, tpch_db, sk_u, catalog=cat_u).canonical()
        assert got_u == want, q.template
        assert cat_u.stats["instance_mask"] == 1
        assert cat_u.stats["instance_slices"] == 0

        # Clustered: fragment-slice concatenation path.
        cat_c = Catalog()
        sk_c = capture_sketch(q, clustered_db, ranges, catalog=cat_c)
        got_c = execute_with_sketch(q, clustered_db, sk_c, catalog=cat_c).canonical()
        assert got_c == want, q.template
        assert cat_c.stats["instance_slices"] == 1
        assert cat_c.stats["instance_mask"] == 0

        # Both sketches describe the same fragments.
        np.testing.assert_array_equal(sk_u.bits, sk_c.bits)
        assert sk_u.size_rows == sk_c.size_rows


def test_cluster_by_layout_offsets(tpch_db):
    table = tpch_db["lineitem"]
    ranges = equi_depth_ranges(table, "l_suppkey", 32)
    clustered = table.cluster_by(ranges)
    layout = clustered.layout
    assert layout is not None and layout.matches(ranges)
    assert layout.offsets[0] == 0 and layout.offsets[-1] == table.num_rows
    # Every fragment slice is homogeneous in its bucket id.
    bucket = np.asarray(ranges.bucketize(clustered[ranges.attr]))
    for f in range(layout.n_fragments):
        lo, hi = layout.offsets[f], layout.offsets[f + 1]
        assert (bucket[lo:hi] == f).all()
    # Row-reordering ops drop the layout; with_column keeps it.
    assert clustered.gather(np.arange(10)).layout is None
    assert clustered.with_column("x", clustered["l_suppkey"]).layout is layout


def test_take_fragments_with_unsorted_tail(tpch_db):
    """Regression: take_fragments on a clustered+appended table used to raise
    ValueError; it must slice the covered prefix and bucket-filter the tail."""
    table = tpch_db["lineitem"]
    ranges = equi_depth_ranges(table, "l_suppkey", 16)
    clustered = table.cluster_by(ranges)
    batch = {a: np.asarray(table[a])[:500] for a in table.schema}
    appended = clustered.append(batch)
    assert appended.layout.tail == 500
    frag_ids = np.array([1, 3, 7])
    got = appended.take_fragments(frag_ids)
    # Oracle: all rows (prefix + tail) whose bucket is one of frag_ids.
    bucket = np.asarray(ranges.bucketize(appended["l_suppkey"]))
    want = int(np.isin(bucket, frag_ids).sum())
    assert got.num_rows == want
    assert np.isin(np.asarray(ranges.bucketize(got["l_suppkey"])), frag_ids).all()
    # Empty selection stays valid on a tailed table too.
    assert appended.take_fragments(np.empty(0, dtype=np.int64)).num_rows == 0


def test_index_hit_on_clustered_appended_table_serves(tpch_db):
    """Regression: an index hit after cluster+append must serve, not crash."""
    db = Database({"crimes": make_crimes(20_000, seed=11)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    q = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.9))))
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.1, seed=0,
                     cluster_tables=True)
    _, info = eng.run(q)
    assert info.created and eng.db["crimes"].layout is not None
    fresh = make_crimes(2_000, seed=99)
    eng.append_rows("crimes", {a: np.asarray(fresh[a]) for a in fresh.schema})
    assert eng.db["crimes"].layout.tail == 2_000
    res, info2 = eng.run(q)
    assert info2.reused and info2.repaired
    assert res.canonical() == execute(q, eng.db).canonical()


def test_tail_bucket_fallback_matches_f32_bucketize_semantics():
    """Host-side tail bucketing must compare in float32 like RangeSet.bucketize
    (jnp.searchsorted under disabled x64): a boundary value inside the f32
    rounding gap of a bound must land in the same fragment on both paths."""
    from repro.core import RangeSet, from_numpy

    t = from_numpy("t", {"a": np.array([1.0, 5.0, 9.0, 12.0]),
                         "v": np.ones(4)})
    ranges = RangeSet("a", np.array([10.0000001]))  # == 10.0 in float32
    clustered = t.cluster_by(ranges)
    # 10.0 is exact in f32; in f64 it is < the bound (fragment 0), in f32 it
    # equals the cast bound and side='right' puts it in fragment 1.
    appended = clustered.append({"a": np.array([10.0]), "v": np.array([1.0])})
    # jnp/f32 semantics put the tail row in fragment 1; f64 would say 0.
    assert np.asarray(ranges.bucketize(appended["a"]))[-1] == 1
    assert appended.take_fragments(np.array([1])).num_rows == 2
    assert appended.take_fragments(np.array([0])).num_rows == 3
    # compact() uses the same comparison: the row merges into fragment 1.
    compacted = appended.compact()
    off = compacted.layout.offsets
    assert off[1] == 3 and off[2] == 5


def test_compact_folds_tail_into_fragments(tpch_db):
    table = make_crimes(10_000, seed=13)
    ranges = equi_depth_ranges(table, "district", 12)
    clustered = table.cluster_by(ranges)
    batch_t = make_crimes(1_500, seed=14)
    appended = clustered.append({a: np.asarray(batch_t[a]) for a in batch_t.schema})
    compacted = appended.compact()
    assert compacted.layout is not None and compacted.layout.tail == 0
    assert compacted.num_rows == appended.num_rows
    assert compacted.uid == appended.uid and compacted.version == appended.version
    # Every fragment slice is homogeneous in its bucket id again.
    bucket = np.asarray(ranges.bucketize(compacted["district"]))
    off = compacted.layout.offsets
    for f in range(compacted.layout.n_fragments):
        assert (bucket[off[f]:off[f + 1]] == f).all()
    # Same multiset of rows: any grouped aggregate is unchanged.
    q = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    assert (execute(q, Database({"crimes": compacted})).canonical()
            == execute(q, Database({"crimes": appended})).canonical())
    # Compacting a tail-free table is a no-op permutation-wise.
    assert clustered.compact().layout.tail == 0


def test_engine_compacts_past_tail_threshold():
    db = Database({"crimes": make_crimes(20_000, seed=15)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    q = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.9))))
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.1, seed=0,
                     cluster_tables=True, compact_tail_frac=0.1)
    eng.run(q)
    fresh = make_crimes(5_000, seed=77)
    eng.append_rows("crimes", {a: np.asarray(fresh[a]) for a in fresh.schema})
    # 5k tail on 25k rows > 10%: compacted back to pure fragment-major.
    assert eng.db["crimes"].layout is not None
    assert eng.db["crimes"].layout.tail == 0
    assert eng.catalog.stats["compact"] == 1
    res, info = eng.run(q)
    assert info.reused
    assert res.canonical() == execute(q, eng.db).canonical()


@pytest.mark.parametrize("spec_name", ["crimes", "tpch_join"])
def test_second_workload_pass_does_zero_host_encode_work(spec_name):
    """Catalog reuse: replaying a workload hits caches only (no np.unique /
    np.argsort join work), and repeated sketch applications reuse instances.

    ``cluster_tables=False`` keeps the table object stable so the replay's
    counters isolate cache behaviour from the one-off physical re-layout
    (clustering + slicing is covered by the tests above/below).
    """
    if spec_name == "crimes":
        db = Database({"crimes": make_crimes(20_000, seed=5)})
        spec = CRIMES_SPEC
    else:
        db = make_tpch(20_000, seed=5)
        spec = TPCH_JOIN_SPEC
    wl = generate_workload(spec, db, 5, seed=5)
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.1, seed=0,
                     cluster_tables=False)
    for q in wl:
        eng.run(q)
    s1 = dict(eng.catalog.stats)
    infos = [eng.run(q)[1] for q in wl]
    s2 = dict(eng.catalog.stats)
    assert any(i.reused for i in infos)
    # Zero new host-side dictionary encodings, join argsorts, bucketizations,
    # or instance materializations on the replay.
    for counter in ("encode_groups", "join_materialize", "bucketize",
                    "instance_build", "distinct_count"):
        assert s2.get(counter, 0) == s1.get(counter, 0), counter
    assert s2.get("encode_groups_hit", 0) > s1.get("encode_groups_hit", 0)
    n_reused = sum(1 for i in infos if i.reused)
    assert s2.get("instance_hit", 0) - s1.get("instance_hit", 0) >= n_reused


def test_engine_clusters_fact_table_and_slices_on_reuse():
    db = Database({"crimes": make_crimes(20_000, seed=3)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    q = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.9))))
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.1, seed=0,
                     cluster_tables=True)
    res, info = eng.run(q)
    assert info.created
    # First created sketch re-clusters the fact table fragment-major, and the
    # warmed instance is built by slice concatenation, not a row scan.
    assert eng.db["crimes"].layout is not None
    assert eng.catalog.stats["instance_slices"] >= 1
    res2, info2 = eng.run(q)
    assert info2.reused
    assert res2.canonical() == execute(q, db).canonical() == res.canonical()


def test_where_mask_cache_hit_miss_and_delta_refresh():
    """Repeated WHERE predicates evaluate once per table version; appends and
    deletes refresh the cached mask from the delta, never a full re-eval."""
    from repro.core import Predicate

    t = make_crimes(8_000, seed=19)
    cat = Catalog()
    pred = Predicate("year", ">", 2015.0)
    m1 = cat.where_mask(t, pred)
    assert cat.stats["where_mask"] == 1 and cat.stats["where_mask_hit"] == 0
    m2 = cat.where_mask(t, pred)
    assert m2 is m1
    assert cat.stats["where_mask_hit"] == 1
    # A different predicate is a separate entry (same table).
    cat.where_mask(t, Predicate("year", ">", 2018.0))
    assert cat.stats["where_mask"] == 2

    # Append: batch-sized refresh, prefix comes from the parent's mask.
    batch_t = make_crimes(1_000, seed=20)
    t2 = t.append({a: np.asarray(batch_t[a]) for a in batch_t.schema})
    m3 = cat.where_mask(t2, pred)
    assert cat.stats["where_mask_delta"] == 1
    assert cat.stats["where_mask"] == 2  # no new full evaluation
    np.testing.assert_array_equal(np.asarray(m3), np.asarray(pred.mask(t2)))

    # Delete: gather of the kept rows.
    mask = np.zeros(t2.num_rows, dtype=bool)
    mask[::7] = True
    t3 = t2.delete(mask)
    m4 = cat.where_mask(t3, pred)
    assert cat.stats["where_mask_delta"] == 2
    assert cat.stats["where_mask"] == 2
    np.testing.assert_array_equal(np.asarray(m4), np.asarray(pred.mask(t3)))


def test_executor_uses_where_cache():
    """Replaying a WHERE query re-uses the cached mask (no re-evaluation)."""
    from repro.core import Predicate

    db = Database({"crimes": make_crimes(8_000, seed=23)})
    q = Query("crimes", ("district",), Aggregate("sum", "records"),
              where=Predicate("year", ">", 2015.0))
    cat = Catalog()
    want = execute(q, db, catalog=cat).canonical()
    assert cat.stats["where_mask"] == 1
    assert execute(q, db, catalog=cat).canonical() == want
    assert cat.stats["where_mask"] == 1
    assert cat.stats["where_mask_hit"] == 1


def test_catalog_group_encoding_identity():
    """Same (table, key) -> the identical cached encoding object."""
    t = make_crimes(3_000, seed=1)
    cat = Catalog()
    e1 = cat.groups(t, ("district", "year"))
    e2 = cat.groups(t, ("district", "year"))
    assert e1 is e2
    assert cat.stats["encode_groups"] == 1
    assert cat.stats["encode_groups_hit"] == 1
    # A different table object recomputes (identity-keyed invalidation).
    t2 = t.gather(np.arange(t.num_rows))
    e3 = cat.groups(t2, ("district", "year"))
    assert e3 is not e1
    assert cat.stats["encode_groups"] == 2


def test_batched_estimation_matches_reference():
    import jax

    from repro.aqp.sampling import stratified_reservoir_sample
    from repro.aqp.size_estimation import (
        approximate_query_result,
        estimate_size,
        estimate_size_batched,
    )

    db = Database({"crimes": make_crimes(20_000, seed=9)})
    q = Query("crimes", ("district", "year"), Aggregate("sum", "records"),
              having=Having(">", 400.0))
    key = jax.random.PRNGKey(0)
    samples = stratified_reservoir_sample(key, db["crimes"], q.groupby, 0.1)
    aqr = approximate_query_result(key, q, db, samples)
    cands = ["district", "year", "beat", "records"]
    ranges_by = {a: equi_depth_ranges(db["crimes"], a, 40) for a in cands}
    batched = estimate_size_batched(key, q, db, ranges_by, samples, aqr=aqr)
    for a in cands:
        ref = estimate_size(key, q, db, ranges_by[a], samples, aqr=aqr)
        got = batched[a]
        np.testing.assert_array_equal(got.est_bits, ref.est_bits)
        assert got.est_rows == pytest.approx(ref.est_rows, rel=1e-5)
        assert got.expected_rows == pytest.approx(ref.expected_rows, rel=1e-4)
        assert got.lo_rows == pytest.approx(ref.lo_rows, rel=1e-4)
        assert got.hi_rows == pytest.approx(ref.hi_rows, rel=1e-4)


def test_incidence_pass_pow2_padding_avoids_retrace():
    """Candidate sets whose pair counts AND fragment counts differ must land
    in one compiled size class: pairs, fragment axis and the leading
    (query x candidate) axis are all pow2-quantized, asserted via the shared
    launch guard over the trace-time counter (``_incidence_pass`` bodies run
    only when jit misses).  A global retrace guard is too broad here: the
    per-``n_ranges`` boundary helpers (quantiles, searchsorted over 33/56
    boundaries) legitimately compile per size.
    """
    import jax

    from repro.aqp.sampling import stratified_reservoir_sample
    from repro.aqp.size_estimation import (
        TRACE_COUNTS,
        approximate_query_result,
        estimate_size_batched,
    )
    from repro.runtime.guards import launch_guard

    db = Database({"crimes": make_crimes(20_000, seed=9)})
    q = Query("crimes", ("district", "year"), Aggregate("sum", "records"),
              having=Having(">", 400.0))
    key = jax.random.PRNGKey(0)
    samples = stratified_reservoir_sample(key, db["crimes"], q.groupby, 0.1)
    aqr = approximate_query_result(key, q, db, samples)
    cands = ["district", "year", "beat"]

    def estimate(n_ranges):
        ranges_by = {a: equi_depth_ranges(db["crimes"], a, n_ranges)
                     for a in cands}
        return estimate_size_batched(key, q, db, ranges_by, samples, aqr=aqr)

    estimate(40)  # warm: one trace for this size class
    # 33..56 ranges all pad to the same pow2 fragment axis (64); satisfied
    # pair counts shift a little but stay inside one pow2 pair class.
    with launch_guard("incidence_pass", expect=0, counter=TRACE_COUNTS):
        estimate(33)
        estimate(56)
        estimate(40)


def test_frag_of_group_cached_per_table_version():
    """The GB fast-path fragment-of-group vector bucketizes once per
    (table version, group-by, partition) and then serves from the catalog."""
    import jax

    from repro.aqp.sampling import stratified_reservoir_sample
    from repro.aqp.size_estimation import approximate_query_result, estimate_size_batched

    db = Database({"crimes": make_crimes(20_000, seed=9)})
    q = Query("crimes", ("district", "year"), Aggregate("sum", "records"),
              having=Having(">", 400.0))
    key = jax.random.PRNGKey(0)
    samples = stratified_reservoir_sample(key, db["crimes"], q.groupby, 0.1)
    aqr = approximate_query_result(key, q, db, samples)
    ranges_by = {a: equi_depth_ranges(db["crimes"], a, 40)
                 for a in ("district", "year")}
    cat = Catalog()
    estimate_size_batched(key, q, db, ranges_by, samples, aqr=aqr, catalog=cat)
    assert cat.stats["frag_of_group"] == 2  # one per partition
    assert cat.stats["frag_of_group_hit"] == 0
    estimate_size_batched(key, q, db, ranges_by, samples, aqr=aqr, catalog=cat)
    assert cat.stats["frag_of_group"] == 2  # no re-bucketize on replay
    assert cat.stats["frag_of_group_hit"] == 2
    # A new table version recomputes (the group dictionary may have grown).
    t2 = db["crimes"].append(
        {a: np.asarray(db["crimes"][a])[:100] for a in db["crimes"].schema})
    db2 = Database({"crimes": t2})
    from repro.aqp.sampling import extend_sample_for_append

    samples2 = extend_sample_for_append(
        key, samples, (t2.delta.appended,), (db["crimes"].num_rows,))
    aqr2 = approximate_query_result(key, q, db2, samples2)
    estimate_size_batched(key, q, db2, ranges_by, samples2, aqr=aqr2, catalog=cat)
    assert cat.stats["frag_of_group"] == 4


def test_benchmark_timeit_blocks_nested_results():
    from benchmarks.common import block_until_ready

    t = make_crimes(500, seed=0)
    res = execute(Query("crimes", ("district",), Aggregate("count", None)),
                  Database({"crimes": t}))
    # Dataclasses, dicts, lists and device arrays all traverse without error.
    block_until_ready({"res": res, "tables": [t], "arr": t["records"]})
