"""Frame codec + socket transport (``repro.runtime.transport``).

The shard RPC rides on this: pickle-5 messages with out-of-band array
buffers, length-prefixed frames with a magic/seq header, per-op deadlines,
and hard frame-size bounds.  Everything here runs over ``socketpair`` — no
subprocesses — so it pins the codec independently of the server loop.
"""
import socket
import threading

import numpy as np
import pytest

from repro.runtime import transport


def _roundtrip_codec(obj):
    return transport.decode_message(
        [bytes(p) for p in transport.encode_message(obj)])


def test_codec_roundtrips_plain_and_array_payloads():
    obj = {
        "op": "ship",
        "args": (3, "append", {"a": np.arange(7, dtype=np.int64),
                               "b": np.linspace(0, 1, 7, dtype=np.float32)}),
        "mask": np.array([True, False, True]),
    }
    out = _roundtrip_codec(obj)
    assert out["op"] == "ship" and out["args"][0] == 3
    np.testing.assert_array_equal(out["args"][2]["a"], obj["args"][2]["a"])
    np.testing.assert_array_equal(out["args"][2]["b"], obj["args"][2]["b"])
    assert out["args"][2]["b"].dtype == np.float32
    np.testing.assert_array_equal(out["mask"], obj["mask"])


def test_codec_lowers_jax_arrays_to_numpy():
    import jax.numpy as jnp

    arr = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    out = _roundtrip_codec({"x": arr, "nested": [arr * 2]})
    # Device arrays cross the wire as host numpy (the peer has its own
    # devices); values and dtype are preserved exactly.
    assert isinstance(out["x"], np.ndarray)
    np.testing.assert_array_equal(out["x"], np.asarray(arr))
    np.testing.assert_array_equal(out["nested"][0], np.asarray(arr) * 2)


def test_send_recv_roundtrip_over_socketpair():
    a, b = socket.socketpair()
    try:
        payload = {"v": np.arange(1000), "s": "hello"}
        transport.send_msg(a, payload, seq=42, deadline_s=5.0)
        seq, out = transport.recv_msg(b, deadline_s=5.0)
        assert seq == 42
        np.testing.assert_array_equal(out["v"], payload["v"])
        assert out["s"] == "hello"
        # Multiple messages in flight keep their framing.
        for i in range(5):
            transport.send_msg(a, {"i": i}, seq=i)
        for i in range(5):
            seq, out = transport.recv_msg(b, deadline_s=5.0)
            assert (seq, out["i"]) == (i, i)
    finally:
        a.close()
        b.close()


def test_recv_deadline_raises_timeout():
    a, b = socket.socketpair()
    try:
        with pytest.raises(transport.RpcTimeout):
            transport.recv_msg(b, deadline_s=0.05)
    finally:
        a.close()
        b.close()


def test_recv_partial_frame_then_close_raises_closed():
    a, b = socket.socketpair()
    try:
        a.sendall(transport.MAGIC)  # header cut short
        a.close()
        with pytest.raises(transport.RpcClosed):
            transport.recv_msg(b, deadline_s=5.0)
    finally:
        b.close()


def test_bad_magic_is_a_frame_error():
    a, b = socket.socketpair()
    try:
        a.sendall(b"XXXX" + b"\x00" * (transport._HDR.size - 4))
        with pytest.raises(transport.FrameError):
            transport.recv_msg(b, deadline_s=5.0)
    finally:
        a.close()
        b.close()


def test_bit_flip_in_payload_is_a_frame_error_not_a_pickle_error():
    """Flip one bit anywhere in a framed message: the crc32 check must
    surface ``FrameError`` at the boundary (which the RPC client maps to
    ``ShardUnavailableError``), never an arbitrary unpickling exception."""
    payload = {"op": "partial", "bits": np.arange(256, dtype=np.int64)}
    parts = transport.encode_message(payload)
    lens = b"".join(len(p).to_bytes(8, "big") for p in parts)
    import zlib

    crc = zlib.crc32(lens)
    for p in parts:
        crc = zlib.crc32(bytes(p), crc)
    frame = bytearray(
        transport._HDR.pack(transport.MAGIC, 9, len(parts) - 1, crc) + lens
        + b"".join(bytes(p) for p in parts))
    body_start = transport._HDR.size + len(lens)
    # Corrupt a byte in the pickle header region and one deep in the array
    # buffer — both must be caught by the same check.
    for flip_at in (body_start + 2, len(frame) - 16):
        corrupt = bytearray(frame)
        corrupt[flip_at] ^= 0x10
        a, b = socket.socketpair()
        try:
            a.sendall(corrupt)
            with pytest.raises(transport.FrameError, match="crc mismatch"):
                transport.recv_msg(b, deadline_s=5.0)
        finally:
            a.close()
            b.close()
    # Sanity: the untouched frame still decodes.
    a, b = socket.socketpair()
    try:
        a.sendall(frame)
        seq, out = transport.recv_msg(b, deadline_s=5.0)
        assert seq == 9
        np.testing.assert_array_equal(out["bits"], payload["bits"])
    finally:
        a.close()
        b.close()


def test_corrupt_frame_surfaces_as_shard_unavailable_in_rpc_client():
    """The shard RPC layer's contract for satellite-level integrity: a
    corrupt frame from a server becomes the serving layer's retryable
    ``ShardUnavailableError``, not a codec exception."""
    from repro.core.shard import ShardUnavailableError
    from repro.core.shard_rpc import _ServerProc

    class _FakeProc:
        def poll(self):
            return None

        pid = 0

    a, b = socket.socketpair()
    try:
        sp = _ServerProc.__new__(_ServerProc)
        sp.proc = _FakeProc()
        sp.path = "<socketpair>"
        sp.conn = a
        import itertools

        sp._seq = itertools.count(1)

        def corrupt_responder():
            try:
                transport.recv_msg(b, deadline_s=5.0)
                parts = transport.encode_message({"ok": True, "value": None})
                lens = b"".join(len(p).to_bytes(8, "big") for p in parts)
                body = b"".join(bytes(p) for p in parts)
                # Deliberately wrong crc: emulates wire corruption.
                b.sendall(transport._HDR.pack(
                    transport.MAGIC, 1, len(parts) - 1, 0xDEADBEEF)
                    + lens + body)
            except transport.TransportError:
                pass

        t = threading.Thread(target=corrupt_responder, daemon=True)
        t.start()
        with pytest.raises(ShardUnavailableError):
            sp.request({"op": "ping", "args": (), "ctl": True}, deadline_s=5.0)
        t.join(timeout=5.0)
    finally:
        a.close()
        b.close()


def test_oversized_frame_refused_on_both_sides():
    a, b = socket.socketpair()
    big = np.zeros(1 << 20, dtype=np.uint8)
    try:
        with pytest.raises(transport.FrameError):
            transport.send_msg(a, {"x": big}, seq=1, max_frame_bytes=1024)
        # Receive side refuses from the length prefix, before allocation
        # (sender threaded: 1 MiB overflows the socketpair buffer, and the
        # receiver bails without ever draining the body).
        def send_big():
            try:
                transport.send_msg(a, {"x": big}, seq=1, deadline_s=5.0)
            except transport.TransportError:
                pass  # receiver bailed and closed: expected

        t = threading.Thread(target=send_big, daemon=True)
        t.start()
        with pytest.raises(transport.FrameError):
            transport.recv_msg(b, deadline_s=5.0, max_frame_bytes=1024)
    finally:
        a.close()
        b.close()
        t.join(timeout=5.0)


def test_deadline_bounds_a_stalled_peer_mid_message():
    a, b = socket.socketpair()
    done = threading.Event()

    def slow_sender():
        # Send only the header+lens, never the body: the receiver must not
        # block past its deadline waiting for the rest.
        parts = transport.encode_message({"x": np.arange(100)})
        lens = b"".join(len(p).to_bytes(8, "big") for p in parts)
        a.sendall(transport._HDR.pack(transport.MAGIC, 7, len(parts) - 1, 0))
        a.sendall(lens)
        done.wait(2.0)

    t = threading.Thread(target=slow_sender, daemon=True)
    t.start()
    try:
        with pytest.raises(transport.RpcTimeout):
            transport.recv_msg(b, deadline_s=0.2)
    finally:
        done.set()
        t.join(timeout=2.0)
        a.close()
        b.close()
