"""Infrastructure layers: sharding rules, checkpointing, elastic planning,
straggler detection, retries, data pipeline, HLO stats parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.models.params import P
from repro.parallel.sharding import batch_axes, spec_for


class FakeMesh:
    """Duck-typed mesh for rule tests (axis_names + devices.shape)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_for_fsdp_tp():
    p = P((4096, 32, 128), ("embed", "q_heads", "head_dim"))
    assert spec_for(p, MESH) == PartitionSpec("data", "model", None)
    assert spec_for(p, MESH3) == PartitionSpec(("pod", "data"), "model", None)


def test_spec_for_indivisible_replicates():
    p = P((4096, 40, 128), ("embed", "q_heads", "head_dim"))  # 40 % 16 != 0
    assert spec_for(p, MESH) == PartitionSpec("data", None, None)
    p2 = P((100, 7), ("embed", "ffn"))  # 100 % 16 != 0, 7 % 16 != 0
    assert spec_for(p2, MESH) == PartitionSpec(None, None)


def test_spec_for_never_reuses_axis():
    p = P((2048, 2048), ("ffn", "ffn"))
    s = spec_for(p, MESH)
    axes = [a for a in s if a is not None]
    assert len(axes) <= 1


def test_batch_axes():
    assert batch_axes(MESH, 64) == "data"
    assert batch_axes(MESH, 7) is None
    assert batch_axes(MESH3, 64) == ("pod", "data")
    assert batch_axes(MESH3, 16) == "data"  # not divisible by 32, but by 16


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager

    state = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.array(7)}
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(1, state, extra={"step": 1})
    mgr.save(2, jax.tree_util.tree_map(lambda x: x + 1, state), extra={"step": 2})
    mgr.save(3, jax.tree_util.tree_map(lambda x: x + 2, state), extra={"step": 3})
    assert mgr.all_steps() == [2, 3]  # keep-last-2 GC
    restored, extra = mgr.restore(state)
    assert extra["step"] == 3
    np.testing.assert_allclose(np.asarray(restored["w"]), np.arange(12.0).reshape(3, 4) + 2)
    # no stray tmp dirs (atomic publish)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_checkpoint_resume_training_equivalence(tmp_path):
    """Train 4 steps straight == train 2, checkpoint, restore, train 2."""
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.optim.adamw import OptConfig
    from repro.train.step import TrainSpec, init_train_state, make_train_step, microbatch_reshape

    cfg = get_config("stablelm-1.6b", smoke=True)
    spec = TrainSpec(microbatch=1, opt=OptConfig(total_steps=10))
    step = jax.jit(make_train_step(cfg, spec))

    def batches(n):
        return [
            microbatch_reshape(
                {"tokens": jax.random.randint(jax.random.PRNGKey(100 + i), (2, 16), 0, cfg.vocab_size)}, 1
            )
            for i in range(n)
        ]

    bs = batches(4)
    s_a = init_train_state(jax.random.PRNGKey(1), cfg, spec)
    for b in bs:
        s_a, _ = step(s_a, b)

    s_b = init_train_state(jax.random.PRNGKey(1), cfg, spec)
    for b in bs[:2]:
        s_b, _ = step(s_b, b)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(2, s_b)
    s_b2, _ = mgr.restore(s_b)
    for b in bs[2:]:
        s_b2, _ = step(s_b2, b)

    la = jax.tree_util.tree_leaves(s_a["params"])
    lb = jax.tree_util.tree_leaves(s_b2["params"])
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6)


def test_elastic_remesh_planning():
    from repro.runtime import feasible_mesh_shape, plan_remesh

    # full fleet
    assert feasible_mesh_shape(256, 16) == (16, 16)
    # lose a host (8 devices): keep TP=16, shrink DP
    shape = feasible_mesh_shape(248, 16)
    assert shape == (15, 16)
    # multi-pod preference
    assert feasible_mesh_shape(512, 16, prefer_pods=2) == (2, 16, 16)
    plan = plan_remesh(248, 16, global_batch=256, old_n_micro=4, old_data_extent=16)
    assert plan is not None
    assert plan.mesh_shape == (15, 16)
    mb = 256 // plan.n_micro
    assert mb % 15 == 0 or plan.n_micro == 256  # microbatch shardable on new DP
    # catastrophic loss: fewer devices than TP extent
    assert feasible_mesh_shape(8, 16) is None


def test_straggler_monitor():
    from repro.runtime import StragglerMonitor

    mon = StragglerMonitor(window=16, threshold=2.0)
    flags = [mon.observe(0.1) for _ in range(10)]
    assert not any(flags)
    assert mon.observe(0.5)  # 5x median
    assert not mon.observe(0.11)


def test_retries():
    from repro.runtime import RetryPolicy, with_retries

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert with_retries(flaky, RetryPolicy(max_attempts=3, backoff_s=0.0)) == "ok"
    assert calls["n"] == 3
    with pytest.raises(RuntimeError):
        with_retries(lambda: (_ for _ in ()).throw(RuntimeError("x")).__next__(),
                     RetryPolicy(max_attempts=2, backoff_s=0.0))


def test_data_pipeline_determinism_and_skipping():
    from repro.data import CurationSpec, SketchedDataPipeline, make_corpus_metadata
    from repro.core.queries import provenance_mask

    meta = make_corpus_metadata(n_docs=3_000, seed=1)
    spec = CurationSpec(having_value=0.55)
    p1 = SketchedDataPipeline(meta, spec, 8, 32, 1000, seed=42)
    p2 = SketchedDataPipeline(meta, spec, 8, 32, 1000, seed=42)
    b1, b2 = next(iter(p1)), next(iter(p2))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert 0.0 < p1.skipped_fraction < 1.0
    # Sketch-selected docs are a superset of the exact curation provenance.
    from repro.core.table import Database

    prov = provenance_mask(spec.query(), Database({"corpus": meta}))
    prov_docs = set(np.asarray(meta["doc_id"])[prov].tolist())
    assert prov_docs <= set(p1.selected_docs.tolist())
    # Regression: pow2-padded sketch instances duplicate masked rows — the
    # pipeline must filter them out, never oversample a document.
    assert len(p1.selected_docs) == len(set(p1.selected_docs.tolist()))


def test_data_pipeline_resume():
    from repro.data import CurationSpec, SketchedDataPipeline, make_corpus_metadata

    meta = make_corpus_metadata(n_docs=2_000, seed=2)
    p1 = SketchedDataPipeline(meta, CurationSpec(), 4, 16, 1000, seed=7)
    it = iter(p1)
    next(it)
    st = p1.state()
    want = next(it)
    p2 = SketchedDataPipeline(meta, CurationSpec(), 4, 16, 1000, seed=7)
    p2.restore(st)
    got = next(iter(p2))
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_dp_rank_sharding_disjoint():
    from repro.data import CurationSpec, SketchedDataPipeline, make_corpus_metadata

    meta = make_corpus_metadata(n_docs=2_000, seed=3)
    parts = []
    for r in range(4):
        p = SketchedDataPipeline(meta, CurationSpec(), 16, 8, 1000, dp_rank=r, dp_size=4, seed=5)
        parts.append(next(iter(p))["tokens"])
    stacked = np.concatenate(parts, 0)
    assert len(np.unique(stacked[:, 0])) >= len(stacked) // 2  # mostly distinct docs


def test_hlo_stats_parser():
    from repro.launch.hlo_stats import analyze_hlo

    hlo = """
HloModule test

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %ar = f32[8,128] all-reduce(%gte), channel_id=1, to_apply=%sum
  ROOT %t = (s32[], f32[8,128]) tuple(%i, %ar)
}

%cond (p2: (s32[], f32[8,128])) -> pred[] {
  %p2 = (s32[], f32[8,128]) parameter(0)
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%gte2, %c), direction=LT
}

ENTRY %main (a: f32[128,256], b: f32[256,64]) -> f32[128,64] {
  %a = f32[128,256] parameter(0)
  %b = f32[256,64] parameter(1)
  %w = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"24"}}
  ROOT %d = f32[128,64] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    res = analyze_hlo(hlo)
    # dot: 2*128*64*256 flops
    assert res["dot_flops"] == 2 * 128 * 64 * 256
    # all-reduce inside 24-trip while: 24 * 8*128*4 bytes
    assert res["collective_bytes"] == 24 * 8 * 128 * 4
