"""Per-kernel allclose vs the pure-jnp oracle, sweeping shapes & dtypes.
Pallas kernels run in interpret mode on CPU (the TPU lowering is exercised on
real hardware; interpret mode executes the same kernel body)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n", [17, 1000, 5000])
@pytest.mark.parametrize("n_ranges", [3, 100, 1000])
def test_fragment_bitmap(n, n_ranges):
    bucket = jnp.asarray(RNG.integers(0, n_ranges, n).astype(np.int32))
    prov = jnp.asarray(RNG.random(n) < 0.05)
    got = ops.fragment_bitmap(prov, bucket, n_ranges, backend="interpret")
    want = ref.fragment_bitmap_ref(prov, bucket, n_ranges)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fragment_bitmap_empty_provenance():
    bucket = jnp.asarray(RNG.integers(0, 10, 100).astype(np.int32))
    prov = jnp.zeros(100, bool)
    got = ops.fragment_bitmap(prov, bucket, 10, backend="interpret")
    assert not np.asarray(got).any()


@pytest.mark.parametrize("n", [64, 2048, 4097])
@pytest.mark.parametrize("n_ranges", [7, 129, 1000])
def test_sketch_filter(n, n_ranges):
    bucket = jnp.asarray(RNG.integers(0, n_ranges, n).astype(np.int32))
    bits = jnp.asarray(RNG.random(n_ranges) < 0.4)
    got = ops.sketch_filter(bucket, bits, backend="interpret")
    want = ref.sketch_filter_ref(bucket, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,g", [(100, 5), (3000, 700), (2048, 512)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_segment_aggregate(n, g, dtype):
    gid = jnp.asarray(RNG.integers(0, g, n).astype(np.int32))
    vals = jnp.asarray(RNG.normal(0, 10, n).astype(dtype))
    w = jnp.asarray((RNG.random(n) < 0.5).astype(np.float32))
    s1, c1 = ops.segment_aggregate(vals, gid, g, w, backend="interpret")
    s2, c2 = ref.segment_aggregate_ref(vals, gid, g, w)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)


@pytest.mark.parametrize("b,n,g", [(1, 100, 5), (4, 700, 130), (3, 2048, 512)])
def test_segment_aggregate_batch(b, n, g):
    """Batched kernel == ref == per-row unbatched kernel, bit-for-bit on
    integral f32 inputs (the sharded fused launch's exactness envelope)."""
    gid = jnp.asarray(RNG.integers(0, g, (b, n)).astype(np.int32))
    vals = jnp.asarray(RNG.integers(0, 100, (b, n)).astype(np.float32))
    w = jnp.asarray((RNG.random((b, n)) < 0.5).astype(np.float32))
    s1, c1 = ops.segment_aggregate_batch(vals, gid, g, w, backend="interpret")
    s2, c2 = ref.segment_aggregate_batch_ref(vals, gid, g, w)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    for i in range(b):
        s3, c3 = ops.segment_aggregate(vals[i], gid[i], g, w[i], backend="ref")
        np.testing.assert_array_equal(np.asarray(s2[i]), np.asarray(s3))
        np.testing.assert_array_equal(np.asarray(c2[i]), np.asarray(c3))


def test_segment_aggregate_matches_engine_groupby():
    """Kernel path == the executor's segment aggregation."""
    from repro.core.datasets import make_crimes
    from repro.core.table import encode_groups

    t = make_crimes(4_000, seed=2)
    gid, g, _ = encode_groups(t, ("district", "year"))
    s1, c1 = ops.segment_aggregate(t["records"], jnp.asarray(gid), g, backend="interpret")
    want = np.bincount(gid, weights=np.asarray(t["records"], np.float64), minlength=g)
    np.testing.assert_allclose(np.asarray(s1), want, rtol=1e-4)


@pytest.mark.parametrize("s,t", [(64, 64), (96, 96), (1, 96)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(s, t, causal, window, dtype):
    if s > t:
        pytest.skip("q longer than kv")
    b, h, d = 2, 3, 64
    q = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, h, t, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, h, t, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window, backend="interpret")
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_chunked_attention_matches_ref():
    """The XLA chunked (flash-schedule) attention used by the models."""
    from repro.models.layers import gqa_chunked

    b, s, hq, hkv, d = 2, 96, 8, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(4), (b, s, hq, d))
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, hkv, d))
    got = gqa_chunked(q, k, v, causal=True, chunk=32)
    # oracle via flash ref with repeated kv heads
    kr = jnp.repeat(k, hq // hkv, axis=2)
    vr = jnp.repeat(v, hq // hkv, axis=2)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), kr.transpose(0, 2, 1, 3), vr.transpose(0, 2, 1, 3),
        causal=True,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)


def test_chunked_attention_sliding_window():
    from repro.models.layers import gqa_chunked

    b, s, h, d = 1, 64, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(7), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(8), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(9), (b, s, h, d))
    got = gqa_chunked(q, k, v, causal=True, window=16, chunk=32)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, window=16,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=2e-3)
