"""Differential workload-replay harness for incremental sketch maintenance.

The contract under test: after any interleaving of appends, deletes and
queries, the *maintained* path (``ColumnTable.append/delete`` deltas +
``SketchMaintainer`` counter updates + engine repair-on-hit) is
indistinguishable from a from-scratch re-capture oracle —

  * maintained sketch bits == ``capture_sketch`` on the mutated data,
  * query results through the maintained sketch == NO-PS execution,
  * and the delta path does *zero* full-table re-bucketization / re-encoding
    (asserted via catalog miss counters).

The oracle keeps plain numpy columns and rebuilds a fresh ``Database`` (and a
fresh ``Catalog``) for every check, so nothing incremental can leak into it.
Mutations are specified *by value* (delete-by-predicate, generated append
batches) so the engine's physically re-clustered tables and the oracle's
logical row order stay comparable.

Data is integer-valued and small enough that every group aggregate is exact
in float32, making bit-for-bit equality between the maintained float64
counters and the executor's float32 kernel arithmetic well-defined.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Catalog,
    Database,
    Having,
    JoinSpec,
    Predicate,
    Query,
    SelectionConfig,
    build_maintainer,
    capture_sketch,
    equi_depth_ranges,
    execute,
    execute_with_sketch,
    from_numpy,
    monotone_safe,
)
from repro.core.engine import PBDSEngine

N_DIM = 200


def _mk_batch(rng, n):
    return dict(
        s_key=rng.integers(1, N_DIM + 1, n).astype(np.int32),
        s_grp=rng.integers(0, 12, n).astype(np.int32),
        s_sub=rng.integers(0, 6, n).astype(np.int32),
        s_attr=rng.integers(0, 240, n).astype(np.int32),
        s_val=rng.integers(0, 40, n).astype(np.int32),
    )


def _mk_dim(seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        d_key=np.arange(1, N_DIM + 1, dtype=np.int32),
        d_w=rng.integers(0, 10, N_DIM).astype(np.int32),
    )


def _oracle_db(fact_np, dim_np):
    return Database({"sales": from_numpy("sales", fact_np),
                     "dim": from_numpy("dim", dim_np)})


def _threshold(q, db, quantile):
    vals = execute(dataclasses.replace(q, having=None, outer_having=None), db).values
    if len(vals) == 0:
        return 0.0
    return float(np.quantile(vals, quantile))


def _templates(db, rng):
    """One calibrated query per supported template (plus a WHERE variant)."""
    agh = Query("sales", ("s_grp",), Aggregate("sum", "s_val"))
    agh = dataclasses.replace(agh, having=Having(">", _threshold(agh, db, 0.6)))

    agh_w = Query("sales", ("s_grp",), Aggregate("count", None),
                  where=Predicate("s_sub", ">=", 3.0))
    agh_w = dataclasses.replace(agh_w, having=Having(">", _threshold(agh_w, db, 0.6)))

    ajgh = Query("sales", ("s_grp",), Aggregate("sum", "s_val"),
                 join=JoinSpec("dim", "s_key", "d_key"))
    ajgh = dataclasses.replace(ajgh, having=Having(">", _threshold(ajgh, db, 0.6)))

    aagh = Query("sales", ("s_grp", "s_sub"), Aggregate("sum", "s_val"),
                 having=Having(">", 0.0),
                 outer_groupby=("s_grp",), outer_agg=Aggregate("sum", None))
    aagh = dataclasses.replace(aagh, outer_having=Having(">", _threshold(aagh, db, 0.6)))

    aajgh = Query("sales", ("s_grp", "s_sub"), Aggregate("sum", "s_val"),
                  join=JoinSpec("dim", "s_key", "d_key"),
                  having=Having(">", 0.0),
                  outer_groupby=("s_grp",), outer_agg=Aggregate("sum", None))
    aajgh = dataclasses.replace(
        aajgh, outer_having=Having(">", _threshold(aajgh, db, 0.6)))
    qs = [agh, agh_w, ajgh, aagh, aajgh]
    assert {q.template for q in qs} == {"Q-AGH", "Q-AJGH", "Q-AAGH", "Q-AAJGH"}
    return qs


def _delete_predicate(rng, fact_np):
    """A value-based deletion predicate removing a small-ish row fraction."""
    kind = rng.integers(0, 3)
    if kind == 0:
        lo = int(rng.integers(0, 200))
        return lambda cols: (cols["s_attr"] >= lo) & (cols["s_attr"] < lo + 30)
    if kind == 1:
        g = int(rng.integers(0, 12))
        return lambda cols: cols["s_grp"] == g
    v = int(rng.integers(1, 7))
    return lambda cols: (cols["s_key"] % 13 == v)


# ---------------------------------------------------------------------------
# 1. Maintainer-level differential replay: >= 200 randomized op sequences.
# ---------------------------------------------------------------------------


def _replay_one_sequence(seed: int, clustered: bool) -> None:
    rng = np.random.default_rng(seed)
    fact_np = _mk_batch(rng, 500)
    dim_np = _mk_dim()
    db0 = _oracle_db(fact_np, dim_np)
    qs = _templates(db0, rng)
    q = qs[int(rng.integers(0, len(qs)))]

    # Sketch attribute: a GROUP BY attr is always safe; a non-GB attr only for
    # monotone-safe queries.
    attrs = ["s_grp"] + (["s_attr"] if monotone_safe(q, db0) else [])
    attr = attrs[int(rng.integers(0, len(attrs)))]

    cat = Catalog()
    t = db0["sales"]
    ranges = equi_depth_ranges(t, attr, int(rng.integers(6, 16)))
    if clustered:
        t = t.cluster_by(ranges)
    db = db0.with_table(t)
    m = build_maintainer(q, db, ranges, cat)

    n_ops = int(rng.integers(4, 8))
    for _ in range(n_ops):
        op = rng.choice(["append", "delete", "query"], p=[0.4, 0.3, 0.3])
        if op == "append":
            batch = _mk_batch(rng, int(rng.integers(20, 100)))
            t = t.append(batch)
            fact_np = {k: np.concatenate([fact_np[k], batch[k]]) for k in fact_np}
        elif op == "delete":
            pred = _delete_predicate(rng, fact_np)
            t_cols = {k: np.asarray(t[k]) for k in ("s_attr", "s_grp", "s_key")}
            mask = pred(t_cols)
            if mask.all():  # never delete the whole table
                continue
            t = t.delete(mask)
            o_mask = pred(fact_np)
            fact_np = {k: v[~o_mask] for k, v in fact_np.items()}
        db = db.with_table(t)
        m.apply(t, db)

        odb = _oracle_db(fact_np, dim_np)
        oracle = capture_sketch(q, odb, ranges, catalog=Catalog())
        np.testing.assert_array_equal(
            m.bits(), oracle.bits,
            err_msg=f"seed={seed} clustered={clustered} tmpl={q.template} attr={attr} op={op}")
        if op == "query":
            sk = m.to_sketch(t, cat)
            assert sk.size_rows == oracle.size_rows
            got = execute_with_sketch(q, db, sk, catalog=cat).canonical()
            assert got == execute(q, odb).canonical(), (
                f"seed={seed} clustered={clustered} tmpl={q.template}")


@pytest.mark.parametrize("clustered", [False, True], ids=["unclustered", "clustered"])
@pytest.mark.parametrize("block", range(10))
def test_differential_replay_maintainer(block, clustered):
    """>= 200 randomized op sequences: 10 blocks x 10 seeds x 2 layouts."""
    for seed in range(block * 10, block * 10 + 10):
        _replay_one_sequence(seed, clustered)


# ---------------------------------------------------------------------------
# 2. Engine-level differential replay: repair-on-hit through the full stack.
# ---------------------------------------------------------------------------


def _engine_replay(seed: int, clustered: bool) -> PBDSEngine:
    rng = np.random.default_rng(1000 + seed)
    fact_np = _mk_batch(rng, 900)
    dim_np = _mk_dim()
    db = _oracle_db(fact_np, dim_np)
    qs = _templates(db, rng)
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.3, seed=seed,
                     min_selectivity_gain=2.0, cluster_tables=clustered)

    n_repaired = 0
    for _ in range(12):
        op = rng.choice(["append", "delete", "query"], p=[0.25, 0.2, 0.55])
        if op == "append":
            batch = _mk_batch(rng, int(rng.integers(30, 150)))
            eng.append_rows("sales", batch)
            fact_np = {k: np.concatenate([fact_np[k], batch[k]]) for k in fact_np}
        elif op == "delete":
            pred = _delete_predicate(rng, fact_np)
            cols = {k: np.asarray(eng.db["sales"][k]) for k in ("s_attr", "s_grp", "s_key")}
            mask = pred(cols)
            if mask.all():
                continue
            eng.delete_rows("sales", mask)
            o_mask = pred(fact_np)
            fact_np = {k: v[~o_mask] for k, v in fact_np.items()}
        else:
            q = qs[int(rng.integers(0, len(qs)))]
            res, info = eng.run(q)
            odb = _oracle_db(fact_np, dim_np)
            assert res.canonical() == execute(q, odb).canonical(), (
                f"seed={seed} clustered={clustered} tmpl={q.template} reused={info.reused}")
            n_repaired += info.repaired
            # Every entry the engine just brought current must carry exactly
            # the oracle's bits.
            for e in eng.index.entries():
                if e.sketch.current_for(eng.db["sales"]):
                    osk = capture_sketch(e.query, odb, e.sketch.ranges, catalog=Catalog())
                    np.testing.assert_array_equal(
                        e.sketch.bits, osk.bits,
                        err_msg=f"seed={seed} clustered={clustered} tmpl={e.query.template}")
    return eng


@pytest.mark.parametrize("clustered", [False, True], ids=["unclustered", "clustered"])
def test_differential_replay_engine(clustered):
    repaired = maintained = 0
    for seed in range(4):
        eng = _engine_replay(seed, clustered)
        maintained += eng.catalog.stats.get("sketch_maintained", 0)
        repaired += eng.catalog.stats.get("sketch_maintained", 0) \
            + eng.catalog.stats.get("sketch_recaptured", 0)
    # The replay must actually exercise the repair path, and mostly through
    # maintenance rather than the re-capture fallback.
    assert repaired > 0
    assert maintained > 0


# ---------------------------------------------------------------------------
# 3. The delta path does zero full-table host work (miss counters).
# ---------------------------------------------------------------------------


def test_maintained_append_does_zero_full_table_rebucketization():
    rng = np.random.default_rng(7)
    fact_np = _mk_batch(rng, 2_000)
    db = _oracle_db(fact_np, _mk_dim())
    q = _templates(db, rng)[0]
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.3, seed=0,
                     min_selectivity_gain=2.0)
    _, info = eng.run(q)
    assert info.created

    before = dict(eng.catalog.stats)
    for _ in range(3):
        eng.append_rows("sales", _mk_batch(rng, 100))
        _, info = eng.run(q)
        assert info.reused and info.repaired
    after = dict(eng.catalog.stats)

    # Full-table host work is frozen; only *_delta counters may grow.  (The
    # group re-encode of each repair's freshly materialized *instance* is
    # execution work proportional to the skipped-down instance, not the table,
    # so ``encode_groups`` is bounded by one per repair rather than frozen.)
    for counter in ("bucketize", "fragment_sizes", "join_materialize"):
        assert after.get(counter, 0) == before.get(counter, 0), counter
    assert after.get("encode_groups", 0) - before.get("encode_groups", 0) <= 3
    assert after.get("bucketize_delta", 0) > before.get("bucketize_delta", 0)
    assert after.get("fragment_sizes_delta", 0) > before.get("fragment_sizes_delta", 0)
    assert after.get("sketch_maintained", 0) - before.get("sketch_maintained", 0) == 3
    assert after.get("sketch_recaptured", 0) == before.get("sketch_recaptured", 0)


def test_selection_on_appended_table_extends_sample_without_rebucketize():
    """Candidate selection after an append reuses the cached sample (delta
    pass) and the catalog's per-fragment counts — no full re-bucketization."""
    rng = np.random.default_rng(11)
    fact_np = _mk_batch(rng, 2_000)
    db = _oracle_db(fact_np, _mk_dim())
    qs = _templates(db, rng)
    # skip_single_candidate would bypass the sample + AQR pass for this
    # one-candidate pool; disable it — the delta-sampling path is the
    # mechanism under test here.
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.3, seed=0,
                     min_selectivity_gain=2.0,
                     selection=SelectionConfig(skip_single_candidate=False))
    eng.run(qs[0])
    eng.append_rows("sales", _mk_batch(rng, 120))
    before_b = eng.catalog.stats.get("bucketize", 0)
    before_ext = eng.samples.extended
    # A *lower*-threshold query is not subsumed by the stored sketch, so the
    # engine runs a fresh selection pass on the appended table.
    q2 = dataclasses.replace(qs[0], having=Having(">", qs[0].having.value * 0.5))
    res, info = eng.run(q2)
    odb = _oracle_db(
        {k: np.asarray(eng.db["sales"][k]) for k in fact_np}, _mk_dim())
    assert res.canonical() == execute(q2, odb).canonical()
    assert eng.samples.extended == before_ext + 1
    assert eng.catalog.stats.get("bucketize", 0) == before_b


# ---------------------------------------------------------------------------
# 4. Table-level delta mechanics.
# ---------------------------------------------------------------------------


def test_append_delete_versioning_and_layout():
    rng = np.random.default_rng(3)
    t0 = from_numpy("sales", _mk_batch(rng, 500))
    ranges = equi_depth_ranges(t0, "s_attr", 8)
    t1 = t0.cluster_by(ranges)
    assert t1.uid == t0.uid and t1.version == 0 and t1.delta is None

    batch = _mk_batch(rng, 60)
    t2 = t1.append(batch)
    assert t2.version == 1 and t2.uid == t1.uid
    assert t2.delta.kind == "append" and t2.delta.parent is t1
    assert t2.layout is not None and t2.layout.tail == 60
    assert t2.num_rows == 560
    np.testing.assert_array_equal(np.asarray(t2["s_val"])[:500], np.asarray(t1["s_val"]))

    mask = np.zeros(560, dtype=bool)
    mask[rng.choice(560, 80, replace=False)] = True
    t3 = t2.delete(mask)
    assert t3.version == 2 and t3.num_rows == 480
    lay = t3.layout
    assert lay is not None
    # Offsets + tail stay consistent: every prefix slice is bucket-homogeneous.
    bucket = np.asarray(ranges.bucketize(t3["s_attr"]))
    for f in range(lay.n_fragments):
        lo, hi = lay.offsets[f], lay.offsets[f + 1]
        assert (bucket[lo:hi] == f).all(), f
    assert lay.offsets[-1] + lay.tail == t3.num_rows
    # A gathered copy is a fresh lineage.
    assert t3.gather(np.arange(10)).uid != t3.uid


def test_catalog_delta_refresh_matches_full_recompute():
    rng = np.random.default_rng(5)
    t0 = from_numpy("sales", _mk_batch(rng, 800))
    ranges = equi_depth_ranges(t0, "s_attr", 9)
    cat = Catalog()
    cat.bucketize(t0, ranges)
    cat.groups(t0, ("s_grp", "s_sub"))
    cat.fragment_sizes(t0, ranges)

    t1 = t0.append(_mk_batch(rng, 100))
    mask = np.asarray(t1["s_key"]) % 5 == 0
    t2 = t1.delete(mask)

    before = cat.stats.get("bucketize", 0), cat.stats.get("encode_groups", 0)
    bucket = np.asarray(cat.bucketize(t2, ranges))
    sizes = cat.fragment_sizes(t2, ranges)
    enc = cat.groups(t2, ("s_grp", "s_sub"))
    after = cat.stats.get("bucketize", 0), cat.stats.get("encode_groups", 0)
    assert before == after  # all delta refreshes
    assert cat.stats.get("bucketize_delta", 0) >= 2

    np.testing.assert_array_equal(bucket, np.asarray(ranges.bucketize(t2["s_attr"])))
    np.testing.assert_array_equal(
        sizes, np.bincount(bucket, minlength=ranges.n_ranges))
    # The incremental dictionary decodes every row to its actual key values.
    for a in ("s_grp", "s_sub"):
        np.testing.assert_array_equal(
            enc.group_values[a][enc.gid], np.asarray(t2[a]), err_msg=a)


def test_engine_bounds_delta_history():
    """Long mutation streams must not pin every prior version: past
    ``max_delta_chain`` the engine advances maintainers and collapses the
    chain, and results stay exact across the collapse."""
    rng = np.random.default_rng(23)
    fact_np = _mk_batch(rng, 800)
    db = _oracle_db(fact_np, _mk_dim())
    q = _templates(db, rng)[0]
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.3, seed=0,
                     min_selectivity_gain=2.0, max_delta_chain=3)
    eng.run(q)
    for i in range(10):
        batch = _mk_batch(rng, 40)
        eng.append_rows("sales", batch)
        fact_np = {k: np.concatenate([fact_np[k], batch[k]]) for k in fact_np}
    assert eng.db["sales"].delta_depth() <= 3
    assert eng.catalog.stats.get("history_collapse", 0) >= 2
    res, info = eng.run(q)
    assert res.canonical() == execute(q, _oracle_db(fact_np, _mk_dim())).canonical()
    entry = eng.index.entries()[0]
    osk = capture_sketch(entry.query, _oracle_db(fact_np, _mk_dim()),
                         entry.sketch.ranges, catalog=Catalog())
    np.testing.assert_array_equal(entry.sketch.bits, osk.bits)


def test_clears_held_back_outside_f32_exact_envelope():
    """With group sums beyond 2**24 the executor's f32 arithmetic is no longer
    provably reproducible, so a group flip to "failing" must keep its bits
    (superset, never subset) rather than trust the maintained aggregates."""
    rng = np.random.default_rng(29)
    n = 400
    cols = dict(
        g=np.repeat(np.arange(4, dtype=np.int32), n // 4),
        a=rng.integers(0, 100, n).astype(np.int32),
        v=np.full(n, 1_000_000, dtype=np.int64),  # sums ~1e8 >> 2**24
    )
    t = from_numpy("t", cols)
    db = Database({"t": t})
    q = Query("t", ("g",), Aggregate("sum", "v"),
              having=Having(">", 99_000_000.0 * n / 400))
    ranges = equi_depth_ranges(t, "a", 6)
    cat = Catalog()
    m = build_maintainer(q, db, ranges, cat)
    assert m.exact and m._values_integral and not m._clears_trustworthy()
    # Delete most rows of group 0: it stops passing, but bits must persist.
    mask = (cols["g"] == 0) & (np.arange(n) % 2 == 0)
    t2 = t.delete(mask)
    m.apply(t2, Database({"t": t2}))
    assert m.conservative  # the flip-to-failing was held back
    oracle = capture_sketch(
        q, Database({"t": from_numpy("t", {k: v[~mask] for k, v in cols.items()})}),
        ranges, catalog=Catalog())
    got = m.bits()
    assert ((got | oracle.bits) == got).all()  # superset, never subset


def test_repair_falls_back_to_recapture_on_dimension_mutation():
    rng = np.random.default_rng(13)
    fact_np = _mk_batch(rng, 900)
    db = _oracle_db(fact_np, _mk_dim())
    qs = _templates(db, rng)
    ajgh = next(q for q in qs if q.template == "Q-AJGH")
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.3, seed=0,
                     min_selectivity_gain=2.0)
    _, info = eng.run(ajgh)
    assert info.created
    # Mutate the *dimension* table: maintenance must refuse and re-capture.
    eng.db = eng.db.with_table(eng.db["dim"].append(dict(
        d_key=np.array([N_DIM + 1], np.int32), d_w=np.array([3], np.int32))))
    eng.append_rows("sales", _mk_batch(rng, 50))
    res, info = eng.run(ajgh)
    assert info.reused and info.repaired
    assert eng.catalog.stats.get("sketch_recaptured", 0) == 1
    odb = Database({"sales": from_numpy("sales", {
        k: np.asarray(eng.db["sales"][k]) for k in fact_np}),
        "dim": from_numpy("dim", {k: np.asarray(eng.db["dim"][k]) for k in ("d_key", "d_w")})})
    assert res.canonical() == execute(ajgh, odb).canonical()
