"""Differential suite for the batched admission pipeline.

The contract under test: ``PBDSEngine.run_batch(qs)`` is *bit-for-bit*
equivalent to ``[engine.run(q) for q in qs]`` — query results, index
contents (which sketches exist, their bits and sizes), and post-mutation
maintainer state — while sharing the miss-path work (one sample + one AQR
pass + one inner-block scan + one capture launch per signature group).

Also covered: the batched capture kernel against the per-mask oracle, the
multi-query padded estimator against the single-query path, and the
steady-state recompile guarantee (pow2-padded instances + pow2-quantized
selection shapes => zero new XLA compilations after warmup).
"""
import contextlib
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Database,
    Having,
    JoinSpec,
    Query,
    execute,
)
from repro.core.datasets import make_crimes, make_tpch
from repro.core.engine import PBDSEngine
from repro.core.strategies import SelectionConfig
from repro.runtime.guards import retrace_guard

N_ROWS = 30_000


@contextlib.contextmanager
def count_xla_compiles():
    """Count real backend compilations via the shared retrace guard
    (cached executions emit no event)."""
    with retrace_guard(allowed=None) as watch:
        yield watch.events


@pytest.fixture(scope="module")
def tpch_db():
    return make_tpch(N_ROWS, seed=7)


def _threshold(q: Query, db: Database, quantile: float) -> float:
    vals = execute(dataclasses.replace(q, having=None, outer_having=None), db).values
    return float(np.quantile(vals, quantile))


def _template_batches(db: Database, quantiles):
    """Per template, a batch of queries differing only in HAVING thresholds.

    Thresholds descend so earlier queries do NOT subsume later ones (every
    query admits); duplicates and ascending pairs are added by the callers
    that exercise the deferral/hit paths.
    """
    batches = {}

    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    batches["Q-AGH"] = [
        dataclasses.replace(agh, having=Having(">", _threshold(agh, db, qt)))
        for qt in quantiles
    ]

    ajgh = Query(
        "lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
    )
    batches["Q-AJGH"] = [
        dataclasses.replace(ajgh, having=Having(">", _threshold(ajgh, db, qt)))
        for qt in quantiles
    ]

    # Nested templates vary the *inner* threshold (what selection estimates
    # see — Alg. 1 runs over the inner block) so admission actually happens.
    inner = Query("lineitem", ("l_suppkey", "l_partkey"),
                  Aggregate("sum", "l_quantity"))
    batches["Q-AAGH"] = [
        dataclasses.replace(
            inner, having=Having(">", _threshold(inner, db, qt)),
            outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
            outer_having=Having(">", 0.0))
        for qt in quantiles
    ]

    inner_j = dataclasses.replace(
        inner, join=JoinSpec("orders", "l_orderkey", "o_orderkey"))
    batches["Q-AAJGH"] = [
        dataclasses.replace(
            inner_j, having=Having(">", _threshold(inner_j, db, qt)),
            outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
            outer_having=Having(">", 0.0))
        for qt in quantiles
    ]
    return batches


def _engines(db, **kw):
    args = dict(strategy="CB-OPT-GB", n_ranges=40, theta=0.1, seed=0,
                min_selectivity_gain=0.98)
    args.update(kw)
    return PBDSEngine(db, **args), PBDSEngine(db, **args)


def _assert_run_parity(seq, bat, ctx=""):
    assert len(seq) == len(bat)
    for i, (s, b) in enumerate(zip(seq, bat)):
        assert s[0].canonical() == b[0].canonical(), f"{ctx} result {i}"
        assert (s[1].reused, s[1].created, s[1].repaired, s[1].attr) == (
            b[1].reused, b[1].created, b[1].repaired, b[1].attr), f"{ctx} info {i}"


def _assert_index_parity(e_seq, e_bat, ctx=""):
    es = sorted(e_seq.index.entries(), key=lambda e: repr(e.query.signature()))
    eb = sorted(e_bat.index.entries(), key=lambda e: repr(e.query.signature()))
    assert len(es) == len(eb), f"{ctx}: {len(es)} vs {len(eb)} entries"
    for a, b in zip(es, eb):
        assert a.query.signature() == b.query.signature(), ctx
        np.testing.assert_array_equal(a.sketch.bits, b.sketch.bits, err_msg=ctx)
        assert a.sketch.size_rows == b.sketch.size_rows, ctx
        assert a.sketch.attr == b.sketch.attr, ctx
        ma, mb = a.maintainer, b.maintainer
        assert (ma is None) == (mb is None), ctx
        if ma is not None:
            np.testing.assert_array_equal(ma.frag_prov, mb.frag_prov, err_msg=ctx)
            np.testing.assert_array_equal(ma.sums, mb.sums, err_msg=ctx)
            np.testing.assert_array_equal(ma.counts, mb.counts, err_msg=ctx)
            np.testing.assert_array_equal(ma.passing, mb.passing, err_msg=ctx)
            assert ma.conservative == mb.conservative, ctx


@pytest.mark.parametrize("template", ["Q-AGH", "Q-AJGH", "Q-AAGH", "Q-AAJGH"])
def test_run_batch_matches_sequential(tpch_db, template):
    """All-miss batches: run_batch == sequential across every template."""
    qs = _template_batches(tpch_db, (0.95, 0.9, 0.85, 0.8))[template]
    qs = qs + [qs[0], qs[-1]]  # duplicates -> within-batch deferral waves
    e_seq, e_bat = _engines(tpch_db)
    seq = [e_seq.run(q) for q in qs]
    bat = e_bat.run_batch(qs)
    _assert_run_parity(seq, bat, template)
    _assert_index_parity(e_seq, e_bat, template)
    assert sum(1 for _, i in bat if i.created) >= 1
    # At least the duplicate of the most selective (created) query hits.
    assert sum(1 for _, i in bat if i.reused) >= 1


def test_run_batch_mixed_hits_and_misses(tpch_db):
    """Pre-warmed sketches serve from the probe phase; the rest admit."""
    batches = _template_batches(tpch_db, (0.95, 0.85))
    warm = [batches["Q-AGH"][0], batches["Q-AJGH"][0]]
    cold = [batches["Q-AGH"][1], batches["Q-AJGH"][1], batches["Q-AAGH"][0]]
    e_seq, e_bat = _engines(tpch_db)
    for q in warm:
        e_seq.run(q)
        e_bat.run(q)
    mixed = [warm[0], cold[0], warm[1], cold[1], cold[2], warm[0]]
    seq = [e_seq.run(q) for q in mixed]
    bat = e_bat.run_batch(mixed)
    _assert_run_parity(seq, bat, "mixed")
    _assert_index_parity(e_seq, e_bat, "mixed")
    assert any(i.reused for _, i in bat) and any(i.created for _, i in bat)


def test_run_batch_mixed_signature_groups_one_wave(tpch_db):
    """A batch spanning several signature groups (different templates and
    aggregates) shares per-group products without cross-talk."""
    batches = _template_batches(tpch_db, (0.9, 0.8))
    other_agg = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_extendedprice"))
    other_agg = dataclasses.replace(
        other_agg, having=Having(">", _threshold(other_agg, tpch_db, 0.9)))
    qs = (batches["Q-AGH"] + batches["Q-AJGH"] + batches["Q-AAGH"]
          + batches["Q-AAJGH"] + [other_agg])
    e_seq, e_bat = _engines(tpch_db)
    seq = [e_seq.run(q) for q in qs]
    bat = e_bat.run_batch(qs)
    _assert_run_parity(seq, bat, "multi-group")
    _assert_index_parity(e_seq, e_bat, "multi-group")


def test_run_batch_interleaved_mutations():
    """batch -> append -> batch (repairs) -> delete -> batch, bit-for-bit."""
    db = Database({"crimes": make_crimes(20_000, seed=11)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    taus = np.quantile(sums, np.linspace(0.95, 0.7, 6))
    qs = [dataclasses.replace(base, having=Having(">", float(t))) for t in taus]
    e_seq, e_bat = _engines(db)

    _assert_run_parity([e_seq.run(q) for q in qs], e_bat.run_batch(qs), "cold")

    fresh = make_crimes(2_500, seed=99)
    for e in (e_seq, e_bat):
        e.append_rows("crimes", {a: np.asarray(fresh[a]) for a in fresh.schema})
    seq2 = [e_seq.run(q) for q in qs]
    bat2 = e_bat.run_batch(qs)
    _assert_run_parity(seq2, bat2, "post-append")
    assert all(i.reused and i.repaired for _, i in bat2)

    for e in (e_seq, e_bat):
        e.delete_rows("crimes", np.asarray(e.db["crimes"]["year"]) < 2012)
    _assert_run_parity([e_seq.run(q) for q in qs], e_bat.run_batch(qs),
                       "post-delete")
    _assert_index_parity(e_seq, e_bat, "post-mutations")


@pytest.mark.parametrize("strategy", ["NO-PS", "RAND-GB", "CB-OPT-REL"])
def test_run_batch_other_strategies(tpch_db, strategy):
    qs = _template_batches(tpch_db, (0.95, 0.85))["Q-AGH"]
    qs = qs + [qs[0]]
    e_seq, e_bat = _engines(tpch_db, strategy=strategy)
    seq = [e_seq.run(q) for q in qs]
    bat = e_bat.run_batch(qs)
    _assert_run_parity(seq, bat, strategy)
    _assert_index_parity(e_seq, e_bat, strategy)


def test_run_batch_clustered_engine(tpch_db):
    """cluster_tables=True: the first admission re-clusters the table; batch
    and sequential agree because selection is GB-fast-path (group-pinned
    incidence) and the aggregates are integral."""
    qs = _template_batches(tpch_db, (0.95, 0.9, 0.8))["Q-AGH"]
    e_seq, e_bat = _engines(tpch_db, cluster_tables=True)
    seq = [e_seq.run(q) for q in qs]
    bat = e_bat.run_batch(qs)
    _assert_run_parity(seq, bat, "clustered")
    _assert_index_parity(e_seq, e_bat, "clustered")
    assert e_bat.db["lineitem"].layout is not None


def test_shared_miss_path_work(tpch_db):
    """The whole point: a B-query miss batch pays one sample, one AQR pass,
    one group encoding and one WHERE/agg scan per signature group."""
    qs = _template_batches(tpch_db, (0.97, 0.95, 0.92, 0.9))["Q-AGH"]
    # Q-AGH has a single group-by candidate: disable the single-candidate
    # shortcut so the batch actually exercises the shared sample/AQR pass
    # this test pins.
    eng = PBDSEngine(tpch_db, strategy="CB-OPT-GB", n_ranges=40, theta=0.1,
                     seed=0, min_selectivity_gain=0.98,
                     selection=SelectionConfig(skip_single_candidate=False))
    out = eng.run_batch(qs)
    n_created = sum(1 for _, i in out if i.created)
    assert n_created >= 2
    assert eng.samples.misses == 1 and eng.aqr.misses == 1
    # One full-table group encoding for the fact table's group-by; each
    # created sketch's instance adds one (distinct instance objects).
    s = eng.catalog.stats
    assert s["encode_groups"] <= 1 + n_created
    # Instances materialize once per created sketch — the shared inner block
    # never re-materializes, and capture never scans per query.
    assert s["instance_build"] == n_created


def test_steady_state_reuse_zero_recompiles(tpch_db):
    """After warmup, reuse over pow2-padded instances compiles nothing new —
    even after a small mutation + repair shifts every instance's row count."""
    qs = _template_batches(tpch_db, (0.97, 0.94))["Q-AGH"]
    eng = PBDSEngine(tpch_db, strategy="CB-OPT-GB", n_ranges=40, theta=0.1,
                     seed=0, min_selectivity_gain=0.98)
    cold = eng.run_batch(qs)   # admit + warm the reuse path
    created = [i for i, (_, inf) in enumerate(cold) if inf.created]
    assert created
    eng.run_batch(qs)   # first reuse pass flushes any remaining warmup
    with count_xla_compiles() as events:
        out = eng.run_batch(qs)
    assert all(out[i][1].reused for i in created)
    assert len(events) == 0, f"steady-state reuse compiled {len(events)} programs"

    # A small append shifts the logical instance sizes; pow2 padding keeps
    # the physical shapes in the same compiled size class.
    fact = eng.db["lineitem"]
    batch = {a: np.asarray(fact[a])[:64] for a in fact.schema}
    eng.append_rows("lineitem", batch)
    eng.run_batch(qs)  # repair + rebuild instances (delta-sized, may compile
    #                    batch-shaped delta ops once)
    eng.run_batch(qs)
    with count_xla_compiles() as events:
        out = eng.run_batch(qs)
    assert all(out[i][1].reused for i in created)
    assert len(events) == 0, (
        f"post-mutation steady state compiled {len(events)} programs")


def test_capture_sketches_batch_matches_single(tpch_db):
    from repro.core import capture_sketch, equi_depth_ranges, provenance_mask
    from repro.core.sketch import capture_sketches_batch

    qs = _template_batches(tpch_db, (0.95, 0.9, 0.8))["Q-AGH"]
    ranges = equi_depth_ranges(tpch_db["lineitem"], "l_suppkey", 40)
    provs = [provenance_mask(q, tpch_db) for q in qs]
    batched = capture_sketches_batch(qs, tpch_db, [ranges] * len(qs), provs)
    for q, prov, sk_b in zip(qs, provs, batched):
        sk_s = capture_sketch(q, tpch_db, ranges, prov=prov)
        np.testing.assert_array_equal(sk_b.bits, sk_s.bits)
        assert sk_b.size_rows == sk_s.size_rows
        assert sk_b.total_rows == sk_s.total_rows


def test_fragment_bitmap_batch_kernel_parity():
    """Pallas interpret-mode batched kernel == per-mask reference kernel."""
    from repro.kernels import ops as kops
    from repro.kernels.ref import fragment_bitmap_batch_ref

    rng = np.random.default_rng(0)
    n, n_ranges, b = 5_000, 37, 5
    bucket = rng.integers(0, n_ranges, n).astype(np.int32)
    provs = rng.random((b, n)) < 0.05
    import jax.numpy as jnp

    ref_bits = np.asarray(fragment_bitmap_batch_ref(
        jnp.asarray(provs), jnp.asarray(bucket), n_ranges))
    for backend in ("ref", "interpret"):
        got = np.asarray(kops.fragment_bitmap_batch(
            jnp.asarray(provs), jnp.asarray(bucket), n_ranges, backend=backend))
        np.testing.assert_array_equal(got, ref_bits, err_msg=backend)
    # Per-mask single kernel agrees too.
    for i in range(b):
        single = np.asarray(kops.fragment_bitmap(
            jnp.asarray(provs[i]), jnp.asarray(bucket), n_ranges))
        np.testing.assert_array_equal(ref_bits[i], single)


def test_estimate_size_multi_matches_single(tpch_db):
    """The padded (query x candidate) launch returns the same estimates the
    per-query path does (integral est_rows exactly; probabilistic fields to
    float tolerance — padding may reassociate their f32 sums)."""
    from repro.aqp.sampling import stratified_reservoir_sample
    from repro.aqp.size_estimation import (
        EstimationSpec,
        approximate_query_result,
        estimate_size_batched,
        estimate_size_multi,
    )
    from repro.core import equi_depth_ranges

    qs = _template_batches(tpch_db, (0.9, 0.8))["Q-AGH"]
    key = jax.random.PRNGKey(0)
    samples = stratified_reservoir_sample(
        key, tpch_db["lineitem"], qs[0].groupby, 0.1)
    cands = ["l_suppkey", "l_partkey", "l_quantity"]
    # Different n_ranges per query exercises the pow2 fragment-axis padding.
    specs = []
    for q, nr in zip(qs, (40, 56)):
        ranges_by = {a: equi_depth_ranges(tpch_db["lineitem"], a, nr) for a in cands}
        specs.append(EstimationSpec(
            q=q, samples=samples, ranges_by_attr=ranges_by,
            aqr=approximate_query_result(key, q, tpch_db, samples)))
    multi = estimate_size_multi(tpch_db, specs)
    for spec, got in zip(specs, multi):
        ref = estimate_size_batched(
            key, spec.q, tpch_db, spec.ranges_by_attr, spec.samples,
            aqr=spec.aqr)
        for a in cands:
            np.testing.assert_array_equal(got[a].est_bits, ref[a].est_bits)
            assert got[a].est_rows == ref[a].est_rows  # exact integral f32
            assert got[a].expected_rows == pytest.approx(
                ref[a].expected_rows, rel=1e-4)
            assert got[a].lo_rows == pytest.approx(ref[a].lo_rows, rel=1e-4)
            assert got[a].hi_rows == pytest.approx(ref[a].hi_rows, rel=1e-4)
