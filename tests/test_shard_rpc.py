"""Real process-boundary shards (``repro.core.shard_rpc``).

Contracts under test:

  * the subprocess backend serves bit-identically to loopback — misses,
    hits, batches, mutations (the transport must be invisible to results);
  * fault injection delivers real mechanisms: ``kill`` SIGKILLs the shard
    server (the respawned process has a NEW pid and genuinely empty state),
    ``partition`` drops the socket with server state intact, ``flaky``
    fails real RPCs through the retry wrapper;
  * recovery after an actual process kill is checkpoint-rebuild +
    delta-replay + maintainer re-registration — never a sketch re-capture
    (pinned on the coordinator index miss counter);
  * the seeded chaos differential harness passes over real processes, with
    the fault-free reference running in-process fused (the cross-backend
    gate the PR 9 bench scales to 100+ replays);
  * ``shutdown()`` returns servers to the warm pool; no orphans.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Database,
    Having,
    Query,
    ShardedEngine,
    execute,
)
from repro.core.datasets import make_crimes
from repro.runtime.chaos import ChaosEvent, differential, random_ops, random_schedule

pytestmark = pytest.mark.slow  # spawns real shard server processes


def _queries(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = [dataclasses.replace(base,
                              having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.5, 0.8)]
    byear = Query("crimes", ("year",), Aggregate("sum", "records"))
    qs.append(dataclasses.replace(byear, having=Having(
        ">", float(np.quantile(execute(byear, db).values, 0.6)))))
    return qs


def _rows(rng, n):
    t = make_crimes(n, seed=int(rng.integers(1 << 30)))
    return {a: np.asarray(t[a]) for a in t.schema}


def _engine(db, n_shards=2, **kw):
    args = dict(n_ranges=16, theta=0.1, seed=0, min_selectivity_gain=2.0,
                transport="subprocess")
    args.update(kw)
    return ShardedEngine(db, "crimes", "district", n_shards=n_shards, **args)


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(3000, seed=2)})


def _pid_alive(pid):
    try:
        os.kill(pid, 0)
        return True
    except ProcessLookupError:
        return False


def test_subprocess_serves_identical_to_loopback(db):
    qs = _queries(db)
    lo = _engine(db, 2, transport="loopback")
    se = _engine(db, 2)
    try:
        for q in qs:
            (r_lo, _), (r_se, _) = lo.run(q), se.run(q)
            assert r_se.canonical() == r_lo.canonical()
        # Warm hits route over RPC; results stay bit-identical.
        for q in qs:
            (r_lo, i_lo), (r_se, i_se) = lo.run(q), se.run(q)
            assert r_se.canonical() == r_lo.canonical()
            assert i_se.reused and not i_se.degraded
            assert i_se.shards_contacted == i_lo.shards_contacted
        # Batch path too (one fused launch over RPC-fetched arrays).
        outs_lo, outs_se = lo.run_batch(qs), se.run_batch(qs)
        for (r1, _), (r2, _) in zip(outs_lo, outs_se):
            assert r2.canonical() == r1.canonical()
        # Mutations replicate over the wire.
        rows = _rows(np.random.default_rng(5), 200)
        lo.append_rows("crimes", rows)
        se.append_rows("crimes", rows)
        for q in qs:
            assert se.run(q)[0].canonical() == lo.run(q)[0].canonical()
    finally:
        lo.shutdown()
        se.shutdown()


def test_kill_is_a_real_sigkill_and_recovery_respawns(db):
    q = _queries(db)[0]
    se = _engine(db, 2)
    try:
        se.run(q)
        expect = execute(q, se.db).canonical()
        misses_before = se.engine.index.misses

        pid0 = se.shards[1].pid
        assert _pid_alive(pid0)
        se.shards[1].inject("kill")
        assert not _pid_alive(pid0)  # genuinely SIGKILLed, not a flag
        assert se.shards[1].pid is None

        # Degraded serving through the dead process.
        res, info = se.run(q)
        assert res.canonical() == expect and info.degraded

        # Mutations while down land in the coordinator's delta log.
        se.append_rows("crimes", _rows(np.random.default_rng(7), 150))
        expect = execute(q, se.db).canonical()

        se.shards[1].heal()
        res, info = se.run(q)
        assert res.canonical() == expect and not info.degraded
        pid1 = se.shards[1].pid
        assert pid1 is not None and pid1 != pid0  # a NEW server process
        assert se.health[1] == "healthy"
        assert se.shards[1].version == se.version
        # Checkpoint-rebuild + replay + re-registration: no re-capture.
        assert se.engine.index.misses == misses_before
        res, info = se.run(q)
        assert res.canonical() == expect and not info.degraded
    finally:
        se.shutdown()


def test_partition_drops_socket_but_keeps_server_state(db):
    q = _queries(db)[0]
    se = _engine(db, 2)
    try:
        se.run(q)
        expect = execute(q, se.db).canonical()
        pid0 = se.shards[0].pid
        se.shards[0].inject("partition")
        assert _pid_alive(pid0)  # the process survives a partition
        res, info = se.run(q)
        assert res.canonical() == expect and info.degraded
        se.shards[0].heal()
        res, info = se.run(q)
        assert res.canonical() == expect and not info.degraded
        assert se.shards[0].pid == pid0  # same server, state intact
        assert se.health[0] == "healthy"
    finally:
        se.shutdown()


def test_flaky_injects_real_rpc_errors_through_retries(db):
    q = _queries(db)[0]
    se = _engine(db, 2)
    try:
        se.run(q)
        expect = execute(q, se.db).canonical()
        se.run(q)
        se.shards[1].inject("flaky", 1)
        res, info = se.run(q)
        assert res.canonical() == expect
        assert se.last_route.n_retries >= 1  # a real RPC failed and retried
        assert not info.degraded
    finally:
        se.shutdown()


def test_chaos_differential_subprocess_vs_fused_smoke(db):
    """Two seeded replay sequences of the cross-backend differential gate —
    subprocess shards under real kills/stalls/socket drops vs fault-free
    in-process fused serving (the bench scales this to 100+)."""
    qs = _queries(db)
    for n_shards, seed in ((2, 1), (3, 2)):
        ops = random_ops(seed, 10, qs, _rows)
        events = random_schedule(seed + 50, 10, n_shards)
        ok, chaotic, clean = differential(
            lambda n=n_shards: _engine(db, n, op_deadline_s=0.5),
            "crimes", ops, events,
            make_clean=lambda n=n_shards: _engine(db, n,
                                                  transport="loopback"))
        assert ok, (
            f"n_shards={n_shards} seed={seed}: subprocess trace diverged at "
            f"op {next(i for i, (a, b) in enumerate(zip(chaotic, clean)) if a != b)}")


def test_shutdown_releases_processes(db):
    q = _queries(db)[0]
    se = _engine(db, 2)
    pids = [s.pid for s in se.shards]
    se.run(q)
    se.shutdown()
    # Servers go back to the warm pool (still alive, reset) — and a second
    # shutdown is a no-op.
    se.shutdown()
    from repro.core import shard_rpc

    pooled = {sp.proc.pid for sp in shard_rpc.POOL._spares}
    assert set(pids) <= pooled or all(not _pid_alive(p) for p in pids)
    # A killed-then-shutdown engine must not leave the dead proc around.
    se2 = _engine(db, 2)
    pid = se2.shards[0].pid
    se2.shards[0].inject("kill")
    se2.shutdown()
    assert not _pid_alive(pid)
