"""Unit coverage for the runtime resilience/elastic primitives.

These are the building blocks the chaos-tolerant serving layer composes:
``with_retries`` wraps every shard op, ``StragglerMonitor`` feeds shard
health, ``plan_remesh``/``feasible_mesh_shape`` and ``plan_replacement``
are the pure re-planning policies (device meshes and fragment placement
respectively).  All are deterministic and tested without any engine.
"""
import time

import numpy as np
import pytest

from repro.runtime import (
    RetryPolicy,
    StragglerMonitor,
    feasible_mesh_shape,
    plan_remesh,
    plan_replacement,
    with_retries,
)


class _Boom(RuntimeError):
    pass


class _Fatal(ValueError):
    pass


def _failing(n_failures, exc=_Boom):
    """A callable that raises ``exc`` for the first ``n_failures`` calls."""
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] <= n_failures:
            raise exc(f"fail {calls['n']}")
        return calls["n"]

    fn.calls = calls
    return fn


def test_with_retries_backoff_sequencing(monkeypatch):
    sleeps = []
    monkeypatch.setattr(time, "sleep", sleeps.append)
    fn = _failing(2)
    policy = RetryPolicy(max_attempts=4, backoff_s=0.1, backoff_mult=3.0,
                         retryable=(_Boom,), jitter=0.0)
    assert with_retries(fn, policy) == 3
    # One sleep per retry, geometric: 0.1 then 0.3 (jitter disabled).
    assert sleeps == pytest.approx([0.1, 0.3])
    assert fn.calls["n"] == 3


def test_with_retries_jitter_decorrelates_and_is_seeded(monkeypatch):
    def run(seed):
        sleeps = []
        monkeypatch.setattr(time, "sleep", sleeps.append)
        policy = RetryPolicy(max_attempts=6, backoff_s=0.1, backoff_mult=3.0,
                             retryable=(_Boom,), jitter=0.5, seed=seed)
        with pytest.raises(_Boom):
            with_retries(_failing(10), policy)
        return sleeps

    a, b, a2 = run(1), run(2), run(1)
    # Seeded: the same seed replays the same sleeps; different seeds (two
    # clients retrying against the same recovering shard) decorrelate.
    assert a == pytest.approx(a2)
    assert a != pytest.approx(b)
    # Every sleep stays within the decorrelated-jitter envelope:
    # [backoff_s, prev * mult * (1 + jitter)).
    prev = 0.1 / 3.0
    for s in a:
        assert 0.1 <= s < prev * 3.0 * 1.5 + 1e-12
        prev = s


def test_with_retries_sleep_capped_to_deadline(monkeypatch):
    sleeps = []
    clock = {"t": 0.0}
    monkeypatch.setattr(time, "perf_counter", lambda: clock["t"])

    def fake_sleep(s):
        sleeps.append(s)
        clock["t"] += s

    monkeypatch.setattr(time, "sleep", fake_sleep)
    fn = _failing(10)
    policy = RetryPolicy(max_attempts=50, backoff_s=10.0, backoff_mult=2.0,
                         retryable=(_Boom,), deadline_s=1.0, jitter=0.0)
    with pytest.raises(_Boom):
        with_retries(fn, policy)
    # The first sleep would be 10s; the cap trims it to the remaining 1s
    # budget, and the next failure hits the exhausted deadline: the loop
    # never sleeps past deadline_s.
    assert sleeps == pytest.approx([1.0])
    assert sum(sleeps) <= policy.deadline_s + 1e-9
    assert fn.calls["n"] == 2


def test_with_retries_on_retry_and_exhaustion(monkeypatch):
    monkeypatch.setattr(time, "sleep", lambda _s: None)
    seen = []
    fn = _failing(10)
    policy = RetryPolicy(max_attempts=3, backoff_s=0.01, retryable=(_Boom,))
    with pytest.raises(_Boom):
        with_retries(fn, policy, on_retry=lambda a, e: seen.append((a, str(e))))
    # on_retry fires for every attempt EXCEPT the last (which re-raises).
    assert seen == [(1, "fail 1"), (2, "fail 2")]
    assert fn.calls["n"] == 3


def test_with_retries_non_retryable_passthrough(monkeypatch):
    sleeps = []
    monkeypatch.setattr(time, "sleep", sleeps.append)
    fn = _failing(1, exc=_Fatal)
    policy = RetryPolicy(max_attempts=5, retryable=(_Boom,))
    with pytest.raises(_Fatal):
        with_retries(fn, policy)
    # No retries, no sleeps: a non-retryable error surfaces immediately.
    assert fn.calls["n"] == 1
    assert sleeps == []


def test_with_retries_deadline_stops_early(monkeypatch):
    monkeypatch.setattr(time, "sleep", lambda _s: None)
    fn = _failing(10)
    policy = RetryPolicy(max_attempts=50, backoff_s=0.0,
                         retryable=(_Boom,), deadline_s=0.0)
    with pytest.raises(_Boom):
        with_retries(fn, policy)
    # Deadline already expired at the first failure: exactly one attempt.
    assert fn.calls["n"] == 1


def test_straggler_monitor_warmup_and_flagging():
    mon = StragglerMonitor(window=32, threshold=2.0)
    # Below max(4, window // 4) = 8 observations there is no baseline.
    for _ in range(7):
        assert mon.median() is None
        assert mon.observe(0.01) is False
    assert mon.median() is None  # 7 observed, still warming up
    assert mon.observe(0.01) is False  # 8th observation forms the baseline
    assert mon.median() == pytest.approx(0.01)
    assert mon.observe(0.019) is False  # under 2x median: not a straggler
    assert mon.observe(0.05) is True    # over 2x median: flagged
    assert mon.flagged == 1
    assert mon.observe(0.5) is True
    assert mon.flagged == 2


def test_straggler_monitor_small_window_floor():
    # window // 4 < 4: the warmup floor is 4 observations.
    mon = StragglerMonitor(window=8, threshold=2.0)
    for _ in range(3):
        mon.observe(1.0)
    assert mon.median() is None
    mon.observe(1.0)
    assert mon.median() == pytest.approx(1.0)


def test_feasible_mesh_shape_invariants():
    assert feasible_mesh_shape(8, 2) == (4, 2)
    assert feasible_mesh_shape(7, 2) == (3, 2)  # drops the odd device
    assert feasible_mesh_shape(1, 2) is None    # cannot fit TP extent
    assert feasible_mesh_shape(8, 2, prefer_pods=2) == (2, 2, 2)
    # Pod preference degrades gracefully when it doesn't divide.
    assert feasible_mesh_shape(6, 2, prefer_pods=2) == (3, 2)


@pytest.mark.parametrize("n_devices", [8, 7, 6, 5, 4])
def test_plan_remesh_preserves_global_batch(n_devices):
    global_batch, model_parallel = 32, 2
    plan = plan_remesh(n_devices, model_parallel, global_batch,
                       old_n_micro=2, old_data_extent=4)
    assert plan is not None
    data_extent = plan.mesh_shape[-2] * (
        plan.mesh_shape[0] if len(plan.mesh_shape) == 3 else 1)
    # Global batch is always preserved exactly through grad accumulation.
    assert global_batch % plan.n_micro == 0
    # And splits evenly across the DP extent whenever that is achievable
    # (a coprime extent, e.g. 3 devices for batch 32, cannot).
    if global_batch % data_extent == 0:
        assert (global_batch // plan.n_micro) % data_extent == 0
    used = int(np.prod(plan.mesh_shape))
    assert used + plan.dropped_devices == n_devices


def test_plan_replacement_invariants():
    sizes = np.array([10, 30, 20, 40, 10, 25])
    owner = np.array([0, 0, 1, 1, 2, 2])
    new = plan_replacement(sizes, owner, 3, dead=[1])
    # Survivors keep every fragment they already owned.
    assert (new[owner == 0] == 0).all()
    assert (new[owner == 2] == 2).all()
    # Orphans all land on survivors.
    assert set(new[owner == 1].tolist()) <= {0, 2}
    # Greedy LPT: the 40-row orphan goes to the lighter survivor (shard 2:
    # 35 rows vs shard 0: 40), then the 20-row one to the other.
    assert new[3] == 2 and new[2] == 0
    # Deterministic and pure.
    assert np.array_equal(new, plan_replacement(sizes, owner, 3, dead=[1]))
    assert np.array_equal(owner, [0, 0, 1, 1, 2, 2])  # input untouched


def test_plan_replacement_no_survivors():
    with pytest.raises(ValueError):
        plan_replacement(np.array([1.0]), np.array([0]), 2, dead=[0, 1])
