"""The paper's Fig. 1 running example, end to end, with exact expected values."""
import numpy as np
import pytest

from repro.core import (
    Aggregate, Having, Query, RangeSet, capture_sketch, execute,
    is_safe_sketch, provenance_mask,
)
from repro.core.datasets import paper_example_db

Q = Query(
    table="crimes",
    groupby=("pid", "month", "year"),
    agg=Aggregate("sum", "records"),
    having=Having(">=", 100),
)

R_PID = RangeSet("pid", np.array([3.5, 6.5]))  # [1,3] [4,6] [7,9]
R_MONTH = RangeSet("month", np.array([4.5, 8.5]))  # [1,4] [5,8] [9,12]
R_YEAR = RangeSet("year", np.array([2012.5, 2020.5]))


@pytest.fixture(scope="module")
def db():
    return paper_example_db()


def test_query_result(db):
    res = execute(Q, db)
    # groups (4,1,2013)=174, (8,6,2015)=182, (2,7,2016)=157 pass HAVING >= 100
    assert res.canonical() == (
        (1.0, 4.0, 2013.0, 174.0),
        (6.0, 8.0, 2015.0, 182.0),
        (7.0, 2.0, 2016.0, 157.0),
    )


def test_provenance_rows(db):
    prov = provenance_mask(Q, db)
    # rows 1..5 (0-indexed) are bold in Fig. 1c
    assert prov.tolist() == [False, True, True, True, True, True, False, False]


@pytest.mark.parametrize(
    "ranges,bits,selectivity",
    [
        (R_PID, [True, True, True], 1.0),  # pid sketch covers everything
        (R_MONTH, [True, True, False], 7 / 8),  # {m1, m2}
        (R_YEAR, [False, True, False], 5 / 8),  # {y2} — the optimal choice
    ],
)
def test_sketches_match_paper(db, ranges, bits, selectivity):
    sk = capture_sketch(Q, db, ranges)
    assert sk.bits.tolist() == bits
    assert sk.selectivity == pytest.approx(selectivity)
    assert is_safe_sketch(Q, db, sk)


def test_year_sketch_range_condition(db):
    """The instrumented predicate is `year BETWEEN 2013 AND 2020`-shaped."""
    sk = capture_sketch(Q, db, R_YEAR)
    (lo, hi), = sk.range_conditions()
    assert lo == pytest.approx(2012.5) and hi == pytest.approx(2020.5)
