"""Fragment-sharded serving: routed execution must equal single-node exactly.

Differential contract (the acceptance criterion of the sharding PR): for every
workload template, the ShardedEngine's routed result equals single-node
execution over the coordinator's authoritative table bit-for-bit — including
across interleaved appends/deletes that advance shard watermarks lazily — and
reused-sketch queries contact only the shards owning sketch fragments.

The exactness tests aggregate integer-valued columns (records, l_quantity):
within that envelope per-shard float32 partial sums are exact integers, so
merged-partial results reproduce the single-node kernel arithmetic exactly —
the same envelope the maintenance differential harness pins.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Aggregate,
    Database,
    Having,
    JoinSpec,
    Query,
    ShardedEngine,
    execute,
    plan_fragments,
)
from repro.core.datasets import make_crimes, make_tpch

N_ROWS = 30_000


def _threshold(q, db, quantile):
    vals = execute(dataclasses.replace(q, having=None, outer_having=None), db).values
    return float(np.quantile(vals, quantile))


def _tpch_templates(db):
    """One query per template, aggregating integer-valued columns only."""
    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    agh = dataclasses.replace(agh, having=Having(">", _threshold(agh, db, 0.8)))

    ajgh = Query(
        "lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
    )
    ajgh = dataclasses.replace(ajgh, having=Having(">", _threshold(ajgh, db, 0.8)))

    aagh = Query(
        "lineitem", ("l_partkey", "l_suppkey"), Aggregate("sum", "l_quantity"),
        having=Having(">", 0.0),
        outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
    )
    aagh = dataclasses.replace(
        aagh, outer_having=Having(">", _threshold(aagh, db, 0.8)))

    aajgh = Query(
        "lineitem", ("l_partkey", "l_suppkey"), Aggregate("count", None),
        join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
        having=Having(">", 0.0),
        outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None),
    )
    aajgh = dataclasses.replace(
        aajgh, outer_having=Having(">", _threshold(aajgh, db, 0.8)))
    return [agh, ajgh, aagh, aajgh]


def test_plan_fragments_policies():
    sizes = np.array([10, 10, 10, 10, 40, 10, 10, 10])
    contig = plan_fragments(sizes, 3, policy="contig")
    assert contig.owner.shape == (8,)
    # Contiguous runs, all shards used, ownership non-decreasing.
    assert (np.diff(contig.owner) >= 0).all()
    assert set(contig.owner.tolist()) == {0, 1, 2}
    spread = plan_fragments(sizes, 3, policy="spread")
    np.testing.assert_array_equal(spread.owner, np.arange(8) % 3)
    np.testing.assert_array_equal(contig.shards_for(np.array([0, 1])),
                                  np.unique(contig.owner[[0, 1]]))
    with pytest.raises(ValueError):
        plan_fragments(sizes, 2, policy="nope")


@pytest.mark.parametrize("n_shards", [1, 3])
def test_routed_equals_single_node_all_templates(n_shards):
    db = make_tpch(N_ROWS, seed=7)
    se = ShardedEngine(db, "lineitem", "l_suppkey", n_shards=n_shards,
                       n_ranges=32, theta=0.1, seed=0, min_selectivity_gain=2.0)
    for q in _tpch_templates(db):
        res_cold, info_cold = se.run(q)
        want = execute(q, se.db).canonical()
        assert res_cold.canonical() == want, q.template
        res_warm, info_warm = se.run(q)
        assert info_warm.reused, q.template
        assert info_warm.shards_contacted is not None
        assert (info_warm.shards_contacted + info_warm.shards_skipped
                == n_shards)
        assert res_warm.canonical() == want, q.template


def test_selective_sketch_skips_shards():
    """A sketch on the serving partition routes to a strict shard subset."""
    db = Database({"crimes": make_crimes(20_000, seed=3)})
    base = Query("crimes", ("district",), Aggregate("sum", "records"))
    sums = execute(base, db).values
    q = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.9))))
    se = ShardedEngine(db, "crimes", "district", n_shards=4, n_ranges=25,
                       theta=0.1, seed=0, min_selectivity_gain=2.0)
    se.run(q)
    res, info = se.run(q)
    assert info.reused and info.shards_skipped > 0
    assert res.canonical() == execute(q, se.db).canonical()
    assert se.last_route.contacted == info.shards_contacted
    assert se.last_route.t_critical_s > 0


def test_non_matching_partition_routes_all_shards_exactly():
    """A sketch on a different attribute than the placement partition cannot
    fragment-skip shards, but routed execution stays exact (keep-mask path)."""
    db = Database({"crimes": make_crimes(20_000, seed=5)})
    base = Query("crimes", ("year",), Aggregate("sum", "records"))
    sums = execute(base, db).values
    q = dataclasses.replace(base, having=Having(">", float(np.quantile(sums, 0.8))))
    # Placement on district; the only GB candidate is year -> mismatch.
    se = ShardedEngine(db, "crimes", "district", n_shards=3, n_ranges=25,
                       theta=0.1, seed=0, min_selectivity_gain=2.0)
    se.run(q)
    res, info = se.run(q)
    assert info.reused
    assert info.shards_contacted == 3 and info.shards_skipped == 0
    assert res.canonical() == execute(q, se.db).canonical()


def test_interleaved_mutations_watermark_and_exactness():
    """Randomized append/delete/query interleavings: shards lag until read,
    reads gate on the watermark, and every routed result is exact."""
    rng = np.random.default_rng(11)
    db = Database({"crimes": make_crimes(20_000, seed=9)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    queries = [
        dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
        for qt in (0.7, 0.9)
    ]
    se = ShardedEngine(db, "crimes", "district", n_shards=4, n_ranges=25,
                       theta=0.1, seed=0, min_selectivity_gain=2.0)
    for q in queries:
        se.run(q)

    n_routed = 0
    for step in range(30):
        op = rng.choice(["append", "delete", "query"], p=[0.35, 0.25, 0.4])
        if op == "append":
            batch = make_crimes(int(rng.integers(200, 800)),
                                seed=int(rng.integers(1 << 30)))
            se.append_rows("crimes", {a: np.asarray(batch[a]) for a in batch.schema})
            # Replication is lazy: shipped but not yet applied anywhere.
            assert se.min_watermark() < se.version
        elif op == "delete":
            n = se.db["crimes"].num_rows
            mask = rng.random(n) < 0.02
            se.delete_rows("crimes", mask)
            assert se.min_watermark() < se.version
        else:
            q = queries[int(rng.integers(len(queries)))]
            res, info = se.run(q)
            assert info.reused
            n_routed += 1
            # The watermark gate drained every shard before serving.
            assert se.min_watermark() == se.version
            assert all(s.lag == 0 for s in se.shards)
            assert res.canonical() == execute(q, se.db).canonical(), step
    assert n_routed > 3


def test_dimension_mutation_evicts_and_recaptures():
    db = make_tpch(N_ROWS, seed=13)
    q = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
              join=JoinSpec("orders", "l_orderkey", "o_orderkey"))
    q = dataclasses.replace(q, having=Having(">", _threshold(q, db, 0.8)))
    se = ShardedEngine(db, "lineitem", "l_suppkey", n_shards=2, n_ranges=32,
                       theta=0.1, seed=0, min_selectivity_gain=2.0)
    se.run(q)
    _, info = se.run(q)
    assert info.reused
    # Mutate the dimension table: the join sketch is no longer trustworthy.
    orders = se.db["orders"]
    new_keys = np.arange(orders.num_rows + 1, orders.num_rows + 101, dtype=np.int64)
    se.append_rows("orders", {
        "o_orderkey": new_keys,
        "o_custkey": np.ones(100, dtype=np.int64),
        "o_totalprice": np.full(100, 1000.0, dtype=np.float32),
        "o_orderdate": np.full(100, 9000, dtype=np.int32),
        "o_shippriority": np.zeros(100, dtype=np.int32),
    })
    res, info2 = se.run(q)
    assert info2.created and not info2.reused  # evicted -> fresh capture
    assert res.canonical() == execute(q, se.db).canonical()
    res3, info3 = se.run(q)
    assert info3.reused
    assert res3.canonical() == execute(q, se.db).canonical()


def test_dim_mutation_while_shards_lag_recaptures():
    """MaintenanceError fallback under lag: a dimension mutation lands while
    fact deltas are still in flight (every shard behind the watermark) and one
    shard is partitioned.  The join sketch must be evicted everywhere, the
    next read drains the lag and re-captures, and no stale-join result is
    ever served."""
    db = make_tpch(N_ROWS, seed=21)
    q = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
              join=JoinSpec("orders", "l_orderkey", "o_orderkey"))
    q = dataclasses.replace(q, having=Having(">", _threshold(q, db, 0.8)))
    se = ShardedEngine(db, "lineitem", "l_suppkey", n_shards=3, n_ranges=32,
                       theta=0.1, seed=0, min_selectivity_gain=2.0)
    se.run(q)
    _, info = se.run(q)
    assert info.reused
    assert all(len(s.maintainers) == 1 for s in se.shards)

    # Fact mutations ship lazily: every shard now lags the watermark.
    rng = np.random.default_rng(0)
    fact = se.db["lineitem"]
    sel = rng.integers(0, fact.num_rows, 500)
    se.append_rows("lineitem",
                   {a: np.asarray(fact[a])[sel] for a in fact.schema})
    assert se.min_watermark() < se.version

    # Partition one shard, then mutate the dimension while the fact deltas
    # are still unapplied: replication can't reach shard 0 (it keeps the
    # stale dimension), but eviction must still drop the sketch everywhere.
    se.shards[0].inject("partition")
    orders = se.db["orders"]
    new_keys = np.arange(orders.num_rows + 1, orders.num_rows + 51,
                         dtype=np.int64)
    dim_batch = {
        "o_orderkey": new_keys,
        "o_custkey": np.ones(50, dtype=np.int64),
        "o_totalprice": np.full(50, 1000.0, dtype=np.float32),
        "o_orderdate": np.full(50, 9000, dtype=np.int32),
        "o_shippriority": np.zeros(50, dtype=np.int32),
    }
    se.append_rows("orders", dim_batch)
    assert all(not s.maintainers for s in se.shards)

    se.shards[0].heal()
    res, info2 = se.run(q)
    assert info2.created and not info2.reused  # evicted -> fresh capture
    assert res.canonical() == execute(q, se.db).canonical()
    assert se.min_watermark() == se.version
    assert se.health[0] == "healthy"  # stale dim refreshed on the read path
    res3, info3 = se.run(q)
    assert info3.reused
    assert res3.canonical() == execute(q, se.db).canonical()

    # Shard-level fallback directly: a local dimension drift the coordinator
    # hasn't reconciled makes the join maintainer unmaintainable; catch_up
    # drops it (MaintenanceError) instead of advancing stale state, and
    # bits_for then signals re-registration upstream.
    s = se.shards[1]
    key, _ = next(iter(s.maintainers.items()))
    s.dims["orders"] = s.dims["orders"].append(dim_batch)
    sel2 = rng.integers(0, se.db["lineitem"].num_rows, 100)
    fact2 = se.db["lineitem"]
    se.append_rows("lineitem",
                   {a: np.asarray(fact2[a])[sel2] for a in fact2.schema})
    s.catch_up(se.version)
    assert key not in s.maintainers
    assert s.bits_for(key) is None
    # The next read reconciles the drifted dim and restores exact serving.
    res4, _ = se.run(q)
    assert res4.canonical() == execute(q, se.db).canonical()


def test_single_shard_degenerates_to_full_routing():
    db = Database({"crimes": make_crimes(10_000, seed=17)})
    base = Query("crimes", ("district",), Aggregate("count", None))
    counts = execute(base, db).values
    q = dataclasses.replace(base, having=Having(">", float(np.quantile(counts, 0.6))))
    se = ShardedEngine(db, "crimes", "district", n_shards=1, n_ranges=16,
                       theta=0.1, seed=0, min_selectivity_gain=2.0)
    se.run(q)
    res, info = se.run(q)
    assert info.reused and info.shards_contacted == 1 and info.shards_skipped == 0
    assert res.canonical() == execute(q, se.db).canonical()


def test_placement_glue_single_device():
    from repro.parallel.placement import (
        failover_device,
        place_table,
        shard_devices,
    )

    devs = shard_devices(3)
    assert len(devs) == 3  # one slot per shard, None = no pinning needed
    t = make_crimes(100, seed=0)
    assert place_table(t, None) is t
    devs_forced = shard_devices(3, use_devices=False)
    assert devs_forced == [None, None, None]
    # Failover placement: None pins stay None; with named devices the rebuilt
    # shard keeps its own pin unless the device also backs another dead shard.
    assert failover_device([None, None, None], 1, dead=[1, 2]) is None
    assert failover_device(["d0", "d1", "d0"], 1, dead=[1]) == "d1"
    assert failover_device(["d0", "d1", "d0"], 2, dead=[0, 2]) == "d1"
    assert failover_device(["d0", "d0"], 1, dead=[0, 1]) == "d0"  # all implicated


def test_sharded_engine_rejects_coordinator_permuting_kwargs():
    db = Database({"crimes": make_crimes(2_000, seed=1)})
    with pytest.raises(ValueError):
        ShardedEngine(db, "crimes", "district", n_shards=2, cluster_tables=True)
    with pytest.raises(ValueError):
        ShardedEngine(db, "crimes", "district", n_shards=2, compact_tail_frac=0.5)
