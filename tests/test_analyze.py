"""Tests for the hot-path invariant linter (``tools.analyze``).

Three layers:

* per-rule fixtures under ``tests/analyze_fixtures/`` — every ``*_bad.py``
  must trip its rule (including the minimized PR 3 ``subsumes`` and PR 7
  key-reuse reconstructions), every ``*_good.py`` must be clean;
* the waiver machinery (line matching, reasons, staleness, strict mode);
* the self-check: ``python -m tools.analyze src/ --strict`` exits 0 on the
  repo itself — zero unexplained findings, zero stale waivers.
"""
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.analyze import analyze_paths, main  # noqa: E402
from tools.analyze.driver import analyze_source  # noqa: E402

FIXTURES = Path(__file__).parent / "analyze_fixtures"
RULES = ("KEY01", "PAD01", "SYNC01", "CACHE01", "DTYPE01", "CMP01")


def _rule_findings(fixture: str, rule: str):
    findings, _ = analyze_paths([str(FIXTURES / fixture)])
    return [f for f in findings if f.rule == rule and not f.waived]


@pytest.mark.parametrize("rule", RULES)
def test_bad_fixture_trips_rule(rule):
    found = _rule_findings(f"{rule.lower()}_bad.py", rule)
    assert found, f"{rule} did not fire on its positive fixture"


@pytest.mark.parametrize("rule", RULES)
def test_good_fixture_is_clean(rule):
    found = _rule_findings(f"{rule.lower()}_good.py", rule)
    assert not found, [f.format() for f in found]


def test_pr7_key_reuse_reconstruction_flagged():
    """The minimized select_attribute bug: one key into two random passes."""
    found = _rule_findings("key01_bad.py", "KEY01")
    messages = "\n".join(f.format() for f in found)
    assert "second consumer" in messages
    assert "loop" in messages and "comprehension" in messages


def test_pr3_subsumes_reconstruction_flagged():
    """Threshold comparison blind to operator strictness must trip CMP01."""
    found = _rule_findings("cmp01_bad.py", "CMP01")
    assert any("strictness" in f.message for f in found)
    assert any("tie-break" in f.message for f in found)


def test_waiver_covers_same_line_and_line_above():
    src = (
        "def aqr_cache_key(q):  # analyze: waive[CACHE01]: fixture reason\n"
        "    return (q.table,)\n"
    )
    findings = analyze_source(src)
    assert findings and all(f.waived for f in findings)
    src_above = (
        "# analyze: waive[CACHE01]: fixture reason\n"
        "def aqr_cache_key(q):\n"
        "    return (q.table,)\n"
    )
    findings = analyze_source(src_above)
    assert findings and all(f.waived for f in findings)


def test_waiver_without_reason_never_explains():
    src = (
        "def aqr_cache_key(q):\n"
        "    return (q.table,)  # analyze: waive[CACHE01]\n"
    )
    findings = analyze_source(src)
    assert findings and not any(f.waived for f in findings)


def test_waiver_for_other_rule_does_not_match():
    src = (
        "def aqr_cache_key(q):\n"
        "    return (q.table,)  # analyze: waive[KEY01]: wrong rule\n"
    )
    findings = analyze_source(src)
    assert findings and not any(f.waived for f in findings)


def test_consecutive_findings_get_their_own_waivers():
    """Same-line waivers match before line-above, so back-to-back flagged
    lines don't cascade onto each other's comments (no stale leftovers)."""
    src = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "from repro.runtime.guards import hot_path\n"
        "@hot_path\n"
        "def serve(t):\n"
        "    a = np.asarray(jnp.sum(t))  # analyze: waive[SYNC01]: first\n"
        "    b = np.asarray(jnp.max(t))  # analyze: waive[SYNC01]: second\n"
        "    return a, b\n"
    )
    findings = sorted(analyze_source(src), key=lambda f: f.line)
    assert [f.waive_reason for f in findings] == ["first", "second"]


def test_main_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("def aqr_cache_key(q):\n    return (q.table,)\n")
    assert main([str(bad)]) == 1
    good = tmp_path / "good.py"
    good.write_text("def aqr_cache_key(q, t):\n    return (t.uid, t.version)\n")
    assert main([str(good)]) == 0
    # strict: a stale waiver fails even with no findings
    stale = tmp_path / "stale.py"
    stale.write_text("x = 1  # analyze: waive[KEY01]: nothing here\n")
    assert main([str(stale)]) == 0
    assert main([str(stale), "--strict"]) == 1
    capsys.readouterr()


def test_repo_self_check_strict():
    """The merge gate: zero unexplained findings over src/, no stale waivers."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.analyze", "src/", "--strict", "--quiet"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_hot_closure_does_not_leak_into_training_stack():
    """The serving hot roots must not pull models/ (training) hot through
    generic-name call edges (``stack``, ``body``, ``__init__``...)."""
    from tools.analyze.driver import Context, iter_py_files, parse_module

    mods = [parse_module(f) for f in iter_py_files([str(REPO / "src" / "repro")])]
    ctx = Context(mods)
    leaked = sorted({p for p, _ in ctx.hot
                     if "/models/" in p or "/data/" in p or "/checkpoint/" in p})
    assert not leaked, leaked
    assert any("/core/" in p for p, _ in ctx.hot)  # sanity: closure non-empty
