"""Tests for the runtime sanitizer layer (``repro.runtime.guards``) and the
process-stable hashing behind ``PBDSEngine._select_key``."""
import collections
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.guards import (
    HOT_PATHS,
    LaunchCountError,
    RetraceError,
    hot_path,
    launch_guard,
    retrace_guard,
    sanitize_enabled,
    sanitized,
    transfer_guard,
)
from repro.runtime.stable_hash import canonical_repr, stable_hash32

REPO = Path(__file__).resolve().parent.parent


# -- retrace guard ----------------------------------------------------------


@jax.jit
def _double(x):
    return x * 2.0


def test_retrace_guard_passes_on_cached_execution():
    _double(jnp.ones(8))  # warm
    with retrace_guard(allowed=0):
        _double(jnp.ones(8))
        _double(jnp.zeros(8))  # same shape: same executable


def test_retrace_guard_raises_on_fresh_compile():
    _double(jnp.ones(8))  # warm the 8-class
    with pytest.raises(RetraceError, match="size class"):
        with retrace_guard(allowed=0, label="double"):
            _double(jnp.ones(16))  # new size class: real backend compile


def test_retrace_guard_observe_mode_counts():
    with retrace_guard(allowed=None) as watch:
        _double(jnp.ones(32))  # cold
    assert watch.compiles >= 1
    with retrace_guard(allowed=None) as watch:
        _double(jnp.ones(32))  # warm
    assert watch.compiles == 0


# -- launch guard -----------------------------------------------------------


def test_launch_guard_expect():
    counter = collections.Counter()
    with launch_guard("probe", expect=2, counter=counter):
        counter["probe"] += 1
        counter["probe"] += 1
    with pytest.raises(LaunchCountError, match="expected 1"):
        with launch_guard("probe", expect=1, counter=counter):
            counter["probe"] += 2


def test_launch_guard_observe():
    counter = collections.Counter(probe=5)
    with launch_guard("probe", counter=counter) as watch:
        counter["probe"] += 3
    assert watch.launches == 3


# -- hot_path ----------------------------------------------------------------


def test_hot_path_is_free_and_registers():
    @hot_path
    def serve(x):
        return x

    assert serve.__hot_path__ is True
    assert serve(41) == 41  # no wrapper
    assert any(name.endswith("serve") for name in HOT_PATHS)


def test_engine_entry_points_are_tagged():
    from repro.core.engine import PBDSEngine
    from repro.core.shard import ShardedEngine

    assert PBDSEngine.run.__hot_path__
    assert PBDSEngine.run_batch.__hot_path__
    assert ShardedEngine.run.__hot_path__
    assert ShardedEngine.run_batch.__hot_path__


# -- sanitized() gating ------------------------------------------------------


def test_sanitized_noop_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    with sanitized(allowed_compiles=0) as watch:
        assert watch is None
        _double(jnp.ones((3, 7)))  # fresh compile: no-op guard stays silent


def test_sanitized_armed_when_enabled(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()
    _double(jnp.ones(8))  # warm
    # leaks=False: jax.checking_leaks uses a fresh trace context, which
    # defeats the executable cache and would count as compiles here.
    with sanitized(allowed_compiles=0, transfer=None, leaks=False) as watch:
        _double(jnp.ones(8))
    assert watch is not None and watch.compiles == 0
    with pytest.raises(RetraceError):
        with sanitized(allowed_compiles=0, transfer=None, leaks=False):
            _double(jnp.ones((2, 2, 2)))


def test_transfer_guard_composes():
    # On CPU host==device so "disallow" cannot trip; this pins that the
    # wrapper at least routes through jax.transfer_guard without breaking
    # device code paths.
    with transfer_guard("log"):
        jnp.arange(4).sum()


# -- stable hashing ----------------------------------------------------------


def test_canonical_repr_matches_repr_for_plain_signatures():
    sig = ("tpch", ("a", "b"), ("sum", "x"), None, (">", 1.5), None)
    assert canonical_repr(sig) == repr(sig)
    assert canonical_repr((1,)) == repr((1,))  # 1-tuple trailing comma


def test_canonical_repr_normalizes_np_scalars_and_sets():
    assert canonical_repr(np.float32(1.5)) == canonical_repr(1.5)
    assert canonical_repr(np.int64(7)) == canonical_repr(7)
    assert canonical_repr({"b", "a"}) == canonical_repr({"a", "b"})
    assert canonical_repr({"k": 1, "j": 2}) == canonical_repr({"j": 2, "k": 1})


def test_canonical_repr_rejects_unknown_types():
    with pytest.raises(TypeError):
        canonical_repr(object())


def test_stable_hash32_range():
    h = stable_hash32(("t", (">", 3.0)))
    assert 0 <= h <= 0x7FFFFFFF


_HASH_SCRIPT = textwrap.dedent("""
    from repro.core.queries import Aggregate, Having, Predicate, Query
    from repro.runtime.stable_hash import stable_hash32

    q = Query("tpch", ("region", "nation"), Aggregate("sum", "rev"),
              where=Predicate("qty", ">", 30.0), having=Having(">=", 100.0))
    print(stable_hash32(q.signature()))
    print(stable_hash32(("mixed", frozenset({"b", "a"}), {"z": 1, "y": 2})))
""")


def test_select_key_hash_stable_across_processes():
    """The shard-routing hash must not depend on PYTHONHASHSEED, interning,
    or numpy repr quirks: two processes with different hash seeds must agree
    (distributed routers disagreeing would double-serve / drop queries)."""
    outs = []
    for seed in ("0", "4242"):
        proc = subprocess.run(
            [sys.executable, "-c", _HASH_SCRIPT],
            capture_output=True, text=True, cwd=REPO,
            env={"PYTHONPATH": str(REPO / "src"), "PYTHONHASHSEED": seed,
                 "PATH": "/usr/bin:/bin:/usr/local/bin"})
        assert proc.returncode == 0, proc.stderr
        outs.append(proc.stdout)
    assert outs[0] == outs[1]


def test_select_key_uses_stable_hash():
    """Engine selection keys derive via stable_hash32, not builtin hash."""
    from repro.core.datasets import make_tpch
    from repro.core.engine import PBDSEngine
    from repro.core.queries import Aggregate, Having, Query

    db = make_tpch(2_000, seed=3)
    eng = PBDSEngine(db)
    q = Query("orders", ("o_orderpriority",), Aggregate("count"),
              having=Having(">", 5.0))
    expected = jax.random.fold_in(eng._base_key, stable_hash32(q.signature()))
    assert np.array_equal(np.asarray(eng._select_key(q)), np.asarray(expected))
