"""Reuse-aware, stats-prefiltered, incremental selection (tentpole suite).

Covers the three new selection layers and their contracts:
  * stats pre-filter — dominance pruning from catalog statistics alone,
    never emptying the pool;
  * reuse-aware worth-it — recurring broad templates get admitted (and repeat
    queries become index hits) where paper-faithful admission declines forever;
  * incremental selection — the SelectionCache makes repeat templates pay
    ~zero selection work, invalidating on table mutation;
plus the satellite regressions: the AQR/estimate PRNG key split (cached and
uncached AQR paths must rank candidates identically) and paper-faithful mode
being bit-identical to calling ``select_attribute`` with no config at all.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.aqp.sampling import AQRCache, SampleCache
from repro.core import (
    Aggregate,
    Catalog,
    Database,
    Having,
    Query,
    SelectionCache,
    SelectionConfig,
    WorkloadLog,
    execute,
    select_attribute,
    selection_cache_key,
    stats_prefilter,
)
from repro.core.datasets import make_crimes
from repro.core.engine import PBDSEngine
from repro.core.strategies import PAPER_FAITHFUL
from repro.core.table import from_numpy


@pytest.fixture(scope="module")
def db():
    return Database({"crimes": make_crimes(15_000, seed=21)})


def _broad_q():
    # Every group passes HAVING -> estimated selectivity 1.0.
    return Query("crimes", ("district",), Aggregate("count", None),
                 having=Having(">", 0.0))


def _two_cand_q():
    return Query("crimes", ("district", "month"), Aggregate("count", None),
                 having=Having(">", 50.0))


# -- config / defaults ---------------------------------------------------------

def test_config_defaults_and_paper_faithful():
    cfg = SelectionConfig()
    assert cfg.stats_prefilter and cfg.skip_single_candidate
    assert cfg.reuse_aware and cfg.cache
    pf = SelectionConfig.paper_faithful()
    assert not (pf.stats_prefilter or pf.skip_single_candidate or
                pf.reuse_aware or pf.cache)


def test_no_config_is_paper_faithful(db):
    """``select_attribute`` without a config == explicit paper-faithful mode:
    same attribute, same candidate pool, same estimate values (bit-identical
    seed behavior — acceptance gate)."""
    q = _two_cand_q()
    key = jax.random.PRNGKey(7)
    kwargs = dict(sample_cache=SampleCache(), theta=0.1, catalog=Catalog())
    a = select_attribute("CB-OPT-GB", key, q, db, 10, **kwargs)
    b = select_attribute("CB-OPT-GB", key, q, db, 10, selection=PAPER_FAITHFUL,
                         selection_cache=SelectionCache(), **kwargs)
    assert a.attr == b.attr and a.candidates == b.candidates
    assert set(a.estimates) == set(b.estimates)
    for attr in a.estimates:
        assert a.estimates[attr].est_rows == b.estimates[attr].est_rows
        np.testing.assert_array_equal(a.estimates[attr].est_bits,
                                      b.estimates[attr].est_bits)


# -- stats pre-filter ----------------------------------------------------------

def _skewed_db():
    """'lo' has 2 distinct values (few fat fragments after bound dedupe),
    'hi' is high-cardinality (many slim equi-depth fragments) -> 'hi'
    dominates 'lo' on (n_nonempty, max_frac, min_frac)."""
    n = 4000
    rng = np.random.default_rng(3)
    return Database({"t": from_numpy("t", {
        "lo": (rng.random(n) < 0.5).astype(np.float32),
        "hi": rng.permutation(n).astype(np.float32),
        "v": rng.random(n).astype(np.float32),
    })})


def test_stats_prefilter_prunes_dominated():
    db2 = _skewed_db()
    q = Query("t", ("hi", "lo"), Aggregate("count", None), having=Having(">", 0.0))
    cat = Catalog()
    from repro.core.ranges import equi_depth_ranges
    rf = lambda a: equi_depth_ranges(db2["t"], a, 16)
    out = stats_prefilter(q, db2, ("hi", "lo"), rf, catalog=cat)
    assert out == ("hi",)


def test_stats_prefilter_never_empties():
    db2 = _skewed_db()
    q = Query("t", ("hi", "lo"), Aggregate("count", None), having=Having(">", 0.0))
    from repro.core.ranges import equi_depth_ranges
    rf = lambda a: equi_depth_ranges(db2["t"], a, 16)
    # Identical statistics (same attr twice under different labels is not
    # constructible; use two equal-cardinality permutations): neither
    # dominates, both survive.
    n = 4000
    rng = np.random.default_rng(4)
    db3 = Database({"t": from_numpy("t", {
        "a1": rng.permutation(n).astype(np.float32),
        "a2": rng.permutation(n).astype(np.float32),
    })})
    q3 = Query("t", ("a1", "a2"), Aggregate("count", None), having=Having(">", 0.0))
    rf3 = lambda a: equi_depth_ranges(db3["t"], a, 16)
    assert stats_prefilter(q3, db3, ("a1", "a2"), rf3, catalog=Catalog()) == ("a1", "a2")
    # Single candidate short-circuits untouched.
    assert stats_prefilter(q, db2, ("lo",), rf, catalog=Catalog()) == ("lo",)
    assert stats_prefilter(q, db2, (), rf, catalog=Catalog()) == ()


def test_stats_prefilter_in_engine_skips_estimation_of_dominated(db):
    """End-to-end: with the pre-filter on, the dominated candidate never
    reaches the estimate pass (it is absent from sel.estimates)."""
    db2 = _skewed_db()
    q = Query("t", ("hi", "lo"), Aggregate("count", None), having=Having(">", 2.0))
    eng = PBDSEngine(db2, strategy="CB-OPT-GB", n_ranges=16, theta=0.2, seed=0,
                     selection=SelectionConfig(skip_single_candidate=False))
    res, info = eng.run(q)
    assert res.canonical() == execute(q, db2).canonical()
    pf = PBDSEngine(db2, strategy="CB-OPT-GB", n_ranges=16, theta=0.2, seed=0,
                    selection=SelectionConfig.paper_faithful())
    res_pf, _ = pf.run(q)
    assert res_pf.canonical() == res.canonical()


# -- single-candidate shortcut -------------------------------------------------

def test_single_candidate_shortcut_skips_sampling(db):
    q = Query("crimes", ("district",), Aggregate("count", None),
              having=Having(">", 50.0))
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1, seed=0)
    res, info = eng.run(q)
    assert info.created and info.attr == "district"
    # The whole sample/AQR/estimate stack was skipped.
    assert eng.samples.misses == 0 and eng.aqr.misses == 0
    assert res.canonical() == execute(q, db).canonical()


# -- reuse-aware admission -----------------------------------------------------

def test_reuse_aware_creates_where_paper_declines(db):
    q = _broad_q()
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1,
                     min_selectivity_gain=0.9, seed=0,
                     selection=SelectionConfig(skip_single_candidate=False))
    res, info = eng.run(q)
    assert info.created  # paper-faithful admission declines this (sel == 1.0)
    res2, info2 = eng.run(q)
    assert info2.reused  # ...and the repeat is an index hit, not a re-selection
    assert res.canonical() == res2.canonical() == execute(q, db).canonical()


def test_reuse_discount_flips_admission_after_enough_repeats(db):
    """With a low gain bar the discount needs reach to accumulate: the same
    broad template is declined first, then admitted once the window shows it
    recurring (1.0 - 0.12*reach < 0.5 at the 5th miss)."""
    q = _broad_q()
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1,
                     min_selectivity_gain=0.5, seed=0,
                     selection=SelectionConfig(skip_single_candidate=False))
    outcomes = []
    for _ in range(6):
        _, info = eng.run(q)
        outcomes.append((info.created, info.reused))
    assert outcomes[:4] == [(False, False)] * 4   # declined while reach is low
    assert outcomes[4] == (True, False)           # 5th miss: reach 5 flips it
    assert outcomes[5] == (False, True)           # then ordinary index hits
    # Declined repeats were selection-cache hits: one estimate pass total.
    assert eng.aqr.misses == 1
    assert eng.selection_cache.hits >= 3


def test_workload_log_reach_window_and_stamps():
    wl = WorkloadLog(window=3)
    q1 = _broad_q()
    q2 = dataclasses.replace(q1, having=Having(">", 10.0))  # q1 subsumes q2
    s1 = wl.record(q1)
    s2 = wl.record(q2)
    assert (s1, s2) == (1, 2)
    assert wl.reach(q1) == 2          # subsumes both
    assert wl.reach(q2) == 1          # subsumes only itself
    assert wl.reach(q1, stamp=s1) == 1  # prefix-exact
    # Window eviction: 3 more records push q1/q2 out.
    for _ in range(3):
        wl.record(q1)
    assert len(wl) == 3
    assert wl.reach(q2) == 0
    # Batch stamps are reserved per position, independent of record order.
    wl2 = WorkloadLog()
    wl2.record(q1)
    wl2.begin_batch(4)
    assert [wl2.batch_stamp(i) for i in range(4)] == [2, 3, 4, 5]
    wl2.record(q2, stamp=wl2.batch_stamp(3))
    wl2.record(q1, stamp=wl2.batch_stamp(1))
    assert wl2.reach(q1, stamp=wl2.batch_stamp(1)) == 2  # q1@1 + earlier q1
    assert wl2.reach(q1, stamp=wl2.batch_stamp(3)) == 3  # ...plus q2@3


def test_selection_state_survives_coordinator_restart(db):
    """Restart persistence (the "one WorkloadLog across restarts" follow-up):
    ``selection_state()`` round-trips through pickle into a fresh engine,
    which keeps accumulating reach instead of reverting to reuse-blind
    declines — the 5th miss overall flips to created exactly as it would
    have without the restart."""
    import pickle

    def mk():
        return PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1,
                          min_selectivity_gain=0.5, seed=0,
                          selection=SelectionConfig(
                              skip_single_candidate=False))

    q = _broad_q()
    eng = mk()
    for _ in range(4):
        _, info = eng.run(q)
        assert not info.created  # declined while reach accumulates
    blob = pickle.dumps(eng.selection_state())  # the checkpoint payload

    fresh = mk()
    _, info = fresh.run(q)
    assert not info.created  # control: a blank restart is reuse-blind again

    restarted = mk()
    restarted.restore_selection_state(pickle.loads(blob))
    assert restarted.workload.clock == eng.workload.clock
    assert restarted.workload.reach(q) == eng.workload.reach(q)
    assert restarted.selection_cache.hits == eng.selection_cache.hits
    assert restarted.selection_cache.misses == eng.selection_cache.misses
    _, info = restarted.run(q)
    assert info.created  # reach carried over: the flip lands on schedule
    _, info = restarted.run(q)
    assert info.reused


# -- incremental selection (SelectionCache) ------------------------------------

def test_selection_cache_repeat_template_pays_zero(db):
    """A repeat of the same template (different threshold) never re-enters
    the sampling/estimate stack — the whole pass is memoized."""
    q1 = _two_cand_q()
    q2 = dataclasses.replace(q1, having=Having(">", 120.0))
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1, seed=0,
                     min_selectivity_gain=2.0,  # always create
                     selection=SelectionConfig(skip_single_candidate=False))
    eng.run(q1)
    aqr_misses, sample_misses = eng.aqr.misses, eng.samples.misses
    _, info2 = eng.run(q2)  # same template, tighter threshold -> index hit
    assert info2.reused
    # Force a genuine selection for a non-subsumed sibling: LOOSER threshold.
    q3 = dataclasses.replace(q1, having=Having(">", 10.0))
    _, info3 = eng.run(q3)
    assert info3.created
    assert eng.selection_cache.hits >= 1
    assert eng.aqr.misses == aqr_misses and eng.samples.misses == sample_misses


def test_selection_cache_invalidates_on_mutation(db):
    q = _two_cand_q()
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1, seed=0,
                     min_selectivity_gain=2.0,
                     selection=SelectionConfig(skip_single_candidate=False))
    eng.run(q)
    misses0 = eng.selection_cache.misses
    fact = eng.db["crimes"]
    batch = {a: np.asarray(fact[a])[:32] for a in fact.schema}
    eng.append_rows("crimes", batch)
    q2 = dataclasses.replace(q, having=Having(">", 10.0))
    eng.run(q2)
    # New table version -> new cache key -> the pass recomputed.
    assert eng.selection_cache.misses > misses0


def test_selection_cache_unit():
    cache = SelectionCache(max_entries=2)
    from repro.core.strategies import SelectionResult
    r = SelectionResult("CB-OPT-GB", "a", ("a",), {})
    k1, k2, k3 = (("s", 1, 1, 0.1, 10, (None, None), "t1"),
                  ("s", 1, 1, 0.1, 10, (None, None), "t2"),
                  ("s", 1, 1, 0.1, 10, (None, None), "t3"))
    assert cache.get(k1) is None and cache.misses == 1
    cache.put(k1, r)
    assert cache.get(k1) is r and cache.hits == 1
    cache.put(k2, r)
    cache.put(k3, r)  # FIFO evicts k1
    assert len(cache) == 2 and cache.get(k1) is None
    # invalidate() matches the table name at key index 6.
    cache.invalidate("t2")
    assert len(cache) == 1 and cache.get(k2) is None


def test_selection_cache_key_separates_having_ops(db):
    q_gt = _two_cand_q()
    q_eq = dataclasses.replace(q_gt, having=Having("==", 50.0))
    t = db["crimes"]
    assert (selection_cache_key("CB-OPT-GB", q_gt, t, 0.1, 10)
            != selection_cache_key("CB-OPT-GB", q_eq, t, 0.1, 10))


# -- satellite 1: AQR/estimate key split ---------------------------------------

def test_cached_and_uncached_aqr_paths_rank_identically(db):
    """Regression for the reused-``k_e`` bug: with the key split, running
    selection through an AQRCache and without one must produce identical
    candidate rankings and estimate values."""
    q = _two_cand_q()
    key = jax.random.PRNGKey(11)
    common = dict(theta=0.1, catalog=Catalog())
    uncached = select_attribute("CB-OPT-GB", key, q, db, 10,
                                sample_cache=SampleCache(), aqr_cache=None,
                                **common)
    cached = select_attribute("CB-OPT-GB", key, q, db, 10,
                              sample_cache=SampleCache(), aqr_cache=AQRCache(),
                              **common)
    assert uncached.attr == cached.attr
    assert uncached.topk == cached.topk
    assert set(uncached.estimates) == set(cached.estimates)
    for a in uncached.estimates:
        assert uncached.estimates[a].est_rows == cached.estimates[a].est_rows


# -- batched admission parity under both configs -------------------------------

@pytest.mark.parametrize("cfg", [None, "paper_faithful"])
def test_run_batch_parity_with_selection_configs(db, cfg):
    sel = SelectionConfig.paper_faithful() if cfg else None
    from repro.core.workload import CRIMES_SPEC, generate_workload
    qs = generate_workload(CRIMES_SPEC, db, 8, seed=5)
    mk = lambda: PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=10, theta=0.1,
                            seed=0, selection=sel)
    e_seq, e_bat = mk(), mk()
    seq = [e_seq.run(q) for q in qs]
    bat = e_bat.run_batch(qs)
    for i, (s, b) in enumerate(zip(seq, bat)):
        assert s[0].canonical() == b[0].canonical(), i
        assert (s[1].reused, s[1].created, s[1].attr) == (
            b[1].reused, b[1].created, b[1].attr), i
    assert len(e_seq.index) == len(e_bat.index)
    es = sorted(e_seq.index.entries(), key=lambda e: repr(e.query.signature()))
    eb = sorted(e_bat.index.entries(), key=lambda e: repr(e.query.signature()))
    for a, b in zip(es, eb):
        assert a.query.signature() == b.query.signature()
        np.testing.assert_array_equal(a.sketch.bits, b.sketch.bits)
    # The two engines' workload logs agree entry-for-entry (stamp order).
    if sel is None:
        sa = sorted((s, repr(p.signature())) for s, p in e_seq.workload.entries())
        sb = sorted((s, repr(p.signature())) for s, p in e_bat.workload.entries())
        assert [x[1] for x in sa] == [x[1] for x in sb]
