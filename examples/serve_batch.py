"""Example: batched serving with sketch-filtered admission + KV-cache decode.

  PYTHONPATH=src python examples/serve_batch.py
"""
import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-27b",
     "--requests", "8", "--prompt-len", "48", "--gen", "12"],
    check=True,
)
