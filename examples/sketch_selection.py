"""Compare all candidate-selection strategies on one workload (paper Sec. 11.3).

  PYTHONPATH=src python examples/sketch_selection.py
"""
import jax
import numpy as np

from repro.core import Database, capture_sketch, equi_depth_ranges, select_attribute
from repro.core.datasets import make_crimes
from repro.core.workload import CRIMES_SPEC, generate_workload

db = Database({"crimes": make_crimes(150_000)})
queries = generate_workload(CRIMES_SPEC, db, 8, seed=1)
key = jax.random.PRNGKey(0)

print(f"{'strategy':14s} {'mean selectivity':>18s} {'mean #candidates':>18s}")
for strat in ("RAND-PK", "RAND-AGG", "RAND-GB", "CB-OPT-GB", "CB-OPT", "OPT"):
    sels, cands = [], []
    for i, q in enumerate(queries):
        sel = select_attribute(strat, jax.random.fold_in(key, i), q, db, 100, theta=0.05)
        if sel.attr is None:
            continue
        sk = capture_sketch(q, db, equi_depth_ranges(db["crimes"], sel.attr, 100))
        sels.append(sk.selectivity)
        cands.append(len(sel.candidates))
    print(f"{strat:14s} {np.mean(sels):18.3f} {np.mean(cands):18.1f}")
print("\nCost-based-GB matches OPT at a fraction of the candidates —")
print("the paper's headline result (Sec. 11.3.4).")
