"""Quickstart: provenance sketches + cost-based selection in ~60 lines.

Reproduces the paper's running example (Fig. 1), then runs the full online
engine on a synthetic crime workload.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    Aggregate, Database, Having, Query, RangeSet, capture_sketch, execute,
    execute_with_sketch,
)
from repro.core.datasets import make_crimes, paper_example_db
from repro.core.engine import PBDSEngine

# --- 1. The paper's Fig. 1 example ------------------------------------------
db = paper_example_db()
q = Query(
    table="crimes",
    groupby=("pid", "month", "year"),
    agg=Aggregate("sum", "records"),
    having=Having(">=", 100),
)
print("Q_highcrime result:", execute(q, db).canonical())

for attr, bounds in [
    ("pid", [3.5, 6.5]), ("month", [4.5, 8.5]), ("year", [2012.5, 2020.5])
]:
    sk = capture_sketch(q, db, RangeSet(attr, np.array(bounds)))
    print(f"sketch on {attr:6s}: fragments={sk.bits.astype(int).tolist()} "
          f"selectivity={sk.selectivity:.3f}")
print("=> 'year' is the optimal choice, as in the paper.\n")

# --- 2. The online engine on a real-sized table ------------------------------
big = Database({"crimes": make_crimes(200_000)})
# min_selectivity_gain=0.98: create the sketch even when the estimated win is
# modest, so the reuse and maintenance paths below have something to show.
eng = PBDSEngine(big, strategy="CB-OPT-GB", n_ranges=100, theta=0.05,
                 min_selectivity_gain=0.98)
# Group on (district, year): the hot districts concentrate the passing
# groups geographically, which is exactly when a sketch pays off.
base = Query(table="crimes", groupby=("district", "year"),
             agg=Aggregate("sum", "records"))
tau = float(np.quantile(execute(base, big).values, 0.9))
q2 = Query(
    table="crimes",
    groupby=("district", "year"),
    agg=Aggregate("sum", "records"),
    having=Having(">", tau),
)
res, info = eng.run(q2)  # cold: samples, estimates, captures
sel_str = f"{info.selectivity:.3f}" if info.selectivity is not None else "n/a"
print(f"cold run : attr={info.attr} selectivity={sel_str} "
      f"select={info.t_select*1e3:.0f}ms capture={info.t_capture*1e3:.0f}ms "
      f"exec={info.t_execute*1e3:.0f}ms")
res2, info2 = eng.run(q2)  # warm: sketch index hit
print(f"warm run : reused={info2.reused} exec={info2.t_execute*1e3:.0f}ms")
assert res.canonical() == res2.canonical()

# sketched execution vs full scan (both through the engine's warm catalog)
sk = eng.index.lookup(q2)
import time
execute(q2, big, catalog=eng.catalog)  # warm both paths' cached state
execute_with_sketch(q2, big, sk, catalog=eng.catalog)
t0 = time.perf_counter(); execute(q2, big, catalog=eng.catalog); t_full = time.perf_counter() - t0
t0 = time.perf_counter(); execute_with_sketch(q2, big, sk, catalog=eng.catalog); t_sk = time.perf_counter() - t0
print(f"full scan {t_full*1e3:.0f}ms vs sketched {t_sk*1e3:.0f}ms "
      f"({t_full/max(t_sk,1e-9):.1f}x)")

# --- 3. Incremental maintenance: the table mutates, the sketch repairs ------
# Tables are versioned: `engine.append_rows` / `engine.delete_rows` produce a
# delta-aware new version, and the next index hit repairs the stored sketch
# from the delta alone (per-fragment provenance counters — no re-capture, no
# full-table re-bucketization).  `RunInfo.repaired` reports it happened.
fresh = make_crimes(5_000, seed=99)
eng.append_rows("crimes", {a: np.asarray(fresh[a]) for a in fresh.schema})
eng.delete_rows("crimes", np.asarray(eng.db["crimes"]["year"]) < 2011)
t0 = time.perf_counter()
res3, info3 = eng.run(q2)  # hit on a mutated table -> transparent repair
t_repair = time.perf_counter() - t0
print(f"mutated run: reused={info3.reused} repaired={info3.repaired} "
      f"total={t_repair*1e3:.0f}ms "
      f"(maintained={eng.catalog.stats['sketch_maintained']}, "
      f"recaptured={eng.catalog.stats['sketch_recaptured']})")
assert res3.canonical() == execute(q2, eng.db).canonical()

# The same machinery is available standalone: build_maintainer(q, db, ranges)
# -> .apply(table, db) after each table.append/.delete -> .to_sketch(table);
# monotone-unsafe aggregates keep bits conservatively until .repair().

# --- 4. Batched admission: one shared sample serves a 16-query miss batch ---
# Under heavy traffic, cold queries arrive in bursts that differ only in
# their thresholds.  `run_batch` probes the index (hits serve immediately),
# then groups the misses by inner-block signature: each group shares ONE
# stratified sample + ONE AQR estimate pass, all selection math runs as a
# single padded device launch, one table scan feeds every admitted sketch's
# provenance, and capture emits all bitvectors from one fused kernel launch.
# Results and sketches are bit-identical to running the queries one by one.
eng2 = PBDSEngine(big, strategy="CB-OPT-GB", n_ranges=100, theta=0.05,
                  min_selectivity_gain=0.98)
taus16 = np.quantile(execute(base, big).values, np.linspace(0.99, 0.86, 16))
batch = [Query(table="crimes", groupby=("district", "year"),
               agg=Aggregate("sum", "records"), having=Having(">", float(t)))
         for t in taus16]
t0 = time.perf_counter()
outs = eng2.run_batch(batch)  # all 16 miss: shared selection + fused capture
t_batch = time.perf_counter() - t0
n_created = sum(1 for _, i in outs if i.created)
# With default selection the whole batch may pay ZERO sampling work: the
# stats pre-filter + single-candidate shortcut admit estimate-free when only
# one candidate survives (see section 8).
print(f"batched admission: {len(batch)} cold queries in {t_batch*1e3:.0f}ms "
      f"({n_created} sketches created, {eng2.samples.misses} sample draw(s), "
      f"{eng2.aqr.misses} AQR pass(es))")
for q, (r, _) in zip(batch, outs):
    assert r.canonical() == execute(q, big).canonical()
outs2 = eng2.run_batch(batch)  # steady state: every query is an index hit
print(f"replayed batch: {sum(1 for _, i in outs2 if i.reused)}/16 index hits, "
      f"mean exec {np.mean([i.t_execute for _, i in outs2])*1e3:.1f}ms/query")

# --- 5. Fragment-sharded serving: route the sketch, skip whole shards -------
# Fragments are the unit of horizontal scale-out: a ShardedEngine places the
# clustered table's fragments across shards and serves an index hit by
# routing the sketch's fragment-id set to only the owning shards, merging
# their per-group partial aggregates.  Mutations ship per-shard deltas that
# apply lazily; reads gate on a version watermark instead of a global lock.
from repro.core import ShardedEngine

sharded = ShardedEngine(big, "crimes", "district", n_shards=2, n_ranges=100,
                        theta=0.05, min_selectivity_gain=0.98)
sharded.run(q2)  # cold: coordinator captures + registers per-shard maintainers
res_s, info_s = sharded.run(q2)  # warm: routed to owning shards only
print(f"sharded run: reused={info_s.reused} "
      f"contacted={info_s.shards_contacted}/{sharded.n_shards} shards "
      f"(skipped {info_s.shards_skipped}) exec={info_s.t_execute*1e3:.0f}ms")
assert res_s.canonical() == execute(q2, sharded.db).canonical()

# Deltas replicate lazily: shards lag until the next read's watermark gate.
sharded.append_rows("crimes", {a: np.asarray(fresh[a]) for a in fresh.schema})
print(f"after append: coordinator v{sharded.version}, "
      f"slowest shard v{sharded.min_watermark()}")
res_s2, info_s2 = sharded.run(q2)  # read drains inboxes, repairs, routes
print(f"mutated sharded run: repaired={info_s2.repaired} "
      f"contacted={info_s2.shards_contacted} skipped={info_s2.shards_skipped}")
assert res_s2.canonical() == execute(q2, sharded.db).canonical()

# --- 6. SPMD batched serving: a whole hit batch in ONE XLA launch ------------
# The warm hit path is fused: registered sketch instances live as stacked
# shard-major arrays (pow2-padded, global group dictionary), so a batch of
# hits — even across different sketches — computes all B x S per-group
# partials in a single program; each query then finishes its own HAVING
# tail on the merged state.  Misses in the same batch go through the shared
# admission pipeline and their captures broadcast to every shard in one pass.
taus_s = np.quantile(execute(base, big).values, (0.97, 0.92, 0.9))
shard_batch = [Query(table="crimes", groupby=("district", "year"),
                     agg=Aggregate("sum", "records"), having=Having(">", float(t)))
               for t in taus_s] + [q2]
sharded.run_batch(shard_batch)   # admits the new sketches, registers shards
sharded.run_batch(shard_batch)   # first hit serve: builds + caches the stacks
t0 = time.perf_counter()
outs_s = sharded.run_batch(shard_batch)  # steady state: all hits, one launch
t_sb = time.perf_counter() - t0
route = sharded.last_route
print(f"sharded run_batch: {len(shard_batch)} hits in {t_sb*1e3:.1f}ms "
      f"({t_sb/len(shard_batch)*1e3:.2f}ms/query, fused={route.fused}, "
      f"one launch for {route.n_queries} queries)")
for q_i, (r_i, i_i) in zip(shard_batch, outs_s):
    assert i_i.reused
    assert r_i.canonical() == execute(q_i, sharded.db).canonical()

# --- 7. Chaos tolerance: kill a shard, keep serving, rebalance, recover ------
# Shards fail.  The engine tracks per-shard health (retry wrappers + straggler
# monitors), serves a down shard's fragment slices coordinator-side (degraded
# mode — bit-identical, just slower), and recovers a rejoining shard from its
# checkpoint + the coordinator's delta log — never by re-capturing sketches.
sharded.shards[1].inject("kill")        # all of shard 1's local state is gone
res_d, info_d = sharded.run(q2)         # ...but serving never stops
route = sharded.last_route
print(f"shard 1 killed: degraded={info_d.degraded} "
      f"failed_shards={route.failed_shards} health={sharded.health}")
assert res_d.canonical() == execute(q2, sharded.db).canonical()

sharded.run(q2)                          # second failed contact: suspect->dead
rebuilt = sharded.rebalance()            # re-place its fragments on survivors
print(f"rebalanced: fragments moved to shards {sorted(set(rebuilt))}, "
      f"shard 1 now owns {sharded.plan.fragments_of(1).size} fragments")
res_r, info_r = sharded.run(q2)          # clean (non-degraded) serving again
assert not info_r.degraded
assert res_r.canonical() == execute(q2, sharded.db).canonical()

sharded.shards[1].heal()                 # the shard process comes back
sharded.run(q2)                          # probe -> recover -> healthy
print(f"shard 1 rejoined: health={sharded.health} "
      f"watermark v{sharded.min_watermark()} == coordinator v{sharded.version}")

# The same arc is scriptable: repro.runtime.chaos replays seeded fault
# schedules (kill/stall/partition/flaky/heal) against seeded workloads and
# asserts chaotic traces equal fault-free ones bit-for-bit (`differential`).

# --- 8. Reuse-aware, stats-prefiltered, incremental selection ----------------
# The selection critical path has four default-on layers (SelectionConfig):
#   stats_prefilter       dominance-prune candidates from catalog fragment
#                         statistics alone, before any sampling;
#   skip_single_candidate a pool of one admits estimate-free (no sample, no
#                         AQR pass, no estimate launch);
#   cache                 whole selection passes memoized per (table version,
#                         template) — repeat templates pay ~zero;
#   reuse_aware           the worth-it rule discounts estimated coverage by
#                         reuse_weight x (subsumption reach over the last
#                         reuse_window misses): templates the workload shows
#                         recurring get admitted even when broad, so repeats
#                         become index hits instead of re-paying selection.
from repro.core import SelectionConfig

eng3 = PBDSEngine(big, strategy="CB-OPT-GB", n_ranges=100, theta=0.05,
                  selection=SelectionConfig(reuse_window=256, reuse_weight=0.12))
broad = Query("crimes", ("district",), Aggregate("count", None),
              having=Having(">", 0.0))  # every group passes: coverage ~1.0
_, b1 = eng3.run(broad)
_, b2 = eng3.run(broad)
print(f"reuse-aware: broad template first={'created' if b1.created else 'declined'}, "
      f"repeat={'index hit' if b2.reused else 'miss'} "
      f"(selection passes paid: {eng3.selection_cache.misses})")
# Paper-faithful Sec. 8-9 selection (every safe candidate sampled and
# estimated, admission by estimated coverage alone) is one switch away —
# benchmarks comparing against the paper use exactly this:
pf = PBDSEngine(big, strategy="CB-OPT-GB", n_ranges=100, theta=0.05,
                selection=SelectionConfig.paper_faithful())
_, p1 = pf.run(broad)
print(f"paper-faithful: broad template "
      f"{'created' if p1.created else 'declined (coverage 1.0 >= 0.9)'}")
assert b1.created and b2.reused and not p1.created

# --- 9. Real process-boundary shards: RPC transport, genuine failures --------
# Everything above ran shards in-process (transport="loopback").  Flip one
# switch and each FragmentShard becomes a separate OS process serving over a
# unix-socket RPC (length-prefixed pickle-5 frames, per-op deadlines).  The
# failure semantics stop being simulated: "kill" is a real SIGKILL — the
# process and ALL its state are gone — and recovery really does respawn a
# server, ship the checkpoint, replay the coordinator's delta log and
# re-register maintainers.  Results stay bit-identical throughout.
import os

rpc = ShardedEngine(big, "crimes", "district", n_shards=2, n_ranges=100,
                    theta=0.05, min_selectivity_gain=0.98,
                    transport="subprocess")
try:
    rpc.run(q2)                          # cold: capture + register over RPC
    res_p, info_p = rpc.run(q2)          # warm: routed over RPC
    pids = [s.pid for s in rpc.shards]
    print(f"subprocess shards: coordinator pid={os.getpid()} "
          f"servers={pids} reused={info_p.reused}")
    assert res_p.canonical() == execute(q2, rpc.db).canonical()

    pid0 = rpc.shards[1].pid
    rpc.shards[1].inject("kill")         # SIGKILL: the OS process is gone
    try:
        os.kill(pid0, 0)
        raise AssertionError("server survived the kill?")
    except ProcessLookupError:
        pass
    res_k, info_k = rpc.run(q2)          # serving continues, degraded
    assert info_k.degraded
    assert res_k.canonical() == execute(q2, rpc.db).canonical()

    rpc.shards[1].heal()                 # respawn from the warm server pool
    res_h, info_h = rpc.run(q2)          # ckpt ship -> replay -> re-register
    print(f"killed pid {pid0} -> respawned pid {rpc.shards[1].pid}: "
          f"degraded={info_h.degraded} health={rpc.health}")
    assert not info_h.degraded and rpc.shards[1].pid != pid0
    assert res_h.canonical() == execute(q2, rpc.db).canonical()
finally:
    rpc.shutdown()                       # servers return to the warm pool

# --- 10. Coordinator failover: kill the coordinator, the standby takes over --
# The shards can die; now the *coordinator* can too.  A FailoverCoordinator
# streams every metadata mutation (registrations, delta logs, checkpoints,
# selection state) to a warm standby as sequenced replication records.  Kill
# the coordinator and the standby folds that stream into a full replacement:
# it re-attaches to the still-running shard servers under a bumped epoch —
# the shards' state never moves, and every index hit is STILL a hit (the
# registrations replicated, so nothing is re-captured).  A partitioned old
# coordinator that still believes it is in charge gets fenced: its ops
# raise StaleEpochError at the shard.
from repro.core import StaleEpochError
from repro.core.standby import FailoverCoordinator

fc = FailoverCoordinator(ShardedEngine(
    big, "crimes", "district", n_shards=2, n_ranges=100,
    theta=0.05, min_selectivity_gain=0.98, transport="subprocess"))
try:
    fc.run(q2)                           # cold: capture + register
    res_a, info_a = fc.run(q2)           # warm: index hit
    assert info_a.reused
    pids = [s.pid for s in fc.shards]

    fc.inject_coord("coord_kill")        # the coordinator is GONE
    misses = fc.index.misses
    res_b, info_b = fc.run(q2)           # the standby serves the same hit
    print(f"takeover: epoch={fc.engine.epoch} shard pids {pids} -> "
          f"{[s.pid for s in fc.shards]} reused={info_b.reused}")
    assert info_b.reused and fc.index.misses == misses  # no re-capture
    assert [s.pid for s in fc.shards] == pids           # no state moved
    assert res_b.canonical() == res_a.canonical()

    fc.inject_coord("coord_partition")   # now a zombie coordinator lingers
    try:
        fc.zombie.shards[0].catch_up(fc.zombie.version)
        raise AssertionError("zombie write went through?")
    except StaleEpochError as e:
        print(f"zombie coordinator fenced: {e}")
    res_c, _ = fc.run(q2)                # takeovers chain: #3 serves too
    assert res_c.canonical() == res_a.canonical()
finally:
    fc.shutdown()
