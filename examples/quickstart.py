"""Quickstart: provenance sketches + cost-based selection in ~60 lines.

Reproduces the paper's running example (Fig. 1), then runs the full online
engine on a synthetic crime workload.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    Aggregate, Database, Having, Query, RangeSet, capture_sketch, execute,
    execute_with_sketch,
)
from repro.core.datasets import make_crimes, paper_example_db
from repro.core.engine import PBDSEngine

# --- 1. The paper's Fig. 1 example ------------------------------------------
db = paper_example_db()
q = Query(
    table="crimes",
    groupby=("pid", "month", "year"),
    agg=Aggregate("sum", "records"),
    having=Having(">=", 100),
)
print("Q_highcrime result:", execute(q, db).canonical())

for attr, bounds in [
    ("pid", [3.5, 6.5]), ("month", [4.5, 8.5]), ("year", [2012.5, 2020.5])
]:
    sk = capture_sketch(q, db, RangeSet(attr, np.array(bounds)))
    print(f"sketch on {attr:6s}: fragments={sk.bits.astype(int).tolist()} "
          f"selectivity={sk.selectivity:.3f}")
print("=> 'year' is the optimal choice, as in the paper.\n")

# --- 2. The online engine on a real-sized table ------------------------------
big = Database({"crimes": make_crimes(200_000)})
eng = PBDSEngine(big, strategy="CB-OPT-GB", n_ranges=100, theta=0.05)
q2 = Query(
    table="crimes",
    groupby=("district", "month", "year"),
    agg=Aggregate("sum", "records"),
    having=Having(">", 600.0),
)
res, info = eng.run(q2)  # cold: samples, estimates, captures
sel_str = f"{info.selectivity:.3f}" if info.selectivity is not None else "n/a"
print(f"cold run : attr={info.attr} selectivity={sel_str} "
      f"select={info.t_select*1e3:.0f}ms capture={info.t_capture*1e3:.0f}ms "
      f"exec={info.t_execute*1e3:.0f}ms")
res2, info2 = eng.run(q2)  # warm: sketch index hit
print(f"warm run : reused={info2.reused} exec={info2.t_execute*1e3:.0f}ms")
assert res.canonical() == res2.canonical()

# sketched execution vs full scan
sk = eng.index.lookup(q2)
import time
t0 = time.perf_counter(); execute(q2, big); t_full = time.perf_counter() - t0
t0 = time.perf_counter(); execute_with_sketch(q2, big, sk); t_sk = time.perf_counter() - t0
print(f"full scan {t_full*1e3:.0f}ms vs sketched {t_sk*1e3:.0f}ms "
      f"({t_full/max(t_sk,1e-9):.1f}x)")
