"""End-to-end driver: train an LM with the PBDS-sketched data pipeline and
demonstrate fault tolerance (checkpoint -> simulated crash -> resume).

  PYTHONPATH=src python examples/train_with_skipping.py
"""
import shutil
import subprocess
import sys

CKPT = "/tmp/repro_example_ckpt"

shutil.rmtree(CKPT, ignore_errors=True)

# Phase 1: train 30 steps, checkpointing every 10.
print("=== phase 1: fresh run (30 steps) ===")
subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-moe-30b-a3b",
     "--steps", "30", "--batch", "8", "--seq", "128", "--ckpt", CKPT,
     "--ckpt-every", "10"],
    check=True,
)

# Phase 2: "node failure" — restart from the latest checkpoint and continue.
print("\n=== phase 2: restart after simulated failure (resume -> 50) ===")
subprocess.run(
    [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-moe-30b-a3b",
     "--steps", "50", "--batch", "8", "--seq", "128", "--ckpt", CKPT,
     "--ckpt-every", "10", "--resume"],
    check=True,
)
print("\nresumed run continued from step 30 with identical pipeline state.")
