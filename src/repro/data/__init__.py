from repro.data.pipeline import (
    CurationSpec,
    SketchedDataPipeline,
    make_corpus_metadata,
)
