"""PBDS-sketched training-data pipeline — the paper's technique as the
framework's data-curation / data-skipping stage.

A training corpus carries a *metadata table* (one row per document: domain,
shard, quality, length, timestamp...).  A **curation query** — a Q-AGH over
that table, e.g. ``GROUP BY (domain, shard) HAVING avg(quality) > tau`` —
defines which data is relevant for the run.  The PBDS engine (cost-based
CB-OPT-GB by default) picks the partition attribute via sample-based size
estimation, captures a provenance sketch, and the loader then **skips whole
fragments**: documents in skipped fragments are never touched, tokenized, or
shipped to devices.  This is exactly the paper's mechanism with "query" =
curation predicate and "physical design" = the corpus' fragment-major shard
layout.

Operational properties needed at scale:
  - deterministic: all sampling/shuffling from a single seed;
  - sharded: each DP rank draws a disjoint document stream (rank, world);
  - resumable: ``state()``/``restore()`` round-trips the cursor, and the
    trainer stores it inside checkpoints;
  - straggler-tolerant: ranks draw by strided index, so reassigning a rank's
    stream after elastic re-mesh needs no data movement.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.core.engine import PBDSEngine
from repro.core.queries import Aggregate, Having, Query
from repro.core.table import ColumnTable, Database, from_numpy

Array = jax.Array


def make_corpus_metadata(
    n_docs: int = 50_000, n_domains: int = 32, n_shards: int = 256, seed: int = 0
) -> ColumnTable:
    """Synthetic corpus metadata with domain-correlated quality (so curation
    queries actually separate data, mirroring the paper's datasets)."""
    rng = np.random.default_rng(seed)
    domain = rng.integers(0, n_domains, n_docs)
    shard = (domain * (n_shards // n_domains) + rng.integers(0, n_shards // n_domains, n_docs))
    base_q = rng.uniform(0.2, 0.9, n_domains)
    quality = np.clip(base_q[domain] + rng.normal(0, 0.15, n_docs), 0, 1)
    length = rng.integers(128, 4096, n_docs)
    timestamp = rng.integers(1_600_000_000, 1_750_000_000, n_docs)
    doc_id = np.arange(n_docs)
    return from_numpy(
        "corpus",
        dict(
            doc_id=doc_id.astype(np.int64),
            domain=domain.astype(np.int32),
            shard=shard.astype(np.int32),
            quality=quality.astype(np.float32),
            length=length.astype(np.int32),
            timestamp=timestamp.astype(np.int64),
        ),
        primary_key=("doc_id",),
    )


@dataclasses.dataclass(frozen=True)
class CurationSpec:
    groupby: Tuple[str, ...] = ("domain", "shard")
    agg: str = "avg"
    agg_attr: str = "quality"
    having_op: str = ">"
    having_value: float = 0.55
    strategy: str = "CB-OPT-GB"
    n_ranges: int = 64
    theta: float = 0.1

    def query(self) -> Query:
        return Query(
            table="corpus",
            groupby=self.groupby,
            agg=Aggregate(self.agg, self.agg_attr),
            having=Having(self.having_op, self.having_value),
        )


class SketchedDataPipeline:
    """Fragment-skipping batch iterator over a sketched corpus."""

    def __init__(
        self,
        metadata: ColumnTable,
        spec: CurationSpec,
        batch_size: int,
        seq_len: int,
        vocab_size: int,
        dp_rank: int = 0,
        dp_size: int = 1,
        seed: int = 0,
    ):
        self.metadata = metadata
        self.spec = spec
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.seed = seed

        self.engine = PBDSEngine(
            Database({"corpus": metadata}),
            strategy=spec.strategy,
            n_ranges=spec.n_ranges,
            theta=spec.theta,
            seed=seed,
            # Fragment-major corpus layout: curation queries group by corpus
            # attributes, so groups stay fragment-contained and selection is
            # unaffected by the reorder; loading skips whole fragments.
            cluster_tables=True,
        )
        q = spec.query()
        _, self.run_info = self.engine.run(q)
        sketch = self.engine.index.lookup(q)
        self.sketch = sketch
        n_docs = metadata.num_rows
        if sketch is not None:
            # Fragment-skipping load: the catalog-cached sketch instance is
            # the surviving fragments' docs (slice concatenation when the
            # engine clustered the corpus fragment-major).
            from repro.core.sketch import apply_sketch
            from repro.core.table import PAD_VALID

            inst = apply_sketch(sketch, self.engine.db, catalog=self.engine.catalog)["corpus"]
            doc_ids = np.asarray(inst["doc_id"])
            if inst.has(PAD_VALID):
                # Instances are pow2-padded with masked duplicate rows (shape
                # stability for the executor); only the valid rows are docs.
                doc_ids = doc_ids[np.asarray(inst[PAD_VALID])]
            self.selected_docs = np.sort(doc_ids)
        else:  # no viable sketch: fall back to exact predicate
            from repro.core.queries import provenance_mask

            keep = provenance_mask(q, self.engine.db, catalog=self.engine.catalog)
            self.selected_docs = np.sort(
                np.asarray(self.engine.db["corpus"]["doc_id"])[keep]
            )
        self.skipped_fraction = 1.0 - len(self.selected_docs) / max(n_docs, 1)
        # Deterministic shuffle; strided rank sharding.
        rng = np.random.default_rng(seed + 17)
        self._order = rng.permutation(self.selected_docs)
        self._cursor = 0
        self._epoch = 0

    # -- iterator state (checkpointable) -----------------------------------
    def state(self) -> Dict[str, Any]:
        return {"cursor": int(self._cursor), "epoch": int(self._epoch), "seed": self.seed}

    def restore(self, state: Dict[str, Any]) -> None:
        self._cursor = int(state["cursor"])
        self._epoch = int(state["epoch"])

    # -- batches ------------------------------------------------------------
    def _doc_tokens(self, doc_ids: np.ndarray) -> np.ndarray:
        """Deterministic per-doc token synthesis (stand-in tokenizer).

        Tokens follow a noisy per-document arithmetic progression so the
        stream is *learnable* (next-token structure exists), which lets the
        example trainer demonstrate real loss descent.
        """
        out = np.empty((len(doc_ids), self.seq_len), np.int32)
        v = self.vocab_size
        for i, d in enumerate(doc_ids):
            rng = np.random.default_rng(int(d) * 1_000_003 + 7)
            start = rng.integers(0, v)
            step = 1 + int(d) % 7
            seq = (start + step * np.arange(self.seq_len)) % v
            noise = rng.random(self.seq_len) < 0.1
            seq = np.where(noise, rng.integers(0, v, self.seq_len), seq)
            out[i] = seq.astype(np.int32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        n = len(self._order)
        per_rank = self.batch_size // self.dp_size
        need = per_rank * self.dp_size
        if self._cursor + need > n:
            self._epoch += 1
            rng = np.random.default_rng(self.seed + 17 + self._epoch)
            self._order = rng.permutation(self.selected_docs)
            self._cursor = 0
        take = self._order[self._cursor : self._cursor + need]
        self._cursor += need
        mine = take[self.dp_rank :: self.dp_size]  # strided => elastic-friendly
        return {"tokens": self._doc_tokens(mine)}
