from repro.train.step import (
    TrainSpec,
    abstract_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
