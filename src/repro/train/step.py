"""Jittable train / prefill / decode steps.

train_step runs gradient accumulation as a ``lax.scan`` over microbatches
(compute/communication overlap: XLA pipelines the FSDP all-gathers of the
next layer against the current layer's matmuls inside the period-scan, and
the single grad all-reduce happens once per *step*, not per microbatch).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import OptConfig, abstract_opt_state, adamw_update

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Per-(arch, shape) fitting knobs — the §Perf hillclimb surface."""

    microbatch: int = 8  # per-step microbatch (global); must divide global batch
    opt: OptConfig = OptConfig()
    acc_dtype: str = "float32"  # grad-accumulator dtype (bf16 halves it at 398B)


def abstract_train_state(cfg: ModelConfig, spec: TrainSpec) -> Dict[str, Any]:
    params = lm.abstract_params(cfg)
    return {"params": params, "opt": abstract_opt_state(params, spec.opt)}


def init_train_state(key: jax.Array, cfg: ModelConfig, spec: TrainSpec) -> Dict[str, Any]:
    from repro.optim.adamw import init_opt_state

    params = lm.concrete_params(key, cfg)
    return {"params": params, "opt": init_opt_state(params, spec.opt)}


def make_train_step(cfg: ModelConfig, spec: TrainSpec):
    """(state, batch) -> (state, metrics).

    ``batch`` leaves have shape (n_micro, micro_batch, ...): the scan axis is
    the accumulation loop.
    """

    acc_dt = jnp.dtype(spec.acc_dtype)

    def train_step(state, batch):
        params = state["params"]

        def micro(carry, mb):
            gacc, lacc = carry
            loss, grads = jax.value_and_grad(lm.loss_fn)(params, cfg, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dt), gacc, grads
            )
            return (gacc, lacc + loss), None

        gzero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params
        )
        n_micro = jax.tree_util.tree_leaves(batch)[0].shape[0]
        (gacc, lsum), _ = jax.lax.scan(micro, (gzero, jnp.zeros((), jnp.float32)), batch)
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, gacc)
        new_params, new_opt, metrics = adamw_update(grads, state["opt"], params, spec.opt)
        metrics["loss"] = lsum / n_micro
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token, pos):
        return lm.decode_step(params, cfg, cache, token, pos)

    return decode_step


def microbatch_reshape(batch: Dict[str, Array], n_micro: int) -> Dict[str, Array]:
    """(B, ...) -> (n_micro, B/n_micro, ...) for the accumulation scan."""

    def leaf(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return jax.tree_util.tree_map(leaf, batch)
