"""Pallas TPU kernel: sketch application (the data-skipping scan).

``keep[i] = bits[bucket[i]]`` — translating a sketch into a row keep-mask.
TPUs have no fast arbitrary gather, so the lookup is expressed as a one-hot
contraction against the bitmap, which the compiler maps onto the VPU: for a
row tile we compute ``max_r bits[r] * (bucket == r)``.  The bitmap block is
pinned in VMEM across the grid; row tiles stream through with the usual
double buffering.  On real partitioned tables the fragment-major layout makes
``bits`` constant per tile, degenerating this to a broadcast — that case is
handled upstream by simply not scheduling skipped fragments (see
``repro/data/pipeline.py``); this kernel covers the unsorted fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 2048
LANE = 128


def _filter_kernel(bucket_ref, bits_ref, out_ref, *, n_ranges_p: int):
    bucket = bucket_ref[...].reshape(-1)  # (rows,)
    bits = bits_ref[...].reshape(-1)  # (n_ranges_p,)
    rows = bucket.shape[0]
    range_ids = jax.lax.broadcasted_iota(jnp.int32, (rows, n_ranges_p), 1)
    onehot = (bucket[:, None] == range_ids).astype(jnp.int32)
    keep = jnp.max(onehot * bits[None, :], axis=1)  # (rows,)
    out_ref[...] = keep.reshape(out_ref.shape)


def sketch_filter_pallas(
    bucket: jax.Array,
    bits: jax.Array,
    rows_per_tile: int = ROWS_PER_TILE,
    interpret: bool = False,
) -> jax.Array:
    """keep (bool[n]) from bucket (int32[n]) and bits (bool[n_ranges])."""
    n = bucket.shape[0]
    n_ranges = bits.shape[0]
    n_pad = -n % rows_per_tile
    bucket_p = jnp.pad(bucket.astype(jnp.int32), (0, n_pad))
    n_ranges_p = n_ranges + (-n_ranges % LANE)
    bits_p = jnp.pad(bits.astype(jnp.int32), (0, n_ranges_p - n_ranges))
    n_tiles = (n + n_pad) // rows_per_tile
    sub = rows_per_tile // LANE

    bucket_2d = bucket_p.reshape(n_tiles * sub, LANE)
    bits_2d = bits_p.reshape(1, n_ranges_p)

    out = pl.pallas_call(
        functools.partial(_filter_kernel, n_ranges_p=n_ranges_p),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((sub, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, n_ranges_p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((sub, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * sub, LANE), jnp.int32),
        interpret=interpret,
    )(bucket_2d, bits_2d)
    return out.reshape(-1)[:n] > 0
