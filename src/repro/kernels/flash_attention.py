"""Pallas TPU kernel: blocked (flash) attention with causal/sliding window.

The model-side compute hot spot.  Online-softmax attention tiled for VMEM:
grid (batch*heads, q blocks, k blocks), with the running max / normalizer /
accumulator held in VMEM scratch across the k-block loop.  Causal and
sliding-window masks are applied per tile, and k-blocks that are entirely
masked for a q-block are skipped via ``pl.when`` — on TPU this prunes ~half
the MXU work for causal training and all-but-`window` for local layers
(gemma3's 5:1 local:global pattern leans on this).

Layouts: q (B, H, S, D), k/v (B, H, T, D), block shapes (1, bq, D)/(1, bk, D)
with D padded to lanes; bq/bk default 128/128 (MXU tile) — set smaller for
interpret-mode tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
    bq: int, bk: int, t_total: int, s_total: int, causal: bool, window: int, scale: float,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Absolute positions; q positions are end-aligned with k (decode-friendly).
    offset = t_total - s_total
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Tile-level skip: causal => skip k-tiles strictly in the future;
    # window  => skip k-tiles entirely left of every q's window.
    q_lo = qi * bq + offset
    q_hi = q_lo + bq - 1
    k_lo = ki * bk
    k_hi = k_lo + bk - 1
    live = True
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window and window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        mask = k_pos < t_total  # padding mask
        if causal:
            mask &= k_pos <= q_pos
        if window and window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """softmax(QK^T/sqrt(d))V, shapes q (B,H,S,D), k/v (B,H,T,D)."""
    b, h, s, d = q.shape
    t = k.shape[2]
    bq_ = min(bq, s)
    bk_ = min(bk, t)
    s_pad = -s % bq_
    t_pad = -t % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    bh = b * h
    qp = qp.reshape(bh, s + s_pad, d)
    kp = kp.reshape(bh, t + t_pad, d)
    vp = vp.reshape(bh, t + t_pad, d)
    grid = (bh, (s + s_pad) // bq_, (t + t_pad) // bk_)
    scale = 1.0 / (d**0.5)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel,
            bq=bq_, bk=bk_, t_total=t, s_total=s,
            causal=causal, window=window, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda bh_, qi, ki: (bh_, qi, 0)),
            pl.BlockSpec((1, bk_, d), lambda bh_, qi, ki: (bh_, ki, 0)),
            pl.BlockSpec((1, bk_, d), lambda bh_, qi, ki: (bh_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda bh_, qi, ki: (bh_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s + s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(b, h, s + s_pad, d)[:, :, :s, :]
