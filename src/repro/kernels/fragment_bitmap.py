"""Pallas TPU kernel: fragment-membership bitmap (sketch capture hot loop).

Computes ``bits[r] = OR_{rows i in fragment r} prov[i]`` — the inner loop of
``capture_sketch``.  The TPU adaptation replaces the row-at-a-time scatter a
CPU engine would use with a *one-hot compare + column-max* over VMEM tiles:
each grid step loads a (ROWS_PER_TILE,)-row tile of (bucket, prov) into VMEM,
materializes the (rows x ranges) one-hot incidence in registers/VMEM, reduces
over rows with a max, and accumulates into the bitmap block that stays
resident in VMEM across the whole grid (index_map pins it to block 0).

VMEM budget per step (defaults): 2048 x 1024 int8 one-hot ≈ 2 MiB + tiles,
comfortably inside the ~16 MiB v5e VMEM while leaving room for double
buffering of the streamed row tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 2048
LANE = 128


def _bitmap_kernel(bucket_ref, prov_ref, out_ref, *, n_ranges_p: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bucket = bucket_ref[...].reshape(-1)  # (rows,)
    prov = prov_ref[...].reshape(-1)  # (rows,) int32 0/1
    rows = bucket.shape[0]
    # One-hot incidence of this tile's rows against every range id.
    range_ids = jax.lax.broadcasted_iota(jnp.int32, (rows, n_ranges_p), 1)
    hit = jnp.where((bucket[:, None] == range_ids) & (prov[:, None] > 0), 1, 0)
    tile_bits = jnp.max(hit, axis=0)  # (n_ranges_p,)
    out_ref[...] = jnp.maximum(out_ref[...], tile_bits.reshape(out_ref.shape))


def fragment_bitmap_pallas(
    bucket: jax.Array,
    prov: jax.Array,
    n_ranges: int,
    rows_per_tile: int = ROWS_PER_TILE,
    interpret: bool = False,
) -> jax.Array:
    """bits (bool[n_ranges]) from bucket (int32[n]) and prov (bool[n])."""
    n = bucket.shape[0]
    n_pad = -n % rows_per_tile
    # Padding rows point at range 0 with prov=False: they contribute nothing.
    bucket_p = jnp.pad(bucket.astype(jnp.int32), (0, n_pad))
    prov_p = jnp.pad(prov.astype(jnp.int32), (0, n_pad))
    n_ranges_p = n_ranges + (-n_ranges % LANE)
    n_tiles = (n + n_pad) // rows_per_tile

    # 2-D views so the last dim is lane-aligned on TPU.
    bucket_2d = bucket_p.reshape(n_tiles * (rows_per_tile // LANE), LANE)
    prov_2d = prov_p.reshape(n_tiles * (rows_per_tile // LANE), LANE)
    sub = rows_per_tile // LANE

    out = pl.pallas_call(
        functools.partial(_bitmap_kernel, n_ranges_p=n_ranges_p),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((sub, LANE), lambda i: (i, 0)),
            pl.BlockSpec((sub, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_ranges_p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_ranges_p), jnp.int32),
        interpret=interpret,
    )(bucket_2d, prov_2d)
    return out[0, :n_ranges] > 0


def _bitmap_batch_kernel(bucket_ref, provs_ref, out_ref, *, n_ranges_p: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bucket = bucket_ref[...].reshape(-1)  # (rows,)
    provs = provs_ref[...].reshape(provs_ref.shape[0], -1).astype(jnp.float32)  # (B, rows)
    rows = bucket.shape[0]
    # One-hot incidence of the tile's rows against every range id, contracted
    # against ALL provenance masks at once: (B, rows) @ (rows, ranges) on the
    # MXU, so the per-query cost of capturing B sketches from one scan is a
    # slice of a single matmul instead of B segmented reductions.
    range_ids = jax.lax.broadcasted_iota(jnp.int32, (rows, n_ranges_p), 1)
    onehot = (bucket[:, None] == range_ids).astype(jnp.float32)
    counts = jnp.dot(provs, onehot, preferred_element_type=jnp.float32)
    out_ref[...] = jnp.maximum(out_ref[...], (counts > 0).astype(jnp.int32))


def fragment_bitmap_batch_pallas(
    bucket: jax.Array,
    provs: jax.Array,
    n_ranges: int,
    rows_per_tile: int = ROWS_PER_TILE,
    interpret: bool = False,
) -> jax.Array:
    """bits (bool[B, n_ranges]) from one bucket (int32[n]) and B stacked
    provenance masks (bool[B, n]) — multi-sketch fused capture: one
    bucketization, one scan of the rows, B bitvectors out."""
    b, n = provs.shape
    n_pad = -n % rows_per_tile
    b_pad = -b % 8  # sublane-align the mask/bitmap batch axis
    bucket_p = jnp.pad(bucket.astype(jnp.int32), (0, n_pad))
    provs_p = jnp.pad(provs.astype(jnp.int32), ((0, b_pad), (0, n_pad)))
    n_ranges_p = n_ranges + (-n_ranges % LANE)
    n_tiles = (n + n_pad) // rows_per_tile
    sub = rows_per_tile // LANE

    bucket_2d = bucket_p.reshape(n_tiles * sub, LANE)
    provs_3d = provs_p.reshape(b + b_pad, n_tiles * sub, LANE)

    out = pl.pallas_call(
        functools.partial(_bitmap_batch_kernel, n_ranges_p=n_ranges_p),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((sub, LANE), lambda i: (i, 0)),
            pl.BlockSpec((b + b_pad, sub, LANE), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((b + b_pad, n_ranges_p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b + b_pad, n_ranges_p), jnp.int32),
        interpret=interpret,
    )(bucket_2d, provs_3d)
    return out[:b, :n_ranges] > 0
