"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def fragment_bitmap_ref(prov: Array, bucket: Array, n_ranges: int) -> Array:
    """bits[r] = OR over rows in fragment r of the provenance mask."""
    hits = jax.ops.segment_max(
        prov.astype(jnp.int32), bucket, num_segments=n_ranges
    )
    return hits > 0


def fragment_bitmap_batch_ref(provs: Array, bucket: Array, n_ranges: int) -> Array:
    """bits[b, r] = OR over rows in fragment r of provenance mask b."""
    return jax.vmap(lambda p: fragment_bitmap_ref(p, bucket, n_ranges))(provs)


def sketch_filter_ref(bucket: Array, bits: Array) -> Array:
    """keep[i] = bits[bucket[i]] — the sketch's disjunction-of-ranges."""
    return bits.astype(bool)[bucket]


def segment_aggregate_ref(
    values: Array, gid: Array, n_groups: int, weights: Optional[Array] = None
) -> Tuple[Array, Array]:
    """(sums, counts) per group with optional row weights (WHERE mask)."""
    w = jnp.ones_like(values, dtype=jnp.float32) if weights is None else weights.astype(jnp.float32)
    v = values.astype(jnp.float32)
    sums = jax.ops.segment_sum(v * w, gid, num_segments=n_groups)
    counts = jax.ops.segment_sum(w, gid, num_segments=n_groups)
    return sums, counts


def flash_attention_ref(
    q: Array, k: Array, v: Array, causal: bool = True, window: int = 0
) -> Array:
    """O = softmax(QK^T / sqrt(d)) V with optional causal/sliding-window mask.

    Shapes: q (B, H, S, D), k/v (B, H, T, D). float32 math.
    """
    qf, kf, vf = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(qf.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf) * scale
    s, t = qf.shape[2], kf.shape[2]
    qpos = jnp.arange(s)[:, None] + (t - s)  # align ends (decode-friendly)
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vf).astype(q.dtype)
