"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def fragment_bitmap_ref(prov: Array, bucket: Array, n_ranges: int) -> Array:
    """bits[r] = OR over rows in fragment r of the provenance mask."""
    hits = jax.ops.segment_max(
        prov.astype(jnp.int32), bucket, num_segments=n_ranges
    )
    return hits > 0


def fragment_bitmap_batch_ref(provs: Array, bucket: Array, n_ranges: int) -> Array:
    """bits[b, r] = OR over rows in fragment r of provenance mask b."""
    return jax.vmap(lambda p: fragment_bitmap_ref(p, bucket, n_ranges))(provs)


def sketch_filter_ref(bucket: Array, bits: Array) -> Array:
    """keep[i] = bits[bucket[i]] — the sketch's disjunction-of-ranges."""
    return bits.astype(bool)[bucket]


def segment_aggregate_ref(
    values: Array, gid: Array, n_groups: int, weights: Optional[Array] = None
) -> Tuple[Array, Array]:
    """(sums, counts) per group with optional row weights (WHERE mask)."""
    w = jnp.ones_like(values, dtype=jnp.float32) if weights is None else weights.astype(jnp.float32)
    v = values.astype(jnp.float32)
    sums = jax.ops.segment_sum(v * w, gid, num_segments=n_groups)
    counts = jax.ops.segment_sum(w, gid, num_segments=n_groups)
    return sums, counts


# Below this group count the batched reference path materializes the one-hot
# membership matrix and reduces with a dense matmul (the same structure the
# Pallas kernel feeds the MXU): XLA CPU lowers it to a multithreaded GEMM,
# ~5x faster than its single-threaded scatter-add.  Above it, the one-hot
# matrix stops paying for itself and the flat offset-scatter wins.
ONEHOT_MAX_GROUPS = 128
# Row-tile budget for the one-hot path: the (rows, groups) one-hot block is
# rematerialized per tile inside a scan (mirroring the Pallas kernel's row
# tiles) so it stays cache-resident instead of spilling a (B, n, G) tensor.
ONEHOT_TILE_ROWS = 16384


def _pow2_tiles(n: int, target: int) -> int:
    """Largest power-of-two tile count dividing ``n`` with tiles >= target."""
    c = 1
    while n % (2 * c) == 0 and n // (2 * c) >= target:
        c *= 2
    return c


def segment_aggregate_batch_ref(
    values: Array, gid: Array, n_groups: int, weights: Optional[Array] = None
) -> Tuple[Array, Array]:
    """(sums, counts) per group for B independent segment problems (B, n).

    Small group counts reduce through a row-tiled one-hot matmul (a scan of
    cache-sized GEMM accumulations); larger ones flatten into ONE segment
    reduction with batch-offset group ids rather than a vmapped scatter
    (XLA lowers the flat scatter-add far better on CPU/GPU, and f32 addition
    order per group is unchanged — row-major — so results match the
    unbatched path bit-for-bit).  The matmul path reassociates the f32
    additions; on integral-valued inputs (the engine's cross-path exactness
    envelope) all orderings are exact and bit-identical.
    """
    b, n = values.shape
    w = (jnp.ones_like(values, dtype=jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    v = values.astype(jnp.float32)
    if n_groups <= ONEHOT_MAX_GROUPS and n > 0:
        groups = jnp.arange(n_groups, dtype=jnp.int32)
        vw = jnp.stack([v * w, w], axis=1)  # (B, 2, n)
        tiles = _pow2_tiles(n, max(ONEHOT_TILE_ROWS // max(b, 1), 1))
        if tiles == 1:
            onehot = (gid[..., None] == groups).astype(jnp.float32)
            out = jnp.einsum("bkn,bng->bkg", vw, onehot)
            return out[:, 0], out[:, 1]
        tn = n // tiles
        vw_t = vw.reshape(b, 2, tiles, tn).transpose(2, 0, 1, 3)  # (T, B, 2, tn)
        g_t = gid.reshape(b, tiles, tn).transpose(1, 0, 2)  # (T, B, tn)

        def step(acc, xs):
            vwk, gk = xs
            onehot = (gk[..., None] == groups).astype(jnp.float32)
            return acc + jnp.einsum("bkn,bng->bkg", vwk, onehot), None

        acc, _ = jax.lax.scan(
            step, jnp.zeros((b, 2, n_groups), jnp.float32), (vw_t, g_t))
        return acc[:, 0], acc[:, 1]
    offset = (jnp.arange(b, dtype=jnp.int32) * n_groups)[:, None]
    flat_gid = (gid.astype(jnp.int32) + offset).reshape(-1)
    sums, counts = segment_aggregate_ref(
        v.reshape(-1), flat_gid, b * n_groups, w.reshape(-1))
    return sums.reshape(b, n_groups), counts.reshape(b, n_groups)


def flash_attention_ref(
    q: Array, k: Array, v: Array, causal: bool = True, window: int = 0
) -> Array:
    """O = softmax(QK^T / sqrt(d)) V with optional causal/sliding-window mask.

    Shapes: q (B, H, S, D), k/v (B, H, T, D). float32 math.
    """
    qf, kf, vf = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(qf.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf) * scale
    s, t = qf.shape[2], kf.shape[2]
    qpos = jnp.arange(s)[:, None] + (t - s)  # align ends (decode-friendly)
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vf).astype(q.dtype)
