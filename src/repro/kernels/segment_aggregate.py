"""Pallas TPU kernel: segmented aggregation (group-by SUM/COUNT hot loop).

Hash aggregation does not map to the TPU; the MXU does.  For a row tile and a
group block we materialize the one-hot membership matrix in VMEM and issue a
single (groups x rows) @ (rows x 2) matmul producing the per-group [sum,
count] partials, accumulated in the VMEM-resident output block across row
tiles.  A 2-D grid (group blocks x row tiles) scales to group counts far
beyond one block: the inner (row) dimension iterates fastest so each group
block's accumulator stays resident while rows stream.

MXU alignment: the contraction dim is the row tile (2048 = 16*128) and the
output dims are (GROUP_BLOCK, 128-lane pairs); both multiples of the 128x128
systolic tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 2048
GROUP_BLOCK = 512
LANE = 128


def _segagg_kernel(gid_ref, val_ref, w_ref, out_ref, *, group_block: int):
    g = pl.program_id(0)
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[...].reshape(-1)  # (rows,)
    vals = val_ref[...].reshape(-1).astype(jnp.float32)
    w = w_ref[...].reshape(-1).astype(jnp.float32)
    rows = gid.shape[0]

    local = gid - g * group_block
    group_ids = jax.lax.broadcasted_iota(jnp.int32, (rows, group_block), 1)
    onehot = (local[:, None] == group_ids).astype(jnp.float32)  # (rows, G)
    # (G, rows) @ (rows, 2) on the MXU: columns are [sum, count].
    vw = jnp.stack([vals * w, w], axis=1)  # (rows, 2)
    partial = jax.lax.dot_general(
        onehot, vw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, 2)
    out_ref[...] += partial.reshape(out_ref.shape)


def _segagg_batch_kernel(gid_ref, val_ref, w_ref, out_ref, *, group_block: int):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[...].reshape(-1)
    vals = val_ref[...].reshape(-1).astype(jnp.float32)
    w = w_ref[...].reshape(-1).astype(jnp.float32)
    rows = gid.shape[0]

    local = gid - pl.program_id(1) * group_block
    group_ids = jax.lax.broadcasted_iota(jnp.int32, (rows, group_block), 1)
    onehot = (local[:, None] == group_ids).astype(jnp.float32)
    vw = jnp.stack([vals * w, w], axis=1)
    partial = jax.lax.dot_general(
        onehot, vw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] += partial.reshape(out_ref.shape)


def segment_aggregate_batch_pallas(
    values: jax.Array,
    gid: jax.Array,
    n_groups: int,
    weights: jax.Array | None = None,
    rows_per_tile: int = ROWS_PER_TILE,
    group_block: int = GROUP_BLOCK,
    interpret: bool = False,
):
    """Batched segmented aggregation: B independent segment problems, one grid.

    ``values``/``gid``/``weights`` are (B, n); returns (sums f32[B, n_groups],
    counts f32[B, n_groups]).  The batch dimension is the slowest grid axis
    so each (batch, group-block) accumulator stays VMEM-resident while its
    row tiles stream — the shard/query axes of the sharded serving engine's
    stacked launch map onto ``B``.
    """
    b, n = values.shape
    w = (jnp.ones_like(values, dtype=jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    n_pad = -n % rows_per_tile
    gid_p = jnp.pad(gid.astype(jnp.int32), ((0, 0), (0, n_pad)), constant_values=-1)
    val_p = jnp.pad(values.astype(jnp.float32), ((0, 0), (0, n_pad)))
    w_p = jnp.pad(w, ((0, 0), (0, n_pad)))
    n_tiles = (n + n_pad) // rows_per_tile
    n_gblocks = (n_groups + group_block - 1) // group_block
    sub = rows_per_tile // LANE

    gid_2d = gid_p.reshape(b * n_tiles * sub, LANE)
    val_2d = val_p.reshape(b * n_tiles * sub, LANE)
    w_2d = w_p.reshape(b * n_tiles * sub, LANE)

    in_spec = pl.BlockSpec((sub, LANE), lambda i, g, r: (i * n_tiles + r, 0))
    out = pl.pallas_call(
        functools.partial(_segagg_batch_kernel, group_block=group_block),
        grid=(b, n_gblocks, n_tiles),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=pl.BlockSpec((group_block, 2), lambda i, g, r: (i * n_gblocks + g, 0)),
        out_shape=jax.ShapeDtypeStruct((b * n_gblocks * group_block, 2), jnp.float32),
        interpret=interpret,
    )(gid_2d, val_2d, w_2d)
    out = out.reshape(b, n_gblocks * group_block, 2)
    return out[:, :n_groups, 0], out[:, :n_groups, 1]


def segment_aggregate_pallas(
    values: jax.Array,
    gid: jax.Array,
    n_groups: int,
    weights: jax.Array | None = None,
    rows_per_tile: int = ROWS_PER_TILE,
    group_block: int = GROUP_BLOCK,
    interpret: bool = False,
):
    """(sums f32[n_groups], counts f32[n_groups]) via one-hot MXU matmuls."""
    n = values.shape[0]
    w = jnp.ones_like(values, dtype=jnp.float32) if weights is None else weights.astype(jnp.float32)
    n_pad = -n % rows_per_tile
    # Padded rows get gid = -1: they match no group block.
    gid_p = jnp.pad(gid.astype(jnp.int32), (0, n_pad), constant_values=-1)
    val_p = jnp.pad(values.astype(jnp.float32), (0, n_pad))
    w_p = jnp.pad(w, (0, n_pad))
    n_tiles = (n + n_pad) // rows_per_tile
    n_gblocks = (n_groups + group_block - 1) // group_block
    sub = rows_per_tile // LANE

    gid_2d = gid_p.reshape(n_tiles * sub, LANE)
    val_2d = val_p.reshape(n_tiles * sub, LANE)
    w_2d = w_p.reshape(n_tiles * sub, LANE)

    out = pl.pallas_call(
        functools.partial(_segagg_kernel, group_block=group_block),
        grid=(n_gblocks, n_tiles),
        in_specs=[
            pl.BlockSpec((sub, LANE), lambda g, r: (r, 0)),
            pl.BlockSpec((sub, LANE), lambda g, r: (r, 0)),
            pl.BlockSpec((sub, LANE), lambda g, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((group_block, 2), lambda g, r: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((n_gblocks * group_block, 2), jnp.float32),
        interpret=interpret,
    )(gid_2d, val_2d, w_2d)
    sums = out[:n_groups, 0]
    counts = out[:n_groups, 1]
    return sums, counts
