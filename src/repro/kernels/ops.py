"""jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively; on the CPU container the kernels
run under ``interpret=True`` (Python-evaluated kernel bodies) so correctness
is validated everywhere.  Callers can force the pure-jnp oracle with
``backend='ref'`` (the default for large CPU workloads, where interpret-mode
row loops are slow) — the kernels' tests assert the two paths agree.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fragment_bitmap import (
    fragment_bitmap_batch_pallas,
    fragment_bitmap_pallas,
)
from repro.kernels.segment_aggregate import (
    segment_aggregate_batch_pallas,
    segment_aggregate_pallas,
)
from repro.kernels.sketch_filter import sketch_filter_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(backend: Optional[str]) -> str:
    """'pallas' | 'interpret' | 'ref'."""
    if backend is not None:
        return backend
    return "pallas" if _on_tpu() else "ref"


@functools.partial(jax.jit, static_argnums=(2, 3))
def _fragment_bitmap_jit(prov, bucket, n_ranges, mode):
    if mode == "pallas":
        return fragment_bitmap_pallas(bucket, prov, n_ranges)
    if mode == "interpret":
        return fragment_bitmap_pallas(bucket, prov, n_ranges, interpret=True)
    return ref.fragment_bitmap_ref(prov, bucket, n_ranges)


def fragment_bitmap(prov: Array, bucket: Array, n_ranges: int, backend: Optional[str] = None) -> Array:
    return _fragment_bitmap_jit(prov, bucket, n_ranges, _mode(backend))


@functools.partial(jax.jit, static_argnums=(2, 3))
def _fragment_bitmap_batch_jit(provs, bucket, n_ranges, mode):
    if mode == "pallas":
        return fragment_bitmap_batch_pallas(bucket, provs, n_ranges)
    if mode == "interpret":
        return fragment_bitmap_batch_pallas(bucket, provs, n_ranges, interpret=True)
    return ref.fragment_bitmap_batch_ref(provs, bucket, n_ranges)


def fragment_bitmap_batch(
    provs: Array, bucket: Array, n_ranges: int, backend: Optional[str] = None
) -> Array:
    """B stacked provenance masks -> B sketch bitvectors, one scan."""
    return _fragment_bitmap_batch_jit(provs, bucket, n_ranges, _mode(backend))


@functools.partial(jax.jit, static_argnums=(2,))
def _sketch_filter_jit(bucket, bits, mode):
    if mode == "pallas":
        return sketch_filter_pallas(bucket, bits)
    if mode == "interpret":
        return sketch_filter_pallas(bucket, bits, interpret=True)
    return ref.sketch_filter_ref(bucket, bits)


def sketch_filter(bucket: Array, bits: Array, backend: Optional[str] = None) -> Array:
    return _sketch_filter_jit(bucket, bits, _mode(backend))


@functools.partial(jax.jit, static_argnums=(2, 4))
def _segment_aggregate_jit(values, gid, n_groups, weights, mode):
    if mode == "pallas":
        return segment_aggregate_pallas(values, gid, n_groups, weights)
    if mode == "interpret":
        return segment_aggregate_pallas(values, gid, n_groups, weights, interpret=True)
    return ref.segment_aggregate_ref(values, gid, n_groups, weights)


def segment_aggregate(
    values: Array,
    gid: Array,
    n_groups: int,
    weights: Optional[Array] = None,
    backend: Optional[str] = None,
) -> Tuple[Array, Array]:
    return _segment_aggregate_jit(values, gid, n_groups, weights, _mode(backend))


@functools.partial(jax.jit, static_argnums=(2, 4))
def _segment_aggregate_batch_jit(values, gid, n_groups, weights, mode):
    if mode == "pallas":
        return segment_aggregate_batch_pallas(values, gid, n_groups, weights)
    if mode == "interpret":
        return segment_aggregate_batch_pallas(values, gid, n_groups, weights,
                                              interpret=True)
    return ref.segment_aggregate_batch_ref(values, gid, n_groups, weights)


def segment_aggregate_batch(
    values: Array,
    gid: Array,
    n_groups: int,
    weights: Optional[Array] = None,
    backend: Optional[str] = None,
) -> Tuple[Array, Array]:
    """B independent segment problems (B, n) -> (B, n_groups) sums/counts.

    The sharded serving engine flattens its (query, shard) axes into ``B`` so
    every shard's per-group partials come out of one launch.
    """
    return _segment_aggregate_batch_jit(values, gid, n_groups, weights, _mode(backend))


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def _flash_attention_jit(q, k, v, causal, window, mode):
    if mode == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window)
    if mode == "interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window, bq=64, bk=64, interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def flash_attention(
    q: Array, k: Array, v: Array, causal: bool = True, window: int = 0,
    backend: Optional[str] = None,
) -> Array:
    """Dispatches Pallas on TPU, reference math elsewhere (used by models)."""
    return _flash_attention_jit(q, k, v, causal, window, _mode(backend))
