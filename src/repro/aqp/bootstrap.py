"""Bootstrap over stratified samples (Sec. 7.2).

For every stratum s_g we draw B resamples *with replacement* of the same size
and average the per-resample statistic; the spread of the B statistics gives a
distribution-free accuracy measure that complements the CLT intervals.  The
whole procedure is vectorized across groups: a resample is just a per-row
"within-my-segment" random offset, so one (B, m) gather covers all strata.
Fig. 4 of the paper sweeps B; 50 is the knee of the curve.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BootstrapStats:
    mean: np.ndarray  # bootstrap mean of the per-group mean statistic
    std: np.ndarray  # bootstrap std of that statistic
    n_resamples: int


def _segment_layout(gid: np.ndarray, n_groups: int):
    order = np.argsort(gid, kind="stable")
    sizes = np.bincount(gid, minlength=n_groups)
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    return order, sizes, starts


from functools import partial


@partial(jax.jit, static_argnums=(4, 6))
def _resample_means(
    vals_sorted: Array,
    starts_row: Array,
    sizes_row: Array,
    gid_sorted: Array,
    n_groups: int,
    key: Array,
    n_resamples: int,
):
    """(B, n_groups) matrix of per-resample per-group means."""
    m = vals_sorted.shape[0]

    def one(k):
        u = jax.random.uniform(k, (m,))
        sizes_i = sizes_row.astype(jnp.int32)
        offs = jnp.floor(u * sizes_row).astype(jnp.int32)
        idx = starts_row + jnp.minimum(offs, sizes_i - 1)
        resampled = vals_sorted[idx]
        s = jax.ops.segment_sum(resampled, gid_sorted, num_segments=n_groups)
        c = jax.ops.segment_sum(jnp.ones_like(resampled), gid_sorted, num_segments=n_groups)
        return s / jnp.maximum(c, 1.0)

    keys = jax.random.split(key, n_resamples)
    return jax.vmap(one)(keys)


def bootstrap_group_means(
    key: jax.Array,
    values: np.ndarray,  # statistic input per sampled row (e.g. u*v)
    gid: np.ndarray,  # group id per sampled row
    n_groups: int,
    n_resamples: int = 50,
) -> BootstrapStats:
    values = np.asarray(values, dtype=np.float32)
    gid = np.asarray(gid)
    order, sizes, starts = _segment_layout(gid, n_groups)
    vals_sorted = jnp.asarray(values[order])
    gid_sorted = jnp.asarray(gid[order])
    sizes_row = jnp.asarray(sizes[gid[order]].astype(np.float32))
    starts_row = jnp.asarray(starts[gid[order]].astype(np.int32))
    # pow2 segment count: keeps the jitted resampler in one compiled size
    # class across group-bys (padded segments get no rows, outputs sliced).
    n_pad = 1 << max(0, (n_groups - 1)).bit_length()
    means = _resample_means(
        vals_sorted, starts_row, sizes_row, gid_sorted, n_pad, key, n_resamples
    )
    means = np.asarray(means)[:, :n_groups]  # analyze: waive[SYNC01]: deliberate merge: bootstrap spreads return to the host cost model once per admission-time estimate
    return BootstrapStats(
        mean=means.mean(axis=0),
        std=means.std(axis=0, ddof=1) if n_resamples > 1 else np.zeros(n_groups),
        n_resamples=n_resamples,
    )
