"""Wander join (Li et al., SIGMOD'16) adapted to TPU idiom (Sec. 8, Alg. 1).

The original walks B+-tree index entries row-at-a-time.  The TPU-native
version keeps the join "index" as a *sorted key column*; one walk step for a
whole batch of sampled fact rows is a vectorized ``searchsorted`` pair giving
each row its partner range [lo, hi), followed by a PRNG-uniform pick inside
the range.  Each sampled row's unbiased contribution to a join-SUM is
``v * (hi - lo)`` (value of the picked partner x its fan-out), exactly the
wander-join estimator with the walk order (fact -> dim).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Array = jax.Array


@dataclasses.dataclass(frozen=True)
class JoinIndex:
    """Sorted-key 'index' over the dimension table (built once, cached)."""

    right: str
    right_key: str
    sorted_keys: np.ndarray
    order: np.ndarray  # position -> original right row id

    @classmethod
    def build(cls, right: "ColumnTable", right_key: str) -> "JoinIndex":
        rk = np.asarray(right[right_key])
        order = np.argsort(rk, kind="stable")
        return cls(right.name, right_key, rk[order], order)


def walk(
    key: jax.Array,
    index: JoinIndex,
    fact_keys: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """One wander-join step for every sampled fact row.

    Returns ``(right_row_id, fanout)``; rows with no partner get fanout 0 and
    right_row_id -1.
    """
    lo = np.searchsorted(index.sorted_keys, fact_keys, side="left")
    hi = np.searchsorted(index.sorted_keys, fact_keys, side="right")
    fanout = hi - lo
    m = fact_keys.shape[0]
    u = np.asarray(jax.random.uniform(key, (m,), dtype=jnp.float32))  # analyze: waive[SYNC01]: deliberate merge: join picks feed host searchsorted/index arithmetic
    pick = lo + np.minimum((u * np.maximum(fanout, 1)).astype(np.int64), np.maximum(fanout - 1, 0))
    right_rows = np.where(fanout > 0, index.order[np.minimum(pick, len(index.order) - 1)], -1)
    return right_rows, fanout


def join_sample_values(
    key: jax.Array,
    index: JoinIndex,
    right: "ColumnTable",
    fact_sample: "ColumnTable",  # the sampled fact rows (gathered)
    join: "JoinSpec",
    agg_attr: Optional[str],
    where: Optional["Predicate"],
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-sampled-row (value, predicate) pairs for the join estimators.

    value v(t) is the wander-join contribution (0 when dangling); pred u(t)
    folds the WHERE predicate evaluated on the joined row.
    """
    fact_keys = np.asarray(fact_sample[join.left_key])
    right_rows, fanout = walk(key, index, fact_keys)
    has_partner = fanout > 0

    if agg_attr is None:  # COUNT(*) over the join: contribution = fan-out
        v = fanout.astype(np.float64)
    elif fact_sample.has(agg_attr):
        v = np.asarray(fact_sample[agg_attr]).astype(np.float64) * fanout
    else:  # aggregate over a dimension attribute: value of the picked partner
        rv = np.asarray(right[agg_attr])
        v = np.where(has_partner, rv[np.maximum(right_rows, 0)], 0.0) * fanout

    u = has_partner.copy()
    if where is not None:
        if fact_sample.has(where.attr):
            u &= np.asarray(where.mask(fact_sample))
        else:
            rcol = np.asarray(right[where.attr])
            joined_vals = np.where(has_partner, rcol[np.maximum(right_rows, 0)], 0.0)
            from repro.core.queries import _OPS

            u &= np.asarray(_OPS[where.op](joined_vals, where.value)) & has_partner
    return v, u
