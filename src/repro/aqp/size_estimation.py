"""Sketch-size estimation — Algorithms 1 & 2 and Def. 9 of the paper.

Pipeline (Fig. 3):
  stratified sample (cached)  ->  AQR: per-group aggregate estimates
  (wander join when the template joins)  ->  HAVING on estimates -> G'
  ->  fragment incidence of G' under the candidate's range partition
  ->  size  = sum of #R_r over satisfied ranges        (Alg. 2)
      E[size], Frechet lo/hi via pass probabilities    (Def. 9)

``estimate_size_batched`` evaluates *all* candidate attributes of one query
in a single vmapped fragment-incidence pass over the catalog's cached
bucketizations — the per-candidate loop only assembles (frag, group)
incidence pairs from the sample.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import TYPE_CHECKING, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.aqp.bootstrap import BootstrapStats, bootstrap_group_means
from repro.aqp.estimators import GroupEstimates, group_estimates, pass_probability
from repro.aqp.sampling import SampleSet
from repro.aqp.wander_join import JoinIndex, join_sample_values
from repro.runtime import guards
from repro.runtime.guards import hot_path

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.catalog import Catalog

Array = jax.Array


def _catalog(catalog: "Optional[Catalog]") -> "Catalog":
    # Imported lazily: repro.core's package init imports the engine, which
    # imports this module — a top-level catalog import would cycle.
    from repro.core.catalog import default_catalog

    return catalog if catalog is not None else default_catalog()


@dataclasses.dataclass(frozen=True)
class SizeEstimate:
    attr: str
    est_rows: float  # point estimate of |R_P| (Alg. 2)
    est_selectivity: float
    expected_rows: float  # E[size] under Def. 9 (independent groups)
    lo_rows: float  # Frechet lower bound
    hi_rows: float  # Frechet upper bound
    est_bits: np.ndarray  # which ranges the estimate marks satisfied
    n_satisfied_groups: int


@dataclasses.dataclass(frozen=True)
class EstimationConfig:
    n_resamples: int = 50
    z: float = 1.959964  # 95% CI
    incidence: str = "sample"  # 'sample' | 'full' (Def. 8's f(G', D))
    use_bootstrap: bool = True


def aqr_estimates(
    key: jax.Array,
    q: "Query",
    db: "Database",
    samples: SampleSet,
    cfg: EstimationConfig = EstimationConfig(),
    join_index: Optional[JoinIndex] = None,
) -> GroupEstimates:
    """Algorithm 1's estimation half: per-group aggregate estimates.

    Depends only on the query's FROM/WHERE/GROUP BY/aggregate — not on the
    HAVING chain — so concurrent queries differing only in thresholds share
    one pass (the batched admission pipeline's AQR cache keys on exactly the
    inputs consumed here).
    """
    fact = db[q.table]
    sample_rows = fact.gather(jnp.asarray(samples.indices))
    kb, kw = jax.random.split(key)

    if q.join is not None:
        if join_index is None:
            join_index = JoinIndex.build(db[q.join.right], q.join.right_key)
        v, u = join_sample_values(
            kw, join_index, db[q.join.right], sample_rows, q.join, q.agg.attr, q.where
        )
        # Wander-join contributions already fold the fan-out; the group scaler
        # #g/#s_g is applied by the Haas estimator below with fn='sum'.
        fn = "sum" if q.agg.fn != "avg" else "avg"
        values = jnp.asarray(v.astype(np.float32))
        pred = jnp.asarray(u)
    else:
        fn = q.agg.fn
        if fn == "count":
            values = None
        else:
            values = sample_rows[q.agg.attr]
        pred = (
            q.where.mask(sample_rows)
            if q.where is not None
            else jnp.ones(samples.num_samples, dtype=bool)
        )

    est = group_estimates(
        fn,
        values,
        pred,
        samples.sample_gid,
        samples.n_groups,
        samples.group_sizes,
        z=cfg.z,
    )

    if cfg.use_bootstrap and samples.stratified:
        # Bootstrap the per-group mean statistic; fold its spread into sigma
        # (max of CLT and bootstrap spreads -> conservative CI, Sec. 7.2).
        uv = np.asarray(pred, dtype=np.float32)  # analyze: waive[SYNC01]: deliberate merge: bootstrap folds spreads on host copies, once per admission-time estimate
        if values is not None:
            uv = uv * np.asarray(values, dtype=np.float32)  # analyze: waive[SYNC01]: deliberate merge: bootstrap folds spreads on host copies, once per admission-time estimate
        bs = bootstrap_group_means(kb, uv, samples.sample_gid, samples.n_groups, cfg.n_resamples)
        if fn in ("sum", "count"):
            scale = samples.group_sizes.astype(np.float64)
            boot_est = scale * bs.mean
            boot_sigma = scale * bs.std
        else:
            boot_est, boot_sigma = est.estimate, est.sigma  # AVG: keep CLT form
        est = GroupEstimates(
            fn=est.fn,
            estimate=np.where(samples.sample_sizes > 1, boot_est, est.estimate),
            sigma=np.maximum(est.sigma, boot_sigma),
            half_width=cfg.z * np.maximum(est.sigma, boot_sigma),
            n_samples=est.n_samples,
        )
    return est


def satisfied_groups(q: "Query", est: GroupEstimates, sampled: np.ndarray) -> np.ndarray:
    """HAVING over the estimates -> the satisfied-group mask G'.

    ``sampled`` is the per-group ever-sampled mask (``sample_sizes > 0``);
    group-level work only, so every query sharing an estimate pass applies its
    own threshold for free.
    """
    if q.having is not None:
        from repro.core.queries import _OPS

        satisfied = np.asarray(_OPS[q.having.op](est.estimate, q.having.value))
    else:
        satisfied = np.ones(est.estimate.shape[0], dtype=bool)
    # Groups never sampled under the predicate contribute nothing.
    return satisfied & sampled


@hot_path
def approximate_query_result(
    key: jax.Array,
    q: "Query",
    db: "Database",
    samples: SampleSet,
    cfg: EstimationConfig = EstimationConfig(),
    join_index: Optional[JoinIndex] = None,
) -> Tuple[GroupEstimates, np.ndarray]:
    """Algorithm 1 (AQR): per-group estimates + satisfied-group mask G'."""
    est = aqr_estimates(key, q, db, samples, cfg, join_index)
    return est, satisfied_groups(q, est, samples.sample_sizes > 0)


def _sample_incidence(
    q: "Query",
    db: "Database",
    samples: SampleSet,
    ranges: "RangeSet",
    satisfied: np.ndarray,
    catalog: "Optional[Catalog]" = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """(frag_id, gid) incidence pairs from the *sample* rows of G'.

    Handles single-attribute ``RangeSet`` and cross-product ``CompositeRanges``
    partitions alike: when every partition attribute is a group-by attribute
    the group key pins the (composite) fragment exactly — the CB-OPT-GB /
    CB-OPT-GB2 fast path.
    """
    catalog = _catalog(catalog)
    fact = db[q.table]
    parts = getattr(ranges, "parts", (ranges,))
    if all(r.attr in samples.groupby for r in parts):
        # GB fast path: the group key pins the fragment — exact.  The
        # fragment-of-group vector is a catalog cache per (table version,
        # group-by, partition), so repeated estimates stop re-bucketizing
        # the group values.
        frag_of_group = catalog.frag_of_group(
            fact, ranges, samples.groupby, samples.group_values)
        gids = np.nonzero(satisfied)[0]
        return frag_of_group[gids], gids
    row_sat = satisfied[samples.sample_gid]
    rows = samples.indices[row_sat]
    gids = samples.sample_gid[row_sat]
    # Prefer the catalog's full bucket vector when it is already cached (or
    # delta-refreshable from a cached ancestor — the appended-table path):
    # gathering beats re-searchsorting the sampled values, and it is the
    # vector capture/application use anyway.
    bucket = catalog.cached_bucket(fact, ranges)
    if bucket is not None:
        frag = np.asarray(bucket)[rows]
    else:
        frag = None
        take = jnp.asarray(rows)
        for r in parts:
            b = np.asarray(r.bucketize(fact[r.attr][take]))  # analyze: waive[SYNC01]: deliberate merge: np.unique pair-dedup of (fragment, group) runs on host
            frag = b if frag is None else frag * r.n_ranges + b
    pairs = np.unique(np.stack([frag, gids], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def _full_incidence(
    q: "Query",
    db: "Database",
    samples: SampleSet,
    ranges: "RangeSet",
    satisfied: np.ndarray,
    catalog: "Optional[Catalog]" = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Def. 8's f(G', D): scan the full table for rows of satisfied groups."""
    catalog = _catalog(catalog)
    fact = db[q.table]
    gid = catalog.groups(fact, tuple(samples.groupby)).gid
    row_sat = satisfied[gid]
    frag = np.asarray(catalog.bucketize(fact, ranges))[row_sat]
    gids = gid[row_sat]
    pairs = np.unique(np.stack([frag, gids], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def _pass_probabilities(
    q: "Query", est: GroupEstimates
) -> np.ndarray:
    """p_g = P(group g satisfies the HAVING) under the CLT/bootstrap CI."""
    p_g = pass_probability(
        est, q.having.op if q.having else ">", q.having.value if q.having else -np.inf
    )
    if q.having is None:
        p_g = np.ones_like(p_g)
    return p_g


def _candidate_incidence(
    q: "Query",
    db: "Database",
    samples: SampleSet,
    ranges: "RangeSet",
    satisfied: np.ndarray,
    cfg: EstimationConfig,
    catalog: "Catalog",
) -> Tuple[np.ndarray, np.ndarray]:
    if cfg.incidence == "full":
        return _full_incidence(q, db, samples, ranges, satisfied, catalog)
    return _sample_incidence(q, db, samples, ranges, satisfied, catalog)


# Retrace telemetry: the counter bumps at *trace* time only, so tests can
# assert that pow2 padding keeps differently-shaped candidate sets inside one
# compiled size class (a steady workload must not retrace the selection math).
# Shared registry in ``runtime.guards`` (this module owns the
# "incidence_pass" key); the module-level name stays for existing callers.
TRACE_COUNTS: collections.Counter = guards.TRACE_COUNTS


def _incidence_pass(frag, valid, p_pair, sizes):
    """Alg. 2 + Def. 9 for one candidate from deduped (frag, group) pairs.

    frag (P,) int32, valid (P,) bool padding mask, p_pair (P,) f32 pass
    probabilities, sizes (R,) f32 fragment sizes.  Vmapped over candidates.
    """
    TRACE_COUNTS["incidence_pass"] += 1
    n_r = sizes.shape[0]
    vf = valid.astype(jnp.float32)
    hits = jnp.zeros(n_r, jnp.float32).at[frag].max(vf)
    bits = hits > 0
    est_rows = (sizes * hits).sum()
    log1m = jnp.log1p(-jnp.minimum(p_pair, 1 - 1e-12)) * vf
    sum_log = jnp.zeros(n_r, jnp.float32).at[frag].add(log1m)
    p_frag = jnp.where(bits, 1.0 - jnp.exp(sum_log), 0.0)
    max_p = jnp.zeros(n_r, jnp.float32).at[frag].max(p_pair * vf)
    sum_p = jnp.zeros(n_r, jnp.float32).at[frag].add(p_pair * vf)
    expected = (sizes * p_frag).sum()
    lo = (sizes * max_p).sum()
    hi = (sizes * jnp.minimum(sum_p, 1.0)).sum()
    return bits, est_rows, expected, lo, hi


_incidence_pass_batch = jax.jit(jax.vmap(_incidence_pass))


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


@dataclasses.dataclass(frozen=True)
class EstimationSpec:
    """One query's candidate-estimation request inside a multi-query batch."""

    q: "Query"
    samples: SampleSet
    ranges_by_attr: Mapping[str, "RangeSet"]
    aqr: Tuple[GroupEstimates, np.ndarray]  # (estimates, satisfied mask)


@hot_path
def estimate_size_multi(
    db: "Database",
    specs: Sequence[EstimationSpec],
    cfg: EstimationConfig = EstimationConfig(),
    catalog: "Optional[Catalog]" = None,
) -> List[Dict[str, SizeEstimate]]:
    """Algorithm 2 + Def. 9 for a whole *batch of queries* in one device pass.

    Flattens every (query, candidate) pair into one padded incidence matrix —
    rows to pow2 pairs, columns to pow2 fragment counts, and the leading
    (query x candidate) dimension to pow2 — so the entire batch's selection
    math is a single ``_incidence_pass_batch`` launch that stays inside a
    small set of compiled size classes.  The per-candidate loop only
    assembles host-side (frag, group) incidence pairs; fragment sizes and
    bucketizations come from the catalog's (delta-refreshed) caches.

    Candidates may mix single-attribute ``RangeSet``s and cross-product
    ``CompositeRanges``; the mapping key is an opaque label echoed back in
    the per-spec result dict.
    """
    catalog = _catalog(catalog)
    rows = []  # (spec_idx, attr, ranges, frag, gids, p_g)
    for si, spec in enumerate(specs):
        if not spec.ranges_by_attr:
            continue
        est, satisfied = spec.aqr
        p_g = _pass_probabilities(spec.q, est)
        for a, ranges in spec.ranges_by_attr.items():
            frag, gids = _candidate_incidence(
                spec.q, db, spec.samples, ranges, satisfied, cfg, catalog)
            rows.append((si, a, ranges, frag, gids, p_g))
    out: List[Dict[str, SizeEstimate]] = [{} for _ in specs]
    if not rows:
        return out

    n_rows = len(rows)
    n_rows_p = _next_pow2(n_rows)
    max_pairs = _next_pow2(max(1, max(len(r[3]) for r in rows)))
    # Pad the fragment axis to pow2 too: candidate sets whose n_ranges differ
    # (equi-depth bound dedupe, mixed composites) land in one size class.
    max_r = _next_pow2(max(r[2].n_ranges for r in rows))

    frag_mat = np.zeros((n_rows_p, max_pairs), dtype=np.int32)
    valid_mat = np.zeros((n_rows_p, max_pairs), dtype=bool)
    p_mat = np.zeros((n_rows_p, max_pairs), dtype=np.float32)
    sizes_mat = np.zeros((n_rows_p, max_r), dtype=np.float32)
    for i, (si, a, ranges, frag, gids, p_g) in enumerate(rows):
        k = len(frag)
        frag_mat[i, :k] = frag
        valid_mat[i, :k] = True
        p_mat[i, :k] = p_g[gids]
        sizes_mat[i, : ranges.n_ranges] = catalog.fragment_sizes(
            db[specs[si].q.table], ranges)

    bits_b, est_b, exp_b, lo_b, hi_b = _incidence_pass_batch(
        jnp.asarray(frag_mat), jnp.asarray(valid_mat), jnp.asarray(p_mat),
        jnp.asarray(sizes_mat),
    )
    bits_b = np.asarray(bits_b)
    est_b, exp_b = np.asarray(est_b), np.asarray(exp_b)
    lo_b, hi_b = np.asarray(lo_b), np.asarray(hi_b)

    for i, (si, a, ranges, frag, gids, p_g) in enumerate(rows):
        spec = specs[si]
        total = max(db[spec.q.table].num_rows, 1)
        out[si][a] = SizeEstimate(
            attr=a,
            est_rows=float(est_b[i]),
            est_selectivity=float(est_b[i]) / total,
            expected_rows=float(exp_b[i]),
            lo_rows=float(lo_b[i]),
            hi_rows=float(hi_b[i]),
            est_bits=bits_b[i, : ranges.n_ranges],
            n_satisfied_groups=int(spec.aqr[1].sum()),
        )
    return out


def estimate_size_batched(
    key: jax.Array,
    q: "Query",
    db: "Database",
    ranges_by_attr: Mapping[str, "RangeSet"],
    samples: SampleSet,
    cfg: EstimationConfig = EstimationConfig(),
    aqr: Optional[Tuple[GroupEstimates, np.ndarray]] = None,
    catalog: "Optional[Catalog]" = None,
) -> Dict[str, SizeEstimate]:
    """Algorithm 2 + Def. 9 for *all* candidates of one query in one pass.

    One shared AQR pass (the estimates are candidate-independent), then the
    per-fragment scatter math for every candidate runs through the same
    padded batch launch ``estimate_size_multi`` uses for whole query batches.
    """
    catalog = _catalog(catalog)
    if not ranges_by_attr:
        return {}
    if aqr is None:
        aqr = approximate_query_result(key, q, db, samples, cfg)
    spec = EstimationSpec(q=q, samples=samples, ranges_by_attr=ranges_by_attr, aqr=aqr)
    return estimate_size_multi(db, [spec], cfg, catalog)[0]


def estimate_size(
    key: jax.Array,
    q: "Query",
    db: "Database",
    ranges: "RangeSet",
    samples: SampleSet,
    cfg: EstimationConfig = EstimationConfig(),
    aqr: Optional[Tuple[GroupEstimates, np.ndarray]] = None,
    catalog: "Optional[Catalog]" = None,
) -> SizeEstimate:
    """Algorithm 2 + Def. 9 for candidate attribute ``ranges.attr``.

    ``aqr`` lets callers share one AQR pass across all candidate attributes
    (the estimates do not depend on the candidate — only incidence does).
    Single-candidate host-math reference; strategies use the batched variant.
    """
    catalog = _catalog(catalog)
    est, satisfied = aqr if aqr is not None else approximate_query_result(key, q, db, samples, cfg)

    frag, gids = _candidate_incidence(q, db, samples, ranges, satisfied, cfg, catalog)

    n_r = ranges.n_ranges
    sizes = catalog.fragment_sizes(db[q.table], ranges).astype(np.float64)

    bits = np.zeros(n_r, dtype=bool)
    bits[frag] = True
    est_rows = float(sizes[bits].sum())

    # Def. 9: P(r in P) = 1 - prod_{g in frag} (1 - p_g)   (independent case)
    # with Frechet bounds max_g p_g <= P <= min(1, sum_g p_g).
    p_g = _pass_probabilities(q, est)
    log1m = np.log1p(-np.minimum(p_g[gids], 1 - 1e-12))
    sum_log = np.zeros(n_r)
    np.add.at(sum_log, frag, log1m)
    p_frag = np.where(bits, 1.0 - np.exp(sum_log), 0.0)
    max_p = np.zeros(n_r)
    np.maximum.at(max_p, frag, p_g[gids])
    sum_p = np.zeros(n_r)
    np.add.at(sum_p, frag, p_g[gids])

    expected = float((sizes * p_frag).sum())
    lo = float((sizes * max_p).sum())
    hi = float((sizes * np.minimum(sum_p, 1.0)).sum())

    total = max(db[q.table].num_rows, 1)
    return SizeEstimate(
        attr=getattr(ranges, "attr", None) or getattr(ranges, "attrs", None),
        est_rows=est_rows,
        est_selectivity=est_rows / total,
        expected_rows=expected,
        lo_rows=lo,
        hi_rows=hi,
        est_bits=bits,
        n_satisfied_groups=int(satisfied.sum()),
    )
