"""Sketch-size estimation — Algorithms 1 & 2 and Def. 9 of the paper.

Pipeline (Fig. 3):
  stratified sample (cached)  ->  AQR: per-group aggregate estimates
  (wander join when the template joins)  ->  HAVING on estimates -> G'
  ->  fragment incidence of G' under the candidate's range partition
  ->  size  = sum of #R_r over satisfied ranges        (Alg. 2)
      E[size], Frechet lo/hi via pass probabilities    (Def. 9)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.aqp.bootstrap import BootstrapStats, bootstrap_group_means
from repro.aqp.estimators import GroupEstimates, group_estimates, pass_probability
from repro.aqp.sampling import SampleSet
from repro.aqp.wander_join import JoinIndex, join_sample_values

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SizeEstimate:
    attr: str
    est_rows: float  # point estimate of |R_P| (Alg. 2)
    est_selectivity: float
    expected_rows: float  # E[size] under Def. 9 (independent groups)
    lo_rows: float  # Frechet lower bound
    hi_rows: float  # Frechet upper bound
    est_bits: np.ndarray  # which ranges the estimate marks satisfied
    n_satisfied_groups: int


@dataclasses.dataclass(frozen=True)
class EstimationConfig:
    n_resamples: int = 50
    z: float = 1.959964  # 95% CI
    incidence: str = "sample"  # 'sample' | 'full' (Def. 8's f(G', D))
    use_bootstrap: bool = True


def approximate_query_result(
    key: jax.Array,
    q: "Query",
    db: "Database",
    samples: SampleSet,
    cfg: EstimationConfig = EstimationConfig(),
    join_index: Optional[JoinIndex] = None,
) -> Tuple[GroupEstimates, np.ndarray]:
    """Algorithm 1 (AQR): per-group estimates + satisfied-group mask G'."""
    fact = db[q.table]
    sample_rows = fact.gather(jnp.asarray(samples.indices))
    kb, kw = jax.random.split(key)

    if q.join is not None:
        if join_index is None:
            join_index = JoinIndex.build(db[q.join.right], q.join.right_key)
        v, u = join_sample_values(
            kw, join_index, db[q.join.right], sample_rows, q.join, q.agg.attr, q.where
        )
        # Wander-join contributions already fold the fan-out; the group scaler
        # #g/#s_g is applied by the Haas estimator below with fn='sum'.
        fn = "sum" if q.agg.fn != "avg" else "avg"
        values = jnp.asarray(v.astype(np.float32))
        pred = jnp.asarray(u)
    else:
        fn = q.agg.fn
        if fn == "count":
            values = None
        else:
            values = sample_rows[q.agg.attr]
        pred = (
            q.where.mask(sample_rows)
            if q.where is not None
            else jnp.ones(samples.num_samples, dtype=bool)
        )

    est = group_estimates(
        fn,
        values,
        pred,
        samples.sample_gid,
        samples.n_groups,
        samples.group_sizes,
        z=cfg.z,
    )

    if cfg.use_bootstrap and samples.stratified:
        # Bootstrap the per-group mean statistic; fold its spread into sigma
        # (max of CLT and bootstrap spreads -> conservative CI, Sec. 7.2).
        uv = np.asarray(pred, dtype=np.float32)
        if values is not None:
            uv = uv * np.asarray(values, dtype=np.float32)
        bs = bootstrap_group_means(kb, uv, samples.sample_gid, samples.n_groups, cfg.n_resamples)
        if fn in ("sum", "count"):
            scale = samples.group_sizes.astype(np.float64)
            boot_est = scale * bs.mean
            boot_sigma = scale * bs.std
        else:
            boot_est, boot_sigma = est.estimate, est.sigma  # AVG: keep CLT form
        est = GroupEstimates(
            fn=est.fn,
            estimate=np.where(samples.sample_sizes > 1, boot_est, est.estimate),
            sigma=np.maximum(est.sigma, boot_sigma),
            half_width=cfg.z * np.maximum(est.sigma, boot_sigma),
            n_samples=est.n_samples,
        )

    if q.having is not None:
        from repro.core.queries import _OPS

        satisfied = np.asarray(_OPS[q.having.op](est.estimate, q.having.value))
    else:
        satisfied = np.ones(samples.n_groups, dtype=bool)
    # Groups never sampled under the predicate contribute nothing.
    satisfied &= samples.sample_sizes > 0
    return est, satisfied


def _sample_incidence(
    q: "Query",
    db: "Database",
    samples: SampleSet,
    ranges: "RangeSet",
    satisfied: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """(frag_id, gid) incidence pairs from the *sample* rows of G'."""
    fact = db[q.table]
    if ranges.attr in samples.groupby:
        # CB-OPT-GB fast path: the group key pins the fragment — exact.
        gvals = samples.group_values[ranges.attr]
        frag_of_group = np.asarray(ranges.bucketize(jnp.asarray(gvals)))
        gids = np.nonzero(satisfied)[0]
        return frag_of_group[gids], gids
    row_sat = satisfied[samples.sample_gid]
    rows = samples.indices[row_sat]
    gids = samples.sample_gid[row_sat]
    frag = np.asarray(ranges.bucketize(fact[ranges.attr][jnp.asarray(rows)]))
    pairs = np.unique(np.stack([frag, gids], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def _full_incidence(
    q: "Query",
    db: "Database",
    samples: SampleSet,
    ranges: "RangeSet",
    satisfied: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """Def. 8's f(G', D): scan the full table for rows of satisfied groups."""
    from repro.core.table import encode_groups

    fact = db[q.table]
    gid, _, _ = encode_groups(fact, samples.groupby)
    row_sat = satisfied[gid]
    frag = np.asarray(ranges.bucketize(fact[ranges.attr]))[row_sat]
    gids = gid[row_sat]
    pairs = np.unique(np.stack([frag, gids], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def estimate_size(
    key: jax.Array,
    q: "Query",
    db: "Database",
    ranges: "RangeSet",
    samples: SampleSet,
    cfg: EstimationConfig = EstimationConfig(),
    aqr: Optional[Tuple[GroupEstimates, np.ndarray]] = None,
) -> SizeEstimate:
    """Algorithm 2 + Def. 9 for candidate attribute ``ranges.attr``.

    ``aqr`` lets callers share one AQR pass across all candidate attributes
    (the estimates do not depend on the candidate — only incidence does).
    """
    from repro.core.ranges import fragment_sizes

    est, satisfied = aqr if aqr is not None else approximate_query_result(key, q, db, samples, cfg)

    if cfg.incidence == "full":
        frag, gids = _full_incidence(q, db, samples, ranges, satisfied)
    else:
        frag, gids = _sample_incidence(q, db, samples, ranges, satisfied)

    n_r = ranges.n_ranges
    sizes = np.asarray(fragment_sizes(db[q.table], ranges)).astype(np.float64)

    bits = np.zeros(n_r, dtype=bool)
    bits[frag] = True
    est_rows = float(sizes[bits].sum())

    # Def. 9: P(r in P) = 1 - prod_{g in frag} (1 - p_g)   (independent case)
    # with Frechet bounds max_g p_g <= P <= min(1, sum_g p_g).
    p_g = pass_probability(est, q.having.op if q.having else ">", q.having.value if q.having else -np.inf)
    if q.having is None:
        p_g = np.ones_like(p_g)
    log1m = np.log1p(-np.minimum(p_g[gids], 1 - 1e-12))
    sum_log = np.zeros(n_r)
    np.add.at(sum_log, frag, log1m)
    p_frag = np.where(bits, 1.0 - np.exp(sum_log), 0.0)
    max_p = np.zeros(n_r)
    np.maximum.at(max_p, frag, p_g[gids])
    sum_p = np.zeros(n_r)
    np.add.at(sum_p, frag, p_g[gids])

    expected = float((sizes * p_frag).sum())
    lo = float((sizes * max_p).sum())
    hi = float((sizes * np.minimum(sum_p, 1.0)).sum())

    total = max(db[q.table].num_rows, 1)
    return SizeEstimate(
        attr=ranges.attr,
        est_rows=est_rows,
        est_selectivity=est_rows / total,
        expected_rows=expected,
        lo_rows=lo,
        hi_rows=hi,
        est_bits=bits,
        n_satisfied_groups=int(satisfied.sum()),
    )
