"""Stratified reservoir sampling (Sec. 7.1), TPU-adapted.

Classic reservoir sampling is inherently sequential (row-at-a-time SPI loop in
the paper's Postgres implementation).  We use the Efraimidis–Spirakis
equivalence — keeping the k rows with the largest random keys draws a uniform
k-reservoir — which vectorizes to a sort + segmented rank, and stratify by
giving every group its own reservoir of size ``max(min_per_group,
floor(theta * #g))``.  When the number of groups exceeds the sample budget the
paper falls back to a plain uniform reservoir; so do we.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SampleSet:
    """A stratified sample with the catalog info estimators need."""

    table: str
    groupby: Tuple[str, ...]
    theta: float
    indices: np.ndarray  # row ids into the base table, shape (m,)
    sample_gid: np.ndarray  # dense group id per sampled row, shape (m,)
    n_groups: int
    group_sizes: np.ndarray  # #g for every group, shape (n_groups,)
    sample_sizes: np.ndarray  # #s_g for every group, shape (n_groups,)
    group_values: Dict[str, np.ndarray]  # group key values, per group
    stratified: bool

    @property
    def num_samples(self) -> int:
        return int(self.indices.shape[0])

    def reusable_for(self, table: str, groupby: Tuple[str, ...]) -> bool:
        """Sec. 7.1: samples stratified on the same group-by are reusable."""
        return self.table == table and tuple(self.groupby) == tuple(groupby)


def stratified_reservoir_sample(
    key: jax.Array,
    table: "ColumnTable",
    groupby: Tuple[str, ...],
    theta: float,
    min_per_group: int = 1,
) -> SampleSet:
    """Per-group reservoirs of size max(min_per_group, floor(theta * #g))."""
    from repro.core.table import encode_groups

    n = table.num_rows
    gid, n_groups, group_values = encode_groups(table, groupby)
    stratified = bool(groupby) and n_groups <= max(1, int(theta * n))
    if not stratified:
        return uniform_reservoir_sample(key, table, groupby, theta, gid, n_groups, group_values)

    u = np.asarray(jax.random.uniform(key, (n,), dtype=jnp.float32))  # analyze: waive[SYNC01]: deliberate merge: uniform draws feed host lexsort/reservoir index math, once per sample build
    # Sort by (group, descending key): the first k_g rows of each segment are
    # a uniform k_g-reservoir of that group.
    order = np.lexsort((-u, gid))
    gid_sorted = gid[order]
    group_sizes = np.bincount(gid, minlength=n_groups)
    starts = np.concatenate([[0], np.cumsum(group_sizes)[:-1]])
    rank = np.arange(n) - starts[gid_sorted]
    k_g = np.maximum(min_per_group, (theta * group_sizes).astype(np.int64))
    k_g = np.minimum(k_g, group_sizes)
    keep = rank < k_g[gid_sorted]
    idx = order[keep]
    return SampleSet(
        table=table.name,
        groupby=tuple(groupby),
        theta=theta,
        indices=idx,
        sample_gid=gid[idx],
        n_groups=n_groups,
        group_sizes=group_sizes,
        sample_sizes=np.bincount(gid[idx], minlength=n_groups),
        group_values=group_values,
        stratified=True,
    )


def uniform_reservoir_sample(
    key: jax.Array,
    table: "ColumnTable",
    groupby: Tuple[str, ...],
    theta: float,
    gid: Optional[np.ndarray] = None,
    n_groups: Optional[int] = None,
    group_values: Optional[Dict[str, np.ndarray]] = None,
) -> SampleSet:
    """Plain k-reservoir over the whole table (no-group-by / too-many-groups)."""
    from repro.core.table import encode_groups

    n = table.num_rows
    if gid is None:
        gid, n_groups, group_values = encode_groups(table, groupby)
    k = max(1, int(theta * n))
    u = np.asarray(jax.random.uniform(key, (n,), dtype=jnp.float32))  # analyze: waive[SYNC01]: deliberate merge: uniform draws feed host argpartition, once per sample build
    idx = np.argpartition(-u, k - 1)[:k] if k < n else np.arange(n)
    idx = np.sort(idx)
    return SampleSet(
        table=table.name,
        groupby=tuple(groupby),
        theta=theta,
        indices=idx,
        sample_gid=gid[idx],
        n_groups=n_groups,
        group_sizes=np.bincount(gid, minlength=n_groups),
        sample_sizes=np.bincount(gid[idx], minlength=n_groups),
        group_values=group_values,
        stratified=False,
    )


def extend_sample_for_append(
    key: jax.Array,
    s: SampleSet,
    batches: "Tuple[ColumnTable, ...]",
    row_offsets: Tuple[int, ...],
) -> SampleSet:
    """Delta pass: fold appended batches into a cached sample.

    Each new row is Bernoulli(theta)-included (new groups keep at least one
    row, matching the stratified ``min_per_group=1`` floor), group sizes are
    updated from *all* delta rows, and unseen group keys extend the
    dictionary — so size estimation on an appended table reuses the existing
    sample plus O(delta) work instead of resampling the whole relation.
    The reservoir is approximate across extensions (old rows are never
    displaced); estimators only need per-group uniformity, which Bernoulli
    inclusion preserves.
    """
    from repro.core.catalog import extend_group_values, map_group_keys

    indices = [s.indices]
    sample_gid = [s.sample_gid]
    group_sizes = s.group_sizes.copy()
    sample_sizes = s.sample_sizes.copy()
    group_values = {a: v.copy() for a, v in s.group_values.items()}
    n_groups = s.n_groups
    key_index: Dict[Tuple, int] = {}
    if s.groupby:
        cols = [group_values[a].tolist() for a in s.groupby]
        key_index = {k: g for g, k in enumerate(zip(*cols))}

    for batch, offset in zip(batches, row_offsets):
        m = batch.num_rows
        if m == 0:
            continue
        if s.groupby:
            stacked = np.stack([np.asarray(batch[a]) for a in s.groupby], axis=1)
            gid_b, new_keys, n_groups = map_group_keys(stacked, key_index, n_groups)
            group_values = extend_group_values(group_values, s.groupby, new_keys)
        else:
            gid_b = np.zeros(m, dtype=np.int64)
        if n_groups > group_sizes.shape[0]:
            pad = n_groups - group_sizes.shape[0]
            group_sizes = np.concatenate([group_sizes, np.zeros(pad, dtype=group_sizes.dtype)])
            sample_sizes = np.concatenate([sample_sizes, np.zeros(pad, dtype=sample_sizes.dtype)])
        np.add.at(group_sizes, gid_b, 1)
        key, k_b = jax.random.split(key)
        take = np.asarray(jax.random.uniform(k_b, (m,))) < s.theta  # analyze: waive[SYNC01]: deliberate merge: per-batch draws feed host reservoir bookkeeping during appends
        # Unsampled groups keep their first batch row (the stratified floor).
        uniq_g, first_idx = np.unique(gid_b, return_index=True)
        force = first_idx[sample_sizes[uniq_g] == 0]
        take[force] = True
        np.add.at(sample_sizes, gid_b[take], 1)
        indices.append(np.nonzero(take)[0] + offset)
        sample_gid.append(gid_b[take])

    return SampleSet(
        table=s.table, groupby=s.groupby, theta=s.theta,
        indices=np.concatenate(indices),
        sample_gid=np.concatenate(sample_gid).astype(s.sample_gid.dtype),
        n_groups=n_groups, group_sizes=group_sizes, sample_sizes=sample_sizes,
        group_values=group_values, stratified=s.stratified,
    )


class SampleCache:
    """Sec. 7.1 reuse: cache stratified samples keyed by (table, group-by).

    Version-aware: entries remember the (uid, version) of the table they were
    drawn from.  A hit on a *newer* version of the same relation extends the
    sample with a delta pass when every intervening step is an append;
    deletes (which invalidate row indices) and lineage changes resample.
    """

    def __init__(self):
        self._cache: Dict[Tuple[str, Tuple[str, ...], float], Tuple[SampleSet, "ColumnTable"]] = {}
        self.hits = 0
        self.misses = 0
        self.extended = 0

    def get_or_create(
        self,
        key: jax.Array,
        table: "ColumnTable",
        groupby: Tuple[str, ...],
        theta: float,
    ) -> SampleSet:
        ck = (table.name, tuple(groupby), theta)
        cached = self._cache.get(ck)
        if cached is not None:
            s, src = cached
            if src is table:
                self.hits += 1
                return s
            if src.uid == table.uid and src.version < table.version:
                # Walk the delta chain back to the sampled version; extend if
                # it is appends all the way down.
                batches, offsets = [], []
                t = table
                ok = True
                while t is not src and t.version > src.version:
                    if t.delta is None or t.delta.kind != "append":
                        ok = False
                        break
                    batches.append(t.delta.appended)
                    offsets.append(t.delta.parent.num_rows)
                    t = t.delta.parent
                if ok and t is src:
                    s2 = extend_sample_for_append(
                        key, s, tuple(reversed(batches)), tuple(reversed(offsets)))
                    self._cache[ck] = (s2, table)
                    self.extended += 1
                    return s2
        self.misses += 1
        s = stratified_reservoir_sample(key, table, groupby, theta)
        self._cache[ck] = (s, table)
        return s

    def invalidate(self, table_name: str) -> None:
        """Drop cached samples of one table (its physical layout changed:
        sample indices refer to row positions, which a re-cluster permutes)."""
        for ck in [ck for ck in self._cache if ck[0] == table_name]:
            del self._cache[ck]


def aqr_cache_key(q: "Query", table: "ColumnTable", theta: float) -> Tuple:
    """Cross-query AQR identity: everything ``aqr_estimates`` consumes.

    ``Query.inner_signature()`` deliberately excludes the HAVING chain —
    per-group aggregate estimates do not depend on it — so a batch of
    concurrent queries differing only in thresholds maps to ONE cache slot.
    Versioned on the table lineage token: a mutation invalidates by key
    mismatch, no eviction protocol needed.
    """
    return (table.uid, table.version, theta) + q.inner_signature()


class AQRCache:
    """Sec. 7.1 reuse, one level up: cache *AQR estimate passes* per
    (table version, inner-block signature, theta).

    The stratified sample is already shared across same-group-by queries via
    ``SampleCache``; this shares the per-group estimate math built on top of
    it, which is candidate- and threshold-independent (Alg. 1's estimates
    feed every HAVING through ``satisfied_groups`` at group-level cost).
    Entries also pin the per-group ever-sampled mask so that a later
    re-sample of the same table version (e.g. after ``cluster_by``
    invalidated row indices) cannot shift the satisfied set of queries that
    already share this pass.
    """

    def __init__(self, max_entries: int = 256):
        self._cache: Dict[Tuple, Tuple[object, np.ndarray]] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0  # FIFO overflow pops (capacity, not invalidation)

    def get_or_compute(
        self,
        key: jax.Array,
        q: "Query",
        db: "Database",
        samples: SampleSet,
        theta: float,
        cfg,
    ) -> Tuple[object, np.ndarray]:
        """(GroupEstimates, per-group sampled mask) for ``q``'s inner block."""
        from repro.aqp.size_estimation import aqr_estimates

        ck = aqr_cache_key(q, db[q.table], theta)
        hit = self._cache.get(ck)
        if hit is not None:
            self.hits += 1
            return hit
        self.misses += 1
        est = aqr_estimates(key, q, db, samples, cfg)
        entry = (est, samples.sample_sizes > 0)
        if len(self._cache) >= self.max_entries:
            self._cache.pop(next(iter(self._cache)))
            self.evictions += 1
        self._cache[ck] = entry
        return entry

    def invalidate(self, table_name: str) -> None:
        # Key layout: (uid, version, theta) + inner_signature, whose first
        # element is the table name.
        for ck in [ck for ck in self._cache if ck[3] == table_name]:
            del self._cache[ck]
