"""Stratified reservoir sampling (Sec. 7.1), TPU-adapted.

Classic reservoir sampling is inherently sequential (row-at-a-time SPI loop in
the paper's Postgres implementation).  We use the Efraimidis–Spirakis
equivalence — keeping the k rows with the largest random keys draws a uniform
k-reservoir — which vectorizes to a sort + segmented rank, and stratify by
giving every group its own reservoir of size ``max(min_per_group,
floor(theta * #g))``.  When the number of groups exceeds the sample budget the
paper falls back to a plain uniform reservoir; so do we.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SampleSet:
    """A stratified sample with the catalog info estimators need."""

    table: str
    groupby: Tuple[str, ...]
    theta: float
    indices: np.ndarray  # row ids into the base table, shape (m,)
    sample_gid: np.ndarray  # dense group id per sampled row, shape (m,)
    n_groups: int
    group_sizes: np.ndarray  # #g for every group, shape (n_groups,)
    sample_sizes: np.ndarray  # #s_g for every group, shape (n_groups,)
    group_values: Dict[str, np.ndarray]  # group key values, per group
    stratified: bool

    @property
    def num_samples(self) -> int:
        return int(self.indices.shape[0])

    def reusable_for(self, table: str, groupby: Tuple[str, ...]) -> bool:
        """Sec. 7.1: samples stratified on the same group-by are reusable."""
        return self.table == table and tuple(self.groupby) == tuple(groupby)


def stratified_reservoir_sample(
    key: jax.Array,
    table: "ColumnTable",
    groupby: Tuple[str, ...],
    theta: float,
    min_per_group: int = 1,
) -> SampleSet:
    """Per-group reservoirs of size max(min_per_group, floor(theta * #g))."""
    from repro.core.table import encode_groups

    n = table.num_rows
    gid, n_groups, group_values = encode_groups(table, groupby)
    stratified = bool(groupby) and n_groups <= max(1, int(theta * n))
    if not stratified:
        return uniform_reservoir_sample(key, table, groupby, theta, gid, n_groups, group_values)

    u = np.asarray(jax.random.uniform(key, (n,), dtype=jnp.float32))
    # Sort by (group, descending key): the first k_g rows of each segment are
    # a uniform k_g-reservoir of that group.
    order = np.lexsort((-u, gid))
    gid_sorted = gid[order]
    group_sizes = np.bincount(gid, minlength=n_groups)
    starts = np.concatenate([[0], np.cumsum(group_sizes)[:-1]])
    rank = np.arange(n) - starts[gid_sorted]
    k_g = np.maximum(min_per_group, (theta * group_sizes).astype(np.int64))
    k_g = np.minimum(k_g, group_sizes)
    keep = rank < k_g[gid_sorted]
    idx = order[keep]
    return SampleSet(
        table=table.name,
        groupby=tuple(groupby),
        theta=theta,
        indices=idx,
        sample_gid=gid[idx],
        n_groups=n_groups,
        group_sizes=group_sizes,
        sample_sizes=np.bincount(gid[idx], minlength=n_groups),
        group_values=group_values,
        stratified=True,
    )


def uniform_reservoir_sample(
    key: jax.Array,
    table: "ColumnTable",
    groupby: Tuple[str, ...],
    theta: float,
    gid: Optional[np.ndarray] = None,
    n_groups: Optional[int] = None,
    group_values: Optional[Dict[str, np.ndarray]] = None,
) -> SampleSet:
    """Plain k-reservoir over the whole table (no-group-by / too-many-groups)."""
    from repro.core.table import encode_groups

    n = table.num_rows
    if gid is None:
        gid, n_groups, group_values = encode_groups(table, groupby)
    k = max(1, int(theta * n))
    u = np.asarray(jax.random.uniform(key, (n,), dtype=jnp.float32))
    idx = np.argpartition(-u, k - 1)[:k] if k < n else np.arange(n)
    idx = np.sort(idx)
    return SampleSet(
        table=table.name,
        groupby=tuple(groupby),
        theta=theta,
        indices=idx,
        sample_gid=gid[idx],
        n_groups=n_groups,
        group_sizes=np.bincount(gid, minlength=n_groups),
        sample_sizes=np.bincount(gid[idx], minlength=n_groups),
        group_values=group_values,
        stratified=False,
    )


class SampleCache:
    """Sec. 7.1 reuse: cache stratified samples keyed by (table, group-by)."""

    def __init__(self):
        self._cache: Dict[Tuple[str, Tuple[str, ...], float], SampleSet] = {}
        self.hits = 0
        self.misses = 0

    def get_or_create(
        self,
        key: jax.Array,
        table: "ColumnTable",
        groupby: Tuple[str, ...],
        theta: float,
    ) -> SampleSet:
        ck = (table.name, tuple(groupby), theta)
        if ck in self._cache:
            self.hits += 1
            return self._cache[ck]
        self.misses += 1
        s = stratified_reservoir_sample(key, table, groupby, theta)
        self._cache[ck] = s
        return s

    def invalidate(self, table_name: str) -> None:
        """Drop cached samples of one table (its physical layout changed:
        sample indices refer to row positions, which a re-cluster permutes)."""
        for ck in [ck for ck in self._cache if ck[0] == table_name]:
            del self._cache[ck]
