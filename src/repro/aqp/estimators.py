"""Haas'97 estimators + CLT confidence intervals (Sec. 8.2, Eqs. 1–7).

Per-group unbiased estimators for SUM/COUNT/AVG with the paper's scaling
rules (Def. 7): for a group g with #g rows in R and #s_g sampled rows,

  SUM:   #g * T_n(u·v)          COUNT: #g * T_n(u)         AVG: T_n(uv)/T_n(u)

where u(t) is the WHERE-predicate indicator and v(t) the aggregated value.
Variances follow Eqs. (5)–(7); half-widths are eps = z_alpha * sigma / sqrt(n).
Everything is computed for *all groups at once* with device segment ops.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

Z_95 = 1.959964  # (alpha+1)/2 quantile for alpha = 0.95
Z_90 = 1.644854


@dataclasses.dataclass(frozen=True)
class GroupEstimates:
    """Per-group estimate + CI, plus the ingredients for Def. 9."""

    fn: str
    estimate: np.ndarray  # shape (n_groups,)
    sigma: np.ndarray  # std of the *estimate* (already scaled), (n_groups,)
    half_width: np.ndarray  # CI half width eps_n, (n_groups,)
    n_samples: np.ndarray  # #s_g, (n_groups,)


def _seg(vals: Array, gid: Array, n: int) -> Array:
    return jax.ops.segment_sum(vals, gid, num_segments=n)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def group_estimates(
    fn: str,
    values: Optional[Array],  # v(t) per sampled row (None for COUNT)
    pred: Array,  # u(t) per sampled row (bool)
    gid: Array,  # dense group id per sampled row
    n_groups: int,
    group_sizes: np.ndarray,  # #g over the full table
    z: float = Z_95,
) -> GroupEstimates:
    # Pad the group axis to pow2 so every group-by of the same table lands in
    # one compiled size class (segment ops specialise on num_segments; a
    # workload whose signatures differ only in n_groups must not recompile
    # the selection math).  Padding segments receive no rows, so the first
    # n_groups outputs are bit-identical to the unpadded computation.
    real_groups = n_groups
    n_groups = _next_pow2(max(1, n_groups))
    gs = np.asarray(group_sizes, dtype=np.float32)
    if n_groups != real_groups:
        gs = np.pad(gs, (0, n_groups - real_groups))
    gid = jnp.asarray(gid)
    u = jnp.asarray(pred).astype(jnp.float32)
    ns = _seg(jnp.ones_like(u), gid, n_groups)  # #s_g
    ns_safe = jnp.maximum(ns, 1.0)
    sizes = jnp.asarray(gs)

    if fn == "count":
        uv = u
    else:
        uv = u * jnp.asarray(values).astype(jnp.float32)

    mean_uv = _seg(uv, gid, n_groups) / ns_safe  # T_n(uv)
    # T_{n,2}(uv): sample variance of uv within the group.
    var_uv = _seg((uv - mean_uv[gid]) ** 2, gid, n_groups) / jnp.maximum(ns - 1.0, 1.0)

    if fn in ("sum", "count"):
        est = sizes * mean_uv
        sigma_mean = jnp.sqrt(var_uv / ns_safe)  # std of T_n(uv)
        sigma = sizes * sigma_mean
    elif fn == "avg":
        mean_u = _seg(u, gid, n_groups) / ns_safe
        var_u = _seg((u - mean_u[gid]) ** 2, gid, n_groups) / jnp.maximum(ns - 1.0, 1.0)
        cov = _seg((uv - mean_uv[gid]) * (u - mean_u[gid]), gid, n_groups) / jnp.maximum(
            ns - 1.0, 1.0
        )  # T_{n,1,1}(uv, u)
        mean_u_safe = jnp.maximum(mean_u, 1e-12)
        r = mean_uv / mean_u_safe  # R_{n,2}
        est = r
        var_ratio = (var_uv - 2.0 * r * cov + r * r * var_u) / (mean_u_safe**2)
        sigma = jnp.sqrt(jnp.maximum(var_ratio, 0.0) / ns_safe)
    else:
        raise ValueError(f"unknown aggregate {fn!r}")

    eps = z * sigma
    return GroupEstimates(
        fn=fn,
        estimate=np.asarray(est)[:real_groups],  # analyze: waive[SYNC01]: deliberate merge: GroupEstimates holds host arrays for the host-side cost model
        sigma=np.asarray(sigma)[:real_groups],  # analyze: waive[SYNC01]: deliberate merge: GroupEstimates holds host arrays for the host-side cost model
        half_width=np.asarray(eps)[:real_groups],  # analyze: waive[SYNC01]: deliberate merge: GroupEstimates holds host arrays for the host-side cost model
        n_samples=np.asarray(ns).astype(np.int64)[:real_groups],  # analyze: waive[SYNC01]: deliberate merge: GroupEstimates holds host arrays for the host-side cost model
    )


def norm_cdf(x: np.ndarray) -> np.ndarray:
    """Standard normal CDF via erf (no scipy dependency)."""
    x = jnp.asarray(x, dtype=jnp.float32)
    return np.asarray(0.5 * (1.0 + jax.scipy.special.erf(x / np.sqrt(2.0)))).astype(np.float64)  # analyze: waive[SYNC01]: deliberate merge: erf runs on device, the CDF is consumed by host probability math


def pass_probability(
    est: GroupEstimates, op: str, threshold: float, floor: float = 1e-6
) -> np.ndarray:
    """P(group passes HAVING) under the CLT normal approximation (Sec. 8.2).

    lambda = Phi((est - tau)/sigma) for '>' style predicates; groups with
    sigma == 0 (fully sampled strata) degenerate to the indicator.
    """
    sigma = np.maximum(est.sigma, 1e-30)
    zscores = (est.estimate - threshold) / sigma
    p_gt = norm_cdf(zscores)
    exact = est.sigma <= 1e-30
    if op in (">", ">="):
        p = np.where(exact, (est.estimate > threshold) if op == ">" else (est.estimate >= threshold), p_gt)
    elif op in ("<", "<="):
        p = np.where(exact, (est.estimate < threshold) if op == "<" else (est.estimate <= threshold), 1.0 - p_gt)
    elif op == "=":
        p = np.where(np.abs(est.estimate - threshold) <= est.half_width, 1.0, floor)
    else:
        raise ValueError(op)
    return np.clip(p.astype(np.float64), floor, 1.0 - 1e-12)
