"""Approximate query processing for sketch-size estimation (Secs. 7 & 8)."""
