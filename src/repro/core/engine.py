"""PBDSEngine — the Fig. 3 workflow as a single online component.

For each incoming query:
  1. probe the sketch index; on a hit, run the instrumented query over the
     catalog-cached sketch instance (fragment skipping, no per-row scan);
  2. otherwise run the configured candidate-selection strategy (sampling is
     cached/reused per Sec. 7.1, AQR estimate passes are cached
     threshold-independently per table version), capture an accurate sketch
     on the chosen attribute via the fused capture+execute path, store it,
     and return the shared result;
  3. when no viable candidate exists, fall back to NO-PS execution.

``run_batch`` accepts a batch of concurrent queries and routes the misses
through the batched admission pipeline (``repro.core.admission``): grouped
shared-sample/shared-AQR selection in one padded device launch, one
inner-block scan per signature group, and multi-sketch fused capture —
bit-identical to sequential ``run`` but with the per-miss cost shared.

All repeated host work (group-by dictionary encoding, join materialization,
bucketization, distinct counts, sketch instances) lives in the engine's
``Catalog``.  With ``cluster_tables=True`` the first created sketch per table
also re-clusters that table fragment-major (``ColumnTable.cluster_by``) so
instance materialization is a slice concatenation; it is opt-in because the
physical reorder reassociates float32 aggregation for queries grouping on
other attributes (bit-identical results are the default contract).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.aqp.sampling import AQRCache, SampleCache
from repro.aqp.size_estimation import EstimationConfig
from repro.core.catalog import Catalog
from repro.core.index import IndexEntry, SketchIndex
from repro.core.maintenance import build_maintainer, repair_sketch
from repro.core.queries import Query, QueryResult, execute, execute_and_provenance
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.sketch import ProvenanceSketch, apply_sketch, capture_sketch, execute_with_sketch
from repro.core.strategies import (
    SelectionCache,
    SelectionConfig,
    SelectionResult,
    select_attribute,
)
from repro.core.table import Database
from repro.core.workload import WorkloadLog
from repro.runtime.guards import hot_path
from repro.runtime.stable_hash import stable_hash32


@dataclasses.dataclass
class RunInfo:
    reused: bool
    created: bool
    attr: Optional[str]
    strategy: str
    selectivity: Optional[float]
    t_select: float = 0.0
    t_capture: float = 0.0
    t_execute: float = 0.0
    # Reused-path timing split: ``t_probe`` is the index lookup, ``t_repair``
    # the bring-current work on a mutated table (both used to hide inside
    # ``t_execute``, silently inflating the reuse numbers).
    t_probe: float = 0.0
    t_repair: float = 0.0
    # Index hit on a mutated table: the sketch was brought current before use
    # (incrementally maintained, or re-captured when maintenance refused —
    # catalog.stats['sketch_maintained'/'sketch_recaptured'] tell them apart).
    repaired: bool = False
    # Fragment-sharded serving (``repro.core.shard``): how many shards were
    # sent work vs skipped because the sketch touched none of their
    # fragments.  ``None`` for single-node execution.
    shards_contacted: Optional[int] = None
    shards_skipped: Optional[int] = None
    # Sharded serving answered with one or more shards down/lagging: their
    # fragment slices were served from the coordinator's authoritative table
    # (bit-identical, but without that shard's parallelism).  The per-route
    # detail (which shards, how many retries) lives on ``RouteInfo``.
    degraded: bool = False

    @property
    def t_total(self) -> float:
        return self.t_probe + self.t_select + self.t_capture + self.t_repair + self.t_execute


class PBDSEngine:
    def __init__(
        self,
        db: Database,
        strategy: str = "CB-OPT-GB",
        n_ranges: int = 100,
        theta: float = 0.05,
        cfg: EstimationConfig = EstimationConfig(),
        seed: int = 0,
        min_selectivity_gain: float = 0.9,
        cluster_tables: bool = False,
        max_delta_chain: int = 64,
        compact_tail_frac: Optional[float] = None,
        selection: Optional[SelectionConfig] = None,
    ):
        self.db = db
        self.strategy = strategy
        self.n_ranges = n_ranges
        self.theta = theta
        self.cfg = cfg
        self.index = SketchIndex()
        self.samples = SampleCache()
        self.aqr = AQRCache()
        self.catalog = Catalog()
        # Selection-path knobs (stats pre-filter, single-candidate shortcut,
        # reuse-aware worth-it, whole-pass memoization) — all ON by default;
        # pass ``SelectionConfig.paper_faithful()`` for seed/Sec. 8-9 behavior.
        self.selection = SelectionConfig() if selection is None else selection
        self.selection_cache = SelectionCache()
        self.workload = WorkloadLog(self.selection.reuse_window)
        self.cluster_tables = cluster_tables
        self._base_key = jax.random.PRNGKey(seed)
        self._ranges_cache: Dict[Tuple[str, str], RangeSet] = {}
        # Delta chains pin every prior version's columns; past this depth the
        # engine advances all maintainers and collapses the history.
        self.max_delta_chain = max_delta_chain
        # Sketches estimated to cover >= this fraction of the table are not
        # worth creating (problem definition (i) in Sec. 4.5).
        self.min_selectivity_gain = min_selectivity_gain
        # When set, a clustered table whose unsorted append tail exceeds this
        # fraction of its rows is physically compacted (tail folded back into
        # fragment-major order) so sketch application returns to pure slice
        # concatenation.  Off by default: compaction is a full-table permute
        # and drops row-position caches, the same trade as cluster_by.
        self.compact_tail_frac = compact_tail_frac

    def selection_state(self) -> dict:
        """Picklable snapshot of the reuse-aware selection state: the
        ``WorkloadLog`` miss window (the reuse-aware cost model's input)
        plus the ``SelectionCache`` hit/miss counters.  A coordinator
        restart that drops this silently reverts CB-OPT-GB to reuse-blind
        declines — checkpoint it alongside the table state."""
        return {
            "workload": self.workload.snapshot(),
            "selection_cache": {"hits": self.selection_cache.hits,
                                "misses": self.selection_cache.misses},
        }

    def restore_selection_state(self, state: Mapping) -> None:
        """Inverse of ``selection_state`` (cache *stats* restore; cached
        selection results themselves rebuild on first use)."""
        self.workload = WorkloadLog.from_snapshot(state["workload"])
        sc = state.get("selection_cache")
        if sc is not None:
            self.selection_cache.hits = int(sc["hits"])
            self.selection_cache.misses = int(sc["misses"])

    def _select_key(self, q: Query) -> jax.Array:
        """Per-query selection randomness, derived from query *content*.

        A chained key stream would make the engine's choices depend on the
        order misses happen to arrive in; folding the query signature into
        the seed key instead makes sequential ``run`` and batched
        ``run_batch`` admission draw identical randomness for identical
        queries — the invariant the differential admission suite pins.

        The hash must also be identical in every *process*: once shards are
        real processes, a coordinator and replica deriving different keys
        for the same query would draw different selection randomness.
        ``stable_hash32`` is repr-compatible for plain-python signatures
        (same key stream as before) but immune to ``PYTHONHASHSEED``, numpy
        scalar reprs and set iteration order — pinned by the subprocess
        determinism test in ``tests/test_guards.py``.
        """
        h = stable_hash32(q.signature())
        return jax.random.fold_in(self._base_key, h)

    def ranges_for(self, table: str, attr: str) -> RangeSet:
        ck = (table, attr)
        if ck not in self._ranges_cache:
            self._ranges_cache[ck] = equi_depth_ranges(self.db[table], attr, self.n_ranges)
        return self._ranges_cache[ck]

    def _maybe_cluster(self, table_name: str, ranges: RangeSet) -> None:
        """Fragment-major re-layout, once per table (first created sketch).

        Equi-depth bounds are permutation-invariant so the ranges cache stays
        valid, but cached sample *indices* refer to row positions and must be
        dropped.
        """
        if not self.cluster_tables:
            return
        table = self.db[table_name]
        if table.layout is not None:
            return
        self.db = self.db.with_table(table.cluster_by(ranges))
        self.samples.invalidate(table_name)
        self.selection_cache.invalidate(table_name)
        self.catalog.invalidate_table(table)  # old object can never hit again
        self.catalog.stats["cluster"] += 1

    # -- mutations -------------------------------------------------------------
    def append_rows(self, table_name: str, rows: Mapping[str, np.ndarray]) -> None:
        """Append a batch; sketches repair lazily on their next index hit."""
        self.db = self.db.with_table(self.db[table_name].append(rows))
        self.catalog.stats["table_append"] += 1
        self._bound_history(table_name)
        self._maybe_compact(table_name)

    def _maybe_compact(self, table_name: str) -> None:
        """Fold an oversized unsorted tail back into fragment-major order.

        Maintainer state is permutation-invariant so index entries survive,
        but every maintainer must be advanced first: compaction drops the
        delta chain, so a lagging maintainer could no longer catch up.
        """
        table = self.db[table_name]
        lay = table.layout
        if (self.compact_tail_frac is None or lay is None or
                lay.tail <= self.compact_tail_frac * max(table.num_rows, 1)):
            return
        from repro.core.maintenance import MaintenanceError

        for e in self.index.entries():
            if e.query.table != table_name or e.maintainer is None:
                continue
            try:
                e.maintainer.apply(table, self.db)
                e.sketch = e.maintainer.to_sketch(table, self.catalog)
            except MaintenanceError:
                e.maintainer = None
        self.db = self.db.with_table(table.compact())
        self.catalog.invalidate_chain(table)
        self.samples.invalidate(table_name)
        self.selection_cache.invalidate(table_name)
        self.catalog.stats["compact"] += 1

    def delete_rows(self, table_name: str, mask: np.ndarray) -> None:
        """Delete the masked rows; sketches repair lazily on their next hit."""
        self.db = self.db.with_table(self.db[table_name].delete(mask))
        self.catalog.stats["table_delete"] += 1
        self._bound_history(table_name)

    def _bound_history(self, table_name: str) -> None:
        """Cap the delta chain: advance every maintainer to the current
        version (delta-sized work), then drop the parent references so prior
        versions' columns can be freed.  Caches keyed to old versions rebuild
        once on next touch — O(table) once per ``max_delta_chain`` mutations,
        amortized away."""
        table = self.db[table_name]
        if table.delta_depth() <= self.max_delta_chain:
            return
        from repro.core.maintenance import MaintenanceError

        for e in self.index.entries():
            if e.query.table != table_name or e.maintainer is None:
                continue
            try:
                e.maintainer.apply(table, self.db)
                e.sketch = e.maintainer.to_sketch(table, self.catalog)
            except MaintenanceError:
                e.maintainer = None  # next hit re-captures
        self.db = self.db.with_table(table.collapse())
        # Drop every chain version's catalog entries and cached samples so the
        # collapsed chain's columns can actually be freed.
        self.catalog.invalidate_chain(table)
        self.samples.invalidate(table_name)
        self.selection_cache.invalidate(table_name)
        self.catalog.stats["history_collapse"] += 1

    def _current_sketch(self, entry: IndexEntry) -> Tuple[ProvenanceSketch, bool]:
        """The entry's sketch, transparently repaired if the table mutated."""
        table = self.db[entry.query.table]
        if entry.sketch.current_for(table):
            return entry.sketch, False
        result, maintainer = repair_sketch(
            entry.query, self.db, entry.sketch, entry.maintainer, catalog=self.catalog)
        entry.sketch = result.sketch
        entry.maintainer = maintainer
        return result.sketch, True

    @hot_path
    def _serve_hit(
        self, q: Query, entry: IndexEntry, t_probe: float
    ) -> Tuple[QueryResult, RunInfo]:
        """Serve one index hit over the (repaired-if-stale) sketch instance —
        the shared hit path of ``run`` and ``run_batch``."""
        tp = time.perf_counter()
        sketch, repaired = self._current_sketch(entry)
        tr = time.perf_counter()
        res = execute_with_sketch(q, self.db, sketch, catalog=self.catalog)
        return res, RunInfo(
            reused=True, created=False, attr=sketch.attr, strategy=self.strategy,
            selectivity=sketch.selectivity, t_probe=t_probe, t_repair=tr - tp,
            t_execute=time.perf_counter() - tr, repaired=repaired,
        )

    def _worth_it(self, sel: SelectionResult, q: Query,
                  stamp: Optional[int]) -> bool:
        """The admission rule (problem definition (i), Sec. 4.5), shared by
        ``run`` and the batched planner.

        Paper rule: create unless the estimate covers >= ``min_selectivity_gain``
        of the table.  Reuse-aware (default): each recent-window query this
        sketch would serve (``WorkloadLog.reach``, self-inclusive) discounts
        the coverage by ``reuse_weight`` first — expected future index hits
        buy back capture cost even for broad sketches."""
        if sel.attr is None:
            return False
        est = sel.estimates.get(sel.attr) if sel.estimates else None
        if est is None:
            return True
        gain = est.est_selectivity
        if stamp is not None:
            gain -= self.selection.reuse_weight * self.workload.reach(q, stamp)
        return gain < self.min_selectivity_gain

    @hot_path
    def run(self, q: Query) -> Tuple[QueryResult, RunInfo]:
        t0 = time.perf_counter()
        entry = self.index.lookup_entry(q) if self.strategy != "NO-PS" else None
        tp = time.perf_counter()
        if entry is not None:
            return self._serve_hit(q, entry, tp - t0)

        if self.strategy == "NO-PS":
            res = execute(q, self.db, catalog=self.catalog)
            return res, RunInfo(False, False, None, "NO-PS", None,
                                t_execute=time.perf_counter() - tp, t_probe=tp - t0)

        stamp = self.workload.record(q) if self.selection.reuse_aware else None
        sel = select_attribute(
            self.strategy, self._select_key(q), q, self.db, self.n_ranges,
            sample_cache=self.samples, theta=self.theta, cfg=self.cfg,
            ranges_for=lambda a: self.ranges_for(q.table, a),
            catalog=self.catalog, aqr_cache=self.aqr,
            selection=self.selection, selection_cache=self.selection_cache,
        )
        t1 = time.perf_counter()

        if not self._worth_it(sel, q, stamp):
            res = execute(q, self.db, catalog=self.catalog)
            t2 = time.perf_counter()
            return res, RunInfo(False, False, None, self.strategy, None,
                                t_probe=tp - t0, t_select=t1 - tp, t_execute=t2 - t1)

        ranges = self.ranges_for(q.table, sel.attr)
        self._maybe_cluster(q.table, ranges)
        tc = time.perf_counter()
        # Fused path: one inner-block evaluation yields the result AND the
        # provenance the sketch is captured from (the seed ran it twice).
        res, prov = execute_and_provenance(q, self.db, catalog=self.catalog)
        t2 = time.perf_counter()
        sketch = capture_sketch(q, self.db, ranges, prov=prov, catalog=self.catalog)
        # Maintenance state rides along from capture: the inner-block products
        # it needs (group encoding, join layout, bucketization) are all catalog
        # hits at this point, so the build costs one delta-free counting pass.
        maintainer = build_maintainer(q, self.db, ranges, self.catalog)
        self.index.insert(q, sketch, maintainer=maintainer)
        # Warm the reuse path now, while we are already paying capture cost:
        # materialize the sketch instance and run the instrumented query once
        # so its catalog entries (instance, group encoding, join layout) and
        # kernel compilations exist before the first index hit.
        execute(q, apply_sketch(sketch, self.db, catalog=self.catalog), catalog=self.catalog)
        t3 = time.perf_counter()
        return res, RunInfo(
            reused=False, created=True, attr=sel.attr, strategy=self.strategy,
            selectivity=sketch.selectivity, t_probe=tp - t0,
            t_select=t1 - tp, t_capture=(tc - t1) + (t3 - t2), t_execute=t2 - tc,
        )

    @hot_path
    def run_batch(self, qs: Sequence[Query]) -> List[Tuple[QueryResult, RunInfo]]:
        """Batched admission: serve index hits immediately, admit the misses
        through the shared-selection / fused-capture pipeline.

        Semantically equivalent to ``[self.run(q) for q in qs]`` — results,
        index contents and sketch bits are pinned bit-identical by
        ``tests/test_admission.py``.  One carve-out: with
        ``cluster_tables=True`` the first admission physically re-clusters
        the table mid-batch and invalidates cached samples; sequential
        execution then re-samples the permuted rows for later same-batch
        misses while the batch shares the pre-cluster sample, so strategies
        whose candidate incidence depends on sample *row positions*
        (non-group-by candidates, e.g. CB-OPT-REL/CB-OPT) may choose
        differently.  Group-by-candidate strategies (CB-OPT-GB, the default
        regime) pin incidence on group values and stay bit-identical either
        way.  The miss-path cost is shared:
        misses are grouped by inner-block signature so each group pays ONE
        stratified sample, ONE AQR estimate pass, and ONE table scan feeding
        every admitted sketch's provenance; all selection math runs as a
        single padded (query x candidate) device launch, and capture emits B
        bitvectors from one bucketization.  Queries whose sketch would be
        created by an earlier query in the same batch are deferred a wave and
        served as ordinary index hits, exactly as sequential execution would.
        """
        from repro.core.admission import admit_misses

        if self.selection.reuse_aware and self.strategy != "NO-PS":
            # Reserve workload-log stamps per batch position up front: wave
            # deferral records misses out of arrival order, and the stamps
            # keep ``reach`` order-exact with a sequential replay.
            self.workload.begin_batch(len(qs))
        out: List[Optional[Tuple[QueryResult, RunInfo]]] = [None] * len(qs)
        pending: List[Tuple[int, Query]] = list(enumerate(qs))
        while pending:
            misses: List[Tuple[int, Query, float]] = []
            for i, q in pending:
                t0 = time.perf_counter()
                entry = self.index.lookup_entry(q) if self.strategy != "NO-PS" else None
                tp = time.perf_counter()
                if entry is None:
                    misses.append((i, q, tp - t0))
                    continue
                out[i] = self._serve_hit(q, entry, tp - t0)
            if not misses:
                break
            served, pending = admit_misses(self, misses)
            for i, item in served.items():
                out[i] = item
        return out  # type: ignore[return-value]
