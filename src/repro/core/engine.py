"""PBDSEngine — the Fig. 3 workflow as a single online component.

For each incoming query:
  1. probe the sketch index; on a hit, run the instrumented query over the
     catalog-cached sketch instance (fragment skipping, no per-row scan);
  2. otherwise run the configured candidate-selection strategy (sampling is
     cached/reused per Sec. 7.1), capture an accurate sketch on the chosen
     attribute via the fused capture+execute path, store it, and return the
     shared result;
  3. when no viable candidate exists, fall back to NO-PS execution.

All repeated host work (group-by dictionary encoding, join materialization,
bucketization, distinct counts, sketch instances) lives in the engine's
``Catalog``.  With ``cluster_tables=True`` the first created sketch per table
also re-clusters that table fragment-major (``ColumnTable.cluster_by``) so
instance materialization is a slice concatenation; it is opt-in because the
physical reorder reassociates float32 aggregation for queries grouping on
other attributes (bit-identical results are the default contract).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax

from repro.aqp.sampling import SampleCache
from repro.aqp.size_estimation import EstimationConfig
from repro.core.catalog import Catalog
from repro.core.index import SketchIndex
from repro.core.queries import Query, QueryResult, execute, execute_and_provenance
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.sketch import apply_sketch, capture_sketch, execute_with_sketch
from repro.core.strategies import select_attribute
from repro.core.table import Database


@dataclasses.dataclass
class RunInfo:
    reused: bool
    created: bool
    attr: Optional[str]
    strategy: str
    selectivity: Optional[float]
    t_select: float = 0.0
    t_capture: float = 0.0
    t_execute: float = 0.0

    @property
    def t_total(self) -> float:
        return self.t_select + self.t_capture + self.t_execute


class PBDSEngine:
    def __init__(
        self,
        db: Database,
        strategy: str = "CB-OPT-GB",
        n_ranges: int = 100,
        theta: float = 0.05,
        cfg: EstimationConfig = EstimationConfig(),
        seed: int = 0,
        min_selectivity_gain: float = 0.9,
        cluster_tables: bool = False,
    ):
        self.db = db
        self.strategy = strategy
        self.n_ranges = n_ranges
        self.theta = theta
        self.cfg = cfg
        self.index = SketchIndex()
        self.samples = SampleCache()
        self.catalog = Catalog()
        self.cluster_tables = cluster_tables
        self._key = jax.random.PRNGKey(seed)
        self._ranges_cache: Dict[Tuple[str, str], RangeSet] = {}
        # Sketches estimated to cover >= this fraction of the table are not
        # worth creating (problem definition (i) in Sec. 4.5).
        self.min_selectivity_gain = min_selectivity_gain

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def ranges_for(self, table: str, attr: str) -> RangeSet:
        ck = (table, attr)
        if ck not in self._ranges_cache:
            self._ranges_cache[ck] = equi_depth_ranges(self.db[table], attr, self.n_ranges)
        return self._ranges_cache[ck]

    def _maybe_cluster(self, table_name: str, ranges: RangeSet) -> None:
        """Fragment-major re-layout, once per table (first created sketch).

        Equi-depth bounds are permutation-invariant so the ranges cache stays
        valid, but cached sample *indices* refer to row positions and must be
        dropped.
        """
        if not self.cluster_tables:
            return
        table = self.db[table_name]
        if table.layout is not None:
            return
        self.db = self.db.with_table(table.cluster_by(ranges))
        self.samples.invalidate(table_name)
        self.catalog.invalidate_table(table)  # old object can never hit again
        self.catalog.stats["cluster"] += 1

    def run(self, q: Query) -> Tuple[QueryResult, RunInfo]:
        t0 = time.perf_counter()
        sketch = self.index.lookup(q) if self.strategy != "NO-PS" else None
        if sketch is not None:
            res = execute_with_sketch(q, self.db, sketch, catalog=self.catalog)
            t1 = time.perf_counter()
            return res, RunInfo(
                reused=True, created=False, attr=sketch.attr, strategy=self.strategy,
                selectivity=sketch.selectivity, t_execute=t1 - t0,
            )

        if self.strategy == "NO-PS":
            res = execute(q, self.db, catalog=self.catalog)
            return res, RunInfo(False, False, None, "NO-PS", None,
                                t_execute=time.perf_counter() - t0)

        sel = select_attribute(
            self.strategy, self._next_key(), q, self.db, self.n_ranges,
            sample_cache=self.samples, theta=self.theta, cfg=self.cfg,
            ranges_for=lambda a: self.ranges_for(q.table, a),
            catalog=self.catalog,
        )
        t1 = time.perf_counter()

        est = sel.estimates.get(sel.attr) if sel.estimates else None
        worth_it = sel.attr is not None and (
            est is None or est.est_selectivity < self.min_selectivity_gain
        )
        if not worth_it:
            res = execute(q, self.db, catalog=self.catalog)
            t2 = time.perf_counter()
            return res, RunInfo(False, False, None, self.strategy, None,
                                t_select=t1 - t0, t_execute=t2 - t1)

        ranges = self.ranges_for(q.table, sel.attr)
        self._maybe_cluster(q.table, ranges)
        tc = time.perf_counter()
        # Fused path: one inner-block evaluation yields the result AND the
        # provenance the sketch is captured from (the seed ran it twice).
        res, prov = execute_and_provenance(q, self.db, catalog=self.catalog)
        t2 = time.perf_counter()
        sketch = capture_sketch(q, self.db, ranges, prov=prov, catalog=self.catalog)
        self.index.insert(q, sketch)
        # Warm the reuse path now, while we are already paying capture cost:
        # materialize the sketch instance and run the instrumented query once
        # so its catalog entries (instance, group encoding, join layout) and
        # kernel compilations exist before the first index hit.
        execute(q, apply_sketch(sketch, self.db, catalog=self.catalog), catalog=self.catalog)
        t3 = time.perf_counter()
        return res, RunInfo(
            reused=False, created=True, attr=sel.attr, strategy=self.strategy,
            selectivity=sketch.selectivity,
            t_select=t1 - t0, t_capture=(tc - t1) + (t3 - t2), t_execute=t2 - tc,
        )
