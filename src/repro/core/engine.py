"""PBDSEngine — the Fig. 3 workflow as a single online component.

For each incoming query:
  1. probe the sketch index; on a hit, instrument the query with the sketch;
  2. otherwise run the configured candidate-selection strategy (sampling is
     cached/reused per Sec. 7.1), capture an accurate sketch on the chosen
     attribute, store it, and instrument the query;
  3. when no viable candidate exists, fall back to NO-PS execution.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.aqp.sampling import SampleCache
from repro.aqp.size_estimation import EstimationConfig
from repro.core.index import SketchIndex
from repro.core.queries import Query, QueryResult, execute
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.sketch import ProvenanceSketch, capture_sketch, execute_with_sketch
from repro.core.strategies import SelectionResult, select_attribute
from repro.core.table import Database


@dataclasses.dataclass
class RunInfo:
    reused: bool
    created: bool
    attr: Optional[str]
    strategy: str
    selectivity: Optional[float]
    t_select: float = 0.0
    t_capture: float = 0.0
    t_execute: float = 0.0

    @property
    def t_total(self) -> float:
        return self.t_select + self.t_capture + self.t_execute


class PBDSEngine:
    def __init__(
        self,
        db: Database,
        strategy: str = "CB-OPT-GB",
        n_ranges: int = 100,
        theta: float = 0.05,
        cfg: EstimationConfig = EstimationConfig(),
        seed: int = 0,
        min_selectivity_gain: float = 0.9,
    ):
        self.db = db
        self.strategy = strategy
        self.n_ranges = n_ranges
        self.theta = theta
        self.cfg = cfg
        self.index = SketchIndex()
        self.samples = SampleCache()
        self._key = jax.random.PRNGKey(seed)
        self._ranges_cache: Dict[Tuple[str, str], RangeSet] = {}
        # Sketches estimated to cover >= this fraction of the table are not
        # worth creating (problem definition (i) in Sec. 4.5).
        self.min_selectivity_gain = min_selectivity_gain

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def ranges_for(self, table: str, attr: str) -> RangeSet:
        ck = (table, attr)
        if ck not in self._ranges_cache:
            self._ranges_cache[ck] = equi_depth_ranges(self.db[table], attr, self.n_ranges)
        return self._ranges_cache[ck]

    def run(self, q: Query) -> Tuple[QueryResult, RunInfo]:
        t0 = time.perf_counter()
        sketch = self.index.lookup(q) if self.strategy != "NO-PS" else None
        if sketch is not None:
            res = execute_with_sketch(q, self.db, sketch)
            t1 = time.perf_counter()
            return res, RunInfo(
                reused=True, created=False, attr=sketch.attr, strategy=self.strategy,
                selectivity=sketch.selectivity, t_execute=t1 - t0,
            )

        if self.strategy == "NO-PS":
            res = execute(q, self.db)
            return res, RunInfo(False, False, None, "NO-PS", None,
                                t_execute=time.perf_counter() - t0)

        sel = select_attribute(
            self.strategy, self._next_key(), q, self.db, self.n_ranges,
            sample_cache=self.samples, theta=self.theta, cfg=self.cfg,
            ranges_for=lambda a: self.ranges_for(q.table, a),
        )
        t1 = time.perf_counter()

        est = sel.estimates.get(sel.attr) if sel.estimates else None
        worth_it = sel.attr is not None and (
            est is None or est.est_selectivity < self.min_selectivity_gain
        )
        if not worth_it:
            res = execute(q, self.db)
            t2 = time.perf_counter()
            return res, RunInfo(False, False, None, self.strategy, None,
                                t_select=t1 - t0, t_execute=t2 - t1)

        sketch = capture_sketch(q, self.db, self.ranges_for(q.table, sel.attr))
        self.index.insert(q, sketch)
        t2 = time.perf_counter()
        res = execute_with_sketch(q, self.db, sketch)
        t3 = time.perf_counter()
        return res, RunInfo(
            reused=False, created=True, attr=sel.attr, strategy=self.strategy,
            selectivity=sketch.selectivity,
            t_select=t1 - t0, t_capture=t2 - t1, t_execute=t3 - t2,
        )
