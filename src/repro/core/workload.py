"""Synthetic query workloads (Sec. 11.1): random instantiations of the
Q-AGH / Q-AJGH / Q-AAJGH templates over the four datasets, with HAVING
thresholds drawn from the actual group-aggregate quantiles so workloads mix
selective and broad queries (like the paper's 1000-query batches).

Also home of the engine's :class:`WorkloadLog` — the bounded window of
recently *missed* queries that reuse-aware selection scores candidate
sketches against (subsumption reach ~ expected future index hits)."""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.index import subsumes
from repro.core.queries import Aggregate, Having, JoinSpec, Query, execute
from repro.core.table import Database


class WorkloadLog:
    """Bounded log of recent sketch-index *misses*, stamped in arrival order.

    The reuse-aware cost model asks: had we captured a sketch for ``q``, how
    many queries in the recent window would it have served?  ``reach(q)``
    answers with the number of logged queries ``p`` that ``q`` subsumes — the
    same predicate the index uses to serve hits — so the worth-it rule can
    trade estimated coverage against expected future hits.

    Stamps make batched admission order-exact: sequential ``run`` records one
    miss at a time, while ``run_batch`` admits whole waves (and defers
    subsumed members to later waves), so entries can be *inserted* out of
    batch-position order.  Each entry carries the stamp of its batch position
    and ``reach(q, stamp)`` only counts entries at or before ``stamp`` —
    reproducing exactly what a sequential replay would have seen.  Only hits
    never enter the log: a served query needs no new sketch in either path.
    """

    def __init__(self, window: int = 256):
        self.window = window
        self._log: collections.deque = collections.deque(maxlen=max(1, window))
        self._clock = 0
        self._batch_base: Optional[int] = None

    def __len__(self) -> int:
        return len(self._log)

    @property
    def clock(self) -> int:
        return self._clock

    def begin_batch(self, n: int) -> None:
        """Reserve stamp slots for an ``n``-query batch: position ``i`` gets
        stamp ``base + i + 1`` no matter which admission wave records it."""
        self._batch_base = self._clock
        self._clock += n

    def batch_stamp(self, pos: int) -> Optional[int]:
        """The reserved stamp of batch position ``pos`` (None outside a batch)."""
        if self._batch_base is None:
            return None
        return self._batch_base + pos + 1

    def record(self, q: Query, stamp: Optional[int] = None) -> int:
        """Log one miss; returns its stamp (auto-incremented when not given)."""
        if stamp is None:
            self._clock += 1
            stamp = self._clock
        self._log.append((stamp, q))
        return stamp

    def reach(self, q: Query, stamp: Optional[int] = None) -> int:
        """#logged queries at-or-before ``stamp`` that a sketch for ``q``
        would serve (``subsumes(q, p)``); the whole window when no stamp."""
        if stamp is None:
            stamp = self._clock
        return sum(1 for s, p in self._log if s <= stamp and subsumes(q, p))

    def entries(self) -> List[Tuple[int, Query]]:
        return list(self._log)

    def snapshot(self) -> dict:
        """Picklable state (queries are frozen value dataclasses): the
        coordinator checkpoints this so a restart keeps the reuse-aware
        cost model's miss window instead of reverting to reuse-blind
        declines."""
        return {"window": self.window, "clock": self._clock,
                "entries": list(self._log)}

    @classmethod
    def from_snapshot(cls, snap: Mapping) -> "WorkloadLog":
        log = cls(snap["window"])
        for stamp, q in snap["entries"]:
            log._log.append((stamp, q))
        log._clock = snap["clock"]
        return log


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    table: str
    gb_pool: Tuple[str, ...]  # attributes eligible for GROUP BY
    agg_pool: Tuple[str, ...]  # attributes eligible for aggregation
    join: Optional[JoinSpec] = None
    n_gb: Tuple[int, ...] = (1, 2, 3)
    agg_fns: Tuple[str, ...] = ("sum", "avg", "count")
    # HAVING threshold quantile range over the group aggregates
    q_range: Tuple[float, float] = (0.5, 0.95)


CRIMES_SPEC = WorkloadSpec(
    table="crimes",
    gb_pool=("district", "month", "year", "pid", "ward", "community"),
    agg_pool=("records",),
)

TPCH_SPEC = WorkloadSpec(
    table="lineitem",
    gb_pool=("l_suppkey", "l_shipdate", "l_partkey"),
    agg_pool=("l_extendedprice", "l_quantity"),
)

TPCH_JOIN_SPEC = WorkloadSpec(
    table="lineitem",
    gb_pool=("l_suppkey", "l_shipdate"),
    agg_pool=("l_extendedprice", "l_quantity"),
    join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
)

PARKING_SPEC = WorkloadSpec(
    table="parking",
    gb_pool=("borough", "precinct", "agency", "year", "month", "hour"),
    agg_pool=("fine", "violation"),
)

STARS_SPEC = WorkloadSpec(
    table="stars",
    gb_pool=("field", "run"),
    agg_pool=("mag_g", "mag_r", "redshift"),
)


def generate_workload(
    spec: WorkloadSpec, db: Database, n_queries: int, seed: int = 0
) -> List[Query]:
    """Random template instantiations with data-calibrated thresholds."""
    rng = np.random.default_rng(seed)
    out: List[Query] = []
    attempts = 0
    while len(out) < n_queries and attempts < n_queries * 10:
        attempts += 1
        k = int(rng.choice(spec.n_gb))
        gb = tuple(sorted(rng.choice(spec.gb_pool, size=min(k, len(spec.gb_pool)), replace=False)))
        fn = str(rng.choice(spec.agg_fns))
        agg_attr = None if fn == "count" else str(rng.choice(spec.agg_pool))
        q0 = Query(
            table=spec.table,
            groupby=gb,
            agg=Aggregate(fn, agg_attr),
            join=spec.join,
        )
        # Calibrate the threshold on the actual group aggregates.
        res = execute(q0, db)
        if len(res.values) < 4:
            continue
        qlo, qhi = spec.q_range
        tau = float(np.quantile(res.values, rng.uniform(qlo, qhi)))
        out.append(dataclasses.replace(q0, having=Having(">", tau)))
    return out
