"""Synthetic query workloads (Sec. 11.1): random instantiations of the
Q-AGH / Q-AJGH / Q-AAJGH templates over the four datasets, with HAVING
thresholds drawn from the actual group-aggregate quantiles so workloads mix
selective and broad queries (like the paper's 1000-query batches)."""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.queries import Aggregate, Having, JoinSpec, Query, execute
from repro.core.table import Database


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    table: str
    gb_pool: Tuple[str, ...]  # attributes eligible for GROUP BY
    agg_pool: Tuple[str, ...]  # attributes eligible for aggregation
    join: Optional[JoinSpec] = None
    n_gb: Tuple[int, ...] = (1, 2, 3)
    agg_fns: Tuple[str, ...] = ("sum", "avg", "count")
    # HAVING threshold quantile range over the group aggregates
    q_range: Tuple[float, float] = (0.5, 0.95)


CRIMES_SPEC = WorkloadSpec(
    table="crimes",
    gb_pool=("district", "month", "year", "pid", "ward", "community"),
    agg_pool=("records",),
)

TPCH_SPEC = WorkloadSpec(
    table="lineitem",
    gb_pool=("l_suppkey", "l_shipdate", "l_partkey"),
    agg_pool=("l_extendedprice", "l_quantity"),
)

TPCH_JOIN_SPEC = WorkloadSpec(
    table="lineitem",
    gb_pool=("l_suppkey", "l_shipdate"),
    agg_pool=("l_extendedprice", "l_quantity"),
    join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
)

PARKING_SPEC = WorkloadSpec(
    table="parking",
    gb_pool=("borough", "precinct", "agency", "year", "month", "hour"),
    agg_pool=("fine", "violation"),
)

STARS_SPEC = WorkloadSpec(
    table="stars",
    gb_pool=("field", "run"),
    agg_pool=("mag_g", "mag_r", "redshift"),
)


def generate_workload(
    spec: WorkloadSpec, db: Database, n_queries: int, seed: int = 0
) -> List[Query]:
    """Random template instantiations with data-calibrated thresholds."""
    rng = np.random.default_rng(seed)
    out: List[Query] = []
    attempts = 0
    while len(out) < n_queries and attempts < n_queries * 10:
        attempts += 1
        k = int(rng.choice(spec.n_gb))
        gb = tuple(sorted(rng.choice(spec.gb_pool, size=min(k, len(spec.gb_pool)), replace=False)))
        fn = str(rng.choice(spec.agg_fns))
        agg_attr = None if fn == "count" else str(rng.choice(spec.agg_pool))
        q0 = Query(
            table=spec.table,
            groupby=gb,
            agg=Aggregate(fn, agg_attr),
            join=spec.join,
        )
        # Calibrate the threshold on the actual group aggregates.
        res = execute(q0, db)
        if len(res.values) < 4:
            continue
        qlo, qhi = spec.q_range
        tau = float(np.quantile(res.values, rng.uniform(qlo, qhi)))
        out.append(dataclasses.replace(q0, having=Having(">", tau)))
    return out
