"""Provenance sketches (Sec. 4): capture, instances, application, selectivity.

A sketch for query Q on range partition ``F_{R,a}`` is the bitvector over
ranges whose fragments contain >= 1 provenance row.  Capture reduces to a
segmented OR of the provenance mask by fragment id — the ``fragment_bitmap``
Pallas kernel.  Application is a *scheduling* decision: on a fragment-major
clustered table (``ColumnTable.cluster_by``) the sketch instance is the
concatenation of the surviving contiguous slices; the ``sketch_filter``
kernel is only the unsorted fallback.  Instances are cached per sketch in
the catalog, so an index hit re-executes over an already-materialized D_P.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.catalog import Catalog, default_catalog
from repro.core.queries import (
    Query,
    QueryResult,
    execute,
    execute_and_provenance,
    provenance_mask,
)
from repro.core.ranges import RangeSet
from repro.core.table import PAD_VALID, ColumnTable, Database

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ProvenanceSketch:
    """An accurate sketch: table + attribute + ranges + membership bits.

    ``table_uid`` / ``table_version`` record which version of the relation the
    bits describe; a mismatch against the live table is the engine's signal to
    repair the sketch through ``repro.core.maintenance`` instead of trusting
    (or re-capturing) it.
    """

    table: str
    ranges: RangeSet
    bits: np.ndarray  # bool, shape (n_ranges,)
    size_rows: int  # |R_P| — rows covered by the sketch instance
    total_rows: int  # |R|
    table_uid: int = 0
    table_version: int = 0

    def current_for(self, table: ColumnTable) -> bool:
        return self.table_uid == table.uid and self.table_version == table.version

    @property
    def attr(self) -> str:
        return self.ranges.attr

    @property
    def selectivity(self) -> float:
        return self.size_rows / max(self.total_rows, 1)

    @property
    def n_fragments(self) -> int:
        return int(self.bits.sum())

    def range_conditions(self) -> Tuple[Tuple[float, float], ...]:
        """The disjunction of [lo, hi) conditions a DBMS would be handed."""
        bounds = np.concatenate([[-np.inf], self.ranges.bounds, [np.inf]])
        out = []
        for i in np.nonzero(self.bits)[0]:
            out.append((float(bounds[i]), float(bounds[i + 1])))
        return tuple(out)


def capture_sketch(
    q: Query,
    db: Database,
    ranges: RangeSet,
    prov: Optional[np.ndarray] = None,
    use_kernel: bool = True,
    catalog: Optional[Catalog] = None,
) -> ProvenanceSketch:
    """Build the accurate sketch R(Q, D, F) for ``q`` on partition ``ranges``."""
    catalog = catalog or default_catalog()
    table = db[q.table]
    if prov is None:
        prov = provenance_mask(q, db, catalog=catalog)
    bucket = catalog.bucketize(table, ranges)
    if use_kernel:
        from repro.kernels import ops as kops

        bits = np.asarray(kops.fragment_bitmap(jnp.asarray(prov), bucket, ranges.n_ranges))  # analyze: waive[SYNC01]: deliberate merge: sketch bits materialize to host once at capture (admission-time)
    else:
        bits = np.asarray(  # analyze: waive[SYNC01]: deliberate merge: sketch bits materialize to host once at capture (admission-time)
            jax.ops.segment_max(
                jnp.asarray(prov).astype(jnp.int32), bucket, num_segments=ranges.n_ranges
            )
            > 0
        )
    sizes = catalog.fragment_sizes(table, ranges)
    size_rows = int(sizes[bits].sum())
    return ProvenanceSketch(
        table=q.table,
        ranges=ranges,
        bits=bits.astype(bool),
        size_rows=size_rows,
        total_rows=table.num_rows,
        table_uid=table.uid,
        table_version=table.version,
    )


def capture_sketches_batch(
    qs: Sequence[Query],
    db: Database,
    ranges_list: Sequence[RangeSet],
    provs: Sequence[np.ndarray],
    use_kernel: bool = True,
    catalog: Optional[Catalog] = None,
) -> List[ProvenanceSketch]:
    """Multi-sketch fused capture: B provenance masks, one scan per partition.

    Queries are grouped by (table, partition); each group pays ONE cached
    bucketization and ONE ``fragment_bitmap_batch`` launch that reduces all
    of the group's stacked masks against the shared one-hot incidence — the
    admission pipeline's replacement for B sequential ``capture_sketch``
    calls.  The mask batch is pow2-padded so batch sizes quantize to a few
    compiled shapes.  Bits are bit-identical to per-query capture.
    """
    catalog = catalog or default_catalog()
    out: List[Optional[ProvenanceSketch]] = [None] * len(qs)
    groups: Dict[Tuple, List[int]] = {}
    for i, (q, ranges) in enumerate(zip(qs, ranges_list)):
        groups.setdefault((q.table, ranges.key()), []).append(i)
    for (table_name, _), idxs in groups.items():
        table = db[table_name]
        ranges = ranges_list[idxs[0]]
        bucket = catalog.bucketize(table, ranges)
        stacked = np.stack([np.asarray(provs[i], dtype=bool) for i in idxs])
        b = stacked.shape[0]
        b_pad = 1 << (b - 1).bit_length()
        if b_pad != b:
            stacked = np.concatenate(
                [stacked, np.zeros((b_pad - b, stacked.shape[1]), dtype=bool)])
        if use_kernel:
            from repro.kernels import ops as kops

            bits_b = np.asarray(  # analyze: waive[SYNC01]: deliberate merge: batched capture materializes the whole wave's bits in one transfer
                kops.fragment_bitmap_batch(jnp.asarray(stacked), bucket, ranges.n_ranges))
        else:
            bits_b = np.asarray(  # analyze: waive[SYNC01]: deliberate merge: batched capture materializes the whole wave's bits in one transfer
                jax.vmap(
                    lambda p: jax.ops.segment_max(
                        p.astype(jnp.int32), bucket, num_segments=ranges.n_ranges)
                )(jnp.asarray(stacked)) > 0
            )
        sizes = catalog.fragment_sizes(table, ranges)
        for j, i in enumerate(idxs):
            bits = bits_b[j].astype(bool)
            out[i] = ProvenanceSketch(
                table=table_name,
                ranges=ranges_list[i],
                bits=bits,
                size_rows=int(sizes[bits].sum()),
                total_rows=table.num_rows,
                table_uid=table.uid,
                table_version=table.version,
            )
    return out  # type: ignore[return-value]


def sketch_keep_mask(
    sketch: ProvenanceSketch,
    table: ColumnTable,
    use_kernel: bool = True,
    catalog: Optional[Catalog] = None,
) -> Array:
    """Row keep-mask: True iff the row's fragment belongs to the sketch."""
    catalog = catalog or default_catalog()
    bucket = catalog.bucketize(table, sketch.ranges)
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.sketch_filter(bucket, jnp.asarray(sketch.bits))
    return jnp.asarray(sketch.bits)[bucket]


def _pad_instance_pow2(
    instance: ColumnTable, rows: np.ndarray, catalog: Catalog
) -> Tuple[ColumnTable, np.ndarray]:
    """Pow2-pad an instance's row count with masked (weight-0) tail rows.

    Steady-state reuse executes over catalog-cached instances whose row
    counts drift with every repair (a handful of rows per mutation), and each
    fresh count is a fresh XLA shape — a recompile on the hot path.  Padding
    every instance to the next power of two quantizes the shape space so a
    repaired instance almost always lands in an already-compiled size class.
    The tail rows duplicate row 0 and carry ``PAD_VALID=False``; the executor
    zero-weights them, so results are bit-identical (adding 0.0 terms to the
    f32 segment sums is exact).  ``rows`` (the base-table row index per
    instance row) is padded alongside for the catalog's subset-derived
    encodings.
    """
    n = instance.num_rows
    if n == 0:
        return instance, rows
    n_pad = 1 << (n - 1).bit_length()
    valid = np.zeros(n_pad, dtype=bool)
    valid[:n] = True
    if n_pad != n:
        idx = np.zeros(n_pad, dtype=np.int64)
        idx[:n] = np.arange(n)
        instance = instance.gather(jnp.asarray(idx))
        rows = rows[idx]
        catalog.stats["instance_padded"] += 1
    return (instance.with_column(PAD_VALID, jnp.asarray(valid[:instance.num_rows])),
            rows)


def _build_instance(
    sketch: ProvenanceSketch, table: ColumnTable, catalog: Catalog
) -> Tuple[ColumnTable, np.ndarray]:
    """Materialize the sketch instance R_P of one table (+ its source rows).

    Clustered tables on the sketch's own partition skip fragments by slicing;
    everything else falls back to the per-row keep-mask kernel.  Either way
    the rows are pow2-padded (masked tail) so reuse execution over the cached
    instance hits an already-compiled shape, and the base-row map rides along
    so group encodings / WHERE masks of the instance derive from the base
    table's cached ones by an O(n) gather instead of fresh host passes.
    """
    lay = table.layout
    if lay is not None and lay.matches(sketch.ranges):
        catalog.stats["instance_slices"] += 1
        frag_ids = np.nonzero(sketch.bits)[0]
        # Appended rows live in the layout's unsorted tail; hand
        # ``take_fragments`` the catalog's (delta-refreshed) bucket ids so
        # the tail filter stays delta-sized and never re-searchsorts.
        tail_bucket = None
        if lay.tail:
            n = table.num_rows
            tail_bucket = np.asarray(
                catalog.bucketize(table, sketch.ranges))[n - lay.tail:]
        inst, rows = table.take_fragments(
            frag_ids, tail_bucket=tail_bucket, return_rows=True)
        return _pad_instance_pow2(inst, rows, catalog)
    catalog.stats["instance_mask"] += 1
    mask = sketch_keep_mask(sketch, table, catalog=catalog)
    rows = np.nonzero(np.asarray(mask))[0]
    return _pad_instance_pow2(table.gather(jnp.asarray(rows)), rows, catalog)


def apply_sketch(
    sketch: ProvenanceSketch, db: Database, catalog: Optional[Catalog] = None
) -> Database:
    """D_P: replace the sketched relation with its sketch instance.

    Instances are cached per (sketch, table) in the catalog: repeated
    applications of a reused sketch cost a dictionary lookup.
    """
    catalog = catalog or default_catalog()
    table = db[sketch.table]
    instance = catalog.get_instance(sketch, table)
    if instance is None:
        instance, rows = _build_instance(sketch, table, catalog)
        catalog.put_instance(sketch, table, instance, rows=rows)
    return db.with_table(instance)


def execute_with_sketch(
    q: Query,
    db: Database,
    sketch: Optional[ProvenanceSketch],
    catalog: Optional[Catalog] = None,
) -> QueryResult:
    """Run ``q`` over ``D_P`` (or D when no sketch) — the instrumented query."""
    if sketch is None:
        return execute(q, db, catalog=catalog)
    return execute(q, apply_sketch(sketch, db, catalog=catalog), catalog=catalog)


def capture_and_execute(
    q: Query, db: Database, ranges: RangeSet, catalog: Optional[Catalog] = None
) -> Tuple[QueryResult, ProvenanceSketch]:
    """Fused capture+execute: one inner-block pass feeds both the result and
    the provenance-derived sketch (the seed evaluated the query twice)."""
    catalog = catalog or default_catalog()
    res, prov = execute_and_provenance(q, db, catalog=catalog)
    sketch = capture_sketch(q, db, ranges, prov=prov, catalog=catalog)
    return res, sketch


def is_safe_sketch(q: Query, db: Database, sketch: ProvenanceSketch) -> bool:
    """Def. 4 checked extensionally: Q(D_P) == Q(D).  (Test utility.)"""
    return execute(q, db).canonical() == execute_with_sketch(q, db, sketch).canonical()


def actual_size(q: Query, db: Database, ranges: RangeSet) -> int:
    """size(Q, D, R, a, R) — ground truth for RSE measurements."""
    return capture_sketch(q, db, ranges).size_rows
