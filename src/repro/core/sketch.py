"""Provenance sketches (Sec. 4): capture, instances, application, selectivity.

A sketch for query Q on range partition ``F_{R,a}`` is the bitvector over
ranges whose fragments contain >= 1 provenance row.  Capture reduces to a
segmented OR of the provenance mask by fragment id — the ``fragment_bitmap``
Pallas kernel.  Application is a *scheduling* decision: on a fragment-major
clustered table (``ColumnTable.cluster_by``) the sketch instance is the
concatenation of the surviving contiguous slices; the ``sketch_filter``
kernel is only the unsorted fallback.  Instances are cached per sketch in
the catalog, so an index hit re-executes over an already-materialized D_P.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.catalog import Catalog, default_catalog
from repro.core.queries import (
    Query,
    QueryResult,
    execute,
    execute_and_provenance,
    provenance_mask,
)
from repro.core.ranges import RangeSet
from repro.core.table import ColumnTable, Database

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ProvenanceSketch:
    """An accurate sketch: table + attribute + ranges + membership bits.

    ``table_uid`` / ``table_version`` record which version of the relation the
    bits describe; a mismatch against the live table is the engine's signal to
    repair the sketch through ``repro.core.maintenance`` instead of trusting
    (or re-capturing) it.
    """

    table: str
    ranges: RangeSet
    bits: np.ndarray  # bool, shape (n_ranges,)
    size_rows: int  # |R_P| — rows covered by the sketch instance
    total_rows: int  # |R|
    table_uid: int = 0
    table_version: int = 0

    def current_for(self, table: ColumnTable) -> bool:
        return self.table_uid == table.uid and self.table_version == table.version

    @property
    def attr(self) -> str:
        return self.ranges.attr

    @property
    def selectivity(self) -> float:
        return self.size_rows / max(self.total_rows, 1)

    @property
    def n_fragments(self) -> int:
        return int(self.bits.sum())

    def range_conditions(self) -> Tuple[Tuple[float, float], ...]:
        """The disjunction of [lo, hi) conditions a DBMS would be handed."""
        bounds = np.concatenate([[-np.inf], self.ranges.bounds, [np.inf]])
        out = []
        for i in np.nonzero(self.bits)[0]:
            out.append((float(bounds[i]), float(bounds[i + 1])))
        return tuple(out)


def capture_sketch(
    q: Query,
    db: Database,
    ranges: RangeSet,
    prov: Optional[np.ndarray] = None,
    use_kernel: bool = True,
    catalog: Optional[Catalog] = None,
) -> ProvenanceSketch:
    """Build the accurate sketch R(Q, D, F) for ``q`` on partition ``ranges``."""
    catalog = catalog or default_catalog()
    table = db[q.table]
    if prov is None:
        prov = provenance_mask(q, db, catalog=catalog)
    bucket = catalog.bucketize(table, ranges)
    if use_kernel:
        from repro.kernels import ops as kops

        bits = np.asarray(kops.fragment_bitmap(jnp.asarray(prov), bucket, ranges.n_ranges))
    else:
        bits = np.asarray(
            jax.ops.segment_max(
                jnp.asarray(prov).astype(jnp.int32), bucket, num_segments=ranges.n_ranges
            )
            > 0
        )
    sizes = catalog.fragment_sizes(table, ranges)
    size_rows = int(sizes[bits].sum())
    return ProvenanceSketch(
        table=q.table,
        ranges=ranges,
        bits=bits.astype(bool),
        size_rows=size_rows,
        total_rows=table.num_rows,
        table_uid=table.uid,
        table_version=table.version,
    )


def sketch_keep_mask(
    sketch: ProvenanceSketch,
    table: ColumnTable,
    use_kernel: bool = True,
    catalog: Optional[Catalog] = None,
) -> Array:
    """Row keep-mask: True iff the row's fragment belongs to the sketch."""
    catalog = catalog or default_catalog()
    bucket = catalog.bucketize(table, sketch.ranges)
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.sketch_filter(bucket, jnp.asarray(sketch.bits))
    return jnp.asarray(sketch.bits)[bucket]


def _build_instance(
    sketch: ProvenanceSketch, table: ColumnTable, catalog: Catalog
) -> ColumnTable:
    """Materialize the sketch instance R_P of one table.

    Clustered tables on the sketch's own partition skip fragments by slicing;
    everything else falls back to the per-row keep-mask kernel.
    """
    lay = table.layout
    if lay is not None and lay.matches(sketch.ranges):
        catalog.stats["instance_slices"] += 1
        frag_ids = np.nonzero(sketch.bits)[0]
        # Appended rows live in the layout's unsorted tail; hand
        # ``take_fragments`` the catalog's (delta-refreshed) bucket ids so
        # the tail filter stays delta-sized and never re-searchsorts.
        tail_bucket = None
        if lay.tail:
            n = table.num_rows
            tail_bucket = np.asarray(
                catalog.bucketize(table, sketch.ranges))[n - lay.tail:]
        return table.take_fragments(frag_ids, tail_bucket=tail_bucket)
    catalog.stats["instance_mask"] += 1
    mask = sketch_keep_mask(sketch, table, catalog=catalog)
    return table.select(mask)


def apply_sketch(
    sketch: ProvenanceSketch, db: Database, catalog: Optional[Catalog] = None
) -> Database:
    """D_P: replace the sketched relation with its sketch instance.

    Instances are cached per (sketch, table) in the catalog: repeated
    applications of a reused sketch cost a dictionary lookup.
    """
    catalog = catalog or default_catalog()
    table = db[sketch.table]
    instance = catalog.get_instance(sketch, table)
    if instance is None:
        instance = _build_instance(sketch, table, catalog)
        catalog.put_instance(sketch, table, instance)
    return db.with_table(instance)


def execute_with_sketch(
    q: Query,
    db: Database,
    sketch: Optional[ProvenanceSketch],
    catalog: Optional[Catalog] = None,
) -> QueryResult:
    """Run ``q`` over ``D_P`` (or D when no sketch) — the instrumented query."""
    if sketch is None:
        return execute(q, db, catalog=catalog)
    return execute(q, apply_sketch(sketch, db, catalog=catalog), catalog=catalog)


def capture_and_execute(
    q: Query, db: Database, ranges: RangeSet, catalog: Optional[Catalog] = None
) -> Tuple[QueryResult, ProvenanceSketch]:
    """Fused capture+execute: one inner-block pass feeds both the result and
    the provenance-derived sketch (the seed evaluated the query twice)."""
    catalog = catalog or default_catalog()
    res, prov = execute_and_provenance(q, db, catalog=catalog)
    sketch = capture_sketch(q, db, ranges, prov=prov, catalog=catalog)
    return res, sketch


def is_safe_sketch(q: Query, db: Database, sketch: ProvenanceSketch) -> bool:
    """Def. 4 checked extensionally: Q(D_P) == Q(D).  (Test utility.)"""
    return execute(q, db).canonical() == execute_with_sketch(q, db, sketch).canonical()


def actual_size(q: Query, db: Database, ranges: RangeSet) -> int:
    """size(Q, D, R, a, R) — ground truth for RSE measurements."""
    return capture_sketch(q, db, ranges).size_rows
