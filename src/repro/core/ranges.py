"""Range partitions (Def. 2 of the paper).

A ``RangeSet`` over attribute ``a`` is a list of half-open intervals covering
the attribute domain.  In the paper the interval bounds come from equi-depth
histograms that the DBMS already maintains; here we compute them with device-
side quantiles.  ``bucketize`` assigns each row its fragment id — the basic
primitive both sketch capture and sketch application are built on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import ColumnTable

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RangeSet:
    """Equi-depth range partitioning of an attribute domain.

    ``bounds`` are the n-1 interior split points of n ranges:
    fragment i covers [bounds[i-1], bounds[i]) with -inf / +inf at the ends.
    """

    attr: str
    bounds: np.ndarray  # shape (n_ranges - 1,), sorted ascending

    @property
    def n_ranges(self) -> int:
        return int(self.bounds.shape[0]) + 1

    def bucketize(self, values: Array) -> Array:
        """Fragment id per value: searchsorted against the interior bounds."""
        return jnp.searchsorted(jnp.asarray(self.bounds), values, side="right").astype(
            jnp.int32
        )

    def key(self) -> Tuple:
        """Hashable identity of the partition (attr + exact bounds)."""
        return (self.attr, self.n_ranges, self.bounds.tobytes())


def equi_depth_ranges(
    table: ColumnTable, attr: str, n_ranges: int
) -> RangeSet:
    """Equi-depth histogram bounds (what Postgres keeps in pg_stats)."""
    col = np.asarray(table[attr]).astype(np.float64)
    qs = np.linspace(0.0, 1.0, n_ranges + 1)[1:-1]
    bounds = np.quantile(col, qs, method="lower")
    # Strictly increasing bounds (duplicates collapse fragments, harmless but
    # we dedupe so fragment sizes stay meaningful).
    bounds = np.unique(bounds)
    return RangeSet(attr=attr, bounds=bounds)


def equi_width_ranges(table: ColumnTable, attr: str, n_ranges: int) -> RangeSet:
    col = np.asarray(table[attr]).astype(np.float64)
    lo, hi = float(col.min()), float(col.max())
    if hi <= lo:
        hi = lo + 1.0
    bounds = np.linspace(lo, hi, n_ranges + 1)[1:-1]
    return RangeSet(attr=attr, bounds=np.unique(bounds))


def fragment_sizes(table: ColumnTable, ranges: RangeSet) -> Array:
    """#R_r for every fragment r (Def. 8 needs these)."""
    bucket = ranges.bucketize(table[ranges.attr])
    return jax.ops.segment_sum(
        jnp.ones_like(bucket, dtype=jnp.int32), bucket, num_segments=ranges.n_ranges
    )


def distinct_count(table: ColumnTable, attr: str) -> int:
    return int(np.unique(np.asarray(table[attr])).shape[0])
