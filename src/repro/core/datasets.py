"""Synthetic schema-matched generators for the paper's four workloads.

The real dumps (Chicago Crime ~6.7M x 9, TPC-H SF, NYC Parking ~31M x 16,
SDSS Stars ~5.2M x 7) are not available offline; these generators match the
schemas, attribute counts, and the *correlation structure* the paper leans on
(geographic attributes in CRIME/PARKING correlate; TPC-H attrs are largely
independent — Sec. 11.2.2 attributes the accuracy gap to exactly this).
Row counts are parameters so tests stay fast while benchmarks can scale up.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.table import ColumnTable, Database, from_numpy


def make_crimes(n: int = 100_000, seed: int = 0) -> ColumnTable:
    """9 numeric attrs; district/zipcode/beat/ward/community are correlated,
    and crime *volume* correlates with district and year — the alignment that
    makes some partition attributes much better sketch choices than others
    (the whole point of the paper's cost model)."""
    rng = np.random.default_rng(seed)
    district = rng.integers(1, 26, n)
    # Geographic correlation: zipcode/beat/ward/community derive from district
    zipcode = 60600 + district * 4 + rng.integers(0, 4, n)
    beat = district * 100 + rng.integers(0, 30, n)
    ward = (district * 2 + rng.integers(0, 3, n)) % 50 + 1
    community = (district * 3 + rng.integers(0, 5, n)) % 77 + 1
    year = rng.integers(2010, 2025, n)
    month = rng.integers(1, 13, n)
    pid = rng.integers(1, 10, n)
    # Skewed count column (the HAVING target), correlated with geography and
    # time: a few "hot" districts, declining trend over years, summer peak.
    district_heat = np.where(district <= 5, 4.0, np.where(district <= 10, 1.5, 0.6))
    year_heat = 1.0 + 1.8 * (2024 - year) / 14.0  # older years hotter
    month_heat = 1.0 + 0.4 * np.sin((month - 1) / 12.0 * 2 * np.pi)
    records = np.maximum(1, rng.zipf(1.8, n))
    records = np.minimum(records, 500)
    records = np.maximum(1, (records * district_heat * year_heat * month_heat)).astype(np.int64)
    return from_numpy(
        "crimes",
        dict(
            pid=pid.astype(np.int32),
            month=month.astype(np.int32),
            year=year.astype(np.int32),
            records=records.astype(np.int32),
            district=district.astype(np.int32),
            zipcode=zipcode.astype(np.int32),
            beat=beat.astype(np.int32),
            ward=ward.astype(np.int32),
            community=community.astype(np.int32),
        ),
        primary_key=("beat", "year", "month"),
    )


def make_tpch(n_lineitem: int = 120_000, seed: int = 1) -> Database:
    """lineitem / orders / part with TPC-H-like distributions (independent)."""
    rng = np.random.default_rng(seed)
    n_orders = max(1, n_lineitem // 4)
    n_part = max(1, n_lineitem // 6)

    orderkey = rng.integers(1, n_orders + 1, n_lineitem)
    partkey = rng.integers(1, n_part + 1, n_lineitem)
    suppkey = rng.integers(1, max(2, n_part // 10), n_lineitem)
    quantity = rng.integers(1, 51, n_lineitem)
    extendedprice = (quantity * rng.uniform(900, 105000 / 50, n_lineitem)).astype(np.float32)
    discount = rng.integers(0, 11, n_lineitem).astype(np.float32) / 100.0
    tax = rng.integers(0, 9, n_lineitem).astype(np.float32) / 100.0
    shipdate = rng.integers(8036, 10592, n_lineitem)  # days, 1992..1998
    commitdate = shipdate + rng.integers(-30, 61, n_lineitem)
    receiptdate = shipdate + rng.integers(1, 31, n_lineitem)
    lineitem = from_numpy(
        "lineitem",
        dict(
            l_orderkey=orderkey.astype(np.int64),
            l_partkey=partkey.astype(np.int64),
            l_suppkey=suppkey.astype(np.int64),
            l_quantity=quantity.astype(np.float32),
            l_extendedprice=extendedprice,
            l_discount=discount,
            l_tax=tax,
            l_shipdate=shipdate.astype(np.int32),
            l_commitdate=commitdate.astype(np.int32),
            l_receiptdate=receiptdate.astype(np.int32),
        ),
        primary_key=("l_orderkey",),
    )
    orders = from_numpy(
        "orders",
        dict(
            o_orderkey=np.arange(1, n_orders + 1, dtype=np.int64),
            o_custkey=rng.integers(1, max(2, n_orders // 10), n_orders).astype(np.int64),
            o_totalprice=rng.uniform(850, 560000, n_orders).astype(np.float32),
            o_orderdate=rng.integers(8036, 10592, n_orders).astype(np.int32),
            o_shippriority=rng.integers(0, 5, n_orders).astype(np.int32),
        ),
        primary_key=("o_orderkey",),
    )
    part = from_numpy(
        "part",
        dict(
            p_partkey=np.arange(1, n_part + 1, dtype=np.int64),
            p_size=rng.integers(1, 51, n_part).astype(np.int32),
            p_retailprice=rng.uniform(900, 2000, n_part).astype(np.float32),
            p_brand=rng.integers(1, 26, n_part).astype(np.int32),
        ),
        primary_key=("p_partkey",),
    )
    return Database({"lineitem": lineitem, "orders": orders, "part": part})


def make_parking(n: int = 100_000, seed: int = 2) -> ColumnTable:
    """16 numeric attrs, NYC-parking-like with correlated geography."""
    rng = np.random.default_rng(seed)
    borough = rng.integers(1, 6, n)
    precinct = borough * 20 + rng.integers(0, 20, n)
    street = precinct * 50 + rng.integers(0, 50, n)
    county = borough
    issuer = rng.integers(1, 1000, n)
    agency = issuer % 12 + 1
    year = rng.integers(2014, 2024, n)
    month = rng.integers(1, 13, n)
    hour = rng.integers(0, 24, n)
    vehicle_year = rng.integers(1990, 2024, n)
    violation = np.maximum(1, rng.zipf(1.6, n)) % 99 + 1
    fine = (violation * 5 + rng.integers(10, 200, n)).astype(np.float32)
    plate_type = rng.integers(1, 90, n)
    body_type = rng.integers(1, 40, n)
    color = rng.integers(1, 20, n)
    reg_state = rng.integers(1, 68, n)
    cols = dict(
        borough=borough, precinct=precinct, street=street, county=county,
        issuer=issuer, agency=agency, year=year, month=month, hour=hour,
        vehicle_year=vehicle_year, violation=violation, fine=fine,
        plate_type=plate_type, body_type=body_type, color=color,
        reg_state=reg_state,
    )
    cols = {k: (v.astype(np.float32) if v.dtype.kind == "f" else v.astype(np.int32)) for k, v in cols.items()}
    return from_numpy("parking", cols, primary_key=("street", "issuer"))


def make_stars(n: int = 100_000, seed: int = 3) -> ColumnTable:
    """7 numeric attrs, SDSS-like (ra/dec sky coords + magnitudes/redshift)."""
    rng = np.random.default_rng(seed)
    ra = rng.uniform(0, 360, n).astype(np.float32)
    dec = rng.uniform(-90, 90, n).astype(np.float32)
    field = (ra / 10).astype(np.int32) * 18 + ((dec + 90) / 10).astype(np.int32)
    mag_g = rng.normal(18, 2, n).astype(np.float32)
    mag_r = (mag_g - rng.normal(0.5, 0.3, n)).astype(np.float32)  # correlated
    redshift = np.abs(rng.normal(0.1, 0.08, n)).astype(np.float32)
    run = rng.integers(100, 900, n).astype(np.int32)
    return from_numpy(
        "stars",
        dict(ra=ra, dec=dec, field=field, mag_g=mag_g, mag_r=mag_r,
             redshift=redshift, run=run),
        primary_key=("run", "field"),
    )


def paper_example_db() -> Database:
    """The Fig. 1 running-example instance, verbatim (8 rows)."""
    crimes = from_numpy(
        "crimes",
        dict(
            pid=np.array([3, 4, 4, 8, 8, 2, 7, 7], np.int32),
            month=np.array([1, 1, 1, 6, 6, 7, 2, 9], np.int32),
            year=np.array([2010, 2013, 2013, 2015, 2015, 2016, 2022, 2023], np.int32),
            records=np.array([88, 73, 101, 86, 96, 157, 83, 58], np.int32),
        ),
    )
    return Database({"crimes": crimes})
