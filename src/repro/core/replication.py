"""Coordinator metadata replication: the stream a warm standby adopts.

The coordinator (``repro.core.shard.ShardedEngine``) is the single owner of
everything a ``selection_state()`` snapshot does not cover: the clustered
table lineage, per-shard delta logs and checkpoint watermarks, the sketch
index's registrations, and the fragment placement.  One coordinator SIGKILL
used to lose all of it — every captured sketch would have to be re-captured,
defeating the paper's premise that a sketch keeps paying for itself.

This module streams every coordinator **metadata mutation** as a
monotonically-sequenced :class:`ReplicationRecord` to a replica, which folds
the stream into a :class:`MetadataStore` — exactly the state a standby needs
to call ``ShardedEngine.from_replica`` and resume serving:

* ``bootstrap`` — the full base state: clustered table, dims, ranges,
  placement, engine construction kwargs, current delta logs.  Emitted once
  at ``attach_replica`` time (and again by a freshly-promoted coordinator to
  re-arm its own standby).
* ``mutation`` — one ``append_rows``/``delete_rows``, with the *original*
  coordinator-order payload (so replay reproduces the exact row order the
  recorded delete masks index into) plus the per-shard ship payloads (so the
  standby's delta logs can re-ship anything a shard never drained).
* ``register`` / ``evict`` — sketch-index registrations keyed by the stable
  ``reg_id`` the shards also key their maintainers by.  Only the query,
  ranges and locality flag travel: sketch *bits* are never replicated — the
  standby re-derives them by local counting (``maintainer_for``), the same
  "maintain, don't re-capture" rule shard recovery follows.
* ``ckpt`` — a shard checkpoint advanced to some version; prunes the
  replica's copy of that shard's delta log.
* ``selection`` — a ``selection_state()`` snapshot (WorkloadLog window +
  SelectionCache stats), emitted at metadata flush points.  Bounded
  staleness here can only shift future *selection* decisions, never query
  results (sketches are lossless).
* ``plan`` — a rebalance re-placement (new owner array + voided shards).

Two replicas share the stream format: :class:`InProcessReplica` folds
records in the coordinator's process (zero-copy; the loopback analogue),
:class:`SubprocessReplica` ships them over ``runtime/transport`` frames to a
warm standby process (``python -m repro.core.replication``) that survives
the coordinator's death and hands the store back at takeover.

A sequence gap raises :class:`ReplicationError` at the replica — a standby
must refuse to take over from a stream it knows is missing records.
"""
from __future__ import annotations

import atexit
import dataclasses
import itertools
import os
import socket
import subprocess
import sys
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.runtime import transport

RECORD_KINDS = ("bootstrap", "mutation", "register", "evict", "ckpt",
                "selection", "plan")


class ReplicationError(RuntimeError):
    """The replication stream is unusable (gap, unknown record, dead
    standby) — the coordinator degrades to unreplicated, never crashes."""


@dataclasses.dataclass(frozen=True)
class ReplicationRecord:
    """One monotonically-sequenced metadata mutation."""

    seq: int
    kind: str
    payload: object


class MetadataStore:
    """A replica's folded view of the coordinator metadata stream.

    Everything ``ShardedEngine.from_replica`` needs: the bootstrap base,
    the coordinator-order mutation log to replay on it, per-shard delta-log
    suffixes, the ordered registration set, the latest selection snapshot,
    and the current placement.
    """

    def __init__(self):
        self.boot: Optional[dict] = None
        # Coordinator-order mutations since bootstrap: (kind, table, payload,
        # version) with version None for dimension-table mutations (they do
        # not advance the serving watermark).
        self.muts: List[Tuple[str, str, object, Optional[int]]] = []
        self._logs: Dict[int, List[Tuple[int, str, object]]] = {}
        self.ckpt_versions: Dict[int, Optional[int]] = {}
        # reg_id -> registration payload, insertion-ordered (dict semantics):
        # index insertion order must replay identically or lookup ties could
        # resolve differently on the standby.
        self.regs: Dict[int, dict] = {}
        self.selection: Optional[dict] = None
        self.owner: Optional[np.ndarray] = None
        self.version = 0
        self.reg_counter = 1
        self.last_seq = 0

    def apply(self, rec: ReplicationRecord) -> None:
        if rec.seq != self.last_seq + 1:
            raise ReplicationError(
                f"replication gap: record seq {rec.seq} after {self.last_seq}")
        self.last_seq = rec.seq
        kind = rec.kind
        if kind == "bootstrap":
            p = dict(rec.payload)
            self.boot = p
            self.owner = np.asarray(p["owner"])
            self.version = int(p["version"])
            self.muts = []
            self._logs = {s: list(entries)
                          for s, entries in enumerate(p.get("log") or [])}
            self.ckpt_versions = dict(
                enumerate(p.get("ckpt_versions") or []))
            self.regs = {}
            self.reg_counter = int(p.get("reg_counter", 1))
            self.selection = p.get("selection")
        elif kind == "mutation":
            mkind, tname, payload, version, ships = rec.payload
            self.muts.append((mkind, tname, payload, version))
            if version is not None:
                self.version = int(version)
                for sid, sp in enumerate(ships or ()):
                    self._logs.setdefault(sid, []).append(
                        (int(version), mkind, sp))
        elif kind == "register":
            for p in rec.payload:
                rid = int(p["reg_id"])
                self.regs[rid] = dict(p)
                self.reg_counter = max(self.reg_counter, rid + 1)
        elif kind == "evict":
            self.regs.pop(int(rec.payload), None)
        elif kind == "ckpt":
            sid, v = rec.payload
            self.ckpt_versions[int(sid)] = v
            log = self._logs.get(int(sid))
            if log and v is not None:
                self._logs[int(sid)] = [e for e in log if e[0] > v]
        elif kind == "selection":
            self.selection = rec.payload
        elif kind == "plan":
            owner, voided = rec.payload
            self.owner = np.asarray(owner)
            for sid in voided:
                self._logs[int(sid)] = []
                self.ckpt_versions[int(sid)] = None
        else:
            raise ReplicationError(f"unknown record kind {kind!r}")

    def ship_logs(self, n_shards: int) -> List[List[Tuple[int, str, object]]]:
        return [list(self._logs.get(s, ())) for s in range(n_shards)]


class InProcessReplica:
    """Warm-standby metadata held in the same process — the loopback
    analogue of :class:`SubprocessReplica` (identical record stream and
    takeover surface, zero serialization)."""

    backend = "loopback"

    def __init__(self):
        self._store = MetadataStore()
        self.records = 0

    def publish(self, rec: ReplicationRecord) -> None:
        self._store.apply(rec)
        self.records += 1

    def snapshot(self) -> MetadataStore:
        return self._store

    # ``close_replica`` (not ``close``): keeps the hot-path analyzer's
    # name-based call graph from aliasing socket ``close()`` calls in the RPC
    # hot path onto replica teardown (which reaches ``Popen.wait``).
    def close_replica(self) -> None:
        pass


# ---------------------------------------------------------------------------
# Subprocess replica: a standby process that outlives the coordinator object
# ---------------------------------------------------------------------------

_SPAWN_TIMEOUT_S = 60.0
_sock_counter = itertools.count(1)
_live_replicas: "set[SubprocessReplica]" = set()


def _kill_live_replicas() -> None:
    for r in list(_live_replicas):
        r.close_replica()


atexit.register(_kill_live_replicas)


class SubprocessReplica:
    """Streams replication records to a warm standby process over the same
    framed transport the shard RPC uses (crc-checked, deadline-bounded).

    The child applies each record into its own :class:`MetadataStore`;
    ``snapshot()`` pulls the folded store back — the takeover path.  The
    child watches its stdin pipe and exits when the parent dies, and every
    spawned replica is killed ``atexit``, so standbys never orphan.
    """

    backend = "subprocess"

    def __init__(self, deadline_s: float = 30.0):
        self._deadline_s = deadline_s
        self._seq = itertools.count(1)
        self.records = 0
        from repro.core.shard_rpc import _socket_dir

        self.path = os.path.join(_socket_dir(),
                                 f"r{next(_sock_counter)}.sock")
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        # NOT ``-m repro.core.replication``: running the module as __main__
        # would make the standby's MetadataStore pickle as
        # ``__main__.MetadataStore`` and fail to unpickle at takeover.
        self.proc: Optional[subprocess.Popen] = subprocess.Popen(
            [sys.executable, "-c",
             "import sys; from repro.core.replication import main; "
             "main(sys.argv[1:])", self.path],
            stdin=subprocess.PIPE, stdout=subprocess.DEVNULL,
            start_new_session=True, env=env)
        self.conn: Optional[socket.socket] = None
        _live_replicas.add(self)

    def _connect(self) -> None:
        import time as _time

        t_end = _time.perf_counter() + _SPAWN_TIMEOUT_S
        last: Optional[Exception] = None
        while _time.perf_counter() < t_end:
            if self.proc is None or self.proc.poll() is not None:
                raise ReplicationError("standby process exited")
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.settimeout(1.0)
            try:
                s.connect(self.path)
                self.conn = s
                return
            except (FileNotFoundError, ConnectionRefusedError,
                    socket.timeout, OSError) as e:
                last = e
                s.close()
                _time.sleep(0.02)
        raise ReplicationError(f"could not connect to standby: {last}")

    def _call(self, msg: dict):
        if self.proc is None:
            raise ReplicationError("replica closed")
        if self.conn is None:
            self._connect()
        seq = next(self._seq)
        try:
            transport.send_msg(self.conn, msg, seq,
                               deadline_s=self._deadline_s)
            rseq, resp = transport.recv_msg(self.conn,
                                            deadline_s=self._deadline_s)
        except transport.TransportError as e:
            raise ReplicationError(f"standby rpc failed: {e}") from e
        if rseq != seq or not resp.get("ok"):
            raise ReplicationError(
                f"standby refused {msg.get('op')}: {resp.get('msg', 'desync')}")
        return resp.get("value")

    def publish(self, rec: ReplicationRecord) -> None:
        self._call({"op": "publish", "rec": rec})
        self.records += 1

    def snapshot(self) -> MetadataStore:
        return self._call({"op": "snapshot"})

    def close_replica(self) -> None:
        proc, self.proc = self.proc, None
        _live_replicas.discard(self)
        if self.conn is not None:
            try:
                self.conn.close()
            except OSError:
                pass
            self.conn = None
        if proc is not None:
            try:
                proc.kill()
                proc.wait(timeout=5.0)
            except Exception:
                pass


# ---------------------------------------------------------------------------
# Standby server entry (python -m repro.core.replication <socket-path>)
# ---------------------------------------------------------------------------


def serve(path: str) -> None:
    """The standby loop: fold published records, hand the store back on
    ``snapshot``.  Reconnect-tolerant like the shard server — the folded
    store survives a dropped coordinator connection (that is the point)."""
    def _watchdog():
        try:
            while True:
                if not sys.stdin.buffer.read(4096):
                    break
        except Exception:
            pass
        os._exit(2)

    threading.Thread(target=_watchdog, daemon=True).start()
    store = MetadataStore()
    closed = False
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.bind(path)
    sock.listen(2)
    while not closed:
        conn, _ = sock.accept()
        try:
            while not closed:
                seq, msg = transport.recv_msg(conn, deadline_s=None)
                op = msg.get("op")
                try:
                    if op == "publish":
                        store.apply(msg["rec"])
                        resp = {"ok": True, "value": None}
                    elif op == "snapshot":
                        resp = {"ok": True, "value": store}
                    elif op == "ping":
                        resp = {"ok": True, "value": "pong"}
                    elif op == "shutdown":
                        closed = True
                        resp = {"ok": True, "value": None}
                    else:
                        resp = {"ok": False, "msg": f"unknown op {op!r}"}
                except Exception as e:
                    resp = {"ok": False, "msg": f"{type(e).__name__}: {e}"}
                transport.send_msg(conn, resp, seq)
        except (transport.RpcClosed, transport.FrameError, OSError):
            pass  # coordinator died or reconnected; keep the store
        finally:
            try:
                conn.close()
            except OSError:
                pass
    os._exit(0)


def main(argv: Optional[List[str]] = None) -> None:
    args = sys.argv[1:] if argv is None else argv
    if len(args) != 1:
        print("usage: python -m repro.core.replication <socket-path>",
              file=sys.stderr)
        raise SystemExit(2)
    serve(args[0])


if __name__ == "__main__":
    main()
