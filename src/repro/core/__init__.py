# The paper's primary contribution: provenance sketches + the cost-based
# selection machinery, implemented as a TPU-native columnar engine.
from repro.core.catalog import Catalog, default_catalog
from repro.core.engine import PBDSEngine, RunInfo
from repro.core.index import IndexEntry, SketchIndex, subsumes
from repro.core.maintenance import (
    MaintenanceError,
    SketchMaintainer,
    build_maintainer,
    repair_sketch,
)
from repro.core.queries import (
    Aggregate,
    Having,
    JoinSpec,
    Predicate,
    Query,
    QueryResult,
    execute,
    execute_and_provenance,
    provenance_mask,
)
from repro.core.ranges import RangeSet, equi_depth_ranges, equi_width_ranges, fragment_sizes
from repro.core.safety import (
    monotone_safe,
    prefilter_candidates,
    safe_attributes,
    stats_prefilter,
)
from repro.core.sketch import (
    ProvenanceSketch,
    apply_sketch,
    capture_and_execute,
    capture_sketch,
    capture_sketches_batch,
    execute_with_sketch,
    is_safe_sketch,
    sketch_keep_mask,
)
from repro.core.strategies import (
    ALL_STRATEGIES,
    COST_STRATEGIES,
    RANDOM_STRATEGIES,
    SelectionCache,
    SelectionConfig,
    SelectionResult,
    candidate_pool,
    select_attribute,
    selection_cache_key,
)
from repro.core.workload import WorkloadLog
from repro.core.table import (
    ColumnTable,
    Database,
    FragmentLayout,
    TableDelta,
    encode_groups,
    from_numpy,
)
from repro.core.shard import (
    BackpressureError,
    FragmentShard,
    RouteInfo,
    ShardPlan,
    ShardUnavailableError,
    ShardedEngine,
    StaleEpochError,
    plan_fragments,
)
from repro.core.replication import (
    InProcessReplica,
    MetadataStore,
    ReplicationError,
    ReplicationRecord,
    SubprocessReplica,
)
from repro.core.standby import FailoverCoordinator, replica_factory

__all__ = [
    "Catalog", "default_catalog",
    "PBDSEngine", "RunInfo", "SketchIndex", "IndexEntry", "subsumes",
    "MaintenanceError", "SketchMaintainer", "build_maintainer", "repair_sketch",
    "monotone_safe", "TableDelta",
    "Aggregate", "Having", "JoinSpec", "Predicate", "Query", "QueryResult",
    "execute", "execute_and_provenance", "provenance_mask",
    "RangeSet", "equi_depth_ranges", "equi_width_ranges", "fragment_sizes",
    "prefilter_candidates", "safe_attributes",
    "ProvenanceSketch", "apply_sketch", "capture_and_execute", "capture_sketch",
    "capture_sketches_batch", "execute_with_sketch", "is_safe_sketch",
    "sketch_keep_mask",
    "ALL_STRATEGIES", "COST_STRATEGIES", "RANDOM_STRATEGIES",
    "SelectionResult", "candidate_pool", "select_attribute",
    "ColumnTable", "Database", "FragmentLayout", "encode_groups", "from_numpy",
    "FragmentShard", "RouteInfo", "ShardPlan", "ShardedEngine", "plan_fragments",
    "BackpressureError", "ShardUnavailableError", "StaleEpochError",
    "InProcessReplica", "MetadataStore", "ReplicationError",
    "ReplicationRecord", "SubprocessReplica",
    "FailoverCoordinator", "replica_factory",
]
