"""Sketch index: storage + reuse test (Fig. 3's first stage).

Reuse rule (the [32] compatibility test, specialized to our templates): a
sketch captured for Q1 answers Q2 when both share the FROM/GROUP BY/aggregate
structure and Q2's provenance is a subset of Q1's — which for upward-monotone
HAVING chains means Q2's thresholds dominate Q1's (tau_2 >= tau_1) and the
WHERE predicates match.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.queries import Query
from repro.core.sketch import ProvenanceSketch


def _pred_key(q: Query) -> Tuple:
    return (
        q.table,
        q.groupby,
        (q.agg.fn, q.agg.attr),
        dataclasses.astuple(q.where) if q.where else None,
        dataclasses.astuple(q.join) if q.join else None,
        q.outer_groupby,
        (q.outer_agg.fn, q.outer_agg.attr) if q.outer_agg else None,
    )


def _thresholds(q: Query) -> Tuple[Optional[float], Optional[float]]:
    t1 = q.having.value if q.having else None
    t2 = q.outer_having.value if q.outer_having else None
    return t1, t2


def subsumes(q1: Query, q2: Query) -> bool:
    """True iff a sketch captured for q1 is guaranteed safe for q2."""
    if _pred_key(q1) != _pred_key(q2):
        return False
    ops_ok = {">", ">="}
    for h1, h2 in zip((q1.having, q1.outer_having), (q2.having, q2.outer_having)):
        if (h1 is None) != (h2 is None):
            return False
        if h1 is None:
            continue
        if h1.op not in ops_ok or h2.op not in ops_ok:
            return dataclasses.astuple(h1) == dataclasses.astuple(h2)
        if h2.value < h1.value:  # q2 asks for *more* provenance than q1 saw
            return False
        # Equal thresholds with mixed ops: `agg >= tau` admits the boundary
        # groups (agg == tau) that `agg > tau` excluded, so a `>`-captured
        # sketch lacks their provenance — q2 must strictly dominate.
        if h2.value == h1.value and h1.op == ">" and h2.op == ">=":
            return False
    return True


@dataclasses.dataclass
class IndexEntry:
    query: Query
    sketch: ProvenanceSketch
    uses: int = 0
    last_hit: int = 0  # index clock at insert/last lookup hit (prune recency)
    # Incremental-maintenance state for this sketch (a
    # ``repro.core.maintenance.SketchMaintainer``); opaque to the index.
    maintainer: Optional[object] = None
    # Stable registration id assigned by the serving layer (0 = unassigned).
    # Shard-side maintainer keys and replication records use this instead of
    # ``id(entry)`` so a standby coordinator's rebuilt entries re-attach to
    # the maintainers the shards already hold.
    reg_id: int = 0


class SketchIndex:
    """In-memory sketch store with subsumption-based retrieval.

    The engine repairs a stale entry *in place* after table mutations
    (``entry.sketch`` is replaced with the maintained sketch), so storage and
    retrieval stay mutation-oblivious.
    """

    def __init__(self):
        self._entries: Dict[Tuple, List[IndexEntry]] = {}
        self.hits = 0
        self.misses = 0
        self._clock = 0

    def lookup_entry(self, q: Query) -> Optional[IndexEntry]:
        """The smallest stored sketch whose query subsumes ``q``, as an entry
        (the engine needs the entry to repair/replace the sketch in place).

        ``size_rows`` ties break by (threshold tightness, recency) — NOT by
        insertion order.  Batched admission can insert a wave's sketches in a
        different order than a sequential replay (deferral reorders waves),
        so insertion-position ties would let batched and sequential probes
        serve the same query from *different* entries, diverging ``uses`` /
        ``last_hit`` bookkeeping and hence prune decisions.  Tighter
        thresholds mean less provenance beyond what ``q`` needs (and a
        tighter future-reuse test), higher ``last_hit`` means the entry is
        hot; both are insertion-order-independent, so equal-size probes pick
        identically however the entries got there."""
        best: Optional[IndexEntry] = None
        best_rank: Optional[Tuple] = None
        neg_inf = float("-inf")
        for pos, e in enumerate(self._entries.get(_pred_key(q), [])):
            if subsumes(e.query, q):
                t1, t2 = _thresholds(e.query)
                rank = (e.sketch.size_rows,
                        -(t1 if t1 is not None else neg_inf),
                        -(t2 if t2 is not None else neg_inf),
                        -e.last_hit, pos)
                if best_rank is None or rank < best_rank:
                    best, best_rank = e, rank
        if best is None:
            self.misses += 1
            return None
        best.uses += 1
        self._clock += 1
        best.last_hit = self._clock
        self.hits += 1
        return best

    def lookup(self, q: Query) -> Optional[ProvenanceSketch]:
        e = self.lookup_entry(q)
        return e.sketch if e is not None else None

    def insert(self, q: Query, sketch: ProvenanceSketch,
               maintainer: Optional[object] = None) -> IndexEntry:
        self._clock += 1
        e = IndexEntry(q, sketch, last_hit=self._clock, maintainer=maintainer)
        self._entries.setdefault(_pred_key(q), []).append(e)
        return e

    def entries(self) -> List[IndexEntry]:
        return [e for v in self._entries.values() for e in v]

    def contains(self, entry: IndexEntry) -> bool:
        """True when ``entry`` (by identity) is still stored — registration
        state keyed on entry ids must not resurrect an evicted entry."""
        return any(e is entry for e in self._entries.get(_pred_key(entry.query), []))

    def remove(self, entry: IndexEntry) -> bool:
        """Evict one entry by identity (e.g. its join dimension mutated and
        the sketch can no longer be repaired); returns True when found."""
        k = _pred_key(entry.query)
        kept = [e for e in self._entries.get(k, []) if e is not entry]
        if len(kept) == len(self._entries.get(k, [])):
            return False
        if kept:
            self._entries[k] = kept
        else:
            self._entries.pop(k, None)
        return True

    def prune(self, max_entries: int) -> int:
        """Keep the ``max_entries`` most-recently-hit sketches; returns
        #evictions (use count, then instance size, break recency ties).

        Evicted sketches stop being served immediately; a later query that
        needed one simply misses and re-captures.  Their materialized
        instances may survive in a ``Catalog`` until its bounded FIFO maps
        evict them (the catalog holds its own sketch references).
        """
        all_entries = self.entries()
        if len(all_entries) <= max_entries:
            return 0
        all_entries.sort(key=lambda e: (e.last_hit, e.uses, -e.sketch.size_rows),
                         reverse=True)
        keep = set(id(e) for e in all_entries[:max_entries])
        evicted = 0
        for k in list(self._entries):
            kept = [e for e in self._entries[k] if id(e) in keep]
            evicted += len(self._entries[k]) - len(kept)
            if kept:
                self._entries[k] = kept
            else:
                del self._entries[k]
        return evicted

    def __len__(self) -> int:
        return sum(len(v) for v in self._entries.values())
