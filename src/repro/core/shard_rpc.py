"""Real process-boundary shards: RPC clients, shard server, process pool.

``repro.core.shard`` splits the shard surface in two:

* **ShardClient** (this module): the coordinator-side handle.  Two
  interchangeable backends — :class:`LoopbackShardClient` wraps an
  in-process ``FragmentShard`` (today's zero-copy behavior; every existing
  test runs unchanged on it), :class:`SubprocessShardClient` talks to a
  ``FragmentShard`` living in a separate OS process over a unix-socket RPC
  channel (``repro.runtime.transport``).  Both expose the same op surface,
  and both speak the serving layer's failure vocabulary: an RPC timeout or
  a dead connection surfaces as ``ShardUnavailableError``, a full inbox as
  ``BackpressureError`` — so the PR 6 health machine, ``rebalance()``, and
  degraded routing run unchanged on top of *real* process failures.

* **ShardServer** (this module, run via ``python -m repro.core.shard_rpc``):
  the shard-side loop.  Owns one ``FragmentShard``, drains its inbox,
  applies deltas, and serves registration / sketch-bit / partial-aggregate
  ops.  Fault injection maps to real mechanisms: ``kill`` is a SIGKILL of
  the server process, ``stall`` a server-side sleep per op, ``partition`` a
  client-side socket drop, ``flaky`` server-injected RPC error responses —
  the same ``runtime/chaos.py`` schedules that drove in-process flags now
  drive genuine process death and socket failures.

Checkpoints cross the boundary differently per backend
(:class:`ShardCheckpoint`): loopback keeps a zero-copy reference to the
shard's immutable local table; the subprocess backend snapshots the
*coordinator's* clustered table at the checkpoint watermark (tables are
immutable, so the reference IS the snapshot) and recovery rebuilds the
shard server-side from it — deterministic because ``FragmentShard``
construction from (table, plan, ranges, version) is a pure function — then
replays the delta log and re-registers maintainers, never re-captures.

The warm read path stays at ~1 RPC per shard per read: ``catch_up``
responses piggyback the shard's state token, maintainer keys, dimension
tokens, and maintained sketch bits, which the client caches until its own
next state-changing op (all mutation flows through the client, so the
cache cannot go stale silently).
"""
from __future__ import annotations

import atexit
import dataclasses
import itertools
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time
from typing import Dict, List, Mapping, Optional, Set, Tuple

import numpy as np

from repro.core.maintenance import MaintenanceError
from repro.core.queries import Query, inner_block_arrays
from repro.core.ranges import RangeSet
from repro.core.table import ColumnTable
from repro.runtime import transport
from repro.runtime.guards import hot_path

# Imported lazily where needed to keep `python -m repro.core.shard_rpc`
# startup lean; shard.py never imports this module at module level, so the
# one-way top-level import below is cycle-free.
from repro.core.shard import (  # noqa: E402
    BackpressureError,
    FragmentShard,
    ShardPlan,
    ShardUnavailableError,
    StaleEpochError,
)


# ---------------------------------------------------------------------------
# Shared client-side value types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardCheckpoint:
    """One shard's durable recovery point.

    ``kind == "local"``: ``table`` is the shard's own immutable local table
    (loopback; adopt is zero-copy).  ``kind == "coord"``: ``table`` is the
    coordinator's clustered table at the checkpoint watermark; recovery
    rebuilds the shard from it server-side (the subprocess backend — the
    coordinator cannot cheaply read a remote shard's table, but it *can*
    reconstruct it deterministically).
    """

    kind: str  # "local" | "coord"
    table: ColumnTable
    version: int


@dataclasses.dataclass(frozen=True)
class _EncView:
    """Client-side stand-in for ``catalog.GroupEncoding`` built from a
    server's ``block_arrays`` response (only the fields the stacked-layout
    builder reads)."""

    n_groups: int
    group_values: Dict[str, np.ndarray]
    gid: np.ndarray


#: Server exception type name -> local class, for re-raising RPC errors as
#: the types the serving layer's retry/health logic dispatches on.
_ERROR_TYPES = {
    "ShardUnavailableError": ShardUnavailableError,
    "BackpressureError": BackpressureError,
    "MaintenanceError": MaintenanceError,
    "StaleEpochError": StaleEpochError,
    "ValueError": ValueError,
    "KeyError": KeyError,
    "RuntimeError": RuntimeError,
}


def _raise_remote(resp: dict) -> None:
    etype, msg = resp.get("etype", "RuntimeError"), resp.get("msg", "")
    cls = _ERROR_TYPES.get(etype)
    if cls is None:
        raise transport.RemoteError(etype, msg)
    raise cls(msg)


# ---------------------------------------------------------------------------
# Loopback client (in-process, zero-copy — today's behavior)
# ---------------------------------------------------------------------------


class LoopbackShardClient:
    """In-process backend: wraps a ``FragmentShard`` directly.

    Everything not defined here delegates to the wrapped shard, so tests
    (and the chaos harness) that poke shard internals — ``maintainers``,
    ``dims``, ``catch_up``, ``inject``/``heal``, ``table`` — behave exactly
    as before the client split.
    """

    backend = "loopback"

    def __init__(self, shard: FragmentShard):
        self._shard = shard
        # This client's coordinator epoch, stamped on every state-touching
        # op (the loopback analog of the RPC payload's epoch field).  The
        # owning ``ShardedEngine`` sets it; a takeover wraps the same
        # ``FragmentShard`` in a *new* client carrying the bumped epoch,
        # after which this client's ops are fenced out.
        self.epoch = 0

    def __getattr__(self, name):
        if name == "_shard":  # during unpickling/partial init
            raise AttributeError(name)
        return getattr(self._shard, name)

    def _fence(self, op: str) -> None:
        """Stamp/check this client's epoch on the shard before a fenced op.

        Skipped while the shard is unreachable: the op itself raises
        ``ShardUnavailableError`` at the fault guard, and a partitioned
        zombie must not be able to *bump* the shard's epoch through the
        partition (nor learn it was fenced — it can't reach the shard)."""
        if self._shard.fault in ("dead", "partition"):
            return
        self._shard.fence(self.epoch, op)

    # -- fenced state-touching ops (otherwise delegated via __getattr__) ------
    def ship(self, version: int, kind: str, payload) -> None:
        self._fence("ship")
        self._shard.ship(version, kind, payload)

    def catch_up(self, watermark: int) -> int:
        self._fence("catch_up")
        return self._shard.catch_up(watermark)

    def register(self, key: int, q: Query, ranges: RangeSet) -> None:
        self._fence("register")
        self._shard.register(key, q, ranges)

    def update_dim(self, table: ColumnTable) -> None:
        self._fence("update_dim")
        self._shard.update_dim(table)

    def bits_for(self, key: int) -> Optional[np.ndarray]:
        self._fence("bits_for")
        return self._shard.bits_for(key)

    def partial(self, q: Query, key: int, ranges: RangeSet,
                bits: np.ndarray):
        self._fence("partial")
        return self._shard.partial(q, key, ranges, bits)

    def clone_for_takeover(self) -> "LoopbackShardClient":
        """A fresh client over the SAME live shard for a takeover
        coordinator — shard state (table, maintainers, epoch) stays put;
        only the client-side identity is new."""
        return LoopbackShardClient(self._shard)

    # -- client-only surface (the API ``ShardedEngine`` is written against)
    def block_arrays(self, key: int, ranges: RangeSet, bits: np.ndarray,
                     q: Query):
        """One shard's inner-block arrays for the stacked layout."""
        self._fence("block_arrays")
        shard = self._shard
        inst = shard._instance(key, ranges, bits)
        if q.join is not None:
            flat, _ = shard.catalog.join(
                inst, shard.dims[q.join.right], q.join.left_key,
                q.join.right_key)
        else:
            flat = inst
        return inner_block_arrays(q, flat, shard.catalog)

    def has_maintainer(self, key: int) -> bool:
        return key in self._shard.maintainers

    def dim_token(self, name: str) -> Optional[Tuple[int, int]]:
        t = self._shard.dims.get(name)
        return None if t is None else (t.uid, t.version)

    def state_token(self) -> Optional[Tuple[int, int]]:
        t = self._shard.table
        return None if t is None else (t.uid, t.version)

    @property
    def state_lost(self) -> bool:
        return self._shard.table is None

    def make_checkpoint(self, coord_table: ColumnTable,
                        coord_version: int) -> ShardCheckpoint:
        t = self._shard.table
        return ShardCheckpoint(kind="local", table=t, version=t.version)

    def restore_checkpoint(self, ckpt: ShardCheckpoint,
                dims: Mapping[str, ColumnTable], plan: ShardPlan,
                ranges: RangeSet) -> None:
        self._fence("restore_checkpoint")
        self._shard.adopt(ckpt.table, dims)

    def rebuild(self, plan: ShardPlan, ranges: RangeSet,
                clustered: ColumnTable, dims: Mapping[str, ColumnTable],
                device, inbox_cap: Optional[int], version: int) -> None:
        self._fence("rebuild")
        epoch = self._shard.epoch
        self._shard = FragmentShard(
            self._shard.shard_id, plan, ranges, clustered, dims, device,
            inbox_cap=inbox_cap, version=version)
        # Epoch is process identity, not table state: it survives the
        # rebuild, so a fenced-out coordinator stays fenced out.
        self._shard.epoch = epoch

    def close_client(self) -> None:
        pass


# ---------------------------------------------------------------------------
# Server process pool
# ---------------------------------------------------------------------------

_SPAWN_TIMEOUT_S = 60.0
_sock_counter = itertools.count(1)
_sock_dir: Optional[str] = None


def _socket_dir() -> str:
    global _sock_dir
    if _sock_dir is None:
        _sock_dir = tempfile.mkdtemp(prefix="repro-shards-")
    return _sock_dir


class _ServerProc:
    """One shard server subprocess + its RPC connection."""

    def __init__(self, proc: subprocess.Popen, path: str):
        self.proc = proc
        self.path = path
        self.conn: Optional[socket.socket] = None
        self._seq = itertools.count(1)

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def connect(self, deadline_s: float = _SPAWN_TIMEOUT_S) -> None:
        """(Re)connect to the server's listening socket, waiting out the
        child's interpreter/jax startup on first contact."""
        self.drop_conn()
        t_end = time.perf_counter() + deadline_s
        last: Optional[Exception] = None
        while time.perf_counter() < t_end:
            if not self.alive:
                raise ShardUnavailableError(
                    f"shard server {self.proc.pid} exited "
                    f"(rc={self.proc.poll()})")
            s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            s.settimeout(1.0)
            try:
                s.connect(self.path)
                self.conn = s
                return
            except (FileNotFoundError, ConnectionRefusedError,
                    socket.timeout, OSError) as e:
                last = e
                s.close()
                time.sleep(0.02)
        raise ShardUnavailableError(
            f"could not connect to shard server at {self.path}: {last}")

    def drop_conn(self) -> None:
        if self.conn is not None:
            try:
                self.conn.close()
            except OSError:
                pass
            self.conn = None

    def request(self, payload: dict, deadline_s: float) -> dict:
        """One request/response exchange.  Transport failures surface as
        ``ShardUnavailableError`` — the retryable class of the serving
        layer — after dropping the (now desynced) connection."""
        if self.conn is None:
            self.connect(deadline_s=max(deadline_s, 10.0))
        seq = next(self._seq)
        try:
            transport.send_msg(self.conn, payload, seq, deadline_s=deadline_s)
            rseq, resp = transport.recv_msg(self.conn, deadline_s=deadline_s)
        except transport.RpcTimeout as e:
            self.drop_conn()
            raise ShardUnavailableError(
                f"rpc {payload.get('op')} timed out: {e}") from e
        except (transport.RpcClosed, transport.FrameError, OSError) as e:
            self.drop_conn()
            raise ShardUnavailableError(
                f"rpc {payload.get('op')} connection lost: {e}") from e
        if rseq != seq:
            self.drop_conn()
            raise ShardUnavailableError(
                f"rpc desync (sent seq {seq}, got {rseq})")
        return resp

    def kill(self) -> None:
        try:
            self.proc.kill()
        except OSError:
            pass
        self.drop_conn()
        try:
            self.proc.wait(timeout=5.0)
        except Exception:
            pass


class ServerPool:
    """Reusable shard-server subprocesses.

    Spawning a server pays the child's interpreter + jax import (~1s); at
    100+ chaos replays that cost would dominate everything.  The pool keeps
    *stateless* warm servers (a ``reset`` op drops the shard between
    tenants but keeps the process and its XLA compile caches alive) and
    tops up a small spare set in the background so a post-kill respawn
    usually pops a warm process instead of cold-starting one.

    Orphan safety is layered: every spawned pid is tracked and SIGKILLed
    ``atexit``; each child also watches its stdin pipe and exits the moment
    the parent dies (EOF) — so neither a crashed test run nor a killed
    coordinator leaks shard servers.
    """

    def __init__(self, spares: Optional[int] = None):
        self._lock = threading.Lock()
        self._spares: List[_ServerProc] = []
        self._all: Set[_ServerProc] = set()
        self._target = (int(os.environ.get("REPRO_SHARD_SPARES", "2"))
                        if spares is None else spares)
        self._filling = False
        self._closed = False

    def _spawn(self) -> _ServerProc:
        # Checked twice: before paying the Popen, and again before tracking
        # the child — a close_pool() racing this spawn (atexit vs the background
        # top-up thread) must never leave an untracked orphan behind.
        with self._lock:
            if self._closed:
                raise ShardUnavailableError("server pool is closed")
        path = os.path.join(_socket_dir(), f"s{next(_sock_counter)}.sock")
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.core.shard_rpc", path],
            stdin=subprocess.PIPE, stdout=subprocess.DEVNULL,
            start_new_session=True, env=env)
        sp = _ServerProc(proc, path)
        with self._lock:
            if not self._closed:
                self._all.add(sp)
                return sp
        sp.kill()
        raise ShardUnavailableError("server pool closed mid-spawn")

    def acquire(self) -> _ServerProc:
        with self._lock:
            sp = self._spares.pop() if self._spares else None
        while sp is not None and not sp.alive:
            with self._lock:
                self._all.discard(sp)
                sp = self._spares.pop() if self._spares else None
        if sp is None:
            sp = self._spawn()
        self._top_up_async()
        return sp

    def release(self, sp: _ServerProc) -> None:
        """Return a server to the spare set (after a state reset), or reap
        it if it is no longer serviceable."""
        if not sp.alive:
            self.discard(sp)
            return
        try:
            resp = sp.request({"op": "reset", "args": (), "ctl": True},
                              deadline_s=10.0)
            if not resp.get("ok"):
                raise ShardUnavailableError("reset refused")
        except ShardUnavailableError:
            self.discard(sp)
            return
        with self._lock:
            self._spares.append(sp)

    def discard(self, sp: _ServerProc) -> None:
        sp.kill()
        with self._lock:
            self._all.discard(sp)
            if sp in self._spares:
                self._spares.remove(sp)

    def prewarm(self, n: int) -> None:
        """Synchronously grow the spare set to ``n`` (bench warmup hook)."""
        need = []
        with self._lock:
            cur = len(self._spares)
        for _ in range(max(0, n - cur)):
            need.append(self._spawn())
        with self._lock:
            self._spares.extend(need)

    def _top_up_async(self) -> None:
        with self._lock:
            if self._filling or len(self._spares) >= self._target:
                return
            self._filling = True

        def fill():
            try:
                while True:
                    with self._lock:
                        if self._closed or len(self._spares) >= self._target:
                            return
                    try:
                        sp = self._spawn()
                    except ShardUnavailableError:
                        return  # pool closed mid-fill
                    with self._lock:
                        if self._closed:
                            break
                        self._spares.append(sp)
                sp.kill()
            finally:
                with self._lock:
                    self._filling = False

        threading.Thread(target=fill, daemon=True).start()

    def _drain(self) -> List[_ServerProc]:
        with self._lock:
            self._closed = True
            procs = list(self._all)
            self._all.clear()
            self._spares.clear()
        return procs

    def shutdown_all(self) -> None:
        """Kill every pooled server, then reopen for the next tenant (bench
        suites reuse the module-level pool across scenarios).  The closed
        window is what makes this race-free against the background top-up
        thread: a spawn landing mid-shutdown is killed, not leaked."""
        procs = self._drain()
        for sp in procs:
            sp.kill()
        with self._lock:
            self._closed = False

    # Named close_pool (not ``close``) so the hot-path analyzer's name-based
    # call graph cannot alias socket ``close()`` calls in the RPC hot path
    # onto this terminal teardown (which reaches Popen.wait).
    def close_pool(self) -> None:
        """Terminal shutdown (atexit): kill everything and stay closed so
        no late daemon-thread spawn can outlive the coordinator."""
        for sp in self._drain():
            sp.kill()


#: Process-wide pool; ``atexit`` guarantees no shard server outlives the
#: coordinator process even when tests die mid-run.
POOL = ServerPool()
atexit.register(POOL.close_pool)


# ---------------------------------------------------------------------------
# Subprocess client
# ---------------------------------------------------------------------------


class SubprocessShardClient:
    """Coordinator-side handle for a shard living in its own OS process.

    Failure semantics are genuine: ``kill`` SIGKILLs the server process
    (heal respawns an *empty* one — state is really gone until the
    coordinator runs checkpoint-rebuild + delta-replay + re-registration),
    ``partition`` drops the socket client-side with server state intact,
    ``stall`` makes the server sleep per op (past the RPC deadline it
    surfaces as a timeout), ``flaky`` makes the server fail the next N ops
    with marshalled errors that exercise the retry path over real RPC.
    """

    backend = "subprocess"

    def __init__(
        self,
        shard_id: int,
        plan: ShardPlan,
        ranges: RangeSet,
        clustered: ColumnTable,
        dims: Mapping[str, ColumnTable],
        inbox_cap: Optional[int] = None,
        version: int = 0,
        op_deadline_s: float = 5.0,
        pool: Optional[ServerPool] = None,
    ):
        self.shard_id = shard_id
        self._pool = pool or POOL
        self._inbox_cap = inbox_cap
        # RPC deadline: comfortably past the engine's op deadline so a
        # mild stall completes slowly (straggler semantics, like loopback)
        # while a hard stall still times out into ShardUnavailableError.
        self._deadline_s = max(op_deadline_s * 2.0, op_deadline_s + 1.0)
        self._build_deadline_s = max(120.0, self._deadline_s)
        self._proc: Optional[_ServerProc] = self._pool.acquire()
        self._fault: Optional[str] = None  # None|"dead"|"partition"|"stall"
        self._state_lost = True
        self._version = -1
        self._lag = 0
        self._bp = 0
        self._token: Optional[Tuple[int, int]] = None
        self._mkeys: Set[int] = set()
        self._bits: Optional[Dict[int, np.ndarray]] = None
        self._dims: Dict[str, Tuple[int, int]] = {}
        self._pending_unregister: Set[int] = set()
        # Coordinator epoch stamped on every non-ctl request; the server
        # fences ops behind the newest epoch it has seen (set by the owning
        # ``ShardedEngine`` — 0 only during this initial build).
        self.epoch = 0
        self._build(plan, ranges, clustered, dims, version)

    # -- plumbing --------------------------------------------------------------
    def _absorb_meta(self, meta: Optional[dict]) -> None:
        if not meta:
            return
        self._version = meta["version"]
        self._lag = meta["lag"]
        self._bp = meta["bp"]
        self._token = meta["token"]
        self._mkeys = set(meta["mkeys"])
        self._dims = dict(meta["dims"])
        if "bits" in meta:
            self._bits = meta["bits"]

    def _request(self, op: str, args: tuple, ctl: bool = False,
                 deadline_s: Optional[float] = None):
        if not ctl and self._fault == "partition":
            raise ShardUnavailableError(
                f"shard {self.shard_id} is partition ({op})")
        if self._proc is None:
            raise ShardUnavailableError(
                f"shard {self.shard_id} is dead ({op})")
        resp = self._proc.request(
            {"op": op, "args": args, "ctl": ctl, "epoch": self.epoch},
            deadline_s=self._deadline_s if deadline_s is None else deadline_s)
        self._absorb_meta(resp.get("meta"))
        if not resp.get("ok"):
            _raise_remote(resp)
        return resp.get("value")

    def _build(self, plan: ShardPlan, ranges: RangeSet,
               clustered: ColumnTable, dims: Mapping[str, ColumnTable],
               version: int) -> None:
        # Collapse before shipping: the wire must carry one table's columns,
        # not its whole delta-chain history.
        self._request(
            "build",
            (self.shard_id, plan.owner, plan.n_shards, ranges,
             clustered.collapse(),
             {k: v.collapse() for k, v in dims.items()},
             self._inbox_cap, version, self.shard_id),
            deadline_s=self._build_deadline_s)
        self._state_lost = False
        self._bits = self._bits if self._bits is not None else {}

    def _flush_unregisters(self) -> None:
        if not self._pending_unregister:
            return
        keys = tuple(self._pending_unregister)
        try:
            self._request("unregister_many", (keys,), ctl=True)
            self._pending_unregister.clear()
        except ShardUnavailableError:
            pass  # still unreachable; retry on a later op

    # -- fault injection (chaos surface) ---------------------------------------
    def inject(self, kind: str, arg=None) -> None:
        """Real-mechanism fault injection (see class docstring)."""
        if kind == "kill":
            if self._proc is not None:
                self._pool.discard(self._proc)
                self._proc = None
            self._fault = "dead"
            self._state_lost = True
            self._version = -1
            self._lag = 0
            self._token = None
            self._mkeys = set()
            self._bits = None
            self._dims = {}
            self._pending_unregister.clear()
        elif kind == "stall":
            s = float(arg) if arg is not None else 0.02
            self._request("set_stall", (s,), ctl=True)
            self._fault = "stall"
        elif kind == "partition":
            self._fault = "partition"
            if self._proc is not None:
                self._proc.drop_conn()
        elif kind == "flaky":
            self._request("set_flaky",
                          (int(arg) if arg is not None else 1,), ctl=True)
        else:
            raise ValueError(f"unknown fault kind {kind!r}")

    def heal(self) -> None:
        """Clear the fault.  After a kill this respawns a *fresh, empty*
        server (from the warm pool when possible): the shard is reachable
        again but its state is genuinely lost until the coordinator runs
        checkpoint-rebuild + delta-replay + re-registration."""
        if self._proc is None or not self._proc.alive:
            if self._proc is not None:
                self._pool.discard(self._proc)
            self._proc = self._pool.acquire()
            self._state_lost = True
            self._version = -1
            self._token = None
            self._mkeys = set()
            self._bits = None
            self._dims = {}
        elif self._fault == "stall" or self._fault is None:
            try:
                self._request("clear_faults", (), ctl=True)
            except ShardUnavailableError:
                pass
        self._fault = None

    @property
    def reachable(self) -> bool:
        return self._fault not in ("dead", "partition")

    # -- replication -----------------------------------------------------------
    @property
    def version(self) -> int:
        return -1 if self._state_lost else self._version

    @property
    def lag(self) -> int:
        return self._lag

    @property
    def backpressure_hits(self) -> int:
        return self._bp

    @property
    def inbox_cap(self) -> Optional[int]:
        return self._inbox_cap

    def ship(self, version: int, kind: str, payload) -> None:
        self._request("ship", (version, kind, payload))

    def catch_up(self, watermark: int) -> int:
        self._flush_unregisters()
        return self._request("catch_up", (watermark,))

    def update_dim(self, table: ColumnTable) -> None:
        self._request("update_dim", (table.collapse(),))

    def dim_token(self, name: str) -> Optional[Tuple[int, int]]:
        return self._dims.get(name)

    # -- sketch registration ---------------------------------------------------
    def register(self, key: int, q: Query, ranges: RangeSet) -> None:
        self._flush_unregisters()
        self._request("register", (key, q, ranges))

    def unregister(self, key: int) -> None:
        # Best-effort, like the loopback (whose unregister has no fault
        # guard): an unreachable shard's stale maintainer is queued and
        # flushed before the next register/catch_up, so a recycled entry
        # id can never alias onto it.
        self._mkeys.discard(key)
        if self._bits is not None:
            self._bits.pop(key, None)
        try:
            self._request("unregister_many", ((key,),), ctl=True)
        except ShardUnavailableError:
            self._pending_unregister.add(key)

    def has_maintainer(self, key: int) -> bool:
        return key in self._mkeys

    def bits_for(self, key: int) -> Optional[np.ndarray]:
        if self._fault in ("dead", "partition"):
            raise ShardUnavailableError(
                f"shard {self.shard_id} is {self._fault} (bits_for)")
        if self._state_lost:
            raise ShardUnavailableError(
                f"shard {self.shard_id} lost its state (bits_for)")
        if self._bits is not None:
            # Piggybacked on the last catch_up/register response; every
            # bit-changing op flows through this client, so the cache is
            # exact — the warm read path pays zero extra RPCs here.
            return self._bits.get(key)
        return self._request("bits_for", (key,))

    # -- query serving ---------------------------------------------------------
    @hot_path
    def partial(self, q: Query, key: int, ranges: RangeSet,
                bits: np.ndarray) -> Tuple[Dict[str, np.ndarray],
                                           np.ndarray, np.ndarray]:
        return self._request("partial", (q, key, ranges, np.asarray(bits)))

    @hot_path
    def block_arrays(self, key: int, ranges: RangeSet, bits: np.ndarray,
                     q: Query):
        n_groups, group_values, gid, where, vals = self._request(
            "block_arrays", (key, ranges, np.asarray(bits), q))
        return (_EncView(n_groups=n_groups, group_values=group_values,
                         gid=gid), where, vals)

    # -- state identity / recovery ---------------------------------------------
    def state_token(self) -> Optional[Tuple[int, int]]:
        return None if self._state_lost else self._token

    @property
    def state_lost(self) -> bool:
        return self._state_lost

    def make_checkpoint(self, coord_table: ColumnTable,
                        coord_version: int) -> ShardCheckpoint:
        # Zero RPCs: the coordinator's clustered table is immutable, so a
        # reference to it at the checkpoint watermark IS a consistent
        # snapshot the shard can be deterministically rebuilt from.
        return ShardCheckpoint(kind="coord", table=coord_table.collapse(),
                               version=coord_version)

    def restore_checkpoint(self, ckpt: ShardCheckpoint,
                dims: Mapping[str, ColumnTable], plan: ShardPlan,
                ranges: RangeSet) -> None:
        self._build(plan, ranges, ckpt.table, dims, ckpt.version)

    def rebuild(self, plan: ShardPlan, ranges: RangeSet,
                clustered: ColumnTable, dims: Mapping[str, ColumnTable],
                device, inbox_cap: Optional[int], version: int) -> None:
        self._inbox_cap = inbox_cap
        self._build(plan, ranges, clustered, dims, version)

    # -- peer-replicated checkpoints -------------------------------------------
    def peer_put(self, sid: int, local: ColumnTable, plan_token: int) -> None:
        """Seed this server with a mirror of peer shard ``sid``'s local
        table (full ship — only at seed/re-seed; deltas keep it current)."""
        self._request("ckpt_put", (sid, local.collapse(), plan_token),
                      deadline_s=self._build_deadline_s)

    def peer_ship(self, sid: int, version: int, kind: str, payload) -> bool:
        """Apply one delta to the mirror of shard ``sid``; False when the
        mirror is missing or the delta would leave a version gap (the
        server drops the mirror — a gapped mirror is useless)."""
        return bool(self._request("ckpt_ship", (sid, version, kind, payload)))

    def peer_fetch(self, sid: int,
                   plan_token: int) -> Optional[Tuple[ColumnTable, int]]:
        """Fetch the mirror of shard ``sid``; None when absent or seeded
        under a different placement plan."""
        return self._request("ckpt_get", (sid, plan_token),
                             deadline_s=self._build_deadline_s)

    def build_local(self, plan: ShardPlan, ranges: RangeSet,
                    local: ColumnTable, dims: Mapping[str, ColumnTable],
                    inbox_cap: Optional[int]) -> None:
        """Rebuild this shard from an already-local table (peer-mirror
        recovery): no coordinator-table gather, no full-table reship."""
        self._inbox_cap = inbox_cap
        self._request(
            "build_local",
            (self.shard_id, plan.owner, plan.n_shards, ranges,
             local.collapse(), {k: v.collapse() for k, v in dims.items()},
             inbox_cap, self.shard_id),
            deadline_s=self._build_deadline_s)
        self._state_lost = False
        self._bits = self._bits if self._bits is not None else {}
        self._fault = None

    def clone_for_takeover(self) -> "SubprocessShardClient":
        """A fresh client over the SAME live server socket for a takeover
        coordinator.  No shard state moves — the new client re-learns the
        server's state cheaply via one ctl round trip (whose meta piggyback
        carries version, maintainer keys and dimension tokens); sketch-bit
        caches refill on the first catch_up."""
        c = object.__new__(SubprocessShardClient)
        c.shard_id = self.shard_id
        c._pool = self._pool
        c._inbox_cap = self._inbox_cap
        c._deadline_s = self._deadline_s
        c._build_deadline_s = self._build_deadline_s
        c._proc = self._proc
        c._fault = self._fault if self._fault in ("dead",) else None
        c._state_lost = True
        c._version = -1
        c._lag = 0
        c._bp = 0
        c._token = None
        c._mkeys = set()
        c._bits = None
        c._dims = {}
        c._pending_unregister = set()
        c.epoch = 0  # the owning engine stamps the real epoch after attach
        if c._proc is not None:
            try:
                token = c._request("state_token", (), ctl=True)
                c._state_lost = token is None
            except ShardUnavailableError:
                c._state_lost = True
        return c

    def close_client(self) -> None:
        """Release the server back to the warm pool (or reap it)."""
        proc, self._proc = self._proc, None
        if proc is not None:
            if self._fault in ("dead",) or not proc.alive:
                self._pool.discard(proc)
            else:
                self._pool.release(proc)
        self._fault = "dead"
        self._state_lost = True

    @property
    def pid(self) -> Optional[int]:
        """The server process id (None after a kill) — test/debug hook."""
        return self._proc.proc.pid if self._proc is not None else None


# ---------------------------------------------------------------------------
# Server side
# ---------------------------------------------------------------------------


class ShardServer:
    """One shard's server loop state: the ``FragmentShard`` plus the
    server-side halves of fault injection (stall = sleep per data op,
    flaky = fail the next N data ops)."""

    #: ops exempt from stall/flaky (fault control, lifecycle, and
    #: unregister — whose loopback counterpart has no fault guard either).
    CTL_OPS = ("ping", "set_stall", "set_flaky", "clear_faults", "reset",
               "shutdown", "unregister_many", "state_token")

    def __init__(self):
        self.shard: Optional[FragmentShard] = None
        self.stall_s = 0.0
        self.flaky_fails = 0
        self.closed = False
        # Highest coordinator epoch seen on a non-ctl op.  Process identity,
        # not shard state: survives shard rebuilds, zeroed only by ``reset``
        # (pool re-tenancy — a different coordinator's epoch space).
        self.epoch = 0
        # Peer-replicated checkpoints: sid -> (mirror table, plan token).
        # Delta-maintained by ``ckpt_ship``; recovery pulls shard-local
        # state from here instead of re-shipping the coordinator's table.
        self.peer_ckpts: Dict[int, Tuple[ColumnTable, int]] = {}

    # -- dispatch --------------------------------------------------------------
    def handle(self, msg: dict) -> dict:
        op = msg.get("op", "")
        args = msg.get("args", ())
        try:
            if op not in self.CTL_OPS:
                if self.stall_s > 0:
                    time.sleep(self.stall_s)
                if self.flaky_fails > 0:
                    self.flaky_fails -= 1
                    raise ShardUnavailableError(
                        f"shard dropped {op} (flaky)")
                # Epoch fence, AFTER the fault simulation (a stalled zombie
                # op must still be rejected, not served): monotone max, so a
                # newer coordinator's first op fences every older one out.
                epoch = int(msg.get("epoch", 0))
                if epoch < self.epoch:
                    raise StaleEpochError(
                        f"coordinator epoch {epoch} is fenced behind "
                        f"{self.epoch} ({op})")
                self.epoch = epoch
            value = self._dispatch(op, args)
            return {"ok": True, "value": value, "meta": self._meta(op)}
        except Exception as e:  # marshalled; the client re-raises by type
            return {"ok": False, "etype": type(e).__name__, "msg": str(e),
                    "meta": self._meta(op)}

    def _meta(self, op: str) -> dict:
        s = self.shard
        if s is None or s.table is None:
            return {"version": -1, "lag": 0, "bp": 0, "token": None,
                    "mkeys": (), "dims": {}, "bits": {}}
        meta = {
            "version": s.version,
            "lag": s.lag,
            "bp": s.backpressure_hits,
            "token": (s.table.uid, s.table.version),
            "mkeys": tuple(s.maintainers.keys()),
            "dims": {k: (v.uid, v.version) for k, v in s.dims.items()},
        }
        if op in ("build", "catch_up", "register", "update_dim"):
            # The only ops after which maintained bits can differ from the
            # client's cache — piggyback the fresh bits so the warm read
            # path never pays a separate bits_for round trip.
            meta["bits"] = {key: np.asarray(m.bits())
                            for key, m in s.maintainers.items()}
        return meta

    def _require_shard(self) -> FragmentShard:
        if self.shard is None:
            raise ShardUnavailableError("server has no shard state (build first)")
        return self.shard

    def _dispatch(self, op: str, args: tuple):
        if op == "ping":
            return "pong"
        if op == "set_stall":
            self.stall_s = float(args[0])
            return None
        if op == "set_flaky":
            self.flaky_fails = int(args[0])
            return None
        if op == "clear_faults":
            self.stall_s = 0.0
            self.flaky_fails = 0
            return None
        if op == "reset":
            self.shard = None
            self.stall_s = 0.0
            self.flaky_fails = 0
            self.epoch = 0
            self.peer_ckpts = {}
            return None
        if op == "shutdown":
            self.closed = True
            return None
        if op == "build":
            (shard_id, owner, n_shards, ranges, clustered, dims,
             inbox_cap, version, device_ord) = args
            plan = ShardPlan(n_shards=n_shards, owner=np.asarray(owner))
            self.shard = FragmentShard(
                shard_id, plan, ranges, clustered, dims,
                _pick_device(device_ord), inbox_cap=inbox_cap,
                version=version)
            return None
        if op == "unregister_many":
            if self.shard is not None:
                for key in args[0]:
                    self.shard.unregister(key)
            return None
        if op == "state_token":
            s = self.shard
            return (None if s is None or s.table is None
                    else (s.table.uid, s.table.version))
        if op == "ckpt_put":
            sid, table, token = args
            self.peer_ckpts[int(sid)] = (table, token)
            return None
        if op == "ckpt_ship":
            sid, version, kind, payload = args
            ent = self.peer_ckpts.get(int(sid))
            if ent is None:
                return False
            table, token = ent
            if version <= table.version:
                return True  # duplicate re-ship: idempotent skip
            if version > table.version + 1:
                # Version gap (an earlier delta never landed): a gapped
                # mirror can never be made current again — drop it so the
                # coordinator re-seeds instead of recovering stale state.
                self.peer_ckpts.pop(int(sid), None)
                return False
            table = (table.append(payload) if kind == "append"
                     else table.delete(payload))
            self.peer_ckpts[int(sid)] = (table, token)
            return True
        if op == "ckpt_get":
            sid, token = args
            ent = self.peer_ckpts.get(int(sid))
            if ent is None or ent[1] != token:
                # Absent, or seeded under a different placement plan: a
                # mirror gathered under the old owner map must never be
                # adopted after a rebalance.
                return None
            return (ent[0].collapse(), ent[0].version)
        if op == "build_local":
            (shard_id, owner, n_shards, ranges, local, dims,
             inbox_cap, device_ord) = args
            plan = ShardPlan(n_shards=n_shards, owner=np.asarray(owner))
            self.shard = FragmentShard.from_local(
                shard_id, plan, ranges, local, dims,
                device=_pick_device(device_ord), inbox_cap=inbox_cap)
            return None
        shard = self._require_shard()
        if op == "ship":
            version, kind, payload = args
            shard.ship(version, kind, payload)
            return None
        if op == "catch_up":
            return shard.catch_up(int(args[0]))
        if op == "register":
            key, q, ranges = args
            shard.register(key, q, ranges)
            return None
        if op == "bits_for":
            return shard.bits_for(args[0])
        if op == "partial":
            q, key, ranges, bits = args
            gv, sums, counts = shard.partial(q, key, ranges, bits)
            return ({k: np.asarray(v) for k, v in gv.items()},
                    np.asarray(sums), np.asarray(counts))
        if op == "block_arrays":
            key, ranges, bits, q = args
            inst = shard._instance(key, ranges, bits)
            if q.join is not None:
                flat, _ = shard.catalog.join(
                    inst, shard.dims[q.join.right], q.join.left_key,
                    q.join.right_key)
            else:
                flat = inst
            enc, where, vals = inner_block_arrays(q, flat, shard.catalog)
            return (int(enc.n_groups),
                    {k: np.asarray(v) for k, v in enc.group_values.items()},
                    np.asarray(enc.gid), np.asarray(where),
                    np.asarray(vals))
        if op == "update_dim":
            shard.update_dim(args[0])
            return None
        raise ValueError(f"unknown rpc op {op!r}")


def _pick_device(device_ord: Optional[int]):
    """The server's own device for its shard's columns (devices are not
    serializable across processes, so the coordinator sends an ordinal and
    the child resolves it against its *own* jax runtime — under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` each shard
    process pins a distinct emulated host device)."""
    import jax

    devices = jax.local_devices()
    if device_ord is None or len(devices) <= 1:
        return None
    return devices[device_ord % len(devices)]


def _stdin_watchdog() -> None:
    """Exit the moment the parent dies: the coordinator holds our stdin
    pipe, so EOF means the parent is gone and we are an orphan."""
    try:
        while True:
            chunk = sys.stdin.buffer.read(4096)
            if not chunk:
                break
    except Exception:
        pass
    os._exit(2)


def _enable_compile_cache() -> None:
    """Point this server at the shared on-disk XLA compilation cache.

    Shard servers are short-lived relative to the kernels they compile: a
    respawned process (post-SIGKILL recovery, pool top-up) would otherwise
    pay every first-call compile again, which dominates kill->recover
    wall-clock.  The persistent cache makes those loads instead of
    compiles.  Opt out with ``REPRO_SHARD_COMPILE_CACHE=""``."""
    cache_dir = os.environ.get(
        "REPRO_SHARD_COMPILE_CACHE",
        os.path.join(tempfile.gettempdir(), "repro-xla-cache"))
    if not cache_dir:
        return
    try:
        import jax

        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        pass  # cache is a perf lever, never a correctness dependency


def serve(path: str) -> None:
    """The subprocess entry: bind, accept one connection at a time, serve
    request/response until shutdown.  A broken connection (client timed
    out mid-stall and reconnected, coordinator dropped a partition) just
    re-enters accept — shard state survives across connections."""
    threading.Thread(target=_stdin_watchdog, daemon=True).start()
    _enable_compile_cache()

    # Disjoint uid space: tables created in this process (local shard
    # tables, instances) must never collide with coordinator-created uids
    # arriving over the wire, or per-uid catalog caches would alias.
    from repro.core import table as table_mod
    table_mod._TABLE_UIDS = itertools.count(((os.getpid() & 0xFFFFF) << 40) | 1)

    srv = ShardServer()
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.bind(path)
    sock.listen(4)
    while not srv.closed:
        conn, _ = sock.accept()
        try:
            while not srv.closed:
                seq, msg = transport.recv_msg(conn, deadline_s=None)
                resp = srv.handle(msg)
                transport.send_msg(conn, resp, seq)
        except (transport.RpcClosed, transport.FrameError, OSError):
            pass  # connection over; accept the next one
        finally:
            try:
                conn.close()
            except OSError:
                pass
    os._exit(0)


def main(argv: Optional[List[str]] = None) -> None:
    args = sys.argv[1:] if argv is None else argv
    if len(args) != 1:
        print("usage: python -m repro.core.shard_rpc <socket-path>",
              file=sys.stderr)
        raise SystemExit(2)
    serve(args[0])


if __name__ == "__main__":
    main()
