"""Batched admission pipeline: the miss path of ``PBDSEngine.run_batch``.

``PBDSEngine.run`` admits exactly one query at a time, so a burst of N cold
queries pays N stratified samples, N AQR estimate passes, N full-table
capture scans and N maintainer builds — even when the queries differ only in
their HAVING thresholds.  Everything on that list is shareable (Sec. 7.1
sampling reuse; Alg. 1's estimates are candidate- and threshold-independent;
provenance for the whole group derives from one inner-block evaluation), so
batched admission restructures the miss path around *signature groups*:

  wave planning   queries whose sketch an earlier batch member would create
                  are deferred a wave and served as plain index hits, exactly
                  as sequential execution would serve them;
  selection       misses are grouped by inner-block signature
                  (table, GROUP BY, aggregate, WHERE, join); each group
                  shares ONE stratified sample and ONE AQR estimate pass,
                  each member applies its own HAVING at group-level cost, and
                  the fragment-incidence math for every (query, candidate)
                  pair in the whole wave runs as ONE padded vmapped launch
                  (``estimate_size_multi``);
  execution       each signature group evaluates the shared inner block ONCE;
                  every member's result and provenance mask are group-level
                  tails of it (bit-exact — the same code sequential execution
                  runs per query);
  capture         admitted sketches grouped by (table, partition) capture
                  from stacked provenance masks in ONE batched bitmap kernel
                  launch (``capture_sketches_batch``), and maintainers clone
                  their threshold-independent counting state from one build
                  per (signature, partition).

Bit-for-bit parity with sequential ``run`` is a design invariant (the
differential suite in ``tests/test_admission.py`` pins results, index
contents and sketch bits): selection randomness is content-derived
(``PBDSEngine._select_key``), estimate ranking compares exact integral f32
sums, and every shared product is the same object sequential execution would
have pulled from the caches.  The one carve-out is documented on
``PBDSEngine.run_batch``: under ``cluster_tables=True`` the mid-batch
re-cluster invalidates samples, so sample-position-dependent candidate
incidence (non-group-by candidates) may select differently than a
sequential replay that re-sampled the permuted rows.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.aqp.size_estimation import (
    EstimationSpec,
    estimate_size_multi,
    satisfied_groups,
)
from repro.core.index import subsumes
from repro.core.queries import (
    Query,
    QueryResult,
    execute,
    inner_block,
    provenance_from_inner,
    result_from_group_state,
)
from repro.core.safety import stats_prefilter
from repro.core.sketch import apply_sketch, capture_sketches_batch
from repro.core.strategies import (
    RANDOM_STRATEGIES,
    SelectionResult,
    candidate_pool,
    select_attribute,
    selection_cache_key,
)
from repro.runtime.guards import hot_path

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.engine import PBDSEngine, RunInfo

Miss = Tuple[int, Query, float]  # (batch position, query, probe seconds)


def exec_group_key(q: Query) -> Tuple:
    """Inner-block signature: queries with equal keys share FROM/WHERE/GROUP
    BY/aggregate products (sample, AQR estimates, inner-block evaluation,
    maintainer counting state) — only their HAVING chains differ."""
    return q.inner_signature()


def plan_wave(misses: List[Miss]) -> Tuple[List[Miss], List[Miss]]:
    """Split one wave's misses into (admit now, defer to the next wave).

    A miss is deferred when an earlier miss in the same wave subsumes it: in
    sequential execution the earlier query's sketch would exist by the time
    the later one runs, so the later query must be served as an index hit
    against it — not admitted as a duplicate capture.  Deferred queries
    re-probe after the wave lands; if the subsuming query declined to create
    a sketch they are admitted next wave with identical (content-derived)
    randomness, so the outcome still matches sequential order.
    """
    wave: List[Miss] = []
    deferred: List[Miss] = []
    for m in misses:
        if any(subsumes(w[1], m[1]) for w in wave):
            deferred.append(m)
        else:
            wave.append(m)
    return wave, deferred


@hot_path
def admit_misses(
    engine: "PBDSEngine", misses: List[Miss]
) -> Tuple[Dict[int, Tuple[QueryResult, "RunInfo"]], List[Tuple[int, Query]]]:
    """One admission wave: plan (subsumption deferral), admit, return
    ``(served by batch position, deferred (position, query) pairs)``.

    The shared miss-path step of ``PBDSEngine.run_batch`` and
    ``ShardedEngine.run_batch`` — NO-PS skips planning (it never creates
    sketches, so within-batch deferral is moot).
    """
    wave, deferred = (
        plan_wave(misses) if engine.strategy != "NO-PS" else (misses, []))
    served = admit_wave(engine, wave)
    return served, [(i, q) for i, q, _ in deferred]


def _select_wave(
    engine: "PBDSEngine", wave: List[Miss]
) -> Dict[int, SelectionResult]:
    """Candidate selection for the whole wave.

    Cost-based strategies share per-signature-group samples + AQR passes and
    run every (query, candidate) incidence row through one padded device
    launch; random/oracle strategies fall back to per-query selection with
    their content-derived keys (no shareable math).
    """
    db, strategy = engine.db, engine.strategy
    out: Dict[int, SelectionResult] = {}
    if strategy == "NO-PS":
        return {pos: SelectionResult("NO-PS", None, (), {}) for pos, _, _ in wave}
    if strategy in RANDOM_STRATEGIES or strategy == "OPT":
        for pos, q, _ in wave:
            out[pos] = select_attribute(
                strategy, engine._select_key(q), q, db, engine.n_ranges,
                sample_cache=engine.samples, theta=engine.theta, cfg=engine.cfg,
                ranges_for=lambda a, t=q.table: engine.ranges_for(t, a),
                catalog=engine.catalog, aqr_cache=engine.aqr,
                selection=engine.selection,
                selection_cache=engine.selection_cache,
            )
        return out

    sel_cfg = engine.selection
    specs: List[EstimationSpec] = []
    # Parallel to ``specs``: (selection-cache key or None, member positions).
    spec_assign: List[Tuple[Optional[Tuple], List[int]]] = []
    groups: Dict[Tuple, List[Tuple[int, Query]]] = {}
    for pos, q, _ in wave:
        groups.setdefault(exec_group_key(q), []).append((pos, q))
    for members in groups.values():
        # Bucket members by selection-cache key: members sharing a key share
        # one pool + pre-filter + estimate pass and one memoized result —
        # exactly what a sequential replay does (first member computes, the
        # rest hit the SelectionCache).  With the cache disabled
        # (paper-faithful) every member is its own bucket.
        buckets: Dict[Tuple, List[Tuple[int, Query]]] = {}
        order: List[Tuple] = []
        for pos, q in members:
            bk = (selection_cache_key(strategy, q, db[q.table], engine.theta,
                                      engine.n_ranges)
                  if sel_cfg.cache else ("pos", pos))
            if bk not in buckets:
                buckets[bk] = []
                order.append(bk)
            buckets[bk].append((pos, q))
        pending: List[Tuple[Optional[Tuple], List[Tuple[int, Query]],
                            Tuple[str, ...]]] = []
        for bk in order:
            bmembers = buckets[bk]
            ck = bk if sel_cfg.cache else None
            if ck is not None:
                hit = engine.selection_cache.get(ck)
                if hit is not None:
                    for pos, _ in bmembers:
                        out[pos] = hit
                    continue
            q0 = bmembers[0][1]
            cands = candidate_pool(strategy, q0, db, engine.n_ranges,
                                   catalog=engine.catalog)
            if sel_cfg.stats_prefilter:
                cands = stats_prefilter(
                    q0, db, cands,
                    lambda a, t=q0.table: engine.ranges_for(t, a),
                    catalog=engine.catalog)
            if not cands:
                res = SelectionResult(strategy, None, cands, {})
            elif sel_cfg.skip_single_candidate and len(cands) == 1:
                res = SelectionResult(strategy, cands[0], cands, {},
                                      topk=cands)
            else:
                pending.append((ck, bmembers, cands))
                continue
            if ck is not None:
                engine.selection_cache.put(ck, res)
            for pos, _ in bmembers:
                out[pos] = res
        if not pending:
            continue
        # The sample/AQR key is the first member that actually reaches the
        # sampling code — cache hits, empty pools and single-candidate
        # shortcuts never do, so the first *pending* bucket's lead query is
        # what a sequential replay would sample with.
        q0 = pending[0][1][0][1]
        k_s, k_e = jax.random.split(engine._select_key(q0))
        samples = engine.samples.get_or_create(
            k_s, db[q0.table], q0.groupby_on_fact(db), engine.theta)
        est, sampled = engine.aqr.get_or_compute(
            k_e, q0, db, samples, engine.theta, engine.cfg)
        for ck, bmembers, cands in pending:
            bq = bmembers[0][1]
            specs.append(EstimationSpec(
                q=bq, samples=samples,
                ranges_by_attr={a: engine.ranges_for(bq.table, a)
                                for a in cands},
                aqr=(est, satisfied_groups(bq, est, sampled)),
            ))
            spec_assign.append((ck, [pos for pos, _ in bmembers]))
    if specs:
        all_estimates = estimate_size_multi(db, specs, engine.cfg, engine.catalog)
        for spec, (ck, positions), estimates in zip(specs, spec_assign,
                                                    all_estimates):
            # Tuple tie-break (attr name second): must match the sequential
            # path in strategies.select_attribute, or batched admission and
            # replay pick different winners at equal estimates.
            ranking = tuple(sorted(estimates,
                                   key=lambda a: (estimates[a].est_rows, a)))
            res = SelectionResult(
                strategy, ranking[0], tuple(spec.ranges_by_attr), estimates,
                topk=ranking[:1])
            if ck is not None:
                engine.selection_cache.put(ck, res)
            for pos in positions:
                out[pos] = res
    return out


def admit_wave(
    engine: "PBDSEngine", wave: List[Miss]
) -> Dict[int, Tuple[QueryResult, "RunInfo"]]:
    """Run one wave of misses through the shared pipeline; returns per-batch-
    position ``(result, info)`` exactly like ``PBDSEngine.run`` would."""
    from repro.core.engine import RunInfo
    from repro.core.maintenance import SketchMaintainer

    catalog = engine.catalog
    out: Dict[int, Tuple[QueryResult, RunInfo]] = {}
    probe_s = {pos: tp for pos, _, tp in wave}

    t0 = time.perf_counter()
    sels = _select_wave(engine, wave)
    t_select_each = (time.perf_counter() - t0) / max(len(wave), 1)

    # Worth-it partition — ``PBDSEngine._worth_it``, the same rule as ``run``
    # including the reuse-aware discount.  Misses are logged in wave order
    # with their *reserved* batch-position stamps, so ``reach`` sees exactly
    # the prefix a sequential replay would.  One carve-out: a miss deferred
    # to a later wave is recorded after this wave's decisions, so a wave
    # member at a higher batch position cannot count it — this can only
    # shift a decision at a worth-it boundary under non-default weights
    # (under the default weight, first-miss admission does not depend on the
    # reach magnitude).
    reuse = engine.selection.reuse_aware and engine.strategy != "NO-PS"
    admitted: Dict[int, object] = {}  # pos -> RangeSet of the chosen attr
    for pos, q, _ in wave:
        stamp = (engine.workload.record(q, stamp=engine.workload.batch_stamp(pos))
                 if reuse else None)
        if engine._worth_it(sels[pos], q, stamp):
            admitted[pos] = engine.ranges_for(q.table, sels[pos].attr)

    # Physical re-layout happens before the shared scans, mirroring the
    # sequential order (select -> cluster -> capture).
    for pos, q, _ in wave:
        if pos in admitted:
            engine._maybe_cluster(q.table, admitted[pos])
    db = engine.db  # clustering may have replaced tables

    # One inner-block evaluation per signature group feeds every member's
    # result and, for admitted members, the provenance its sketch captures.
    exec_groups: Dict[Tuple, List[Tuple[int, Query]]] = {}
    for pos, q, _ in wave:
        exec_groups.setdefault(exec_group_key(q), []).append((pos, q))
    results: Dict[int, QueryResult] = {}
    provs: Dict[int, np.ndarray] = {}
    t_exec: Dict[int, float] = {}
    for members in exec_groups.values():
        te0 = time.perf_counter()
        ib = inner_block(db, members[0][1], catalog)
        ib_share = (time.perf_counter() - te0) / len(members)
        n_fact = db[members[0][1].table].num_rows
        for pos, q in members:
            tq0 = time.perf_counter()
            results[pos] = result_from_group_state(
                q, ib.group_values, ib.agg_np, ib.present)
            if pos in admitted:
                provs[pos] = provenance_from_inner(q, ib, n_fact)
            t_exec[pos] = ib_share + (time.perf_counter() - tq0)

    # Fused capture: one bucketize + one batched bitmap launch per partition.
    adm_pos = [pos for pos, _, _ in wave if pos in admitted]
    t_capture: Dict[int, float] = {pos: 0.0 for pos in adm_pos}
    sketches: Dict[int, object] = {}
    if adm_pos:
        q_of = {pos: q for pos, q, _ in wave}
        tc0 = time.perf_counter()
        sk_list = capture_sketches_batch(
            [q_of[pos] for pos in adm_pos], db,
            [admitted[pos] for pos in adm_pos],
            [provs[pos] for pos in adm_pos], catalog=catalog)
        cap_share = (time.perf_counter() - tc0) / len(adm_pos)
        sketches = dict(zip(adm_pos, sk_list))

        # Maintainer counting state is HAVING-independent: build once per
        # (signature group, partition), clone for the rest of the group.
        bases: Dict[Tuple, SketchMaintainer] = {}
        for pos in adm_pos:
            q, ranges, sketch = q_of[pos], admitted[pos], sketches[pos]
            tm0 = time.perf_counter()
            bk = (exec_group_key(q), ranges.key())
            base = bases.get(bk)
            if base is None:
                maintainer = SketchMaintainer(q, db, ranges, catalog)
                bases[bk] = maintainer
            else:
                maintainer = base.clone_for(q, db, catalog)
            engine.index.insert(q, sketch, maintainer=maintainer)
            # Warm the reuse path while we are already paying capture cost
            # (instance materialization + compiled shapes), same as ``run``.
            execute(q, apply_sketch(sketch, db, catalog=catalog), catalog=catalog)
            t_capture[pos] = cap_share + (time.perf_counter() - tm0)

    for pos, q, _ in wave:
        sel = sels[pos]
        if pos in sketches:
            sketch = sketches[pos]
            info = RunInfo(
                reused=False, created=True, attr=sel.attr,
                strategy=engine.strategy, selectivity=sketch.selectivity,
                t_probe=probe_s[pos], t_select=t_select_each,
                t_capture=t_capture[pos], t_execute=t_exec[pos],
            )
        else:
            info = RunInfo(
                reused=False, created=False, attr=None,
                strategy=engine.strategy, selectivity=None,
                t_probe=probe_s[pos], t_select=t_select_each,
                t_execute=t_exec[pos],
            )
        out[pos] = (results[pos], info)
    return out
