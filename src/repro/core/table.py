"""Columnar tables: the storage substrate for provenance-based data skipping.

The paper's engine runs on Postgres heap tables; the TPU-native equivalent is a
struct-of-arrays ``ColumnTable`` whose columns are device-resident 1-D arrays.
Fragments of a range partition are *logical* row subsets; the fragment-major
physical layout (``sort_by``) makes a fragment a contiguous tile so that data
skipping maps to "do not issue the HBM->VMEM copy for this tile".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class FragmentLayout:
    """Physical fragment-major layout descriptor for a clustered table.

    After ``cluster_by(ranges)`` every fragment of the range partition is a
    contiguous row slice ``[offsets[f], offsets[f+1])``, so applying a sketch
    on the same partition degenerates to concatenating the surviving slices —
    no per-row filter scan.  Identity-hashed (``eq=False``) so it can ride in
    pytree aux data.
    """

    attr: str
    ranges_key: Tuple
    offsets: np.ndarray  # (n_fragments + 1,) row offsets, offsets[0] == 0

    @property
    def n_fragments(self) -> int:
        return int(self.offsets.shape[0]) - 1

    def matches(self, ranges) -> bool:
        return self.attr == ranges.attr and self.ranges_key == ranges.key()


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ColumnTable:
    """An immutable bag-semantics relation stored column-major.

    Attributes:
      name: relation name (static / aux data, not traced).
      columns: mapping attribute -> 1-D array; all columns share length.
      primary_key: attribute names forming the primary key (may be empty).
      layout: fragment-major physical layout, set by ``cluster_by`` (row-
        reordering operations drop it).
    """

    name: str
    columns: Dict[str, Array]
    primary_key: Tuple[str, ...] = ()
    layout: Optional[FragmentLayout] = None

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        keys = tuple(sorted(self.columns))
        children = tuple(self.columns[k] for k in keys)
        aux = (self.name, keys, self.primary_key, self.layout)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        name, keys, pk, layout = aux
        return cls(name=name, columns=dict(zip(keys, children)), primary_key=pk,
                   layout=layout)

    # -- basic accessors -----------------------------------------------------
    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return int(next(iter(self.columns.values())).shape[0])

    @property
    def schema(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def __getitem__(self, attr: str) -> Array:
        return self.columns[attr]

    def has(self, attr: str) -> bool:
        return attr in self.columns

    # -- functional updates ----------------------------------------------------
    def with_column(self, attr: str, values: Array) -> "ColumnTable":
        cols = dict(self.columns)
        cols[attr] = values
        # Row order is unchanged, so the physical layout survives.
        return ColumnTable(self.name, cols, self.primary_key, self.layout)

    def select(self, mask: Array) -> "ColumnTable":
        """Keep rows where ``mask`` is True (host-side compaction)."""
        idx = jnp.nonzero(np.asarray(mask))[0]
        return self.gather(idx)

    def gather(self, idx: Array) -> "ColumnTable":
        return ColumnTable(
            self.name,
            {k: jnp.take(v, idx, axis=0) for k, v in self.columns.items()},
            self.primary_key,
        )

    def sort_by(self, attrs: Sequence[str]) -> "ColumnTable":
        """Physically order rows by ``attrs``."""
        keys = [np.asarray(self.columns[a]) for a in reversed(list(attrs))]
        order = np.lexsort(keys)
        return self.gather(jnp.asarray(order))

    def cluster_by(self, ranges) -> "ColumnTable":
        """Fragment-major physical layout for a range partition.

        Rows are stably reordered by fragment id so fragment ``f`` occupies
        the contiguous slice ``[offsets[f], offsets[f+1])``; the resulting
        ``FragmentLayout`` makes sketch application a concatenation of the
        surviving slices (see ``repro.core.sketch.apply_sketch``).
        """
        bucket = np.asarray(ranges.bucketize(self[ranges.attr]))
        order = np.argsort(bucket, kind="stable")
        counts = np.bincount(bucket, minlength=ranges.n_ranges)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        clustered = self.gather(jnp.asarray(order))
        layout = FragmentLayout(attr=ranges.attr, ranges_key=ranges.key(), offsets=offsets)
        return ColumnTable(self.name, clustered.columns, self.primary_key, layout)

    def take_fragments(self, frag_ids: np.ndarray) -> "ColumnTable":
        """Concatenate the given fragments' contiguous slices (clustered only)."""
        if self.layout is None:
            raise ValueError(f"{self.name}: take_fragments needs a clustered table")
        off = self.layout.offsets
        frag_ids = np.asarray(frag_ids)
        if frag_ids.size:
            idx = np.concatenate([np.arange(off[f], off[f + 1]) for f in frag_ids])
        else:
            idx = np.empty(0, dtype=np.int64)
        return self.gather(jnp.asarray(idx))

    def head(self, n: int) -> "ColumnTable":
        return ColumnTable(
            self.name,
            {k: v[:n] for k, v in self.columns.items()},
            self.primary_key,
        )

    def to_numpy(self) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.columns.items()}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ColumnTable({self.name!r}, rows={self.num_rows}, cols={list(self.schema)})"


def from_numpy(
    name: str,
    data: Mapping[str, np.ndarray],
    primary_key: Iterable[str] = (),
) -> ColumnTable:
    cols = {k: jnp.asarray(v) for k, v in data.items()}
    lengths = {k: int(v.shape[0]) for k, v in cols.items()}
    if len(set(lengths.values())) > 1:
        raise ValueError(f"ragged columns: {lengths}")
    return ColumnTable(name, cols, tuple(primary_key))


@dataclasses.dataclass(frozen=True)
class Database:
    """A named collection of tables (the ``D`` of the paper)."""

    tables: Dict[str, ColumnTable]

    def __getitem__(self, name: str) -> ColumnTable:
        return self.tables[name]

    def with_table(self, table: ColumnTable) -> "Database":
        t = dict(self.tables)
        t[table.name] = table
        return Database(t)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.tables))


def encode_groups(
    table: ColumnTable, attrs: Sequence[str]
) -> Tuple[np.ndarray, int, Dict[str, np.ndarray]]:
    """Dictionary-encode the group-by key.

    Returns ``(gid, n_groups, group_values)`` where ``gid[i]`` is the dense
    group id of row ``i`` and ``group_values[a][g]`` is the value of attribute
    ``a`` for group ``g``.  Host-side (``np.unique``), mirroring the catalog /
    dictionary structures a DBMS maintains; the per-row heavy lifting stays on
    device.
    """
    if not attrs:
        n = table.num_rows
        return np.zeros(n, dtype=np.int32), 1, {}
    stacked = np.stack([np.asarray(table[a]) for a in attrs], axis=1)
    uniq, gid = np.unique(stacked, axis=0, return_inverse=True)
    group_values = {a: uniq[:, i] for i, a in enumerate(attrs)}
    return gid.astype(np.int32), int(uniq.shape[0]), group_values
