"""Columnar tables: the storage substrate for provenance-based data skipping.

The paper's engine runs on Postgres heap tables; the TPU-native equivalent is a
struct-of-arrays ``ColumnTable`` whose columns are device-resident 1-D arrays.
Fragments of a range partition are *logical* row subsets; the fragment-major
physical layout (``sort_by``) makes a fragment a contiguous tile so that data
skipping maps to "do not issue the HBM->VMEM copy for this tile".
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Monotone lineage ids: every freshly created relation gets a new uid; append/
# delete/cluster_by preserve it while bumping (or keeping) the version token,
# so caches and sketch maintainers can tell "same relation, newer contents"
# apart from "a different relation entirely".
_TABLE_UIDS = itertools.count(1)

# Reserved column marking pow2-padded tables (sketch instances): True for
# real rows, False for the shape-pinning tail.  The executor folds it into
# the aggregation weights so padded and unpadded execution agree bit-for-bit.
PAD_VALID = "__valid__"


def _bucketize_np(bounds: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Host-side fragment ids with ``RangeSet.bucketize``'s exact comparison
    semantics: ``jnp.searchsorted`` compares in float32 when x64 is disabled,
    so a float64 ``np.searchsorted`` could place boundary-adjacent values in
    a different fragment than every cached bucketization and sketch bit in
    the system.  All host-side tail bucketing must go through here."""
    return np.searchsorted(bounds.astype(np.float32),
                           np.asarray(values).astype(np.float32), side="right")


@dataclasses.dataclass(frozen=True, eq=False)
class FragmentLayout:
    """Physical fragment-major layout descriptor for a clustered table.

    After ``cluster_by(ranges)`` every fragment of the range partition is a
    contiguous row slice ``[offsets[f], offsets[f+1])``, so applying a sketch
    on the same partition degenerates to concatenating the surviving slices —
    no per-row filter scan.  Identity-hashed (``eq=False``) so it can ride in
    pytree aux data.

    ``tail`` is the number of trailing rows *not* covered by ``offsets``:
    appends land in an unsorted tail region so a batch insert does not force
    a physical re-cluster.  Sketch application then concatenates the prefix
    slices and filters only the tail rows (delta-sized work).
    """

    attr: str
    ranges_key: Tuple
    offsets: np.ndarray  # (n_fragments + 1,) row offsets, offsets[0] == 0
    tail: int = 0

    @property
    def n_fragments(self) -> int:
        return int(self.offsets.shape[0]) - 1

    def matches(self, ranges) -> bool:
        return self.attr == ranges.attr and self.ranges_key == ranges.key()

    def bounds(self) -> np.ndarray:
        """The partition's interior split points, recovered from the key.

        ``ranges_key`` is ``RangeSet.key() == (attr, n_ranges, bounds bytes)``;
        round-tripping the bytes lets layout-only consumers (tail bucketing in
        ``take_fragments``, ``compact``) re-bucketize appended rows without
        threading the original ``RangeSet`` through every call site.
        """
        bounds = np.frombuffer(self.ranges_key[2], dtype=np.float64)
        if bounds.shape[0] != self.ranges_key[1] - 1:
            raise ValueError("layout ranges_key does not hold float64 bounds")
        return bounds


@dataclasses.dataclass(frozen=True, eq=False)
class TableDelta:
    """One append/delete step linking a table version to its parent.

    The delta is what makes incremental maintenance possible: catalog caches
    refresh themselves from the parent entry plus the delta (no full-table
    re-encode / re-bucketize), and ``repro.core.maintenance`` re-ORs sketch
    bits only for touched fragments.  ``parent`` is a strong reference so
    id()-keyed parent cache entries stay valid while the delta is reachable.
    """

    kind: str  # 'append' | 'delete'
    parent: "ColumnTable"
    appended: Optional["ColumnTable"] = None  # kind='append': the new rows
    deleted_idx: Optional[np.ndarray] = None  # kind='delete': parent rows removed
    kept_idx: Optional[np.ndarray] = None  # kind='delete': parent rows kept

    @property
    def n_delta(self) -> int:
        if self.kind == "append":
            return self.appended.num_rows
        return int(self.deleted_idx.shape[0])


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ColumnTable:
    """An immutable bag-semantics relation stored column-major.

    Attributes:
      name: relation name (static / aux data, not traced).
      columns: mapping attribute -> 1-D array; all columns share length.
      primary_key: attribute names forming the primary key (may be empty).
      layout: fragment-major physical layout, set by ``cluster_by`` (row-
        reordering operations drop it; appends push rows into its tail).
      version: monotone per-lineage version token, bumped by append/delete.
      uid: lineage identity — preserved by append/delete/cluster_by, fresh
        for any other derived table (gather/select/head/...).
      delta: the append/delete step that produced this version (None for a
        root table); the hook for incremental catalog refresh + maintenance.
    """

    name: str
    columns: Dict[str, Array]
    primary_key: Tuple[str, ...] = ()
    layout: Optional[FragmentLayout] = None
    version: int = 0
    uid: int = 0
    delta: Optional[TableDelta] = None

    def __post_init__(self):
        if self.uid == 0:
            object.__setattr__(self, "uid", next(_TABLE_UIDS))

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        keys = tuple(sorted(self.columns))
        children = tuple(self.columns[k] for k in keys)
        aux = (self.name, keys, self.primary_key, self.layout, self.version,
               self.uid, self.delta)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        name, keys, pk, layout, version, uid, delta = aux
        return cls(name=name, columns=dict(zip(keys, children)), primary_key=pk,
                   layout=layout, version=version, uid=uid, delta=delta)

    # -- basic accessors -----------------------------------------------------
    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return int(next(iter(self.columns.values())).shape[0])

    @property
    def schema(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def __getitem__(self, attr: str) -> Array:
        return self.columns[attr]

    def has(self, attr: str) -> bool:
        return attr in self.columns

    # -- functional updates ----------------------------------------------------
    def with_column(self, attr: str, values: Array) -> "ColumnTable":
        cols = dict(self.columns)
        cols[attr] = values
        # Row order is unchanged, so the physical layout survives.
        return ColumnTable(self.name, cols, self.primary_key, self.layout)

    def select(self, mask: Array) -> "ColumnTable":
        """Keep rows where ``mask`` is True (host-side compaction)."""
        idx = jnp.nonzero(np.asarray(mask))[0]
        return self.gather(idx)

    def gather(self, idx: Array) -> "ColumnTable":
        return ColumnTable(
            self.name,
            {k: jnp.take(v, idx, axis=0) for k, v in self.columns.items()},
            self.primary_key,
        )

    def sort_by(self, attrs: Sequence[str]) -> "ColumnTable":
        """Physically order rows by ``attrs``."""
        keys = [np.asarray(self.columns[a]) for a in reversed(list(attrs))]
        order = np.lexsort(keys)
        return self.gather(jnp.asarray(order))

    def cluster_by(self, ranges) -> "ColumnTable":
        """Fragment-major physical layout for a range partition.

        Rows are stably reordered by fragment id so fragment ``f`` occupies
        the contiguous slice ``[offsets[f], offsets[f+1])``; the resulting
        ``FragmentLayout`` makes sketch application a concatenation of the
        surviving slices (see ``repro.core.sketch.apply_sketch``).
        """
        bucket = np.asarray(ranges.bucketize(self[ranges.attr]))
        order = np.argsort(bucket, kind="stable")
        counts = np.bincount(bucket, minlength=ranges.n_ranges)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        clustered = self.gather(jnp.asarray(order))
        layout = FragmentLayout(attr=ranges.attr, ranges_key=ranges.key(), offsets=offsets)
        # Same relation contents, physically permuted: lineage and version
        # survive (sketch/maintainer state is permutation-invariant) but the
        # delta chain does not — parent row positions no longer line up.
        return ColumnTable(self.name, clustered.columns, self.primary_key, layout,
                           version=self.version, uid=self.uid)

    def take_fragments(
        self, frag_ids: np.ndarray, tail_bucket: Optional[np.ndarray] = None,
        return_rows: bool = False,
    ):
        """Concatenate the given fragments' contiguous slices (clustered only).

        Appended rows live in the layout's unsorted ``tail``; they are
        bucket-filtered individually (delta-sized work) against ``frag_ids``
        rather than invalidating the slice path.  ``tail_bucket`` — the tail
        rows' fragment ids — may be passed in when the caller holds a cached
        (delta-refreshed) bucketization; otherwise it is recomputed here from
        the layout's own bounds.  With ``return_rows`` the selected source
        row indices are returned alongside (the catalog's instance-encoding
        derivation needs the subset map).
        """
        if self.layout is None:
            raise ValueError(f"{self.name}: take_fragments needs a clustered table")
        lay = self.layout
        frag_ids = np.asarray(frag_ids)
        off = lay.offsets
        parts = [np.arange(off[f], off[f + 1]) for f in frag_ids]
        if lay.tail:
            n = self.num_rows
            if tail_bucket is None:
                tail_vals = np.asarray(self[lay.attr])[n - lay.tail:]
                tail_bucket = _bucketize_np(lay.bounds(), tail_vals)
            tail_bucket = np.asarray(tail_bucket)
            if tail_bucket.shape[0] != lay.tail:
                raise ValueError(
                    f"tail_bucket has {tail_bucket.shape[0]} entries for a "
                    f"{lay.tail}-row tail")
            keep = np.zeros(lay.n_fragments, dtype=bool)
            keep[frag_ids] = True
            parts.append(np.arange(n - lay.tail, n)[keep[tail_bucket]])
        idx = np.concatenate(parts) if parts else np.empty(0, dtype=np.int64)
        out = self.gather(jnp.asarray(idx))
        return (out, idx) if return_rows else out

    def compact(self) -> "ColumnTable":
        """Fold the layout's unsorted tail back into fragment-major order.

        Same contents, lineage and version (a physical permutation, like
        ``cluster_by``), with ``tail == 0`` afterwards so sketch application
        is pure slice concatenation again.  Row-position caches (samples,
        bucketizations, instances) must be invalidated by the caller — the
        delta chain is dropped for the same reason as in ``cluster_by``.
        """
        lay = self.layout
        if lay is None or lay.tail == 0:
            return self.collapse()
        n = self.num_rows
        tail_rows = np.arange(n - lay.tail, n)
        tail_vals = np.asarray(self[lay.attr])[tail_rows]
        tail_bucket = _bucketize_np(lay.bounds(), tail_vals)
        order_t = np.argsort(tail_bucket, kind="stable")
        # Merge each tail run into its fragment, after the existing rows
        # (stable: prefix rows keep their relative order, tail rows append).
        tail_counts = np.bincount(tail_bucket, minlength=lay.n_fragments)
        new_offsets = np.concatenate(
            [[0], np.cumsum(np.diff(lay.offsets) + tail_counts)]).astype(np.int64)
        parts = []
        t_off = np.concatenate([[0], np.cumsum(tail_counts)])
        for f in range(lay.n_fragments):
            parts.append(np.arange(lay.offsets[f], lay.offsets[f + 1]))
            parts.append(tail_rows[order_t[t_off[f]:t_off[f + 1]]])
        idx = np.concatenate(parts)
        compacted = self.gather(jnp.asarray(idx))
        layout = FragmentLayout(attr=lay.attr, ranges_key=lay.ranges_key,
                                offsets=new_offsets)
        return ColumnTable(self.name, compacted.columns, self.primary_key, layout,
                           version=self.version, uid=self.uid)

    # -- mutations (delta-aware) ----------------------------------------------
    def delta_depth(self) -> int:
        """Length of the delta chain behind this version."""
        depth, t = 0, self
        while t.delta is not None:
            depth += 1
            t = t.delta.parent
        return depth

    def collapse(self) -> "ColumnTable":
        """Drop the delta history: same contents, version and lineage, no
        parent references.  Bounds memory — every prior version's columns are
        pinned by the chain — at the cost of one full-cache rebuild for
        consumers that would have delta-refreshed (see
        ``PBDSEngine.max_delta_chain``)."""
        if self.delta is None:
            return self
        return ColumnTable(self.name, self.columns, self.primary_key, self.layout,
                           version=self.version, uid=self.uid)

    def append(self, rows: Mapping[str, np.ndarray]) -> "ColumnTable":
        """Append a batch of rows, producing the next table version.

        The new version carries a ``TableDelta`` so catalog entries and
        provenance sketches refresh from the batch alone.  A fragment-major
        layout survives: the batch lands in the layout's unsorted ``tail``
        region rather than forcing a physical re-cluster.
        """
        if set(rows) != set(self.columns):
            raise ValueError(
                f"append schema mismatch: {sorted(rows)} vs {sorted(self.columns)}")
        batch = {}
        for k, v in rows.items():
            src = np.asarray(v)
            dst = src.astype(self.columns[k].dtype)
            # Reject lossy coercion at the mutation boundary: silently
            # truncated/wrapped values would flow through every maintained
            # aggregate undetectably.
            if not np.array_equal(dst.astype(np.float64), src.astype(np.float64),
                                  equal_nan=True):
                raise ValueError(
                    f"append column {k!r}: lossy cast {src.dtype} -> "
                    f"{self.columns[k].dtype}")
            batch[k] = jnp.asarray(dst)
        lengths = {int(v.shape[0]) for v in batch.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged append batch: { {k: int(v.shape[0]) for k, v in batch.items()} }")
        n_new = lengths.pop()
        appended = ColumnTable(self.name, batch, self.primary_key)
        cols = {k: jnp.concatenate([self.columns[k], batch[k]]) for k in self.columns}
        layout = (dataclasses.replace(self.layout, tail=self.layout.tail + n_new)
                  if self.layout is not None else None)
        return ColumnTable(
            self.name, cols, self.primary_key, layout,
            version=self.version + 1, uid=self.uid,
            delta=TableDelta(kind="append", parent=self, appended=appended),
        )

    def delete(self, mask: np.ndarray) -> "ColumnTable":
        """Delete the rows where ``mask`` is True, producing the next version.

        Compaction preserves relative row order, so a fragment-major layout
        survives with shrunk offsets (per-fragment deletion counts follow from
        the offsets themselves — no re-bucketization).
        """
        mask = np.asarray(mask, dtype=bool)
        if mask.shape[0] != self.num_rows:
            raise ValueError(f"delete mask length {mask.shape[0]} != {self.num_rows} rows")
        deleted_idx = np.nonzero(mask)[0]
        kept_idx = np.nonzero(~mask)[0]
        cols = {k: jnp.take(v, jnp.asarray(kept_idx), axis=0) for k, v in self.columns.items()}
        layout = None
        if self.layout is not None:
            lay = self.layout
            prefix_len = self.num_rows - lay.tail
            del_prefix = deleted_idx[deleted_idx < prefix_len]
            frag_of_deleted = np.searchsorted(lay.offsets, del_prefix, side="right") - 1
            counts = np.diff(lay.offsets) - np.bincount(
                frag_of_deleted, minlength=lay.n_fragments)
            offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
            tail = lay.tail - int((deleted_idx >= prefix_len).sum())
            layout = FragmentLayout(attr=lay.attr, ranges_key=lay.ranges_key,
                                    offsets=offsets, tail=tail)
        return ColumnTable(
            self.name, cols, self.primary_key, layout,
            version=self.version + 1, uid=self.uid,
            delta=TableDelta(kind="delete", parent=self,
                             deleted_idx=deleted_idx, kept_idx=kept_idx),
        )

    def head(self, n: int) -> "ColumnTable":
        return ColumnTable(
            self.name,
            {k: v[:n] for k, v in self.columns.items()},
            self.primary_key,
        )

    def to_numpy(self) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.columns.items()}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ColumnTable({self.name!r}, rows={self.num_rows}, cols={list(self.schema)})"


def from_numpy(
    name: str,
    data: Mapping[str, np.ndarray],
    primary_key: Iterable[str] = (),
) -> ColumnTable:
    cols = {k: jnp.asarray(v) for k, v in data.items()}
    lengths = {k: int(v.shape[0]) for k, v in cols.items()}
    if len(set(lengths.values())) > 1:
        raise ValueError(f"ragged columns: {lengths}")
    return ColumnTable(name, cols, tuple(primary_key))


@dataclasses.dataclass(frozen=True)
class Database:
    """A named collection of tables (the ``D`` of the paper)."""

    tables: Dict[str, ColumnTable]

    def __getitem__(self, name: str) -> ColumnTable:
        return self.tables[name]

    def with_table(self, table: ColumnTable) -> "Database":
        t = dict(self.tables)
        t[table.name] = table
        return Database(t)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.tables))


def encode_groups(
    table: ColumnTable, attrs: Sequence[str]
) -> Tuple[np.ndarray, int, Dict[str, np.ndarray]]:
    """Dictionary-encode the group-by key.

    Returns ``(gid, n_groups, group_values)`` where ``gid[i]`` is the dense
    group id of row ``i`` and ``group_values[a][g]`` is the value of attribute
    ``a`` for group ``g``.  Host-side (``np.unique``), mirroring the catalog /
    dictionary structures a DBMS maintains; the per-row heavy lifting stays on
    device.
    """
    if not attrs:
        n = table.num_rows
        return np.zeros(n, dtype=np.int32), 1, {}
    stacked = np.stack([np.asarray(table[a]) for a in attrs], axis=1)
    uniq, gid = np.unique(stacked, axis=0, return_inverse=True)
    group_values = {a: uniq[:, i] for i, a in enumerate(attrs)}
    return gid.astype(np.int32), int(uniq.shape[0]), group_values
