"""Attribute safety (Def. 5) — the static pre-filter for sketch candidates.

Following [32] we use a sufficient condition.  For the supported templates a
range partition on attribute ``a`` is safe when either:

  1. ``a`` is a group-by attribute of the (inner) block: every group lies
     entirely inside one fragment, so groups present in the sketch instance
     are *complete* and aggregate exactly as over D; or
  2. the HAVING chain is *upward monotone* (>, >= thresholds) and the
     aggregate is monotone under row removal (COUNT, or SUM over non-negative
     values): partially-present non-provenance groups can only shrink, so
     they cannot spuriously pass the HAVING filter.

Additionally (Sec. 9) candidates whose distinct-value count is below the
number of ranges are pre-filtered: such partitions degenerate (several ranges
map to one value) and [32]'s safety argument needs value-aligned bounds.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.catalog import Catalog, default_catalog
from repro.core.queries import Query
from repro.core.table import Database


def _having_upward_monotone(q: Query) -> bool:
    ops_ok = {">", ">="}
    if q.having is not None and q.having.op not in ops_ok:
        return False
    if q.outer_having is not None and q.outer_having.op not in ops_ok:
        return False
    return True


def _agg_monotone(q: Query, db: Database, catalog: Catalog) -> bool:
    aggs = [q.agg] + ([q.outer_agg] if q.outer_agg else [])
    for agg in aggs:
        if agg.fn == "count":
            continue
        if agg.fn == "avg":
            return False  # partial AVG can move either way
        if agg.fn == "sum":
            if not db[q.table].has(agg.attr):
                return False
            if not catalog.column_nonnegative(db[q.table], agg.attr):
                return False
    return True


def monotone_safe(q: Query, db: Database, catalog: Optional[Catalog] = None) -> bool:
    """Upward-monotone HAVING chain + removal-monotone aggregates.

    This is the condition under which row removal can only shrink a group's
    aggregate (and row insertion only grow it), so a maintained sketch may
    *clear* bits on group flips without risking an unsafe (subset) sketch —
    see ``repro.core.maintenance``.

    Slightly sharper than ``_agg_monotone``: the nested templates' outer
    ``sum`` over the *inner aggregate values* (attr=None) is monotone whenever
    those inner values are guaranteed non-negative (COUNT, or SUM of a
    non-negative column) — ``_agg_monotone`` has no notion of a None attr and
    stays conservative there to keep ``safe_attributes`` unchanged.
    """
    catalog = catalog or default_catalog()
    if not _having_upward_monotone(q):
        return False
    fact = db[q.table]

    def col_nonneg(attr: Optional[str]) -> bool:
        return (attr is not None and fact.has(attr)
                and catalog.column_nonnegative(fact, attr))

    if q.agg.fn == "avg":
        return False
    if q.agg.fn == "sum" and not col_nonneg(q.agg.attr):
        return False
    inner_nonneg = q.agg.fn == "count" or col_nonneg(q.agg.attr)
    if q.outer_agg is not None:
        if q.outer_agg.fn == "avg":
            return False
        if q.outer_agg.fn == "sum":
            if q.outer_agg.attr is None:
                if not inner_nonneg:
                    return False
            elif not col_nonneg(q.outer_agg.attr):
                return False
    return True


def safe_attributes(
    q: Query, db: Database, catalog: Optional[Catalog] = None
) -> Tuple[str, ...]:
    """SAFE(Q) restricted to the sketched (fact) relation's schema."""
    catalog = catalog or default_catalog()
    fact = db[q.table]
    gb_on_fact = tuple(a for a in q.groupby if fact.has(a))
    if _having_upward_monotone(q) and _agg_monotone(q, db, catalog):
        return tuple(sorted(fact.schema))
    return gb_on_fact


def prefilter_candidates(
    q: Query,
    db: Database,
    candidates: Tuple[str, ...],
    n_ranges: int,
    catalog: Optional[Catalog] = None,
) -> Tuple[str, ...]:
    """Drop candidates with fewer distinct values than ranges (Sec. 9).

    Group-by attributes are exempt: they are safe by the whole-group argument
    no matter how coarse the (deduplicated) partition ends up, and the paper's
    own experiments sketch low-cardinality GB attributes (e.g. ``district``).
    Distinct counts are catalog-cached, so the pre-filter scans each column
    once per table lifetime rather than once per query.
    """
    catalog = catalog or default_catalog()
    fact = db[q.table]
    out = []
    for a in candidates:
        if not fact.has(a):
            continue
        if a in q.groupby or catalog.distinct_count(fact, a) >= n_ranges:
            out.append(a)
    return tuple(out)
