"""Attribute safety (Def. 5) — the static pre-filter for sketch candidates.

Following [32] we use a sufficient condition.  For the supported templates a
range partition on attribute ``a`` is safe when either:

  1. ``a`` is a group-by attribute of the (inner) block: every group lies
     entirely inside one fragment, so groups present in the sketch instance
     are *complete* and aggregate exactly as over D; or
  2. the HAVING chain is *upward monotone* (>, >= thresholds) and the
     aggregate is monotone under row removal (COUNT, or SUM over non-negative
     values): partially-present non-provenance groups can only shrink, so
     they cannot spuriously pass the HAVING filter.

Additionally (Sec. 9) candidates whose distinct-value count is below the
number of ranges are pre-filtered: such partitions degenerate (several ranges
map to one value) and [32]'s safety argument needs value-aligned bounds.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

from repro.core.catalog import Catalog, default_catalog
from repro.core.queries import Query
from repro.core.table import Database


def _having_upward_monotone(q: Query) -> bool:
    ops_ok = {">", ">="}
    if q.having is not None and q.having.op not in ops_ok:
        return False
    if q.outer_having is not None and q.outer_having.op not in ops_ok:
        return False
    return True


def _agg_monotone(q: Query, db: Database, catalog: Catalog) -> bool:
    aggs = [q.agg] + ([q.outer_agg] if q.outer_agg else [])
    for agg in aggs:
        if agg.fn == "count":
            continue
        if agg.fn == "avg":
            return False  # partial AVG can move either way
        if agg.fn == "sum":
            if not db[q.table].has(agg.attr):
                return False
            if not catalog.column_nonnegative(db[q.table], agg.attr):
                return False
    return True


def monotone_safe(q: Query, db: Database, catalog: Optional[Catalog] = None) -> bool:
    """Upward-monotone HAVING chain + removal-monotone aggregates.

    This is the condition under which row removal can only shrink a group's
    aggregate (and row insertion only grow it), so a maintained sketch may
    *clear* bits on group flips without risking an unsafe (subset) sketch —
    see ``repro.core.maintenance``.

    Slightly sharper than ``_agg_monotone``: the nested templates' outer
    ``sum`` over the *inner aggregate values* (attr=None) is monotone whenever
    those inner values are guaranteed non-negative (COUNT, or SUM of a
    non-negative column) — ``_agg_monotone`` has no notion of a None attr and
    stays conservative there to keep ``safe_attributes`` unchanged.
    """
    catalog = catalog or default_catalog()
    if not _having_upward_monotone(q):
        return False
    fact = db[q.table]

    def col_nonneg(attr: Optional[str]) -> bool:
        return (attr is not None and fact.has(attr)
                and catalog.column_nonnegative(fact, attr))

    if q.agg.fn == "avg":
        return False
    if q.agg.fn == "sum" and not col_nonneg(q.agg.attr):
        return False
    inner_nonneg = q.agg.fn == "count" or col_nonneg(q.agg.attr)
    if q.outer_agg is not None:
        if q.outer_agg.fn == "avg":
            return False
        if q.outer_agg.fn == "sum":
            if q.outer_agg.attr is None:
                if not inner_nonneg:
                    return False
            elif not col_nonneg(q.outer_agg.attr):
                return False
    return True


def safe_attributes(
    q: Query, db: Database, catalog: Optional[Catalog] = None
) -> Tuple[str, ...]:
    """SAFE(Q) restricted to the sketched (fact) relation's schema."""
    catalog = catalog or default_catalog()
    fact = db[q.table]
    gb_on_fact = tuple(a for a in q.groupby if fact.has(a))
    if _having_upward_monotone(q) and _agg_monotone(q, db, catalog):
        return tuple(sorted(fact.schema))
    return gb_on_fact


def prefilter_candidates(
    q: Query,
    db: Database,
    candidates: Tuple[str, ...],
    n_ranges: int,
    catalog: Optional[Catalog] = None,
) -> Tuple[str, ...]:
    """Drop candidates with fewer distinct values than ranges (Sec. 9).

    Group-by attributes are exempt: they are safe by the whole-group argument
    no matter how coarse the (deduplicated) partition ends up, and the paper's
    own experiments sketch low-cardinality GB attributes (e.g. ``district``).
    Distinct counts are catalog-cached, so the pre-filter scans each column
    once per table lifetime rather than once per query.
    """
    catalog = catalog or default_catalog()
    fact = db[q.table]
    out = []
    for a in candidates:
        if not fact.has(a):
            continue
        if a in q.groupby or catalog.distinct_count(fact, a) >= n_ranges:
            out.append(a)
    return tuple(out)


def stats_prefilter(
    q: Query,
    db: Database,
    candidates: Tuple[str, ...],
    ranges_for: Callable[[str], "object"],
    catalog: Optional[Catalog] = None,
) -> Tuple[str, ...]:
    """Summary-statistics dominance prune (PS3-style), before any sampling.

    For a fixed number of satisfied groups, a candidate's sketch covers the
    fragments those groups land in — so its size is bounded by (#covered
    fragments) x (fragment sizes).  A partition with *more* nonempty
    fragments whose largest and smallest nonempty fragments are both
    *smaller* (as fractions of the table) bounds every query's sketch no
    larger than a coarser partition does: the same group set touches at most
    as many rows.  Candidate ``a`` is pruned when some ``b`` dominates it on
    ``(n_nonempty >=, max_frac <=, min_frac <=)`` with at least one strict
    inequality — a product partial order, so maximal candidates always
    survive and the pool never empties.  Equi-depth partitions of two
    high-cardinality attributes tie on all three statistics and both survive
    (the AQR estimate pass ranks them); the prune bites on low-cardinality
    attributes whose deduplicated bounds collapse to few, fat fragments.

    All statistics come from catalog-cached fragment counts
    (``Catalog.frag_stats``): no sampling, no estimate launch.  Gated behind
    ``SelectionConfig.stats_prefilter`` — paper-faithful CB-OPT runs disable
    it and estimate every safe candidate.
    """
    if len(candidates) <= 1:
        return candidates
    catalog = catalog or default_catalog()
    fact = db[q.table]
    stats = {a: catalog.frag_stats(fact, ranges_for(a)) for a in candidates}

    def dominates(b: str, a: str) -> bool:
        nb, xb, mb = stats[b]
        na, xa, ma = stats[a]
        return (nb >= na and xb <= xa and mb <= ma
                and (nb > na or xb < xa or mb < ma))

    out = tuple(a for a in candidates
                if not any(b != a and dominates(b, a) for b in candidates))
    return out or candidates
