"""Device-resident query catalog: the per-query host work, done once.

The paper's premise is that sketch *application* is nearly free — skipping is
a scheduling decision, not a scan.  The seed executor violated that premise on
every query: group-by keys were re-dictionary-encoded on host (``np.unique``),
joins re-materialized (``np.argsort`` + searchsorted), partition attributes
re-bucketized, and each sketch application gathered a filtered copy of the
whole relation.  The ``Catalog`` is the DBMS-style fix: per table it caches

  * the dictionary encoding of every seen GROUP BY tuple (dense gids, host
    and device copies, plus per-group key values),
  * the bucketization vector of every candidate partition attribute under a
    given ``RangeSet`` (a device array reused by capture, application, and
    size estimation),
  * the materialized join layout per join spec (joined columns + the
    fact-row back-map),
  * per-sketch *instances* (the filtered relation D_P), so an index hit
    re-executes over an already-materialized fragment subset,
  * cheap per-attribute statistics (distinct counts, non-negativity) used by
    the safety pre-filter.

Tables are immutable, so entries are keyed by object identity with a strong
reference held for validity — replacing a table (e.g. after ``cluster_by``)
naturally invalidates its cached state.  ``stats`` counts cache misses (real
work) and hits, which the tests use to assert that a repeated workload does
zero host-side encode/argsort work.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import TYPE_CHECKING, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import ColumnTable, encode_groups

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.ranges import RangeSet

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GroupEncoding:
    """Cached dictionary encoding of one GROUP BY tuple on one table."""

    gid: np.ndarray  # dense group id per row (host)
    gid_dev: Array  # same, device-resident
    n_groups: int
    group_values: Dict[str, np.ndarray]  # per-group key values


class Catalog:
    """Cross-query cache of encodings, bucketizations, joins and instances.

    Every map is bounded FIFO (``max_entries`` per map): entries hold strong
    table references to keep their id() keys valid, so an unbounded cache
    would pin every table ever touched for the catalog's lifetime.  Replaced
    tables can be dropped eagerly with ``invalidate_table``.
    """

    def __init__(self, max_entries: int = 512):
        self.stats: collections.Counter = collections.Counter()
        self.max_entries = max_entries
        # All maps key by id() of the table(s) involved and keep a strong
        # reference to them, so ids stay valid while the entry lives.
        self._groups: Dict[Tuple[int, Tuple[str, ...]], Tuple[ColumnTable, GroupEncoding]] = {}
        self._buckets: Dict[Tuple[int, Tuple], Tuple[ColumnTable, Array]] = {}
        self._frag_sizes: Dict[Tuple[int, Tuple], Tuple[ColumnTable, np.ndarray]] = {}
        self._joins: Dict[Tuple[int, int, str, str], Tuple[ColumnTable, ColumnTable, ColumnTable, np.ndarray]] = {}
        self._instances: Dict[Tuple[int, int], Tuple[object, ColumnTable, ColumnTable]] = {}
        self._distinct: Dict[Tuple[int, str], Tuple[ColumnTable, int]] = {}
        self._nonneg: Dict[Tuple[int, str], Tuple[ColumnTable, bool]] = {}

    def clear(self) -> None:
        self.__init__(max_entries=self.max_entries)

    def _put(self, cache: Dict, key, value) -> None:
        if len(cache) >= self.max_entries:
            cache.pop(next(iter(cache)))  # FIFO eviction (insertion-ordered)
            self.stats["evictions"] += 1
        cache[key] = value

    def invalidate_table(self, table: ColumnTable) -> None:
        """Drop every entry keyed to ``table`` (it was replaced, e.g. by
        ``cluster_by``): id-guarded entries of a dead object can never hit
        again but would otherwise pin the old columns until evicted."""
        tid = id(table)
        for cache in (self._groups, self._buckets, self._frag_sizes,
                      self._distinct, self._nonneg):
            for k in [k for k in cache if k[0] == tid]:
                del cache[k]
        for k in [k for k in self._joins if tid in (k[0], k[1])]:
            del self._joins[k]
        for k in [k for k in self._instances if k[1] == tid]:
            del self._instances[k]

    # -- group-by dictionary encodings --------------------------------------
    def groups(self, table: ColumnTable, attrs: Tuple[str, ...]) -> GroupEncoding:
        key = (id(table), tuple(attrs))
        hit = self._groups.get(key)
        if hit is not None and hit[0] is table:
            self.stats["encode_groups_hit"] += 1
            return hit[1]
        self.stats["encode_groups"] += 1
        gid, n_groups, group_values = encode_groups(table, attrs)
        enc = GroupEncoding(gid=gid, gid_dev=jnp.asarray(gid), n_groups=n_groups,
                            group_values=group_values)
        self._put(self._groups, key, (table, enc))
        return enc

    # -- partition-attribute bucketizations ----------------------------------
    def bucketize(self, table: ColumnTable, ranges: "RangeSet") -> Array:
        key = (id(table), ranges.key())
        hit = self._buckets.get(key)
        if hit is not None and hit[0] is table:
            self.stats["bucketize_hit"] += 1
            return hit[1]
        self.stats["bucketize"] += 1
        bucket = ranges.bucketize(table[ranges.attr])
        self._put(self._buckets, key, (table, bucket))
        return bucket

    def fragment_sizes(self, table: ColumnTable, ranges: "RangeSet") -> np.ndarray:
        key = (id(table), ranges.key())
        hit = self._frag_sizes.get(key)
        if hit is not None and hit[0] is table:
            self.stats["fragment_sizes_hit"] += 1
            return hit[1]
        self.stats["fragment_sizes"] += 1
        bucket = self.bucketize(table, ranges)
        sizes = np.asarray(
            jax.ops.segment_sum(
                jnp.ones_like(bucket, dtype=jnp.int32), bucket,
                num_segments=ranges.n_ranges,
            )
        )
        self._put(self._frag_sizes, key, (table, sizes))
        return sizes

    # -- join layouts ---------------------------------------------------------
    def join(
        self, fact: ColumnTable, right: ColumnTable, left_key: str, right_key: str
    ) -> Tuple[ColumnTable, np.ndarray]:
        """Materialized equi-join (right key unique) + fact-row back-map.

        Fact rows with no partner are dropped (inner join); right-side columns
        are prefixed with ``<right>.`` when their name collides.
        """
        key = (id(fact), id(right), left_key, right_key)
        hit = self._joins.get(key)
        if hit is not None and hit[0] is fact and hit[1] is right:
            self.stats["join_hit"] += 1
            return hit[2], hit[3]
        self.stats["join_materialize"] += 1
        lk = np.asarray(fact[left_key])
        rk = np.asarray(right[right_key])
        order = np.argsort(rk, kind="stable")
        rk_sorted = rk[order]
        pos = np.searchsorted(rk_sorted, lk)
        pos_clip = np.minimum(pos, len(rk_sorted) - 1)
        matched = rk_sorted[pos_clip] == lk
        fact_idx = np.nonzero(matched)[0]
        right_idx = order[pos_clip[fact_idx]]

        cols: Dict[str, Array] = {}
        fact_take = jnp.asarray(fact_idx)
        right_take = jnp.asarray(right_idx)
        for a in fact.schema:
            cols[a] = jnp.take(fact[a], fact_take, axis=0)
        for a in right.schema:
            name = a if a not in cols else f"{right.name}.{a}"
            cols[name] = jnp.take(right[a], right_take, axis=0)
        joined = ColumnTable(f"{fact.name}_join_{right.name}", cols, fact.primary_key)
        self._put(self._joins, key, (fact, right, joined, fact_idx))
        return joined, fact_idx

    # -- sketch instances (D_P) ----------------------------------------------
    def get_instance(self, sketch: object, table: ColumnTable) -> Optional[ColumnTable]:
        key = (id(sketch), id(table))
        hit = self._instances.get(key)
        if hit is not None and hit[0] is sketch and hit[1] is table:
            self.stats["instance_hit"] += 1
            return hit[2]
        return None

    def put_instance(self, sketch: object, table: ColumnTable, instance: ColumnTable) -> None:
        self.stats["instance_build"] += 1
        self._put(self._instances, (id(sketch), id(table)), (sketch, table, instance))

    # -- cheap per-attribute statistics ---------------------------------------
    def distinct_count(self, table: ColumnTable, attr: str) -> int:
        key = (id(table), attr)
        hit = self._distinct.get(key)
        if hit is not None and hit[0] is table:
            return hit[1]
        self.stats["distinct_count"] += 1
        n = int(np.unique(np.asarray(table[attr])).shape[0])
        self._put(self._distinct, key, (table, n))
        return n

    def column_nonnegative(self, table: ColumnTable, attr: str) -> bool:
        key = (id(table), attr)
        hit = self._nonneg.get(key)
        if hit is not None and hit[0] is table:
            return hit[1]
        self.stats["column_stats"] += 1
        ok = not bool((np.asarray(table[attr]) < 0).any())
        self._put(self._nonneg, key, (table, ok))
        return ok


_DEFAULT = Catalog()


def default_catalog() -> Catalog:
    """Process-wide catalog used when callers don't thread their own."""
    return _DEFAULT
