"""Device-resident query catalog: the per-query host work, done once.

The paper's premise is that sketch *application* is nearly free — skipping is
a scheduling decision, not a scan.  The seed executor violated that premise on
every query: group-by keys were re-dictionary-encoded on host (``np.unique``),
joins re-materialized (``np.argsort`` + searchsorted), partition attributes
re-bucketized, and each sketch application gathered a filtered copy of the
whole relation.  The ``Catalog`` is the DBMS-style fix: per table it caches

  * the dictionary encoding of every seen GROUP BY tuple (dense gids, host
    and device copies, plus per-group key values),
  * the bucketization vector of every candidate partition attribute under a
    given ``RangeSet`` (a device array reused by capture, application, and
    size estimation),
  * the materialized join layout per join spec (joined columns + the
    fact-row back-map),
  * per-sketch *instances* (the filtered relation D_P), so an index hit
    re-executes over an already-materialized fragment subset,
  * cheap per-attribute statistics (distinct counts, non-negativity) used by
    the safety pre-filter.

Tables are immutable *values*, but a relation evolves through versions:
``ColumnTable.append`` / ``.delete`` produce a new object carrying a
``TableDelta`` back-pointer.  Entries are keyed by object identity with a
strong reference held for validity; a cache miss on a table that has a delta
is *refreshed incrementally* from the parent's entry (bucketize the batch and
concatenate, extend the group dictionary with the batch's keys, add/subtract
per-fragment counts) instead of redoing the full-table host work.  The
``*_delta`` stat counters separate that delta-sized work from full misses, so
tests can assert the delta path never re-bucketizes a whole table.  ``stats``
counts cache misses (real work) and hits, which the tests use to assert that
a repeated workload does zero host-side encode/argsort work.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import TYPE_CHECKING, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import ColumnTable, encode_groups

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.ranges import RangeSet

Array = jax.Array


@dataclasses.dataclass
class GroupEncoding:
    """Cached dictionary encoding of one GROUP BY tuple on one table."""

    gid: np.ndarray  # dense group id per row (host)
    gid_dev: Array  # same, device-resident
    n_groups: int
    group_values: Dict[str, np.ndarray]  # per-group key values
    _key_index: Optional[Dict[Tuple, int]] = None  # lazy key-tuple -> gid

    def key_index(self, attrs: Tuple[str, ...]) -> Dict[Tuple, int]:
        """key tuple -> gid, built lazily (delta refresh needs the lookup)."""
        if self._key_index is None:
            cols = [self.group_values[a].tolist() for a in attrs]
            self._key_index = {key: g for g, key in enumerate(zip(*cols))} if cols else {(): 0}
        return self._key_index


def map_group_keys(
    stacked: np.ndarray, key_index: Dict[Tuple, int], n_groups: int,
    grow: bool = True,
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Map a batch of stacked group-key rows through an existing dictionary.

    Known keys take their existing gid; unseen ones get fresh ids appended
    (``key_index`` is mutated in place) — or raise ``KeyError`` when
    ``grow=False``.  The shared primitive behind catalog encoding refresh,
    sketch maintainers and sample extension, so the stable-gid-numbering
    invariant lives in exactly one place.  Returns ``(gid per batch row,
    unseen unique key rows in assignment order, new group count)``; per-row
    work is vectorized, the Python loop touches only *unique* batch keys.
    """
    uniq, inv = np.unique(stacked, axis=0, return_inverse=True)
    mapped = np.empty(uniq.shape[0], dtype=np.int64)
    new_rows = []
    for i, row in enumerate(uniq):
        key = tuple(row.tolist())
        g = key_index.get(key)
        if g is None:
            if not grow:
                raise KeyError(key)
            g = n_groups
            key_index[key] = g
            n_groups += 1
            new_rows.append(i)
        mapped[i] = g
    return mapped[inv], uniq[new_rows], n_groups


def extend_group_values(
    group_values: Dict[str, np.ndarray],
    attrs: Tuple[str, ...],
    new_keys: np.ndarray,
) -> Dict[str, np.ndarray]:
    """Append freshly assigned groups' key values (dtype-preserving).

    Companion to ``map_group_keys``: ``new_keys`` is its unseen-unique-rows
    output, column ``j`` holding attribute ``attrs[j]``.  Returns a new dict
    (inputs are shared with live cache entries and must not mutate).
    """
    if not len(new_keys):
        return group_values
    return {
        a: np.concatenate([group_values[a],
                           new_keys[:, j].astype(group_values[a].dtype, copy=False)])
        for j, a in enumerate(attrs)
    }


def extend_encoding(
    parent: GroupEncoding, batch: ColumnTable, attrs: Tuple[str, ...]
) -> GroupEncoding:
    """Dictionary-encode ``batch`` against ``parent``'s group dictionary.

    Known keys map to their existing gid; unseen keys get fresh ids appended,
    so downstream per-group state (aggregates, incidence counters) stays
    aligned and only grows.  Work is O(batch + new groups), never O(table).
    """
    if not attrs:
        gid = np.concatenate([parent.gid, np.zeros(batch.num_rows, dtype=np.int32)])
        return GroupEncoding(gid, jnp.asarray(gid), parent.n_groups, parent.group_values)
    stacked = np.stack([np.asarray(batch[a]) for a in attrs], axis=1)
    key_index = dict(parent.key_index(attrs))  # copy: parent entry stays valid
    delta_gid, new_keys, n_groups = map_group_keys(stacked, key_index, parent.n_groups)
    group_values = extend_group_values(parent.group_values, attrs, new_keys)
    gid = np.concatenate([parent.gid, delta_gid]).astype(np.int32)
    return GroupEncoding(gid, jnp.asarray(gid), n_groups, group_values, key_index)


def join_rows(
    fact_cols: Dict[str, np.ndarray],
    right: ColumnTable,
    left_key: str,
    right_key: str,
) -> Tuple[Dict[str, Array], np.ndarray, np.ndarray]:
    """Inner equi-join of a column batch against ``right`` (right key unique).

    Returns ``(joined columns, matched batch row ids, right row ids)`` with
    the same column-naming rule the full catalog join uses, so a delta batch
    joins byte-compatibly with its parent layout.
    """
    lk = np.asarray(fact_cols[left_key])
    rk = np.asarray(right[right_key])
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    pos = np.searchsorted(rk_sorted, lk)
    pos_clip = np.minimum(pos, len(rk_sorted) - 1)
    matched = rk_sorted[pos_clip] == lk
    fact_idx = np.nonzero(matched)[0]
    right_idx = order[pos_clip[fact_idx]]

    cols: Dict[str, Array] = {}
    fact_take = jnp.asarray(fact_idx)
    right_take = jnp.asarray(right_idx)
    for a in sorted(fact_cols):
        cols[a] = jnp.take(jnp.asarray(fact_cols[a]), fact_take, axis=0)
    for a in right.schema:
        name = a if a not in cols else f"{right.name}.{a}"
        cols[name] = jnp.take(right[a], right_take, axis=0)
    return cols, fact_idx, right_idx


class Catalog:
    """Cross-query cache of encodings, bucketizations, joins and instances.

    Every map is bounded FIFO (``max_entries`` per map): entries hold strong
    table references to keep their id() keys valid, so an unbounded cache
    would pin every table ever touched for the catalog's lifetime.  Replaced
    tables can be dropped eagerly with ``invalidate_table``.
    """

    def __init__(self, max_entries: int = 512):
        self.stats: collections.Counter = collections.Counter()
        self.max_entries = max_entries
        # All maps key by id() of the table(s) involved and keep a strong
        # reference to them, so ids stay valid while the entry lives.
        self._groups: Dict[Tuple[int, Tuple[str, ...]], Tuple[ColumnTable, GroupEncoding]] = {}
        self._buckets: Dict[Tuple[int, Tuple], Tuple[ColumnTable, Array]] = {}
        self._frag_sizes: Dict[Tuple[int, Tuple], Tuple[ColumnTable, np.ndarray]] = {}
        self._joins: Dict[Tuple[int, int, str, str], Tuple[ColumnTable, ColumnTable, ColumnTable, np.ndarray]] = {}
        self._instances: Dict[Tuple[int, int], Tuple[object, ColumnTable, ColumnTable]] = {}
        self._distinct: Dict[Tuple[int, str], Tuple[ColumnTable, int, np.ndarray]] = {}
        self._nonneg: Dict[Tuple[int, str], Tuple[ColumnTable, bool]] = {}
        self._wheres: Dict[Tuple[int, Tuple], Tuple[ColumnTable, Array]] = {}
        # GB fast-path fragment-of-group vectors, keyed by (uid, version,
        # group-by, partition) — *value* keys, not id(): the vector is a pure
        # function of the group dictionary (deterministic per table version)
        # and the partition bounds, so it survives re-samples and re-clusters.
        self._frag_groups: Dict[Tuple, np.ndarray] = {}
        # Instance -> (base table, base-row index per instance row): lets
        # ``groups``/``where_mask`` on a sketch instance gather from the base
        # table's cached products instead of fresh full host passes.
        self._instance_rows: Dict[int, Tuple[ColumnTable, ColumnTable, np.ndarray]] = {}
        # Stacked shard-major instances (``repro.core.shard``), keyed by
        # (registration key, table uid/version, plan identity) with a token
        # guard (per-shard table ids + sketch bits) so any shard-side delta
        # application or bit flip rebuilds the stack.  Values are opaque to
        # the catalog (a ``shard.StackedInstances``).
        self._stacked: Dict[Tuple, Tuple[Tuple, object]] = {}

    def clear(self) -> None:
        self.__init__(max_entries=self.max_entries)

    def _put(self, cache: Dict, key, value) -> None:
        if len(cache) >= self.max_entries:
            cache.pop(next(iter(cache)))  # FIFO eviction (insertion-ordered)
            self.stats["evictions"] += 1
        cache[key] = value

    def invalidate_table(self, table: ColumnTable) -> None:
        """Drop every entry keyed to ``table`` (it was replaced, e.g. by
        ``cluster_by``): id-guarded entries of a dead object can never hit
        again but would otherwise pin the old columns until evicted."""
        tid = id(table)
        for cache in (self._groups, self._buckets, self._frag_sizes,
                      self._distinct, self._nonneg, self._wheres):
            for k in [k for k in cache if k[0] == tid]:
                del cache[k]
        for k in [k for k in self._joins if tid in (k[0], k[1])]:
            del self._joins[k]
        for k in [k for k in self._instances if k[1] == tid]:
            del self._instances[k]
        for k in [k for k, v in self._instance_rows.items()
                  if k == tid or v[1] is table]:
            del self._instance_rows[k]

    def invalidate_chain(self, table: ColumnTable) -> None:
        """Invalidate ``table`` and every ancestor on its delta chain.

        The companion of ``ColumnTable.collapse``: id()-keyed entries hold
        strong table references, so without this the collapsed chain (every
        prior version's columns) would stay pinned until FIFO eviction.
        """
        t = table
        while t is not None:
            self.invalidate_table(t)
            t = t.delta.parent if t.delta is not None else None

    # -- stacked shard-major instances ---------------------------------------
    def get_stacked(self, key: Tuple, token: Tuple) -> Optional[object]:
        hit = self._stacked.get(key)
        if hit is not None and hit[0] == token:
            self.stats["stacked_hit"] += 1
            return hit[1]
        return None

    def put_stacked(self, key: Tuple, token: Tuple, value: object) -> None:
        self.stats["stacked_build"] += 1
        self._put(self._stacked, key, (token, value))

    def drop_stacked(self, key_prefix) -> None:
        """Drop stacked entries whose key starts with ``key_prefix`` (used
        when a registration is evicted so its stack stops pinning arrays)."""
        for k in [k for k in self._stacked if k[: len(key_prefix)] == key_prefix]:
            del self._stacked[k]

    # -- group-by dictionary encodings --------------------------------------
    def groups(self, table: ColumnTable, attrs: Tuple[str, ...]) -> GroupEncoding:
        key = (id(table), tuple(attrs))
        hit = self._groups.get(key)
        if hit is not None and hit[0] is table:
            self.stats["encode_groups_hit"] += 1
            return hit[1]
        parent = self._instance_parent(table) if attrs else None
        if parent is not None:
            # Sketch instance: derive from the base table's cached encoding
            # by a gather + dense renumber.  ``np.unique(axis=0)`` numbers
            # groups lexicographically, so restricting the base numbering to
            # the present groups (order-preserving) reproduces a from-scratch
            # encode of the instance bit-for-bit — in O(rows + groups)
            # instead of an O(n log n) host sort per instance.
            base, rows = parent
            base_enc = self.groups(base, attrs)
            gid_rows = base_enc.gid[rows]
            counts = np.bincount(gid_rows, minlength=base_enc.n_groups)
            present = counts > 0
            new_of_base = np.cumsum(present) - 1
            gid = new_of_base[gid_rows].astype(np.int32)
            n_groups = int(present.sum())
            group_values = {a: v[present] for a, v in base_enc.group_values.items()}
            enc = GroupEncoding(gid, jnp.asarray(gid), n_groups, group_values)
            self.stats["encode_groups_instance"] += 1
            self._put(self._groups, key, (table, enc))
            return enc
        d = table.delta
        if d is not None and attrs:
            parent = self.groups(d.parent, attrs)
            if d.kind == "append":
                enc = extend_encoding(parent, d.appended, tuple(attrs))
            else:
                gid = parent.gid[d.kept_idx].astype(np.int32)
                # Group numbering survives a delete; emptied groups simply
                # stop appearing (the executor's present-mask hides them).
                enc = GroupEncoding(gid, jnp.asarray(gid), parent.n_groups,
                                    parent.group_values, parent._key_index)
            self.stats["encode_groups_delta"] += 1
            self._put(self._groups, key, (table, enc))
            return enc
        self.stats["encode_groups"] += 1
        gid, n_groups, group_values = encode_groups(table, attrs)
        enc = GroupEncoding(gid=gid, gid_dev=jnp.asarray(gid), n_groups=n_groups,
                            group_values=group_values)
        self._put(self._groups, key, (table, enc))
        return enc

    # -- partition-attribute bucketizations ----------------------------------
    @staticmethod
    def _bucketize_raw(table: ColumnTable, ranges) -> Array:
        """Bucketize one table under a single-attribute or composite partition."""
        if hasattr(ranges, "parts"):  # CompositeRanges duck-type
            return ranges.bucketize(table)
        return ranges.bucketize(table[ranges.attr])

    def bucketize(self, table: ColumnTable, ranges: "RangeSet") -> Array:
        key = (id(table), ranges.key())
        hit = self._buckets.get(key)
        if hit is not None and hit[0] is table:
            self.stats["bucketize_hit"] += 1
            return hit[1]
        d = table.delta
        if d is not None:
            parent_bucket = self.bucketize(d.parent, ranges)
            if d.kind == "append":
                bucket = jnp.concatenate(
                    [parent_bucket, self._bucketize_raw(d.appended, ranges)])
            else:
                bucket = jnp.take(parent_bucket, jnp.asarray(d.kept_idx), axis=0)
            self.stats["bucketize_delta"] += 1
            self._put(self._buckets, key, (table, bucket))
            return bucket
        self.stats["bucketize"] += 1
        bucket = self._bucketize_raw(table, ranges)
        self._put(self._buckets, key, (table, bucket))
        return bucket

    def frag_of_group(
        self,
        table: ColumnTable,
        ranges: "RangeSet",
        groupby: Tuple[str, ...],
        group_values: Dict[str, np.ndarray],
    ) -> np.ndarray:
        """Fragment id per *group* under a partition on group-by attributes.

        The CB-OPT-GB fast path's vector: when every partition attribute is a
        group-by attribute the group key pins the fragment exactly, so the
        bucketization of the per-group key values answers incidence for every
        estimate over this (table version, group-by, partition) — cached here
        instead of re-bucketizing the group values on each estimate.
        Composite partitions assemble the row-major cross-product id.
        """
        key = (table.uid, table.version, tuple(groupby), ranges.key())
        hit = self._frag_groups.get(key)
        n_groups = len(next(iter(group_values.values()))) if group_values else 1
        if hit is not None and hit.shape[0] == n_groups:
            self.stats["frag_of_group_hit"] += 1
            return hit
        self.stats["frag_of_group"] += 1
        parts = getattr(ranges, "parts", (ranges,))
        frag = None
        for r in parts:
            b = np.asarray(r.bucketize(jnp.asarray(group_values[r.attr])))  # analyze: waive[SYNC01]: deliberate merge: fragment-of-group cache stores host arrays, computed once per (table, ranges)
            frag = b if frag is None else frag * r.n_ranges + b
        if len(self._frag_groups) >= self.max_entries:
            self._frag_groups.pop(next(iter(self._frag_groups)))
        self._frag_groups[key] = frag
        return frag

    def cached_bucket(self, table: ColumnTable, ranges: "RangeSet") -> Optional[Array]:
        """The full bucket vector iff it is available without full-table work.

        Returns the cached entry, or delta-refreshes it when every ancestor up
        to a cached entry is reachable through deltas; returns ``None`` when
        producing it would cost a full-table bucketize (callers then fall back
        to bucketizing just the rows they touch).
        """
        t = table
        while True:
            hit = self._buckets.get((id(t), ranges.key()))
            if hit is not None and hit[0] is t:
                return self.bucketize(table, ranges)  # delta-refresh the chain
            if t.delta is None:
                return None
            t = t.delta.parent

    def fragment_sizes(self, table: ColumnTable, ranges: "RangeSet") -> np.ndarray:
        key = (id(table), ranges.key())
        hit = self._frag_sizes.get(key)
        if hit is not None and hit[0] is table:
            self.stats["fragment_sizes_hit"] += 1
            return hit[1]
        d = table.delta
        if d is not None:
            parent_sizes = self.fragment_sizes(d.parent, ranges)
            if d.kind == "append":
                # Refresh the full bucket vector through the delta path: the
                # batch-sized tail feeds the counts here and the cached vector
                # is exactly what sketch application gathers from next.
                delta_bucket = np.asarray(
                    self.bucketize(table, ranges))[d.parent.num_rows:]
                sign = 1
            else:
                delta_bucket = np.asarray(self.bucketize(d.parent, ranges))[d.deleted_idx]
                sign = -1
            counts = np.bincount(delta_bucket, minlength=ranges.n_ranges)
            sizes = parent_sizes + sign * counts.astype(parent_sizes.dtype)
            self.stats["fragment_sizes_delta"] += 1
            self._put(self._frag_sizes, key, (table, sizes))
            return sizes
        self.stats["fragment_sizes"] += 1
        bucket = self.bucketize(table, ranges)
        sizes = np.asarray(  # analyze: waive[SYNC01]: deliberate merge: fragment-size histogram is cached as a host array, once per (table, ranges)
            jax.ops.segment_sum(
                jnp.ones_like(bucket, dtype=jnp.int32), bucket,
                num_segments=ranges.n_ranges,
            )
        )
        self._put(self._frag_sizes, key, (table, sizes))
        return sizes

    def frag_stats(self, table: ColumnTable, ranges: "RangeSet") -> Tuple[int, float, float]:
        """Summary statistics of a partition: ``(n_nonempty, max_frac,
        min_frac)`` over the nonempty fragments (fractions of table rows).

        The PS3-style pre-filter input: everything it needs to bound a
        candidate's sketch size comes from the cached per-fragment counts, so
        dominance pruning costs catalog metadata only — no sampling, no AQR
        pass, no estimate launch.  Delta-refreshed along with
        ``fragment_sizes``.
        """
        sizes = self.fragment_sizes(table, ranges)
        total = max(int(sizes.sum()), 1)
        nonempty = sizes[sizes > 0]
        if nonempty.size == 0:
            return (0, 0.0, 0.0)
        return (int(nonempty.size),
                float(nonempty.max()) / total,
                float(nonempty.min()) / total)

    # -- predicate-pushdown WHERE masks --------------------------------------
    def where_mask(self, table: ColumnTable, pred) -> Array:
        """The row mask of ``pred`` over ``table``, cached per (table version,
        predicate).

        ``pred`` is a ``queries.Predicate`` (duck-typed here — importing it
        would cycle).  Keys use object identity for the table (each version is
        a distinct object) plus the predicate's value tuple, and a miss on a
        delta-carrying version refreshes from the parent's mask: appends
        evaluate the predicate on the batch alone, deletes gather the kept
        rows — never a full-table re-evaluation.
        """
        key = (id(table), (pred.attr, pred.op, pred.value))
        hit = self._wheres.get(key)
        if hit is not None and hit[0] is table:
            self.stats["where_mask_hit"] += 1
            return hit[1]
        parent = self._instance_parent(table)
        if parent is not None:
            base, rows = parent
            mask = jnp.take(self.where_mask(base, pred), jnp.asarray(rows), axis=0)
            self.stats["where_mask_instance"] += 1
            self._put(self._wheres, key, (table, mask))
            return mask
        d = table.delta
        if d is not None:
            parent_mask = self.where_mask(d.parent, pred)
            if d.kind == "append":
                mask = jnp.concatenate([parent_mask, pred.mask(d.appended)])
            else:
                mask = jnp.take(parent_mask, jnp.asarray(d.kept_idx), axis=0)
            self.stats["where_mask_delta"] += 1
            self._put(self._wheres, key, (table, mask))
            return mask
        self.stats["where_mask"] += 1
        mask = pred.mask(table)
        self._put(self._wheres, key, (table, mask))
        return mask

    # -- join layouts ---------------------------------------------------------
    def join(
        self, fact: ColumnTable, right: ColumnTable, left_key: str, right_key: str
    ) -> Tuple[ColumnTable, np.ndarray]:
        """Materialized equi-join (right key unique) + fact-row back-map.

        Fact rows with no partner are dropped (inner join); right-side columns
        are prefixed with ``<right>.`` when their name collides.
        """
        key = (id(fact), id(right), left_key, right_key)
        hit = self._joins.get(key)
        if hit is not None and hit[0] is fact and hit[1] is right:
            self.stats["join_hit"] += 1
            return hit[2], hit[3]
        d = fact.delta
        if d is not None:
            p_joined, p_fact_idx = self.join(d.parent, right, left_key, right_key)
            if d.kind == "append":
                batch_cols = {a: np.asarray(d.appended[a]) for a in d.appended.schema}
                cols_new, b_idx, _ = join_rows(batch_cols, right, left_key, right_key)
                # Build the new joined table *as an append of its parent*, so
                # the joined relation carries its own delta chain and its
                # group encodings delta-refresh just like base tables'.
                joined = p_joined.append({a: cols_new[a] for a in p_joined.schema})
                fact_idx = np.concatenate([p_fact_idx, b_idx + d.parent.num_rows])
            else:
                keep_row = np.zeros(d.parent.num_rows, dtype=bool)
                keep_row[d.kept_idx] = True
                old_to_new = np.cumsum(keep_row) - 1
                joined_keep = keep_row[p_fact_idx]
                joined = p_joined.delete(~joined_keep)
                fact_idx = old_to_new[p_fact_idx[joined_keep]]
            self.stats["join_delta"] += 1
            self._put(self._joins, key, (fact, right, joined, fact_idx))
            return joined, fact_idx
        self.stats["join_materialize"] += 1
        cols, fact_idx, _ = join_rows(
            {a: fact[a] for a in fact.schema}, right, left_key, right_key)
        joined = ColumnTable(f"{fact.name}_join_{right.name}", cols, fact.primary_key)
        self._put(self._joins, key, (fact, right, joined, fact_idx))
        return joined, fact_idx

    # -- sketch instances (D_P) ----------------------------------------------
    def get_instance(self, sketch: object, table: ColumnTable) -> Optional[ColumnTable]:
        key = (id(sketch), id(table))
        hit = self._instances.get(key)
        if hit is not None and hit[0] is sketch and hit[1] is table:
            self.stats["instance_hit"] += 1
            return hit[2]
        return None

    def put_instance(self, sketch: object, table: ColumnTable,
                     instance: ColumnTable, rows: Optional[np.ndarray] = None) -> None:
        self.stats["instance_build"] += 1
        self._put(self._instances, (id(sketch), id(table)), (sketch, table, instance))
        if rows is not None:
            # Remember the subset map so the instance's group encodings and
            # WHERE masks derive from the base table's cached ones by a
            # gather (``groups`` / ``where_mask`` consult this first).
            self._put(self._instance_rows, id(instance),
                      (instance, table, np.asarray(rows)))

    def _instance_parent(
        self, table: ColumnTable
    ) -> Optional[Tuple[ColumnTable, np.ndarray]]:
        hit = self._instance_rows.get(id(table))
        if hit is not None and hit[0] is table:
            return hit[1], hit[2]
        return None

    # -- cheap per-attribute statistics ---------------------------------------
    def distinct_count(self, table: ColumnTable, attr: str) -> int:
        key = (id(table), attr)
        hit = self._distinct.get(key)
        if hit is not None and hit[0] is table:
            return hit[1]
        d = table.delta
        if d is not None and d.kind == "append":
            parent_hit = self._distinct.get((id(d.parent), attr))
            if parent_hit is not None and parent_hit[0] is d.parent:
                uniq = np.union1d(parent_hit[2], np.asarray(d.appended[attr]))
                self.stats["distinct_count_delta"] += 1
                self._put(self._distinct, key, (table, int(uniq.shape[0]), uniq))
                return int(uniq.shape[0])
        # Deletes may or may not remove a value's last occurrence, so they
        # recompute; appends without a cached parent do too.
        self.stats["distinct_count"] += 1
        uniq = np.unique(np.asarray(table[attr]))
        self._put(self._distinct, key, (table, int(uniq.shape[0]), uniq))
        return int(uniq.shape[0])

    def column_nonnegative(self, table: ColumnTable, attr: str) -> bool:
        key = (id(table), attr)
        hit = self._nonneg.get(key)
        if hit is not None and hit[0] is table:
            return hit[1]
        d = table.delta
        if d is not None:
            parent_hit = self._nonneg.get((id(d.parent), attr))
            if parent_hit is not None and parent_hit[0] is d.parent:
                parent_ok = parent_hit[1]
                if d.kind == "append":
                    ok = parent_ok and not bool((np.asarray(d.appended[attr]) < 0).any())
                    self.stats["column_stats_delta"] += 1
                    self._put(self._nonneg, key, (table, ok))
                    return ok
                if parent_ok:  # removing rows cannot introduce negatives
                    self.stats["column_stats_delta"] += 1
                    self._put(self._nonneg, key, (table, True))
                    return True
        self.stats["column_stats"] += 1
        ok = not bool((np.asarray(table[attr]) < 0).any())
        self._put(self._nonneg, key, (table, ok))
        return ok


_DEFAULT = Catalog()


def default_catalog() -> Catalog:
    """Process-wide catalog used when callers don't thread their own."""
    return _DEFAULT
