"""Fragment-sharded serving: place fragments across shards, route sketches.

The paper's payoff is that a captured sketch makes subsequent queries nearly
free by skipping non-provenance fragments.  Fragments are therefore the
natural unit of horizontal scale-out: a clustered ``ColumnTable``'s fragments
are placed across S shards (host-emulated shard objects by default, pinned to
a ``jax`` device mesh when one exists — see ``repro.parallel.placement``), and
a reused sketch is routed as a *fragment-id set* to only the shards owning set
bits.  Each contacted shard evaluates the inner block over its local sketch
instance and returns per-group partial aggregates (sums + WHERE-passing
counts); the coordinator merges them by group key and finishes the query with
the same group-level code single-node execution uses
(``queries.result_from_group_state``), so routed results match single-node
results exactly whenever the aggregate arithmetic is exact (integer-valued
columns within float32 range — the same envelope the maintenance subsystem
pins, see ``SketchMaintainer._clears_trustworthy``).

Replication is delta-based, not state-based: ``append_rows``/``delete_rows``
are coordinator operations that route each batch by fragment ownership and
*ship* per-shard deltas into shard inboxes.  Shards advance independently —
each applies its pending deltas and advances its per-sketch maintainers the
next time it is read — and cross-shard reads gate on a minimum version
watermark (every contacted shard must have drained up to the coordinator's
mutation count) instead of a global lock.  Per-shard ``SketchMaintainer``s
behind one logical index entry keep the sketch's bits current shard-locally
whenever every group is shard-local (the placement attribute is part of the
(outer) GROUP BY, so a group's rows share one fragment and one owner); the
logical bits are then the OR of the shard bits.  For group-spanning queries
the HAVING chain needs global aggregates, so the coordinator's own maintainer
repairs the logical sketch (still delta-sized) and shards serve only the
routed partial aggregation.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.catalog import Catalog
from repro.core.engine import PBDSEngine, RunInfo
from repro.core.index import IndexEntry
from repro.core.maintenance import MaintenanceError, SketchMaintainer
from repro.core.queries import (
    Query,
    QueryResult,
    inner_group_partials,
    result_from_group_state,
)
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.table import ColumnTable, Database, FragmentLayout
from repro.parallel.placement import place_table, shard_devices


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Fragment -> shard ownership map for one range partition."""

    n_shards: int
    owner: np.ndarray  # (n_fragments,) shard id per fragment

    def fragments_of(self, shard_id: int) -> np.ndarray:
        return np.nonzero(self.owner == shard_id)[0]

    def shards_for(self, frag_ids: np.ndarray) -> np.ndarray:
        """The distinct shards owning any of ``frag_ids`` — the route set."""
        return np.unique(self.owner[np.asarray(frag_ids)])


def plan_fragments(
    sizes: np.ndarray, n_shards: int, policy: str = "contig"
) -> ShardPlan:
    """Place fragments on shards.

    ``contig`` (default) cuts the fragment sequence into row-balanced
    contiguous runs, preserving range locality — a selective sketch's bits
    are usually clustered in value space, so contiguous ownership maximizes
    fully-skipped shards.  ``spread`` round-robins fragments, trading
    locality for uniform load under adversarial (striped) sketches.
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    n_frags = sizes.shape[0]
    owner = np.zeros(n_frags, dtype=np.int64)
    if policy == "spread":
        owner = np.arange(n_frags, dtype=np.int64) % n_shards
    elif policy == "contig":
        per = sizes.sum() / max(n_shards, 1)
        s, load = 0, 0.0
        for f in range(n_frags):
            if s < n_shards - 1 and load >= per:
                s, load = s + 1, 0.0
            owner[f] = s
            load += sizes[f]
    else:
        raise ValueError(f"unknown placement policy {policy!r}")
    return ShardPlan(n_shards=n_shards, owner=owner)


# ---------------------------------------------------------------------------
# One shard
# ---------------------------------------------------------------------------


class FragmentShard:
    """One shard: its owned fragments' rows, catalog, and sketch maintainers.

    The local table is clustered over the *owned* fragments (local fragment j
    is the j-th owned global fragment, ascending), with appended rows landing
    in the layout's unsorted tail exactly like a single-node clustered table.
    Deltas arrive through ``ship`` into an inbox and are applied lazily by
    ``catch_up`` — the emulation of asynchronous replication.
    """

    MAX_DELTA_CHAIN = 16

    def __init__(
        self,
        shard_id: int,
        plan: ShardPlan,
        ranges: RangeSet,
        clustered: ColumnTable,
        dims: Mapping[str, ColumnTable],
        device=None,
    ):
        if clustered.layout is None or clustered.layout.tail:
            raise ValueError("shards are built from a tail-free clustered table")
        self.shard_id = shard_id
        self.ranges = ranges
        self.owned = plan.fragments_of(shard_id)
        # global fragment id -> local fragment position (-1 = not owned).
        self._local_of_global = np.full(ranges.n_ranges, -1, dtype=np.int64)
        self._local_of_global[self.owned] = np.arange(self.owned.shape[0])

        off = clustered.layout.offsets
        parts = [np.arange(off[f], off[f + 1]) for f in self.owned]
        idx = np.concatenate(parts) if parts else np.empty(0, dtype=np.int64)
        local = clustered.gather(jnp.asarray(idx))
        local_sizes = np.array([off[f + 1] - off[f] for f in self.owned],
                               dtype=np.int64)
        layout = FragmentLayout(
            attr=ranges.attr,
            # Never collides with a RangeSet.key(): local fragment ids are a
            # different coordinate system from the global partition's.
            ranges_key=("shard", shard_id, ranges.key()),
            offsets=np.concatenate([[0], np.cumsum(local_sizes)]).astype(np.int64),
        )
        self.device = device
        self.table = place_table(
            ColumnTable(local.name, local.columns, clustered.primary_key, layout),
            device)
        self.dims: Dict[str, ColumnTable] = {
            k: place_table(v, device) for k, v in dims.items()}
        self.catalog = Catalog()
        self.maintainers: Dict[int, SketchMaintainer] = {}
        self._inst: Dict[int, Tuple[Tuple, ColumnTable]] = {}
        self._inbox: Deque[Tuple[str, object]] = collections.deque()

    # -- replication -----------------------------------------------------------
    @property
    def version(self) -> int:
        """Local watermark: how many fact-table deltas have been applied."""
        return self.table.version

    @property
    def lag(self) -> int:
        return len(self._inbox)

    def ship(self, kind: str, payload) -> None:
        """Enqueue one delta (``append`` row batch / ``delete`` local mask)."""
        self._inbox.append((kind, payload))

    def update_dim(self, table: ColumnTable) -> None:
        """Replace a replicated dimension table (applied eagerly — dimension
        mutations are rare and invalidate join maintainers wholesale)."""
        old = self.dims.get(table.name)
        if old is not None:
            self.catalog.invalidate_table(old)
        self.dims[table.name] = place_table(table, self.device)
        for key in [k for k, m in self.maintainers.items()
                    if m.q.join is not None and m.q.join.right == table.name]:
            del self.maintainers[key]

    def _db(self) -> Database:
        tables = dict(self.dims)
        tables[self.table.name] = self.table
        return Database(tables)

    def catch_up(self, watermark: int) -> int:
        """Drain pending deltas up to ``watermark``; advance maintainers.

        Returns the number of deltas applied.  Work is delta-sized: the
        table grows/shrinks by the batch, maintainers re-count only the
        batch rows, and catalog entries refresh through the delta chain.
        A maintainer that cannot advance (e.g. its dimension table was
        replaced mid-chain) is dropped; the coordinator re-registers it
        from scratch on the next read that needs it.
        """
        applied = 0
        while self.table.version < watermark and self._inbox:
            kind, payload = self._inbox.popleft()
            if kind == "append":
                self.table = self.table.append(payload)
            elif kind == "delete":
                self.table = self.table.delete(payload)
            else:  # pragma: no cover - defensive
                raise ValueError(f"unknown delta kind {kind!r}")
            applied += 1
        if applied:
            db = self._db()
            for key, m in list(self.maintainers.items()):
                try:
                    m.apply(self.table, db)
                except MaintenanceError:
                    del self.maintainers[key]
            self._inst.clear()
        if self.table.delta_depth() > self.MAX_DELTA_CHAIN:
            self.catalog.invalidate_chain(self.table)
            self.table = self.table.collapse()
        return applied

    # -- sketch registration ---------------------------------------------------
    def register(self, key: int, q: Query, ranges: RangeSet) -> None:
        """Build this shard's maintainer for one logical index entry.

        The shard must be at the coordinator's watermark (the maintainer
        counts the *current* local rows).
        """
        self.maintainers[key] = SketchMaintainer(q, self._db(), ranges,
                                                 self.catalog)

    def unregister(self, key: int) -> None:
        self.maintainers.pop(key, None)
        self._inst.pop(key, None)

    def bits_for(self, key: int) -> Optional[np.ndarray]:
        """This shard's maintained sketch bits (global fragment ids), or
        ``None`` when the maintainer was lost and needs re-registration."""
        m = self.maintainers.get(key)
        return m.bits() if m is not None else None

    # -- query serving ---------------------------------------------------------
    def _instance(self, key: int, ranges: RangeSet, bits: np.ndarray) -> ColumnTable:
        """The local sketch instance: owned ∩ sketch fragments (+ tail filter).

        When the sketch's partition is the serving partition this is pure
        slice concatenation over the local fragment-major layout; any other
        partition falls back to the per-row keep-mask over local rows.
        """
        token = (id(self.table), bits.tobytes())
        cached = self._inst.get(key)
        if cached is not None and cached[0] == token:
            self.catalog.stats["instance_hit"] += 1
            return cached[1]
        lay = self.table.layout
        if ranges.key() == self.ranges.key():
            local_ids = np.nonzero(bits[self.owned])[0]
            tail_bucket = None
            if lay.tail:
                gfrag = np.asarray(self.catalog.bucketize(self.table, self.ranges))
                tail_bucket = self._local_of_global[
                    gfrag[self.table.num_rows - lay.tail:]]
                if tail_bucket.size and tail_bucket.min() < 0:
                    # A tail row bucketized to a fragment this shard does not
                    # own: routing and bucketization disagree — corruption.
                    raise RuntimeError(
                        f"shard {self.shard_id}: mis-routed tail rows "
                        f"(fragments {np.unique(gfrag[self.table.num_rows - lay.tail:][tail_bucket < 0])})")
            inst = self.table.take_fragments(local_ids, tail_bucket=tail_bucket)
            self.catalog.stats["instance_slices"] += 1
        else:
            bucket = self.catalog.bucketize(self.table, ranges)
            inst = self.table.select(jnp.asarray(bits)[bucket])
            self.catalog.stats["instance_mask"] += 1
        self._inst[key] = (token, inst)
        return inst

    def partial(
        self, q: Query, key: int, ranges: RangeSet, bits: np.ndarray
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]:
        """Per-group partial aggregates of the inner block over the local
        sketch instance: ``(group key values, sums, WHERE-passing counts)``.

        Group ids are shard-local; the coordinator re-keys on the group
        *values* when merging, so numbering never has to be coordinated.
        """
        inst = self._instance(key, ranges, bits)
        if q.join is not None:
            flat, _ = self.catalog.join(
                inst, self.dims[q.join.right], q.join.left_key, q.join.right_key)
        else:
            flat = inst
        enc, _, sums, counts = inner_group_partials(q, flat, self.catalog)
        return enc.group_values, np.asarray(sums), np.asarray(counts)


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Registered:
    """Routed-serving state for one logical index entry.

    ``group_local`` selects the bits source: per-shard maintainers when every
    group is shard-local, the coordinator's maintainer otherwise.  ``entry``
    is a strong reference: registration state is keyed by ``id(entry)``, so
    the entry must stay alive while registered or a recycled id could alias
    a new entry onto stale shard state.
    """

    entry: IndexEntry
    ranges: RangeSet
    group_local: bool


@dataclasses.dataclass
class RouteInfo:
    """Bookkeeping of one routed (reused-sketch) execution."""

    contacted: int
    skipped: int
    watermark: int
    deltas_applied: int
    per_shard_s: Dict[int, float]
    t_merge_s: float

    @property
    def t_critical_s(self) -> float:
        """Emulated shard-parallel latency: slowest contacted shard + merge
        (host-emulated shards run sequentially; real deployments overlap)."""
        return (max(self.per_shard_s.values()) if self.per_shard_s else 0.0) \
            + self.t_merge_s


class ShardedEngine:
    """Coordinator: a ``PBDSEngine`` for selection/capture plus S fragment
    shards for serving.

    The coordinator keeps the authoritative table (captures, candidate
    selection, and NO-PS fallbacks run single-node over it); index *hits* are
    served routed: per-shard maintained bits are OR-merged into the logical
    sketch, only shards owning set bits are contacted, and their per-group
    partials are merged into the final result.  Mutations ship per-shard
    deltas and return immediately; shards drain on their next read.
    """

    def __init__(
        self,
        db: Database,
        table: str,
        attr: str,
        n_shards: int,
        n_ranges: int = 64,
        strategy: str = "CB-OPT-GB",
        policy: str = "contig",
        use_devices: bool = True,
        **engine_kwargs,
    ):
        for k in ("cluster_tables", "compact_tail_frac"):
            if k in engine_kwargs:
                # Physical re-permutes of the coordinator table would desync
                # the global-row -> shard-row map that delete routing needs.
                raise ValueError(f"{k} is coordinator-managed in ShardedEngine")
        self.table_name = table
        self.attr = attr
        self.n_shards = n_shards
        self.ranges = equi_depth_ranges(db[table], attr, n_ranges)
        clustered = db[table].cluster_by(self.ranges)
        self.engine = PBDSEngine(
            db.with_table(clustered), strategy=strategy, n_ranges=n_ranges,
            **engine_kwargs)
        # The serving partition IS the engine's partition for ``attr``, so a
        # sketch selected on it routes as fragment slices on every shard.
        self.engine._ranges_cache[(table, attr)] = self.ranges
        self.plan = plan_fragments(
            np.diff(clustered.layout.offsets), n_shards, policy=policy)
        dims = {k: v for k, v in self.engine.db.tables.items() if k != table}
        devices = shard_devices(n_shards, use_devices)
        self.shards: List[FragmentShard] = [
            FragmentShard(s, self.plan, self.ranges, clustered, dims, devices[s])
            for s in range(n_shards)
        ]
        # Global-row -> (shard, local-row) map, maintained across mutations so
        # coordinator delete masks translate to shard-local masks.
        n = clustered.num_rows
        frag_of_row = np.searchsorted(
            clustered.layout.offsets, np.arange(n), side="right") - 1
        self._row_shard = self.plan.owner[frag_of_row]
        self._row_local = np.empty(n, dtype=np.int64)
        self._shard_rows = np.zeros(n_shards, dtype=np.int64)
        for s in range(n_shards):
            sel = self._row_shard == s
            self._shard_rows[s] = int(sel.sum())
            self._row_local[sel] = np.arange(self._shard_rows[s])
        # Coordinator mutation count == the read watermark.
        self.version = 0
        # id(IndexEntry) -> routed-serving state for that logical entry.
        self._registered: Dict[int, _Registered] = {}
        self.last_route: Optional[RouteInfo] = None

    # -- convenience -----------------------------------------------------------
    @property
    def db(self) -> Database:
        return self.engine.db

    @property
    def index(self):
        return self.engine.index

    def min_watermark(self) -> int:
        """The slowest shard's applied-delta count (monitoring hook)."""
        return min((s.version for s in self.shards), default=self.version)

    # -- mutations -------------------------------------------------------------
    def append_rows(self, table_name: str, rows: Mapping[str, np.ndarray]) -> None:
        """Route the batch by fragment ownership and ship per-shard deltas.

        Every shard receives a delta (possibly empty) so shard versions stay
        aligned with the coordinator's watermark; application is lazy.
        """
        if table_name != self.table_name:
            self.engine.append_rows(table_name, rows)
            self._replicate_dim(table_name)
            return
        rows_np = {k: np.asarray(v) for k, v in rows.items()}
        # Route through RangeSet.bucketize itself so coordinator routing and
        # shard-side re-bucketization agree bit-for-bit on boundary values.
        bucket = np.asarray(self.ranges.bucketize(jnp.asarray(rows_np[self.attr])))
        shard_of = self.plan.owner[bucket]
        counts = np.bincount(shard_of, minlength=self.n_shards)
        new_local = np.empty(shard_of.shape[0], dtype=np.int64)
        for s, shard in enumerate(self.shards):
            sel = shard_of == s
            shard.ship("append", {k: v[sel] for k, v in rows_np.items()})
            new_local[sel] = self._shard_rows[s] + np.arange(counts[s])
        self._shard_rows += counts
        self._row_shard = np.concatenate([self._row_shard, shard_of])
        self._row_local = np.concatenate([self._row_local, new_local])
        self.engine.append_rows(table_name, rows)
        self.version += 1

    def delete_rows(self, table_name: str, mask: np.ndarray) -> None:
        """Translate the coordinator-row mask into per-shard local masks."""
        if table_name != self.table_name:
            self.engine.delete_rows(table_name, mask)
            self._replicate_dim(table_name)
            return
        mask = np.asarray(mask, dtype=bool)
        for s, shard in enumerate(self.shards):
            local_mask = np.zeros(self._shard_rows[s], dtype=bool)
            local_mask[self._row_local[mask & (self._row_shard == s)]] = True
            shard.ship("delete", local_mask)
        keep = ~mask
        self._row_shard = self._row_shard[keep]
        self._row_local = self._row_local[keep]
        self._shard_rows = np.bincount(self._row_shard, minlength=self.n_shards)
        for s in range(self.n_shards):
            sel = self._row_shard == s
            self._row_local[sel] = np.arange(self._shard_rows[s])
        self.engine.delete_rows(table_name, mask)
        self.version += 1

    def _replicate_dim(self, table_name: str) -> None:
        """Replicate a mutated dimension table and evict sketches it serves.

        A join sketch's provenance depends on the dimension contents, but
        sketches are versioned against the *fact* table only — serving one
        across a dimension mutation could silently return a stale-join
        result.  Eviction forces a fresh capture on the next miss.
        """
        for shard in self.shards:
            shard.update_dim(self.engine.db[table_name])
        for e in list(self.engine.index.entries()):
            if e.query.join is not None and e.query.join.right == table_name:
                self.engine.index.remove(e)
                self._unregister(id(e))

    # -- queries ---------------------------------------------------------------
    def run(self, q: Query) -> Tuple[QueryResult, RunInfo]:
        t0 = time.perf_counter()
        entry = (self.engine.index.lookup_entry(q)
                 if self.engine.strategy != "NO-PS" else None)
        if entry is not None:
            routed = self._run_routed(q, entry, t0)
            if routed is not None:
                return routed
        # Miss (or unroutable hit): single-node path on the coordinator, then
        # register any fresh capture with every shard.
        res, info = self.engine.run(q)
        if self.engine.strategy != "NO-PS":
            for e in self.engine.index.entries():
                if e.query.table == self.table_name and id(e) not in self._registered:
                    self._register(e)
        return res, info

    def _group_local(self, q: Query) -> bool:
        """May sketch bits be maintained shard-locally for ``q``?

        A shard's maintainer evaluates the HAVING chain on *local* per-group
        aggregates, which equals the global evaluation only when every group
        (and, for nested templates, every outer group) lives entirely on one
        shard — i.e. the placement attribute is part of the (outer) GROUP BY,
        so a group's rows all share one fragment and hence one owner.
        """
        if self.attr not in q.groupby:
            return False
        if q.outer_groupby is not None and self.attr not in q.outer_groupby:
            return False
        return True

    def _register(self, entry: IndexEntry) -> None:
        ranges = entry.sketch.ranges
        group_local = self._group_local(entry.query)
        if group_local:
            for shard in self.shards:
                shard.catch_up(self.version)
                shard.register(id(entry), entry.query, ranges)
        self._registered[id(entry)] = _Registered(entry, ranges, group_local)

    def _unregister(self, key: int) -> None:
        for shard in self.shards:
            shard.unregister(key)
        self._registered.pop(key, None)

    def _run_routed(
        self, q: Query, entry: IndexEntry, t0: float
    ) -> Optional[Tuple[QueryResult, RunInfo]]:
        key = id(entry)
        reg = self._registered.get(key)
        if reg is None:
            return None
        ranges = reg.ranges
        # Watermark gate: every shard must drain its inbox up to the
        # coordinator's mutation count before serving — an un-contacted
        # lagging shard could own fragments the mutations just made
        # provenance-bearing (and its data must be current for partials).
        applied = 0
        for shard in self.shards:
            applied += shard.catch_up(self.version)
        if reg.group_local:
            # Fully decentralized maintenance: every group is shard-local,
            # so the logical bits are the OR of per-shard maintained bits.
            bits_parts = []
            for shard in self.shards:
                b = shard.bits_for(key)
                if b is None:  # maintainer lost (e.g. dimension replaced)
                    self._unregister(key)
                    return None
                bits_parts.append(b)
            bits = np.logical_or.reduce(bits_parts)
        else:
            # Groups span shards: the HAVING chain needs global aggregates,
            # so the *coordinator's* maintainer repairs the logical sketch
            # (delta-sized) and shards only serve the routed partials.
            sketch, _ = self.engine._current_sketch(entry)
            bits = sketch.bits

        routable = ranges.key() == self.ranges.key()
        per_shard_s: Dict[int, float] = {}
        partials = []
        for shard in self.shards:
            if routable and not bits[shard.owned].any():
                continue  # fragment-skip the whole shard
            ts = time.perf_counter()
            partials.append(shard.partial(q, key, ranges, bits))
            per_shard_s[shard.shard_id] = time.perf_counter() - ts
        tm = time.perf_counter()
        res = _merge_partials(q, partials)
        t1 = time.perf_counter()
        self.last_route = RouteInfo(
            contacted=len(per_shard_s),
            skipped=self.n_shards - len(per_shard_s),
            watermark=self.version,
            deltas_applied=applied,
            per_shard_s=per_shard_s,
            t_merge_s=t1 - tm,
        )
        info = RunInfo(
            reused=True, created=False, attr=ranges.attr,
            strategy=self.engine.strategy, selectivity=entry.sketch.selectivity,
            t_execute=t1 - t0, repaired=applied > 0,
            shards_contacted=len(per_shard_s),
            shards_skipped=self.n_shards - len(per_shard_s),
        )
        return res, info


def _merge_partials(
    q: Query,
    partials: List[Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]],
) -> QueryResult:
    """Merge per-shard per-group partials into the final result.

    Partial sums/counts are re-keyed on group *values* (shard-local group
    numbering is arbitrary) and accumulated in float64; under the integral
    exactness envelope the float32 cast below reproduces the single-node
    kernel's per-group values bit-for-bit, and the shared
    ``result_from_group_state`` finishes HAVING chains and outer blocks
    identically to single-node execution.
    """
    attrs = tuple(q.groupby)
    if not attrs:
        s = float(sum(p[1].sum() for p in partials))
        c = float(sum(p[2].sum() for p in partials))
        agg = _finalize(q.agg.fn, np.array([s], dtype=np.float64),
                        np.array([c], dtype=np.float64))
        return result_from_group_state(q, {}, agg, np.array([c > 0]))
    keys, sums, counts = [], [], []
    for gv, s, c in partials:
        if s.shape[0] == 0:
            continue
        keys.append(np.stack([np.asarray(gv[a]) for a in attrs], axis=1))
        sums.append(s.astype(np.float64))
        counts.append(c.astype(np.float64))
    if not keys:
        return QueryResult(
            group_values={a: np.empty(0) for a in
                          (q.outer_groupby if q.outer_groupby else attrs)},
            values=np.empty(0))
    all_keys = np.concatenate(keys)
    uniq, inv = np.unique(all_keys, axis=0, return_inverse=True)
    sums_m = np.zeros(uniq.shape[0], dtype=np.float64)
    counts_m = np.zeros(uniq.shape[0], dtype=np.float64)
    np.add.at(sums_m, inv, np.concatenate(sums))
    np.add.at(counts_m, inv, np.concatenate(counts))
    agg = _finalize(q.agg.fn, sums_m, counts_m)
    group_values = {a: uniq[:, i] for i, a in enumerate(attrs)}
    return result_from_group_state(q, group_values, agg, counts_m > 0)


def _finalize(fn: str, sums: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """float32 finalization mirroring the executor's kernel arithmetic."""
    sums32 = sums.astype(np.float32)
    counts32 = counts.astype(np.float32)
    if fn == "count":
        return counts32
    if fn == "sum":
        return sums32
    if fn == "avg":
        return sums32 / np.maximum(counts32, np.float32(1.0))
    raise ValueError(f"unknown aggregate {fn!r}")
