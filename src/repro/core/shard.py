"""Fragment-sharded serving: place fragments across shards, route sketches.

The paper's payoff is that a captured sketch makes subsequent queries nearly
free by skipping non-provenance fragments.  Fragments are therefore the
natural unit of horizontal scale-out: a clustered ``ColumnTable``'s fragments
are placed across S shards (host-emulated shard objects by default, pinned to
a ``jax`` device mesh when one exists — see ``repro.parallel.placement``), and
a reused sketch is routed as a *fragment-id set* to only the shards owning set
bits.

Serving is SPMD by default: the contacted shards' local sketch instances are
kept as a *stacked shard-major* representation (``StackedInstances`` — rows
pow2-padded to a common count, stacked on a leading shard axis, group ids
rewritten into a coordinator-owned global dictionary), and ONE
``shard_map``/vmapped launch computes every shard's per-group partial
aggregates (sums + WHERE-passing counts) in a single XLA program, merging
them over the shard axis inside the launch.  ``ShardedEngine.run_batch``
extends the same launch with a leading query axis, so a whole hit batch —
even across different registered sketches — costs one program.  The
per-shard host loop (each shard's ``partial()`` evaluated separately, merged
by group key on the coordinator) survives behind ``fused=False`` — it is the
shape a real multi-process RPC deployment would take, and the benchmark
baseline.  Either way the query finishes with the same group-level code
single-node execution uses (``queries.result_from_group_state``), so routed
results match single-node results exactly whenever the aggregate arithmetic
is exact (integer-valued columns within float32 range — the same envelope
the maintenance subsystem pins, see
``SketchMaintainer._clears_trustworthy``).

Replication is delta-based, not state-based: ``append_rows``/``delete_rows``
are coordinator operations that route each batch by fragment ownership and
*ship* per-shard deltas into shard inboxes.  Shards advance independently —
each applies its pending deltas and advances its per-sketch maintainers the
next time it is read — and cross-shard reads gate on a minimum version
watermark (every contacted shard must have drained up to the coordinator's
mutation count) instead of a global lock.  Per-shard ``SketchMaintainer``s
behind one logical index entry keep the sketch's bits current shard-locally
whenever every group is shard-local (the placement attribute is part of the
(outer) GROUP BY, so a group's rows share one fragment and one owner); the
logical bits are then the OR of the shard bits.  For group-spanning queries
the HAVING chain needs global aggregates, so the coordinator's own maintainer
repairs the logical sketch (still delta-sized) and shards serve only the
routed partial aggregation.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.catalog import Catalog
from repro.core.engine import PBDSEngine, RunInfo
from repro.runtime import guards
from repro.runtime.guards import hot_path
from repro.core.index import IndexEntry
from repro.core.maintenance import MaintenanceError, SketchMaintainer, maintainer_for
from repro.core.replication import ReplicationRecord
from repro.core.queries import (
    Query,
    QueryResult,
    inner_block_arrays,
    inner_group_partials,
    result_from_group_state,
)
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.table import ColumnTable, Database, FragmentLayout
from repro.parallel.placement import (
    failover_device,
    place_stacked,
    place_table,
    serving_mesh,
    shard_devices,
)
from repro.runtime.elastic import plan_replacement
from repro.runtime.resilience import RetryPolicy, StragglerMonitor, with_retries


class ShardUnavailableError(RuntimeError):
    """A shard could not be reached: dead, partitioned, or mid-failure.

    The retryable error class of the serving layer — ``ShardedEngine`` wraps
    every shard op in ``runtime.resilience.with_retries`` against exactly
    this type, so transient drops retry while logic errors (e.g. the
    mis-routed-tail corruption guard) surface immediately.
    """


class BackpressureError(RuntimeError):
    """A shard's inbox is at its depth cap; the coordinator must drain or
    let its per-shard delta log carry the entry until the next resync."""


class StaleEpochError(RuntimeError):
    """A shard rejected an op fenced behind the coordinator epoch it has
    seen.  Deliberately NOT a ``ShardUnavailableError``: the serving layer
    must never retry a fenced-out coordinator's op — the op is invalid, not
    transient, and the only correct reaction is to stop acting as the
    coordinator (a newer one has taken over)."""


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Fragment -> shard ownership map for one range partition."""

    n_shards: int
    owner: np.ndarray  # (n_fragments,) shard id per fragment

    def fragments_of(self, shard_id: int) -> np.ndarray:
        return np.nonzero(self.owner == shard_id)[0]

    def shards_for(self, frag_ids: np.ndarray) -> np.ndarray:
        """The distinct shards owning any of ``frag_ids`` — the route set."""
        return np.unique(self.owner[np.asarray(frag_ids)])


def plan_fragments(
    sizes: np.ndarray, n_shards: int, policy: str = "contig"
) -> ShardPlan:
    """Place fragments on shards.

    ``contig`` (default) cuts the fragment sequence into row-balanced
    contiguous runs, preserving range locality — a selective sketch's bits
    are usually clustered in value space, so contiguous ownership maximizes
    fully-skipped shards.  ``spread`` round-robins fragments, trading
    locality for uniform load under adversarial (striped) sketches.
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    n_frags = sizes.shape[0]
    owner = np.zeros(n_frags, dtype=np.int64)
    if policy == "spread":
        owner = np.arange(n_frags, dtype=np.int64) % n_shards
    elif policy == "contig":
        per = sizes.sum() / max(n_shards, 1)
        s, load = 0, 0.0
        for f in range(n_frags):
            if s < n_shards - 1 and load >= per:
                s, load = s + 1, 0.0
            owner[f] = s
            load += sizes[f]
    else:
        raise ValueError(f"unknown placement policy {policy!r}")
    return ShardPlan(n_shards=n_shards, owner=owner)


def local_table_for(
    shard_id: int,
    plan: ShardPlan,
    ranges: RangeSet,
    clustered: ColumnTable,
    version: int = 0,
) -> ColumnTable:
    """Gather ``shard_id``'s owned rows out of the coordinator's clustered
    table into a shard-local clustered layout.

    Factored out of ``FragmentShard.__init__`` so the peer-checkpoint path
    can derive the exact same local table on the coordinator and ship it to
    a *peer* shard process — recovery then pulls shard-sized state from the
    peer instead of re-shipping the full table from the coordinator.
    """
    if clustered.layout is None:
        raise ValueError("shards are built from a clustered table")
    owned = plan.fragments_of(shard_id)
    lay = clustered.layout
    off = lay.offsets
    parts = [np.arange(off[f], off[f + 1]) for f in owned]
    n_tail_local = 0
    if lay.tail:
        # Rebuild-from-coordinator path (failover/rebalance): the source
        # table may carry an unsorted append tail — route its rows by
        # fragment ownership exactly like ``ShardedEngine.append_rows``.
        n = clustered.num_rows
        tail_vals = np.asarray(clustered[ranges.attr])[n - lay.tail:]
        tail_frag = np.asarray(ranges.bucketize(jnp.asarray(tail_vals)))  # analyze: waive[SYNC01]: recovery/rebuild path (shard construction), not a serving hot path — tail routing needs host fragment ids
        own_tail = (n - lay.tail) + np.nonzero(
            plan.owner[tail_frag] == shard_id)[0]
        n_tail_local = int(own_tail.shape[0])
        parts.append(own_tail)
    idx = np.concatenate(parts) if parts else np.empty(0, dtype=np.int64)
    local = clustered.gather(jnp.asarray(idx))
    local_sizes = np.array([off[f + 1] - off[f] for f in owned],
                           dtype=np.int64)
    layout = FragmentLayout(
        attr=ranges.attr,
        # Never collides with a RangeSet.key(): local fragment ids are a
        # different coordinate system from the global partition's.
        ranges_key=("shard", shard_id, ranges.key()),
        offsets=np.concatenate([[0], np.cumsum(local_sizes)]).astype(np.int64),
        tail=n_tail_local,
    )
    return ColumnTable(local.name, local.columns, clustered.primary_key,
                       layout, version=version)


class FragmentShard:
    """One shard: its owned fragments' rows, catalog, and sketch maintainers.

    The local table is clustered over the *owned* fragments (local fragment j
    is the j-th owned global fragment, ascending), with appended rows landing
    in the layout's unsorted tail exactly like a single-node clustered table.
    Deltas arrive through ``ship`` into an inbox and are applied lazily by
    ``catch_up`` — the emulation of asynchronous replication.
    """

    MAX_DELTA_CHAIN = 16

    def __init__(
        self,
        shard_id: int,
        plan: ShardPlan,
        ranges: RangeSet,
        clustered: ColumnTable,
        dims: Mapping[str, ColumnTable],
        device=None,
        inbox_cap: Optional[int] = None,
        version: int = 0,
        local_table: Optional[ColumnTable] = None,
    ):
        self.shard_id = shard_id
        self.ranges = ranges
        self.owned = plan.fragments_of(shard_id)
        # global fragment id -> local fragment position (-1 = not owned).
        self._local_of_global = np.full(ranges.n_ranges, -1, dtype=np.int64)
        self._local_of_global[self.owned] = np.arange(self.owned.shape[0])

        if local_table is None:
            local_table = local_table_for(shard_id, plan, ranges, clustered,
                                          version=version)
        self.device = device
        self.table: Optional[ColumnTable] = place_table(local_table, device)
        self.dims: Dict[str, ColumnTable] = {
            k: place_table(v, device) for k, v in dims.items()}
        self.catalog = Catalog()
        self.maintainers: Dict[int, SketchMaintainer] = {}
        self._inst: Dict[int, Tuple[Tuple, ColumnTable]] = {}
        self._inbox: Deque[Tuple[int, str, object]] = collections.deque()
        # Inbox depth cap: a shard that never drains (dead, partitioned)
        # must not silently eat the coordinator's memory — past the cap
        # ``ship`` raises ``BackpressureError`` and the coordinator's delta
        # log carries the entry until the next resync.
        self.inbox_cap = inbox_cap
        self.backpressure_hits = 0
        # Fault-injection state (``runtime.chaos`` drives it): the guard
        # below is the in-process stand-in for an RPC boundary.
        self.fault: Optional[str] = None  # None|"dead"|"stall"|"partition"|"flaky"
        self.stall_s = 0.0
        self._flaky_fails = 0
        # Highest coordinator epoch this shard has accepted an op from.
        # Survives kills/rebuilds of the shard's *state* — it is process
        # identity, not table state — so a fenced-out coordinator stays
        # fenced out even across shard recovery.
        self.epoch = 0

    @classmethod
    def from_local(
        cls,
        shard_id: int,
        plan: ShardPlan,
        ranges: RangeSet,
        local_table: ColumnTable,
        dims: Mapping[str, ColumnTable],
        device=None,
        inbox_cap: Optional[int] = None,
    ) -> "FragmentShard":
        """Build a shard directly from an already-local table (peer-replicated
        checkpoint recovery) — no coordinator-table gather, no full reship."""
        return cls(shard_id, plan, ranges, clustered=None, dims=dims,
                   device=device, inbox_cap=inbox_cap,
                   local_table=local_table)

    # -- epoch fencing ---------------------------------------------------------
    def fence(self, epoch: int, op: str = "") -> None:
        """Reject ops fenced behind the newest coordinator epoch seen.

        Monotone max: a newer coordinator's first op bumps the shard's epoch,
        after which every op from the old (possibly partitioned) coordinator
        raises ``StaleEpochError`` — zombie mutations cannot land.
        """
        if epoch < self.epoch:
            raise StaleEpochError(
                f"shard {self.shard_id}: coordinator epoch {epoch} is fenced "
                f"behind {self.epoch} ({op or 'op'})")
        self.epoch = epoch

    # -- fault injection -------------------------------------------------------
    def _guard(self, op: str) -> None:
        """Every shard op passes through here — the failure choke point."""
        if self.fault in ("dead", "partition"):
            raise ShardUnavailableError(
                f"shard {self.shard_id} is {self.fault} ({op})")
        if self.fault == "flaky":
            self._flaky_fails -= 1
            if self._flaky_fails <= 0:
                self.fault = None
            raise ShardUnavailableError(
                f"shard {self.shard_id} dropped {op} (flaky)")
        if self.fault == "stall" and self.stall_s > 0:
            time.sleep(self.stall_s)
        if self.table is None:
            raise ShardUnavailableError(
                f"shard {self.shard_id} lost its state ({op})")

    def inject(self, kind: str, arg=None) -> None:
        """Inject one fault.  ``kill`` loses ALL in-memory state — table,
        maintainers, caches, inbox — exactly like a process death; ``stall``
        makes every op sleep (a straggler); ``partition`` makes the shard
        unreachable with state intact; ``flaky`` fails the next ``arg`` ops
        then self-heals (exercises the retry path)."""
        if kind == "kill":
            self.fault = "dead"
            self.table = None
            self.maintainers.clear()
            self._inst.clear()
            self._inbox.clear()
            self.catalog = Catalog()
        elif kind == "stall":
            self.fault = "stall"
            self.stall_s = float(arg) if arg is not None else 0.02
        elif kind == "partition":
            self.fault = "partition"
        elif kind == "flaky":
            self.fault = "flaky"
            self._flaky_fails = int(arg) if arg is not None else 1
        else:
            raise ValueError(f"unknown fault kind {kind!r}")

    def heal(self) -> None:
        """Clear any injected fault.  A killed shard becomes *reachable but
        empty* — the coordinator detects the lost state on its next read and
        runs checkpoint-adopt + delta-replay + re-registration recovery."""
        self.fault = None
        self.stall_s = 0.0
        self._flaky_fails = 0

    @property
    def reachable(self) -> bool:
        """Can the coordinator talk to this shard at all right now?"""
        return self.fault not in ("dead", "partition")

    def adopt(self, table: ColumnTable, dims: Mapping[str, ColumnTable]) -> None:
        """Install recovered state (checkpoint table + current dims) after a
        kill; maintainers and caches are gone until re-registration."""
        self.table = place_table(table, self.device)
        self.dims = {k: place_table(v, self.device) for k, v in dims.items()}
        self.catalog = Catalog()
        self.maintainers = {}
        self._inst = {}
        self._inbox.clear()

    # -- replication -----------------------------------------------------------
    @property
    def version(self) -> int:
        """Local watermark: how many fact-table deltas have been applied
        (``-1`` while the shard's state is lost)."""
        return self.table.version if self.table is not None else -1

    @property
    def lag(self) -> int:
        return len(self._inbox)

    def ship(self, version: int, kind: str, payload) -> None:
        """Enqueue one versioned delta (``append`` row batch / ``delete``
        local mask).  Delivery is idempotent — ``catch_up`` drops entries at
        or below the local version — so the coordinator may re-ship a log
        suffix after a partition without coordination."""
        self._guard("ship")
        if self.inbox_cap is not None and len(self._inbox) >= self.inbox_cap:
            self.backpressure_hits += 1
            raise BackpressureError(
                f"shard {self.shard_id} inbox at cap ({self.inbox_cap})")
        self._inbox.append((version, kind, payload))

    def update_dim(self, table: ColumnTable) -> None:
        """Replace a replicated dimension table (applied eagerly — dimension
        mutations are rare and invalidate join maintainers wholesale)."""
        self._guard("update_dim")
        old = self.dims.get(table.name)
        if old is not None:
            self.catalog.invalidate_table(old)
        self.dims[table.name] = place_table(table, self.device)
        for key in [k for k, m in self.maintainers.items()
                    if m.q.join is not None and m.q.join.right == table.name]:
            del self.maintainers[key]

    def _db(self) -> Database:
        tables = dict(self.dims)
        tables[self.table.name] = self.table
        return Database(tables)

    def catch_up(self, watermark: int) -> int:
        """Drain pending deltas up to ``watermark``; advance maintainers.

        Returns the number of deltas applied.  Work is delta-sized: the
        table grows/shrinks by the batch, maintainers re-count only the
        batch rows, and catalog entries refresh through the delta chain.
        A maintainer that cannot advance (e.g. its dimension table was
        replaced mid-chain) is dropped; the coordinator re-registers it
        from scratch on the next read that needs it.  Duplicate inbox
        entries (version at or below the local watermark — resync re-ships)
        are dropped; a version *gap* (a ship lost to backpressure or a
        partition) stops the drain so the coordinator can resync the
        missing suffix from its delta log.
        """
        self._guard("catch_up")
        applied = 0
        while self.table.version < watermark and self._inbox:
            version, kind, payload = self._inbox[0]
            if version <= self.table.version:
                self._inbox.popleft()  # duplicate re-ship: idempotent skip
                continue
            if version > self.table.version + 1:
                break  # gap: wait for the coordinator's log resync
            self._inbox.popleft()
            if kind == "append":
                self.table = self.table.append(payload)
            elif kind == "delete":
                self.table = self.table.delete(payload)
            else:  # pragma: no cover - defensive
                raise ValueError(f"unknown delta kind {kind!r}")
            applied += 1
        if applied:
            db = self._db()
            for key, m in list(self.maintainers.items()):
                try:
                    m.apply(self.table, db)
                except MaintenanceError:
                    del self.maintainers[key]
            self._inst.clear()
        if self.table.delta_depth() > self.MAX_DELTA_CHAIN:
            self.catalog.invalidate_chain(self.table)
            self.table = self.table.collapse()
        return applied

    # -- sketch registration ---------------------------------------------------
    def register(self, key: int, q: Query, ranges: RangeSet) -> None:
        """Build this shard's maintainer for one logical index entry.

        The shard must be at the coordinator's watermark (the maintainer
        counts the *current* local rows).  Registration waves sharing an
        inner-block signature (batched admission, recovery re-registration)
        pay ONE local counting pass and clone the rest.
        """
        self._guard("register")
        self.maintainers[key] = maintainer_for(
            q, self._db(), ranges, self.catalog,
            list(self.maintainers.values()))

    def unregister(self, key: int) -> None:
        self.maintainers.pop(key, None)
        self._inst.pop(key, None)

    def bits_for(self, key: int) -> Optional[np.ndarray]:
        """This shard's maintained sketch bits (global fragment ids), or
        ``None`` when the maintainer was lost and needs re-registration."""
        self._guard("bits_for")
        m = self.maintainers.get(key)
        return m.bits() if m is not None else None

    # -- query serving ---------------------------------------------------------
    def _instance(self, key: int, ranges: RangeSet, bits: np.ndarray) -> ColumnTable:
        """The local sketch instance: owned ∩ sketch fragments (+ tail filter).

        When the sketch's partition is the serving partition this is pure
        slice concatenation over the local fragment-major layout; any other
        partition falls back to the per-row keep-mask over local rows.
        """
        self._guard("instance")
        token = (id(self.table), bits.tobytes())
        cached = self._inst.get(key)
        if cached is not None and cached[0] == token:
            self.catalog.stats["instance_hit"] += 1
            return cached[1]
        lay = self.table.layout
        if ranges.key() == self.ranges.key():
            local_ids = np.nonzero(bits[self.owned])[0]
            tail_bucket = None
            if lay.tail:
                gfrag = np.asarray(self.catalog.bucketize(self.table, self.ranges))  # analyze: waive[SYNC01]: deliberate merge: instance build (registration-time) maps global fragments to shard-local ids on host
                tail_bucket = self._local_of_global[
                    gfrag[self.table.num_rows - lay.tail:]]
                if tail_bucket.size and tail_bucket.min() < 0:
                    # A tail row bucketized to a fragment this shard does not
                    # own: routing and bucketization disagree — corruption.
                    raise RuntimeError(
                        f"shard {self.shard_id}: mis-routed tail rows "
                        f"(fragments {np.unique(gfrag[self.table.num_rows - lay.tail:][tail_bucket < 0])})")
            inst = self.table.take_fragments(local_ids, tail_bucket=tail_bucket)
            self.catalog.stats["instance_slices"] += 1
        else:
            bucket = self.catalog.bucketize(self.table, ranges)
            inst = self.table.select(jnp.asarray(bits)[bucket])
            self.catalog.stats["instance_mask"] += 1
        self._inst[key] = (token, inst)
        return inst

    def partial(
        self, q: Query, key: int, ranges: RangeSet, bits: np.ndarray
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]:
        """Per-group partial aggregates of the inner block over the local
        sketch instance: ``(group key values, sums, WHERE-passing counts)``.

        Group ids are shard-local; the coordinator re-keys on the group
        *values* when merging, so numbering never has to be coordinated.
        """
        inst = self._instance(key, ranges, bits)
        if q.join is not None:
            flat, _ = self.catalog.join(
                inst, self.dims[q.join.right], q.join.left_key, q.join.right_key)
        else:
            flat = inst
        enc, _, sums, counts = inner_group_partials(q, flat, self.catalog)
        return enc.group_values, np.asarray(sums), np.asarray(counts)


# ---------------------------------------------------------------------------
# Stacked shard-major execution (the fused SPMD hot path)
# ---------------------------------------------------------------------------

# Telemetry for the fused launch: ``TRACE_COUNTS`` bumps at trace time only
# (tests assert pow2 quantization keeps shard-count / sketch-set changes in
# one compiled size class), ``LAUNCH_COUNTS`` bumps once per host-side
# invocation (tests assert the hit path costs exactly one launch per batch).
# Both live in the shared ``runtime.guards`` registry (keys owned here);
# the module-level names stay for callers/tests addressing them as
# ``shard.TRACE_COUNTS``.
TRACE_COUNTS: collections.Counter = guards.TRACE_COUNTS
LAUNCH_COUNTS: collections.Counter = guards.LAUNCH_COUNTS


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


@dataclasses.dataclass(frozen=True)
class StackedInstances:
    """Shard-major stacked inner-block arrays for one registered entry.

    Per-shard sketch-instance rows (post-join flat tables) are padded to a
    common pow2 row count and stacked on a leading shard axis — values, group
    ids (in the coordinator-owned *global* group dictionary), and weights
    (WHERE ∧ row-validity; padded rows carry weight 0, the ``__valid__``
    convention of pow2-padded instances).  All three carry a leading
    query axis of 1 so a batch of hits concatenates without reshapes.  The
    shard axis is pow2-padded too, and placed over the 1-D serving mesh when
    one exists, so one ``shard_map``/vmapped launch computes every shard's
    per-group partials in a single XLA program.
    """

    vals: jax.Array  # (1, S_pad, R_pad) f32
    gid: jax.Array  # (1, S_pad, R_pad) i32 — global group ids
    weights: jax.Array  # (1, S_pad, R_pad) f32 — WHERE ∧ valid
    n_groups: int
    g_pad: int
    group_values: Dict[str, np.ndarray]  # global dictionary (np.unique order)
    contacted_ids: Tuple[int, ...]  # shard ids owning >= 1 sketch fragment
    token: Tuple = ()  # freshness token (shard table versions + sketch bits)

    @property
    def contacted(self) -> int:
        return len(self.contacted_ids)

    @property
    def r_pad(self) -> int:
        return int(self.vals.shape[2])


def _fused_body(vals, gid, w, g_pad: int):
    """(K, S, R) stacked arrays -> (K, g_pad) merged per-group sums/counts.

    One program: each query's shard slices flatten into one row axis (the
    shard-axis reduction IS the segment sum — group ids are already global),
    so the batched segment-aggregate kernel runs with batch = the query axis
    only.  f32 sums of integral values are exact under any association, so
    the result is bit-identical to the host-loop per-shard-partial merge and
    to single-node execution (the envelope ``tests/test_shard.py`` pins).
    """
    TRACE_COUNTS["fused_partials"] += 1
    from repro.kernels import ops as kops

    k, s, r = vals.shape
    return kops.segment_aggregate_batch(
        vals.reshape(k, s * r), gid.reshape(k, s * r), g_pad,
        w.reshape(k, s * r))


_fused_jit = functools.partial(jax.jit, static_argnums=(3,))(_fused_body)

# mesh id -> (mesh, jitted shard_map fn); the mesh reference keeps the id valid.
_SPMD_FNS: Dict[int, Tuple[object, object]] = {}


def _spmd_body(vals, gid, w, g_pad: int):
    """Per-device block of the shard_map launch: each device reduces its
    local shard slices into (K, g_pad) partial matrices, psum merges."""
    TRACE_COUNTS["fused_partials"] += 1
    from repro.kernels import ops as kops

    k, s, r = vals.shape
    sums, counts = kops.segment_aggregate_batch(
        vals.reshape(k, s * r), gid.reshape(k, s * r), g_pad,
        w.reshape(k, s * r))
    return jax.lax.psum(sums, "shards"), jax.lax.psum(counts, "shards")


def _fused_spmd_fn(mesh):
    """The jitted shard_map launch for one mesh (cached per mesh)."""
    hit = _SPMD_FNS.get(id(mesh))
    if hit is not None:
        return hit[1]
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    @functools.partial(jax.jit, static_argnums=(3,))
    def fn(vals, gid, w, g_pad):
        body = shard_map(
            functools.partial(_spmd_body, g_pad=g_pad),
            mesh=mesh,
            in_specs=(P(None, "shards", None),) * 3,
            out_specs=(P(None, None), P(None, None)),
        )
        return body(vals, gid, w)

    _SPMD_FNS[id(mesh)] = (mesh, fn)
    return fn


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Registered:
    """Routed-serving state for one logical index entry.

    ``group_local`` selects the bits source: per-shard maintainers when every
    group is shard-local, the coordinator's maintainer otherwise.
    Registration state is keyed by ``entry.reg_id`` — a stable counter the
    coordinator assigns at admission and replicates, so a standby
    coordinator's rebuilt entries re-attach to the maintainers the shard
    processes already hold without re-registration.
    """

    entry: IndexEntry
    ranges: RangeSet
    group_local: bool


@dataclasses.dataclass
class RouteInfo:
    """Bookkeeping of one routed (reused-sketch) execution or hit batch."""

    contacted: int
    skipped: int
    watermark: int
    deltas_applied: int
    per_shard_s: Dict[int, float]
    t_merge_s: float
    # Device-launch wall time: the single stacked program on the fused path,
    # the summed per-shard ``partial()`` calls on the host-loop path.
    t_launch_s: float = 0.0
    # True when served by the stacked one-launch SPMD path, False for the
    # per-shard host loop.
    fused: bool = False
    # Queries served by this route (one, or a run_batch hit batch).
    n_queries: int = 1
    # Degraded-mode bookkeeping: ``failed_shards`` lists the shards whose
    # fragment slices were served from the coordinator's authoritative table
    # this route (down, partitioned, or past the op deadline), ``n_retries``
    # the transient shard-op failures retried away by ``with_retries``.
    degraded: bool = False
    failed_shards: Tuple[int, ...] = ()
    n_retries: int = 0
    # Cumulative count of peer-mirror refreshes that could not land (peer
    # dead/backpressured): while nonzero-and-growing, a shard kill may pay a
    # full coordinator-checkpoint re-ship instead of the delta-sized peer
    # restore.  Surfaced so operators see silent staleness, not just feel it.
    stale_checkpoints: int = 0

    @property
    def t_critical_s(self) -> float:
        """Emulated shard-parallel latency.  Host-loop: slowest contacted
        shard + merge (host-emulated shards run sequentially; real
        deployments overlap).  Fused: the one launch already computes all
        shards in a single program, so launch + merge IS the critical path."""
        if self.fused:
            return self.t_launch_s + self.t_merge_s
        return (max(self.per_shard_s.values()) if self.per_shard_s else 0.0) \
            + self.t_merge_s


class ShardedEngine:
    """Coordinator: a ``PBDSEngine`` for selection/capture plus S fragment
    shards for serving.

    The coordinator keeps the authoritative table (captures, candidate
    selection, and NO-PS fallbacks run single-node over it); index *hits* are
    served routed: per-shard maintained bits are OR-merged into the logical
    sketch, only shards owning set bits are contacted, and their per-group
    partials are merged into the final result.  Mutations ship per-shard
    deltas and return immediately; shards drain on their next read.
    """

    def __init__(
        self,
        db: Database,
        table: str,
        attr: str,
        n_shards: int,
        n_ranges: int = 64,
        strategy: str = "CB-OPT-GB",
        policy: str = "contig",
        use_devices: bool = True,
        fused: bool = True,
        max_registered: Optional[int] = None,
        health: bool = True,
        op_deadline_s: float = 5.0,
        inbox_cap: Optional[int] = 4096,
        retry_policy: Optional[RetryPolicy] = None,
        transport: str = "loopback",
        epoch: int = 0,
        _boot: Optional[Mapping] = None,
        **engine_kwargs,
    ):
        for k in ("cluster_tables", "compact_tail_frac"):
            if k in engine_kwargs:
                # Physical re-permutes of the coordinator table would desync
                # the global-row -> shard-row map that delete routing needs.
                raise ValueError(f"{k} is coordinator-managed in ShardedEngine")
        boot = dict(_boot or {})
        self.table_name = table
        self.attr = attr
        self.n_shards = n_shards
        if "ranges" in boot:
            # Takeover path (``from_replica``): the partition, clustering and
            # placement are *adopted* from the replicated bootstrap, never
            # re-derived — re-deriving them on the post-mutation table would
            # silently re-fragment and orphan every registered sketch.
            self.ranges = boot["ranges"]
            clustered = boot["clustered"]
        else:
            self.ranges = equi_depth_ranges(db[table], attr, n_ranges)
            clustered = db[table].cluster_by(self.ranges)
        self.engine = PBDSEngine(
            db.with_table(clustered), strategy=strategy, n_ranges=n_ranges,
            **engine_kwargs)
        # The serving partition IS the engine's partition for ``attr``, so a
        # sketch selected on it routes as fragment slices on every shard.
        self.engine._ranges_cache[(table, attr)] = self.ranges
        if "owner" in boot:
            self.plan = ShardPlan(n_shards=n_shards,
                                  owner=np.asarray(boot["owner"]))
        else:
            self.plan = plan_fragments(
                np.diff(clustered.layout.offsets), n_shards, policy=policy)
        self.policy = policy
        self.use_devices = use_devices
        self._engine_kwargs = dict(engine_kwargs)
        self._n_ranges = n_ranges
        dims = {k: v for k, v in self.engine.db.tables.items() if k != table}
        self._devices = shard_devices(n_shards, use_devices)
        self._inbox_cap = inbox_cap
        # Shard surface: every shard op goes through a ShardClient.  The
        # loopback backend wraps in-process FragmentShards (zero-copy,
        # today's behavior); the subprocess backend runs each shard as a
        # separate OS process behind a socket RPC channel — same failure
        # vocabulary (ShardUnavailableError / BackpressureError), so the
        # health machine and degraded routing below are backend-blind.
        from repro.core import shard_rpc  # deferred: shard_rpc imports us

        self.transport = transport
        # Coordinator epoch: carried on every shard op; shards fence out any
        # lower epoch (see ``FragmentShard.fence``), so a superseded
        # coordinator cannot land zombie mutations after a takeover.
        self.epoch = int(epoch)
        if boot.get("attach") is not None:
            # Takeover re-attach: wrap the *live* shard transports (loopback
            # FragmentShards / subprocess server sockets) in fresh clients
            # owned by this coordinator — no shard state moves.
            self.shards = [c.clone_for_takeover() for c in boot["attach"]]
        elif transport == "loopback":
            self.shards = [
                shard_rpc.LoopbackShardClient(
                    FragmentShard(s, self.plan, self.ranges, clustered, dims,
                                  self._devices[s], inbox_cap=inbox_cap))
                for s in range(n_shards)
            ]
        elif transport == "subprocess":
            self.shards = [
                shard_rpc.SubprocessShardClient(
                    s, self.plan, self.ranges, clustered, dims,
                    inbox_cap=inbox_cap, op_deadline_s=op_deadline_s)
                for s in range(n_shards)
            ]
        else:
            raise ValueError(f"unknown transport {transport!r}")
        for c in self.shards:
            c.epoch = self.epoch
        # Global-row -> (shard, local-row) map, maintained across mutations so
        # coordinator delete masks translate to shard-local masks.
        if boot:
            # The adopted table may carry an unsorted append tail; the full
            # recompute routes it by ownership like ``append_rows`` did.
            self._rebuild_row_maps()
        else:
            n = clustered.num_rows
            frag_of_row = np.searchsorted(
                clustered.layout.offsets, np.arange(n), side="right") - 1
            self._row_shard = self.plan.owner[frag_of_row]
            self._row_local = np.empty(n, dtype=np.int64)
            self._shard_rows = np.zeros(n_shards, dtype=np.int64)
            for s in range(n_shards):
                sel = self._row_shard == s
                self._shard_rows[s] = int(sel.sum())
                self._row_local[sel] = np.arange(self._shard_rows[s])
        # Coordinator mutation count == the read watermark.
        self.version = int(boot.get("version", 0))
        # reg_id -> routed-serving state for that logical entry.
        self._registered: Dict[int, _Registered] = {}
        # Monotone registration-id source; replicated so a standby keeps
        # minting ids the shards have never seen.
        self._reg_counter = int(boot.get("reg_counter", 1))
        # -- metadata replication (``core/replication``): every metadata
        # mutation is streamed as a monotonically-sequenced record to the
        # attached replica.  ``None`` = not replicating; a publish failure
        # degrades (drops the replica) but never takes down serving.
        self._replica = None
        self._rep_seq = 0
        self.replica_degraded = False
        # -- peer-replicated checkpoints (subprocess transport): shard
        # ``sid``'s local table is mirrored on shard ``(sid+1) % S``'s server
        # process and kept current by the same per-shard deltas ``_ship``
        # already produces, so recovery of a killed server pulls shard-sized
        # state from the peer instead of re-shipping the full table.
        self._peer_mirroring = (transport == "subprocess" and n_shards > 1)
        self._peer_ok = [False] * n_shards
        self.peer_restores = 0
        # Stale-checkpoint signal (satellite): a checkpoint or peer mirror
        # that could not advance past a failure is *counted*, not silently
        # left behind; ``RouteInfo.stale_checkpoints`` surfaces the total.
        self.stale_checkpoints = [0] * n_shards
        self.last_route: Optional[RouteInfo] = None
        # Fused SPMD serving: stacked one-launch execution (the default);
        # ``fused=False`` keeps the per-shard host loop (benchmark baseline,
        # and the only path real multi-process RPC shards could take today).
        self.fused = fused
        self._mesh = serving_mesh(use_devices)
        # Per-shard memory bound: registrations beyond this are pruned by the
        # coordinator's recency clock (``SketchIndex.prune``) after each
        # registration pass, evicting shard maintainers + cached instances.
        self.max_registered = max_registered
        # -- shard health tracking (healthy -> suspect -> dead -> recovering
        # -> healthy).  ``health=False`` bypasses the per-op wrapper entirely
        # (fault-free benchmarking baseline: quantifies the tracking layer's
        # overhead; never run it against a chaotic cluster).
        self.health_tracking = health
        self.op_deadline_s = op_deadline_s
        self._retry_policy = retry_policy or RetryPolicy(
            max_attempts=3, backoff_s=1e-3, backoff_mult=2.0,
            retryable=(ShardUnavailableError,), deadline_s=op_deadline_s)
        self.health: List[str] = ["healthy"] * n_shards
        self._monitors: Dict[Tuple[int, str], StragglerMonitor] = {}
        self._route_retries = 0
        # Coordinator-durable recovery state: per-shard checkpoint (loopback:
        # a reference to the shard's immutable local table as of its last
        # fully drained read; subprocess: the coordinator's clustered table
        # at the watermark, from which the shard rebuilds server-side) plus
        # the delta log of everything shipped past it.  Recovery of a lost
        # shard is checkpoint-restore + delta-replay + maintainer
        # re-registration — never a from-scratch re-capture.
        if boot.get("attach") is not None:
            # Live shards keep their state; the replicated delta-log suffix
            # covers anything a shard has not yet drained or ever received.
            self._ckpt = []
            for c in self.shards:
                try:
                    self._ckpt.append(
                        None if c.state_lost
                        else c.make_checkpoint(self.db[table], self.version))
                except ShardUnavailableError:
                    self._ckpt.append(None)
            logs = boot.get("log") or [[] for _ in range(n_shards)]
            self._log = [list(entries) for entries in logs]
        else:
            self._ckpt = [c.make_checkpoint(clustered, 0) for c in self.shards]
            self._log = [[] for _ in range(n_shards)]

    # -- metadata replication / standby takeover -------------------------------
    def _emit(self, kind: str, payload) -> None:
        if self._replica is None:
            return
        self._rep_seq += 1
        try:
            self._replica.publish(ReplicationRecord(self._rep_seq, kind,
                                                    payload))
        except Exception:
            # Replica loss degrades replication, never serving: the active
            # coordinator keeps answering queries without a standby.
            self._replica = None
            self.replica_degraded = True

    def _plan_token(self) -> int:
        """Fingerprint of the current placement; stamps peer-mirrored
        checkpoints so a mirror built under a pre-rebalance plan can never
        be adopted under the new one."""
        return hash((self.n_shards, self.plan.owner.tobytes()))

    def _reg_payloads(self, entries: Sequence[IndexEntry]) -> List[dict]:
        out = []
        for e in entries:
            reg = self._registered.get(e.reg_id)
            m = e.maintainer
            out.append({
                "reg_id": e.reg_id,
                "query": e.query,
                "ranges": reg.ranges if reg is not None else e.sketch.ranges,
                "registered": reg is not None,
                "group_local": (reg.group_local if reg is not None else False),
                # Counter state rides along (miss-path only — registration is
                # already a capture) so takeover restores the maintainer
                # instead of paying the per-sketch group re-encode.
                "state": (m.state_dict()
                          if isinstance(m, SketchMaintainer) else None),
            })
        return out

    def _boot_payload(self) -> dict:
        dims = {k: v.collapse() for k, v in self.engine.db.tables.items()
                if k != self.table_name}
        return {
            "table": self.table_name,
            "attr": self.attr,
            "n_shards": self.n_shards,
            "n_ranges": self._n_ranges,
            "strategy": self.engine.strategy,
            "policy": self.policy,
            "use_devices": self.use_devices,
            "fused": self.fused,
            "max_registered": self.max_registered,
            "health": self.health_tracking,
            "op_deadline_s": self.op_deadline_s,
            "inbox_cap": self._inbox_cap,
            "transport": self.transport,
            "engine_kwargs": dict(self._engine_kwargs),
            "ranges": self.ranges,
            "owner": np.asarray(self.plan.owner),
            "clustered": self.db[self.table_name].collapse(),
            "dims": dims,
            "version": self.version,
            "log": [list(entries) for entries in self._log],
            "ckpt_versions": [None if c is None else c.version
                              for c in self._ckpt],
            "reg_counter": self._reg_counter,
            "selection": self.selection_state(),
            "epoch": self.epoch,
        }

    def attach_replica(self, replica) -> None:
        """Start streaming metadata mutations to ``replica`` (warm standby).

        Emits a full bootstrap — base state, current delta logs, every live
        registration, the selection snapshot — so a standby attached mid-life
        (or re-armed by a freshly-promoted coordinator) holds everything;
        every later metadata mutation streams as its own sequenced record.
        """
        self._replica = replica
        self._rep_seq = 0
        self.replica_degraded = False
        self._emit("bootstrap", self._boot_payload())
        regs = self._reg_payloads(
            [e for e in self.engine.index.entries() if e.reg_id > 0])
        if regs:
            self._emit("register", regs)

    @classmethod
    def from_replica(cls, store, *, epoch: int,
                     attach: Optional[Sequence] = None) -> "ShardedEngine":
        """Standby takeover: rebuild a serving coordinator from replicated
        metadata alone.

        * The clustered base table + the replicated mutation log replay to
          the exact coordinator table (same row order, same (uid, version)
          lineage — so shard-side freshness tokens keep matching).
        * Partition, placement and delta-log suffixes are adopted, never
          re-derived.
        * Index entries rebuild **locally** under their replicated
          ``reg_id``s — replicated maintainer counter state restores via
          ``SketchMaintainer.from_state`` + delta replay (falling back to an
          eager ``maintainer_for`` counting pass when the state is stale or
          unwalkable) — the shards' maintainers are still keyed by those
          ids, so hits stay hits with zero re-registration RPCs and
          ``index.misses`` stays flat: no re-capture, ever.
        * ``attach`` re-wraps the *live* shard transports; no full-table
          reship to any shard that still has its state.

        The caller owns fencing: construct with a bumped ``epoch``, then the
        first catch-up round stamps it onto every shard.
        """
        b = store.boot
        if b is None:
            raise RuntimeError("replica has no bootstrap record")
        table_name = b["table"]
        fact = b["clustered"]
        dims = dict(b["dims"])
        for mkind, tname, payload, _v in store.muts:
            if tname == table_name:
                fact = (fact.append(payload) if mkind == "append"
                        else fact.delete(payload))
                if fact.delta_depth() >= 64:
                    fact = fact.collapse()
            else:
                t = dims[tname]
                dims[tname] = (t.append(payload) if mkind == "append"
                               else t.delete(payload))
        db = Database({table_name: fact, **dims})
        self = cls(
            db, table_name, b["attr"], b["n_shards"],
            n_ranges=b["n_ranges"], strategy=b["strategy"],
            policy=b["policy"], use_devices=b["use_devices"],
            fused=b["fused"], max_registered=b["max_registered"],
            health=b["health"], op_deadline_s=b["op_deadline_s"],
            inbox_cap=b["inbox_cap"], transport=b["transport"],
            epoch=epoch,
            _boot=dict(
                ranges=b["ranges"], clustered=fact, owner=store.owner,
                attach=attach, version=store.version,
                log=store.ship_logs(b["n_shards"]),
                reg_counter=store.reg_counter),
            **b["engine_kwargs"],
        )
        if store.selection:
            self.restore_selection_state(store.selection)
        catalog = self.engine.catalog
        pool: List[SketchMaintainer] = []
        for rid, p in store.regs.items():
            q, ranges = p["query"], p["ranges"]
            m = None
            state = p.get("state")
            if state is not None:
                # Fast path: resurrect the replicated counter state and
                # delta-replay it to the current version — skips the
                # per-sketch group re-encode, which dominates takeover cost.
                try:
                    m = SketchMaintainer.from_state(
                        q, self.engine.db, ranges, state)
                    m.apply(self.engine.db[q.table], self.engine.db)
                except MaintenanceError:
                    m = None  # stale/unwalkable state: rebuild eagerly below
            try:
                if m is None:
                    m = maintainer_for(q, self.engine.db, ranges, catalog,
                                       pool)
                sketch = m.to_sketch(self.engine.db[q.table], catalog)
            except MaintenanceError:
                continue  # unrebuildable under the current tables: drop it
            pool.append(m)
            e = self.engine.index.insert(q, sketch, maintainer=m)
            e.reg_id = rid
            self.engine._ranges_cache.setdefault((q.table, ranges.attr),
                                                 ranges)
            if p["registered"]:
                self._registered[rid] = _Registered(e, ranges,
                                                    p["group_local"])
        return self

    # -- convenience -----------------------------------------------------------
    @property
    def db(self) -> Database:
        return self.engine.db

    @property
    def index(self):
        return self.engine.index

    def min_watermark(self) -> int:
        """The slowest shard's applied-delta count (monitoring hook)."""
        return min((s.version for s in self.shards), default=self.version)

    # -- mutations -------------------------------------------------------------
    def append_rows(self, table_name: str, rows: Mapping[str, np.ndarray]) -> None:
        """Route the batch by fragment ownership and ship per-shard deltas.

        Every shard receives a delta (possibly empty) so shard versions stay
        aligned with the coordinator's watermark; application is lazy.
        """
        if table_name != self.table_name:
            self.engine.append_rows(table_name, rows)
            self._emit("mutation", ("append", table_name,
                                    {k: np.asarray(v) for k, v in rows.items()},
                                    None, None))
            self._replicate_dim(table_name)
            return
        rows_np = {k: np.asarray(v) for k, v in rows.items()}
        # Route through RangeSet.bucketize itself so coordinator routing and
        # shard-side re-bucketization agree bit-for-bit on boundary values.
        bucket = np.asarray(self.ranges.bucketize(jnp.asarray(rows_np[self.attr])))
        shard_of = self.plan.owner[bucket]
        counts = np.bincount(shard_of, minlength=self.n_shards)
        new_local = np.empty(shard_of.shape[0], dtype=np.int64)
        version = self.version + 1
        ships = []
        for s, shard in enumerate(self.shards):
            sel = shard_of == s
            payload = {k: v[sel] for k, v in rows_np.items()}
            self._ship(s, version, "append", payload)
            ships.append(payload)
            new_local[sel] = self._shard_rows[s] + np.arange(counts[s])
        self._shard_rows += counts
        self._row_shard = np.concatenate([self._row_shard, shard_of])
        self._row_local = np.concatenate([self._row_local, new_local])
        self.engine.append_rows(table_name, rows)
        self.version += 1
        self._emit("mutation", ("append", table_name, rows_np, version, ships))

    def delete_rows(self, table_name: str, mask: np.ndarray) -> None:
        """Translate the coordinator-row mask into per-shard local masks."""
        if table_name != self.table_name:
            self.engine.delete_rows(table_name, mask)
            self._emit("mutation", ("delete", table_name,
                                    np.asarray(mask, dtype=bool), None, None))
            self._replicate_dim(table_name)
            return
        mask = np.asarray(mask, dtype=bool)
        version = self.version + 1
        ships = []
        for s, shard in enumerate(self.shards):
            local_mask = np.zeros(self._shard_rows[s], dtype=bool)
            local_mask[self._row_local[mask & (self._row_shard == s)]] = True
            self._ship(s, version, "delete", local_mask)
            ships.append(local_mask)
        keep = ~mask
        self._row_shard = self._row_shard[keep]
        self._row_local = self._row_local[keep]
        self._shard_rows = np.bincount(self._row_shard, minlength=self.n_shards)
        for s in range(self.n_shards):
            sel = self._row_shard == s
            self._row_local[sel] = np.arange(self._shard_rows[s])
        self.engine.delete_rows(table_name, mask)
        self.version += 1
        self._emit("mutation", ("delete", table_name, mask, version, ships))

    def _ship(self, sid: int, version: int, kind: str, payload) -> None:
        """Best-effort delivery of one delta.  The coordinator's per-shard
        delta log is the authoritative copy (appended first, pruned at
        checkpoints), so a failed or backpressured ship just leaves the
        shard lagging until the next read resyncs it from the log."""
        self._log[sid].append((version, kind, payload))
        if self._peer_mirroring:
            self._peer_ship(sid, version, kind, payload)
        if self.health_tracking and self.health[sid] == "dead":
            return  # known-dead: don't even try; recovery replays the log
        try:
            self.shards[sid].ship(version, kind, payload)
        except BackpressureError:
            pass  # inbox full; the log carries it
        except ShardUnavailableError:
            if self.health_tracking:
                self.health[sid] = ("dead" if self.health[sid] == "suspect"
                                    else "suspect")

    def _peer_ship(self, sid: int, version: int, kind: str, payload) -> None:
        """Keep shard ``sid``'s peer mirror current with the same delta.
        A failed or refused peer ship marks the mirror stale — counted, not
        silent — and the next checkpoint round re-seeds it."""
        if not self._peer_ok[sid]:
            return
        peer = (sid + 1) % self.n_shards
        try:
            ok = self.shards[peer].peer_ship(sid, version, kind, payload)
        except (ShardUnavailableError, BackpressureError):
            ok = False
        if not ok:
            self._peer_ok[sid] = False
            self.stale_checkpoints[sid] += 1

    def _replicate_dim(self, table_name: str) -> None:
        """Replicate a mutated dimension table and evict sketches it serves.

        A join sketch's provenance depends on the dimension contents, but
        sketches are versioned against the *fact* table only — serving one
        across a dimension mutation could silently return a stale-join
        result.  Eviction forces a fresh capture on the next miss.
        Unreachable shards are skipped — ``_sync_shard`` re-replicates any
        dimension whose (uid, version) drifted before the shard serves again.
        """
        for sid, shard in enumerate(self.shards):
            if self.health_tracking and self.health[sid] == "dead":
                continue
            try:
                shard.update_dim(self.engine.db[table_name])
            except ShardUnavailableError:
                if self.health_tracking:
                    self.health[sid] = ("dead" if self.health[sid] == "suspect"
                                        else "suspect")
        for e in list(self.engine.index.entries()):
            if e.query.join is not None and e.query.join.right == table_name:
                self.engine.index.remove(e)
                if e.reg_id:
                    self._unregister(e.reg_id)
                    self._emit("evict", e.reg_id)

    # -- queries ---------------------------------------------------------------
    @hot_path
    def run(self, q: Query) -> Tuple[QueryResult, RunInfo]:
        t0 = time.perf_counter()
        entry = (self.engine.index.lookup_entry(q)
                 if self.engine.strategy != "NO-PS" else None)
        if entry is not None:
            routed = self._run_routed(q, entry, t0)
            if routed is not None:
                return routed
        # Miss (or unroutable hit): single-node path on the coordinator, then
        # register any fresh capture with every shard.
        res, info = self.engine.run(q)
        self._register_new()
        return res, info

    def _group_local(self, q: Query) -> bool:
        """May sketch bits be maintained shard-locally for ``q``?

        A shard's maintainer evaluates the HAVING chain on *local* per-group
        aggregates, which equals the global evaluation only when every group
        (and, for nested templates, every outer group) lives entirely on one
        shard — i.e. the placement attribute is part of the (outer) GROUP BY,
        so a group's rows all share one fragment and hence one owner.
        """
        if self.attr not in q.groupby:
            return False
        if q.outer_groupby is not None and self.attr not in q.outer_groupby:
            return False
        return True

    def _register_new(self) -> None:
        """Broadcast every not-yet-registered index entry to the shards.

        One pass: the watermark catch-up runs once across all shards (not
        once per entry), then every new entry's per-shard maintainers are
        registered — the path ``run_batch`` uses to register a whole admitted
        wave's captures at once.
        """
        if self.engine.strategy == "NO-PS":
            return
        new = [e for e in self.engine.index.entries() if e.reg_id == 0]
        if not new:
            return
        for e in new:
            # Stable registration ids: shard maintainers, replication records
            # and routed-serving state all key on ``reg_id`` — a standby's
            # rebuilt entries re-attach to shard state without any
            # re-registration RPCs (``id(entry)`` dies with the process).
            e.reg_id = self._reg_counter
            self._reg_counter += 1
        fact_new = [e for e in new if e.query.table == self.table_name]
        down: Set[int] = set()
        if any(self._group_local(e.query) for e in fact_new):
            _, down = self._catch_up_all()
        for e in fact_new:
            group_local = self._group_local(e.query)
            if group_local:
                for sid, shard in enumerate(self.shards):
                    if sid in down or (self.health_tracking
                                       and self.health[sid] != "healthy"):
                        continue  # registered at recovery (_reregister_shard)
                    try:
                        self._shard_call(
                            sid, "register",
                            functools.partial(shard.register, e.reg_id,
                                              e.query, e.sketch.ranges))
                    except ShardUnavailableError:
                        pass
            self._registered[e.reg_id] = _Registered(e, e.sketch.ranges,
                                                     group_local)
        if self._replica is not None:
            self._emit("register", self._reg_payloads(new))
            self._emit("selection", self.selection_state())
        if self.max_registered is not None:
            self.prune(self.max_registered)

    def _unregister(self, key: int) -> None:
        for shard in self.shards:
            shard.unregister(key)
        self._registered.pop(key, None)
        self.engine.catalog.drop_stacked(("stacked", key))

    def prune(self, max_entries: int) -> int:
        """Bound per-shard memory with the coordinator's recency clock.

        Evicts least-recently-hit sketches from the coordinator index
        (``SketchIndex.prune``) and drops every evicted entry's shard-side
        state in the same pass: per-shard maintainers, cached local
        instances, and the stacked launch arrays.  Returns #evictions.
        """
        before = ({e.reg_id for e in self.engine.index.entries() if e.reg_id}
                  if self._replica is not None else set())
        evicted = self.engine.index.prune(max_entries)
        if evicted:
            alive = {e.reg_id for e in self.engine.index.entries()}
            for key in [k for k in self._registered if k not in alive]:
                self._unregister(key)
            for rid in sorted(before - alive):
                self._emit("evict", rid)
        return evicted

    def shutdown(self) -> None:
        """Release shard resources: loopback clients no-op, subprocess
        clients return their server process to the warm pool (or reap it).
        Safe to call more than once."""
        for c in self.shards:
            try:
                c.close_client()
            except Exception:
                pass

    # -- coordinator selection-state checkpointing ----------------------------
    def selection_state(self) -> dict:
        """The coordinator's reuse-aware selection state (WorkloadLog window
        + SelectionCache stats), picklable — shards never see it, so ONE
        log survives a coordinator restart even when shards are separate
        processes."""
        return self.engine.selection_state()

    def restore_selection_state(self, state: Mapping) -> None:
        self.engine.restore_selection_state(state)

    # -- health tracking / failover -------------------------------------------
    def _shard_call(self, sid: int, op: str, fn):
        """One guarded shard op: bounded retries with backoff + a deadline
        (``runtime.resilience.with_retries``), per-(shard, op) straggler
        tracking, and the shard state machine transitions.  A hard failure
        demotes healthy -> suspect -> dead; a clean in-deadline op promotes
        suspect/recovering -> healthy."""
        if not self.health_tracking:
            return fn()
        if self.health[sid] == "dead":
            raise ShardUnavailableError(f"shard {sid} marked dead")
        retries = 0

        def _count(_attempt: int, _e: Exception) -> None:
            nonlocal retries
            retries += 1

        t0 = time.perf_counter()
        try:
            out = with_retries(fn, self._retry_policy, on_retry=_count)
        except ShardUnavailableError:
            self._route_retries += retries
            self.health[sid] = ("dead" if self.health[sid] == "suspect"
                                else "suspect")
            raise
        dt = time.perf_counter() - t0
        self._route_retries += retries
        mon = self._monitors.get((sid, op))
        if mon is None:
            mon = self._monitors[(sid, op)] = StragglerMonitor()
        mon.observe(dt)
        if dt > self.op_deadline_s and mon.median() is not None:
            # Past the deadline (an injected stall, or a genuinely slow
            # host): route around it — its slices serve coordinator-side
            # until it answers within the deadline again.  The monitor's
            # warmup window grants grace while the op's timing baseline
            # forms (first calls pay one-time XLA compiles; demoting on
            # those would degrade perfectly healthy shards).
            self.health[sid] = "suspect"
        elif self.health[sid] in ("suspect", "recovering"):
            self.health[sid] = "healthy"
        return out

    def _checkpoint(self, sid: int) -> None:
        """Advance one shard's durable recovery point.  Called only when the
        shard is at version parity with the coordinator, so both checkpoint
        kinds (loopback: shard-table reference; subprocess: coordinator-table
        snapshot) are one immutable reference + a log prune.  Skips entirely
        when the checkpoint is already at the watermark — the warm read path
        pays a version compare, nothing else."""
        cur = self._ckpt[sid]
        if cur is not None and cur.version == self.version:
            self._mirror_ckpt(sid)
            return
        ckpt = self.shards[sid].make_checkpoint(
            self.db[self.table_name], self.version)
        self._ckpt[sid] = ckpt
        v = ckpt.version
        if self._log[sid] and self._log[sid][0][0] <= v:
            self._log[sid] = [e for e in self._log[sid] if e[0] > v]
        self._emit("ckpt", (sid, v))
        self._mirror_ckpt(sid)

    def _mirror_ckpt(self, sid: int) -> None:
        """(Re)seed shard ``sid``'s peer mirror when it is stale: derive the
        shard-local table coordinator-side (``local_table_for`` — the same
        pure gather shard construction uses) and put it on the peer.  Once
        seeded, ``_peer_ship`` keeps it current delta-sized."""
        if not self._peer_mirroring or self._peer_ok[sid]:
            return
        peer = (sid + 1) % self.n_shards
        if self.health_tracking and self.health[peer] == "dead":
            self.stale_checkpoints[sid] += 1
            return
        try:
            local = local_table_for(sid, self.plan, self.ranges,
                                    self.db[self.table_name].collapse(),
                                    version=self.version)
            self.shards[peer].peer_put(sid, local, self._plan_token())
            self._peer_ok[sid] = True
        except (ShardUnavailableError, BackpressureError):
            self.stale_checkpoints[sid] += 1

    def _restore_from_peer(self, sid: int) -> bool:
        """Recovery fast path: re-seed a killed shard from the peer-held
        mirror of its local table instead of re-shipping the coordinator
        checkpoint.  The mirror is delta-maintained, so the shipped bytes are
        O(shard-local rows) held *by the peer process* — the coordinator
        never serializes the table.  Tried regardless of ``_peer_ok``: the
        flag is this coordinator's knowledge, but mirrors survive coordinator
        takeover (they live in shard processes), so a fresh coordinator asks
        first and trusts the plan token to reject stale placements."""
        if not self._peer_mirroring:
            return False
        peer = (sid + 1) % self.n_shards
        if self.health_tracking and self.health[peer] == "dead":
            return False
        try:
            got = self.shards[peer].peer_fetch(sid, self._plan_token())
            if got is None:
                return False
            local, _version = got
            dims = {k: v for k, v in self.engine.db.tables.items()
                    if k != self.table_name}
            self.shards[sid].build_local(self.plan, self.ranges, local, dims,
                                         self._inbox_cap)
        except (ShardUnavailableError, BackpressureError):
            return False
        self.peer_restores += 1
        self._peer_ok[sid] = True
        return True

    def _sync_shard(self, sid: int) -> int:
        """Bring one shard to the coordinator watermark: refresh drifted
        dimension replicas, drain the inbox, and re-ship any log suffix the
        shard is missing (ships lost to a partition or to backpressure)."""
        shard = self.shards[sid]
        for name, t in self.engine.db.tables.items():
            if name == self.table_name:
                continue
            if shard.dim_token(name) != (t.uid, t.version):
                shard.update_dim(t)
        applied = shard.catch_up(self.version)
        while shard.version < self.version:
            missing = [e for e in self._log[sid] if e[0] > shard.version]
            if not missing:
                # The log cannot reach the watermark (pruned past a loss):
                # rebuild outright from the coordinator's table.
                return applied + self._rebuild_shard(sid)
            before = shard.version
            for entry in missing:
                try:
                    shard.ship(*entry)
                except BackpressureError:
                    break  # drain below, then ship the rest
            applied += shard.catch_up(self.version)
            if shard.version == before:
                # No progress: a version gap the log cannot bridge (e.g. it
                # was voided by a rebalance).  Rebuild outright.
                return applied + self._rebuild_shard(sid)
        return applied

    def _recover_shard(self, sid: int) -> int:
        """Failover recovery of a reachable-again shard: adopt the last
        checkpoint (state-lost kill), replay the delta log up to the
        watermark, re-register per-shard maintainers.  Delta-replay +
        re-registration — never a from-scratch re-capture: the maintainers
        re-count only the shard's local rows and the sketch *bits* come back
        through the same counting scheme that produced them."""
        shard = self.shards[sid]
        self.health[sid] = "recovering"
        applied = 0
        if shard.state_lost:  # killed: all local state lost
            if not self._restore_from_peer(sid):
                if self._ckpt[sid] is None:
                    # No coherent checkpoint (placement changed while it was
                    # gone): rebuild from the coordinator's table outright.
                    self._rebuild_shard(sid)
                    self.health[sid] = "healthy"
                    return 0
                dims = {k: v for k, v in self.engine.db.tables.items()
                        if k != self.table_name}
                shard.restore_checkpoint(self._ckpt[sid], dims, self.plan,
                                         self.ranges)
        applied += self._sync_shard(sid)
        self._reregister_shard(sid)
        self._checkpoint(sid)
        self.health[sid] = "healthy"
        return applied

    def _reregister_shard(self, sid: int) -> None:
        """Re-register every routed entry's per-shard maintainer after the
        shard's maintainer set was lost (kill) or rebuilt (rebalance)."""
        shard = self.shards[sid]
        for key, reg in self._registered.items():
            if not reg.group_local or not self.engine.index.contains(reg.entry):
                continue
            if not shard.has_maintainer(key):
                shard.register(key, reg.entry.query, reg.ranges)

    def _rebuild_shard(self, sid: int) -> int:
        """Rebuild one shard outright from the coordinator's authoritative
        clustered table per the current plan (O(local rows) gather) — the
        path elastic rebalancing takes, and the recovery fallback when the
        delta log cannot reach the watermark.  Still not a re-capture:
        maintainers re-register by local counting."""
        ctable = self.db[self.table_name]
        dims = {k: v for k, v in self.engine.db.tables.items()
                if k != self.table_name}
        dead = [s for s, h in enumerate(self.health) if h == "dead"]
        self._devices[sid] = failover_device(self._devices, sid, dead)
        self.shards[sid].rebuild(
            self.plan, self.ranges, ctable, dims, self._devices[sid],
            self._inbox_cap, self.version)
        self._log[sid] = []
        self._reregister_shard(sid)
        self._checkpoint(sid)
        return 0

    def _rebuild_row_maps(self) -> None:
        """Recompute the global-row -> (shard, local-row) maps from the
        coordinator table and the current plan (after a re-placement)."""
        ctable = self.db[self.table_name]
        lay = ctable.layout
        n = ctable.num_rows
        n_tail = lay.tail
        frag_prefix = np.searchsorted(lay.offsets, np.arange(n - n_tail),
                                      side="right") - 1
        if n_tail:
            tail_vals = np.asarray(ctable[self.attr])[n - n_tail:]
            tail_frag = np.asarray(self.ranges.bucketize(jnp.asarray(tail_vals)))
            row_frag = np.concatenate([frag_prefix, tail_frag])
        else:
            row_frag = frag_prefix
        self._row_shard = self.plan.owner[row_frag]
        self._row_local = np.empty(n, dtype=np.int64)
        self._shard_rows = np.zeros(self.n_shards, dtype=np.int64)
        for s in range(self.n_shards):
            sel = self._row_shard == s
            self._shard_rows[s] = int(sel.sum())
            self._row_local[sel] = np.arange(self._shard_rows[s])

    def rebalance(self, dead: Optional[Sequence[int]] = None) -> List[int]:
        """Elastic failover: re-plan fragment placement away from ``dead``
        shards (default: every shard currently marked dead) via the pure
        ``runtime.elastic.plan_replacement`` policy and rebuild the shards
        whose owned fragment set changed.  Returns the rebuilt shard ids."""
        if dead is None:
            dead = [s for s in range(self.n_shards)
                    if self.health[s] == "dead"]
        dead_set = {int(d) for d in dead}
        if not dead_set:
            return []
        sizes = np.diff(self.db[self.table_name].layout.offsets)
        new_owner = plan_replacement(sizes, self.plan.owner, self.n_shards,
                                     sorted(dead_set))
        changed = [s for s in range(self.n_shards)
                   if not np.array_equal(np.nonzero(new_owner == s)[0],
                                         self.plan.fragments_of(s))]
        self.plan = ShardPlan(n_shards=self.n_shards, owner=new_owner)
        self._rebuild_row_maps()
        # Every peer mirror speaks the OLD placement: the plan token embedded
        # at put-time no longer matches, so fetches would be refused anyway —
        # drop our seeded flags so the next checkpoint round re-seeds.
        self._peer_ok = [False] * self.n_shards
        rebuilt = []
        voided = []
        for sid in changed:
            if sid in dead_set:
                # The lost shard now owns nothing; void its recovery state —
                # checkpoint AND log speak the old placement, so a later
                # rejoin must rebuild from the coordinator, never replay.
                self._ckpt[sid] = None
                self._log[sid] = []
                voided.append(sid)
                continue
            self._rebuild_shard(sid)
            self.health[sid] = "healthy"
            rebuilt.append(sid)
        self._emit("plan", (new_owner, voided))
        # The plan object changed identity: every stacked cache key is dead.
        self.engine.catalog.drop_stacked(("stacked",))
        self.engine.catalog.drop_stacked(("stacked_batch",))
        return rebuilt

    def _catch_up_all(self) -> Tuple[int, Set[int]]:
        """Watermark gate, fault-tolerant: every reachable shard drains its
        inbox up to the coordinator's mutation count before serving — an
        un-contacted lagging shard could own fragments the mutations just
        made provenance-bearing (and its data must be current for
        partials).  Shards that cannot be brought current are returned as
        ``down``: their fragment slices serve from the coordinator's
        authoritative table this route (degraded mode).  Dead shards are
        probed each read; a reachable-again one runs checkpoint + delta-log
        recovery on the spot."""
        applied = 0
        down: Set[int] = set()
        for sid, shard in enumerate(self.shards):
            if self.health_tracking and self.health[sid] == "dead":
                if shard.reachable:
                    try:
                        applied += self._recover_shard(sid)
                    except (ShardUnavailableError, BackpressureError):
                        self.health[sid] = "dead"
                        down.add(sid)
                else:
                    down.add(sid)
                continue
            if shard.state_lost and shard.reachable:
                # Healed after a kill without ever being demoted to dead (no
                # serve happened in between): recover on the spot instead of
                # burning a serve discovering the loss through a failing
                # catch_up.
                try:
                    applied += self._recover_shard(sid)
                except (ShardUnavailableError, BackpressureError):
                    self.health[sid] = "dead"
                    down.add(sid)
                continue
            try:
                applied += self._shard_call(
                    sid, "catch_up", functools.partial(self._sync_shard, sid))
            except (ShardUnavailableError, BackpressureError):
                down.add(sid)
                continue
            shard = self.shards[sid]  # _sync_shard may have rebuilt it
            if shard.version < self.version:  # pragma: no cover - defensive
                down.add(sid)
            else:
                self._checkpoint(sid)
                if self.health_tracking and self.health[sid] == "healthy":
                    # A shard that sat out a registration wave (suspect at
                    # the time) picks up its missing maintainers the first
                    # read after it is healthy again.
                    try:
                        self._reregister_shard(sid)
                    except (ShardUnavailableError, BackpressureError):
                        down.add(sid)
        return applied, down

    def _degraded_set(self, down: Set[int]) -> Set[int]:
        """The shards served coordinator-side this route: unrecoverable
        (``down``) plus any flagged suspect by the op wrapper (stalled past
        the deadline, or one hard failure away from dead).  Shards owning no
        fragments (re-placed away by a rebalance) are excluded — they have
        nothing to substitute, so their state cannot degrade a route."""
        degraded = set(down)
        if self.health_tracking:
            degraded |= {s for s in range(self.n_shards)
                         if self.health[s] in ("suspect", "dead")}
        return {s for s in degraded if self.plan.fragments_of(s).size > 0}

    def _resolve_bits(
        self, key: int, reg: _Registered, degraded: Set[int]
    ) -> Optional[np.ndarray]:
        """The logical sketch bits for one registered entry (or ``None`` when
        a shard maintainer was lost — caller falls back to the miss path).

        Degraded shards are never contacted: the coordinator's own maintainer
        substitutes (``_current_sketch`` maintains or re-captures the logical
        sketch).  For group-local entries the coordinator bits equal the OR
        of per-shard bits — shard-locality of every group makes the local
        HAVING evaluations exactly the global one — so the substitution is
        bit-identical, not merely safe."""
        if reg.group_local:
            # Fully decentralized maintenance: every group is shard-local,
            # so the logical bits are the OR of per-shard maintained bits.
            bits_parts = []
            for sid, shard in enumerate(self.shards):
                if self.plan.fragments_of(sid).size == 0:
                    continue  # owns nothing (re-placed away): no bits to OR
                if sid in degraded:
                    bits_parts = None
                    break
                try:
                    b = self._shard_call(
                        sid, "bits_for", functools.partial(shard.bits_for, key))
                except ShardUnavailableError:
                    degraded.add(sid)
                    bits_parts = None
                    break
                if b is None:  # maintainer lost (e.g. dimension replaced)
                    self._unregister(key)
                    return None
                bits_parts.append(b)
            if bits_parts is not None:
                return np.logical_or.reduce(bits_parts)
        # Groups span shards (or a shard is degraded): the HAVING chain needs
        # global aggregates, so the *coordinator's* maintainer repairs the
        # logical sketch (delta-sized) and shards only serve routed partials.
        sketch, _ = self.engine._current_sketch(reg.entry)
        return sketch.bits

    # -- degraded-mode serving -------------------------------------------------
    def _degraded_flat(
        self, sid: int, q: Query, reg: _Registered, bits: np.ndarray
    ) -> ColumnTable:
        """Shard ``sid``'s sketch-instance slice served *coordinator-side*
        from the authoritative clustered table — the degraded-mode stand-in
        while the shard is down or lagging.  Row set matches the shard's own
        instance exactly; row order may differ, which is invisible under the
        exactness envelope (order-insensitive sums, value-keyed groups)."""
        ctable = self.db[self.table_name]
        ranges = reg.ranges
        owned = self.plan.fragments_of(sid)
        if ranges.key() == self.ranges.key():
            frag_ids = owned[np.asarray(bits)[owned]]
            lay = ctable.layout
            tail_bucket = None
            if lay.tail:
                gfrag = np.asarray(
                    self.engine.catalog.bucketize(ctable, self.ranges))
                tail_bucket = gfrag[ctable.num_rows - lay.tail:]
            inst = ctable.take_fragments(frag_ids, tail_bucket=tail_bucket)
        else:
            bucket = np.asarray(self.engine.catalog.bucketize(ctable, ranges))
            mask = np.asarray(bits)[bucket] & (self._row_shard == sid)
            inst = ctable.select(jnp.asarray(mask))
        if q.join is not None:
            flat, _ = self.engine.catalog.join(
                inst, self.db[q.join.right], q.join.left_key, q.join.right_key)
        else:
            flat = inst
        return flat

    def _degraded_partial(
        self, sid: int, q: Query, reg: _Registered, bits: np.ndarray
    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]:
        """Coordinator-side substitute for ``FragmentShard.partial``."""
        flat = self._degraded_flat(sid, q, reg, bits)
        enc, _, sums, counts = inner_group_partials(q, flat, self.engine.catalog)
        return enc.group_values, np.asarray(sums), np.asarray(counts)

    def _shard_arrays(
        self, sid: int, key: int, reg: _Registered, bits: np.ndarray, q: Query
    ):
        """One shard's inner-block arrays for the stacked layout (live path:
        zero-copy on loopback, one RPC on the subprocess backend)."""
        return self.shards[sid].block_arrays(key, reg.ranges, bits, q)

    def _stacked_token(self, degraded: Set[int], bits: np.ndarray) -> Tuple:
        """Freshness token for the stacked arrays.  Degraded shards' slices
        come from the coordinator's authoritative table, so their entry pins
        the *coordinator* table version (a dead shard's table may not even
        exist); live entries pin the shard-local (uid, version) — monotone
        under append/delete and surviving collapse(), whereas a recycled
        object address could alias a stale stack onto fresh data."""
        ctable = self.db[self.table_name]
        per = tuple(
            ("coord", ctable.uid, ctable.version) if sid in degraded
            # A state-less shard outside the degraded set owns no fragments
            # (re-placed away) — it contributes no slice, any sentinel works.
            else (s.state_token() or ("lost",))
            for sid, s in enumerate(self.shards))
        return (per, bits.tobytes())

    def _stacked_for(
        self, key: int, reg: _Registered, bits: np.ndarray,
        degraded: Set[int],
    ) -> StackedInstances:
        """Build (or fetch) the stacked shard-major arrays for one entry.

        The cache key pins the registration + fragment plan; the token guards
        freshness (per-shard table identities + the sketch bits + the
        degraded set), so any shard-side delta application or maintained-bit
        flip rebuilds the stack while the steady state costs one dictionary
        probe.  Degraded shards' slices are built coordinator-side
        (``_degraded_flat``) — the fused launch itself is indifferent to
        where a slice came from.
        """
        catalog = self.engine.catalog
        ckey = ("stacked", key, self.db[self.table_name].uid, id(self.plan))
        token = self._stacked_token(degraded, bits)
        hit = catalog.get_stacked(ckey, token)
        if hit is not None:
            return hit
        q = reg.entry.query
        ranges = reg.ranges
        routable = ranges.key() == self.ranges.key()
        attrs = tuple(q.groupby)

        # The stacked shard axis covers *contacted* shards only: a fragment-
        # skipped shard owns no sketch fragments, so its instance is empty by
        # construction and stacking it would only inflate the padded compute
        # (routing — which shards to skip — stays a host decision; the launch
        # then computes exactly the routed work).
        per_shard: List[Tuple] = []
        contacted_ids: List[int] = []
        for sid in range(self.n_shards):
            owned = self.plan.fragments_of(sid)
            if owned.size == 0 or (routable and not bits[owned].any()):
                continue  # fragment-skip: contributes no stacked slice
            contacted_ids.append(sid)
            if sid in degraded:
                per_shard.append(inner_block_arrays(
                    q, self._degraded_flat(sid, q, reg, bits),
                    self.engine.catalog))
                continue
            try:
                per_shard.append(self._shard_call(
                    sid, "instance",
                    functools.partial(self._shard_arrays, sid, key, reg,
                                      bits, q)))
            except ShardUnavailableError:
                # Mid-build failure: fall through to the degraded slice —
                # the caller's route report picks the shard up via the
                # (mutated) degraded set.
                degraded.add(sid)
                per_shard.append(inner_block_arrays(
                    q, self._degraded_flat(sid, q, reg, bits),
                    self.engine.catalog))

        # Coordinator-owned global group dictionary: np.unique over the
        # concatenated per-shard group key values — the same construction
        # the host-loop merge re-keys with, so numbering (and hence result
        # row order) is identical across the fused, host-loop and
        # single-node paths.
        if not attrs:
            n_groups, group_values = 1, {}
            global_of_local: List[Optional[np.ndarray]] = [None] * len(per_shard)
        else:
            mats, owners = [], []
            for i, a in enumerate(per_shard):
                if a[0].n_groups > 0:
                    mats.append(np.stack(
                        [np.asarray(a[0].group_values[at]) for at in attrs],
                        axis=1))
                    owners.append(i)
            global_of_local = [None] * len(per_shard)
            if mats:
                all_keys = np.concatenate(mats)
                uniq, inv = np.unique(all_keys, axis=0, return_inverse=True)
                n_groups = int(uniq.shape[0])
                group_values = {a: uniq[:, i] for i, a in enumerate(attrs)}
                off = 0
                for i, m in zip(owners, mats):
                    global_of_local[i] = inv[off:off + m.shape[0]]
                    off += m.shape[0]
            else:
                n_groups, group_values = 0, {}

        r_max = max((int(a[1].shape[0]) for a in per_shard), default=0)
        r_pad = _next_pow2(max(r_max, 1))
        s_pad = _next_pow2(max(len(per_shard), 1))
        g_pad = _next_pow2(max(n_groups, 1))
        vals_np = np.zeros((s_pad, r_pad), np.float32)
        gid_np = np.zeros((s_pad, r_pad), np.int32)
        w_np = np.zeros((s_pad, r_pad), np.float32)
        for i, a in enumerate(per_shard):
            enc, where_mask, vals = a
            n = int(where_mask.shape[0])
            if n == 0:
                continue
            gmap = global_of_local[i]
            gid_np[i, :n] = (enc.gid if gmap is None
                             else gmap[enc.gid]).astype(np.int32)
            vals_np[i, :n] = np.asarray(vals, dtype=np.float32)
            w_np[i, :n] = np.asarray(where_mask, dtype=np.float32)

        # A shard may have failed mid-build (degraded grew): re-derive the
        # token so the cached stack is keyed on how it was *actually* built.
        token = self._stacked_token(degraded, bits)
        st = StackedInstances(
            vals=place_stacked(jnp.asarray(vals_np[None]), self._mesh),
            gid=place_stacked(jnp.asarray(gid_np[None]), self._mesh),
            weights=place_stacked(jnp.asarray(w_np[None]), self._mesh),
            n_groups=n_groups,
            g_pad=g_pad,
            group_values=group_values,
            contacted_ids=tuple(contacted_ids),
            token=token,
        )
        catalog.put_stacked(ckey, token, st)
        return st

    @hot_path
    def _launch(self, vals, gid, weights, g_pad: int):
        """The one fused launch: shard_map over the serving mesh when its
        device count divides the (pow2-padded) shard axis, the vmapped
        single-program path otherwise."""
        mesh = self._mesh
        if mesh is not None and vals.shape[1] % mesh.devices.size == 0:
            fn = _fused_spmd_fn(mesh)
        else:
            fn = _fused_jit
        LAUNCH_COUNTS["fused_partials"] += 1
        return fn(vals, gid, weights, g_pad)

    def _result_from_merged(
        self, q: Query, st: StackedInstances,
        sums: np.ndarray, counts: np.ndarray,
    ) -> QueryResult:
        """Finish one query from the fused launch's merged per-group state —
        the same group-level tail as ``_merge_partials``, minus the re-key
        (the stacked layout already speaks the global dictionary)."""
        if not q.groupby:
            s, c = float(sums[0]), float(counts[0])
            agg = _finalize(q.agg.fn, np.array([s], dtype=np.float64),
                            np.array([c], dtype=np.float64))
            return result_from_group_state(q, {}, agg, np.array([c > 0]))
        if st.n_groups == 0:
            return QueryResult(
                group_values={a: np.empty(0) for a in
                              (q.outer_groupby if q.outer_groupby
                               else q.groupby)},
                values=np.empty(0))
        sums64 = sums[:st.n_groups].astype(np.float64)
        counts64 = counts[:st.n_groups].astype(np.float64)
        agg = _finalize(q.agg.fn, sums64, counts64)
        return result_from_group_state(q, st.group_values, agg, counts64 > 0)

    def _run_routed(
        self, q: Query, entry: IndexEntry, t0: float
    ) -> Optional[Tuple[QueryResult, RunInfo]]:
        key = entry.reg_id
        reg = self._registered.get(key)
        if reg is None:
            return None
        self._route_retries = 0
        applied, down = self._catch_up_all()
        degraded = self._degraded_set(down)
        bits = self._resolve_bits(key, reg, degraded)
        if bits is None:
            return None

        if self.fused:
            st = self._stacked_for(key, reg, bits, degraded)
            tl = time.perf_counter()
            sums, counts = self._launch(st.vals, st.gid, st.weights, st.g_pad)
            sums_np, counts_np = np.asarray(sums[0]), np.asarray(counts[0])
            tm = time.perf_counter()
            res = self._result_from_merged(q, st, sums_np, counts_np)
            t1 = time.perf_counter()
            contacted = st.contacted
            per_shard_s: Dict[int, float] = {}
            t_launch, t_merge = tm - tl, t1 - tm
        else:
            ranges = reg.ranges
            routable = ranges.key() == self.ranges.key()
            per_shard_s = {}
            partials = []
            for sid in range(self.n_shards):
                owned = self.plan.fragments_of(sid)
                if owned.size == 0 or (routable and not bits[owned].any()):
                    continue  # fragment-skip the whole shard
                ts = time.perf_counter()
                if sid in degraded:
                    partials.append(self._degraded_partial(sid, q, reg, bits))
                else:
                    try:
                        partials.append(self._shard_call(
                            sid, "partial",
                            functools.partial(self.shards[sid].partial, q, key,
                                              ranges, bits)))
                    except ShardUnavailableError:
                        degraded.add(sid)
                        partials.append(
                            self._degraded_partial(sid, q, reg, bits))
                per_shard_s[sid] = time.perf_counter() - ts
            tm = time.perf_counter()
            res = _merge_partials(q, partials)
            t1 = time.perf_counter()
            contacted = len(per_shard_s)
            t_launch, t_merge = sum(per_shard_s.values()), t1 - tm
        self.last_route = RouteInfo(
            contacted=contacted,
            skipped=self.n_shards - contacted,
            watermark=self.version,
            deltas_applied=applied,
            per_shard_s=per_shard_s,
            t_merge_s=t_merge,
            t_launch_s=t_launch,
            fused=self.fused,
            degraded=bool(degraded),
            failed_shards=tuple(sorted(degraded)),
            n_retries=self._route_retries,
            stale_checkpoints=sum(self.stale_checkpoints),
        )
        info = RunInfo(
            reused=True, created=False, attr=reg.ranges.attr,
            strategy=self.engine.strategy, selectivity=entry.sketch.selectivity,
            t_execute=t1 - t0, repaired=applied > 0,
            shards_contacted=contacted,
            shards_skipped=self.n_shards - contacted,
            degraded=bool(degraded),
        )
        return res, info

    # -- batched serving -------------------------------------------------------
    @hot_path
    def run_batch(self, qs: Sequence[Query]) -> List[Tuple[QueryResult, RunInfo]]:
        """Batched sharded serving: one fused launch for ALL index hits, and
        cross-shard batched admission for the misses.

        Semantically equivalent to ``[self.run(q) for q in qs]`` (results,
        index contents, sketch bits and shard maintainer state — pinned by
        ``tests/test_shard_batch.py``).  Hits are grouped by index entry and
        their stacked arrays concatenate on a leading query axis: the B×S
        per-group partial matrices for the whole batch come out of ONE XLA
        launch (counter-asserted), each query finishing with its own
        HAVING-chain tail on the merged state.  Misses run through the same
        ``core/admission`` pipeline single-node ``run_batch`` uses (shared
        samples/AQR/inner-block/capture per signature group), and every
        captured sketch broadcasts to shard registrations in one pass.
        """
        from repro.core.admission import admit_misses

        if self.engine.selection.reuse_aware and self.engine.strategy != "NO-PS":
            # Same stamp reservation as single-node ``run_batch``: wave
            # deferral records misses out of batch order.
            self.engine.workload.begin_batch(len(qs))
        out: List[Optional[Tuple[QueryResult, RunInfo]]] = [None] * len(qs)
        pending: List[Tuple[int, Query]] = list(enumerate(qs))
        while pending:
            misses: List[Tuple[int, Query, float]] = []
            hits: Dict[int, List[Tuple[int, Query, IndexEntry, float]]] = {}
            for i, q in pending:
                t0 = time.perf_counter()
                entry = (self.engine.index.lookup_entry(q)
                         if self.engine.strategy != "NO-PS" else None)
                tp = time.perf_counter()
                if entry is None:
                    misses.append((i, q, tp - t0))
                elif entry.reg_id in self._registered:
                    hits.setdefault(entry.reg_id, []).append((i, q, entry, tp - t0))
                else:
                    # Hit without routed registration (rare: the registration
                    # was dropped): single-node serve + re-register, exactly
                    # like ``run``'s fallback.
                    out[i] = self.engine.run(q)
                    self._register_new()
            if hits:
                self._serve_hits_batch(list(hits.items()), out)
            if not misses:
                break
            served, pending = admit_misses(self.engine, misses)
            for i, item in served.items():
                out[i] = item
            self._register_new()
        return out  # type: ignore[return-value]

    def _serve_hits_batch(
        self,
        groups: List[Tuple[int, List[Tuple[int, Query, IndexEntry, float]]]],
        out: List[Optional[Tuple[QueryResult, RunInfo]]],
    ) -> None:
        """Serve one wave's index hits routed — all entries, one launch."""
        self._route_retries = 0
        applied, down = self._catch_up_all()
        degraded = self._degraded_set(down)
        serving: List[Tuple[int, List, StackedInstances]] = []
        loop_stats: List[Tuple[Tuple[int, ...], Dict[int, float], float, int]] = []
        for key, members in groups:
            reg = self._registered.get(key)
            bits = (self._resolve_bits(key, reg, degraded)
                    if reg is not None else None)
            if bits is None:
                # Maintainer lost mid-flight: single-node serve (the entry
                # still answers from the coordinator), re-register after.
                for i, q, _, _ in members:
                    out[i] = self.engine.run(q)
                self._register_new()
                continue
            if not self.fused:
                loop_stats.append(
                    self._serve_key_host_loop(key, reg, bits, members,
                                              applied, degraded, out))
                continue
            serving.append(
                (key, members, self._stacked_for(key, reg, bits, degraded)))
        if loop_stats:
            contacted = set().union(*(set(c) for c, _, _, _ in loop_stats))
            per_shard_s: Dict[int, float] = {}
            for _, ps, _, _ in loop_stats:
                for sid, dt in ps.items():
                    per_shard_s[sid] = per_shard_s.get(sid, 0.0) + dt
            self.last_route = RouteInfo(
                contacted=len(contacted),
                skipped=self.n_shards - len(contacted),
                watermark=self.version, deltas_applied=applied,
                per_shard_s=per_shard_s,
                t_merge_s=sum(m for _, _, m, _ in loop_stats),
                t_launch_s=sum(per_shard_s.values()), fused=False,
                n_queries=sum(n for _, _, _, n in loop_stats),
                degraded=bool(degraded),
                failed_shards=tuple(sorted(degraded)),
                n_retries=self._route_retries,
                stale_checkpoints=sum(self.stale_checkpoints),
            )
        if not serving:
            return

        tl = time.perf_counter()
        if len(serving) == 1:
            st0 = serving[0][2]
            sums, counts = self._launch(st0.vals, st0.gid, st0.weights,
                                        st0.g_pad)
        else:
            vals, gid, weights, g_pad = self._assemble_batch(serving)
            sums, counts = self._launch(vals, gid, weights, g_pad)
        sums_np, counts_np = np.asarray(sums), np.asarray(counts)
        tm = time.perf_counter()

        union_contacted: set = set()
        n_served = 0
        for row, (key, members, st) in enumerate(serving):
            union_contacted.update(st.contacted_ids)
            for i, q, entry, tp in members:
                tq = time.perf_counter()
                res = self._result_from_merged(
                    q, st, sums_np[row], counts_np[row])
                out[i] = (res, RunInfo(
                    reused=True, created=False,
                    attr=self._registered[key].ranges.attr,
                    strategy=self.engine.strategy,
                    selectivity=entry.sketch.selectivity,
                    t_probe=tp, t_execute=time.perf_counter() - tq,
                    repaired=applied > 0,
                    shards_contacted=st.contacted,
                    shards_skipped=self.n_shards - st.contacted,
                    degraded=bool(degraded),
                ))
                n_served += 1
        t1 = time.perf_counter()
        self.last_route = RouteInfo(
            contacted=len(union_contacted),
            skipped=self.n_shards - len(union_contacted),
            watermark=self.version, deltas_applied=applied,
            per_shard_s={}, t_merge_s=t1 - tm, t_launch_s=tm - tl,
            fused=True, n_queries=n_served,
            degraded=bool(degraded),
            failed_shards=tuple(sorted(degraded)),
            n_retries=self._route_retries,
            stale_checkpoints=sum(self.stale_checkpoints),
        )

    def _assemble_batch(self, serving: List[Tuple[int, List, StackedInstances]]):
        """Concatenate multiple entries' stacked arrays on the query axis.

        Every entry's arrays are padded to the batch's common (pow2)
        shard/row/group classes; dummy query rows (pow2 tail) carry weight 0
        everywhere.  The assembled tensors are cached in the catalog keyed by
        the ordered entry set and token-guarded by every member's freshness
        token, so a steady-state batch pays one dictionary probe instead of
        re-padding/concatenating per serve.
        """
        catalog = self.engine.catalog
        bkey = ("stacked_batch",) + tuple(key for key, _, _ in serving)
        token = tuple(st.token for _, _, st in serving)
        hit = catalog.get_stacked(bkey, token)
        if hit is not None:
            return hit
        s_pad = max(int(st.vals.shape[1]) for _, _, st in serving)
        r_pad = max(st.r_pad for _, _, st in serving)
        g_pad = max(st.g_pad for _, _, st in serving)
        k_pad = _next_pow2(len(serving))

        def stack(field, dtype):
            parts = [jnp.pad(getattr(st, field),
                             ((0, 0), (0, s_pad - int(st.vals.shape[1])),
                              (0, r_pad - st.r_pad)))
                     for _, _, st in serving]
            if k_pad > len(serving):
                # analyze: waive[PAD01]: filler shape varies with the entry count, but assembly runs only on a stacked-cache miss (registration/eviction/failover), never steady-state — the result is cached under the freshness token
                parts.append(jnp.zeros(
                    (k_pad - len(serving), s_pad, r_pad), dtype))
            return jnp.concatenate(parts)

        assembled = (stack("vals", jnp.float32), stack("gid", jnp.int32),
                     stack("weights", jnp.float32), g_pad)
        catalog.put_stacked(bkey, token, assembled)
        return assembled

    def _serve_key_host_loop(
        self, key: int, reg: _Registered, bits: np.ndarray,
        members: List[Tuple[int, Query, IndexEntry, float]],
        applied: int, degraded: Set[int],
        out: List[Optional[Tuple[QueryResult, RunInfo]]],
    ) -> Tuple[Tuple[int, ...], Dict[int, float], float, int]:
        """Host-loop batch fallback: per-shard partials once per entry (they
        are HAVING-independent), merged once, member tails per query.
        Returns ``(contacted shard ids, per-shard seconds, merge seconds,
        queries served)`` for the caller's aggregated ``last_route``."""
        ranges = reg.ranges
        routable = ranges.key() == self.ranges.key()
        per_shard_s: Dict[int, float] = {}
        partials = []
        q0 = reg.entry.query
        for sid in range(self.n_shards):
            owned = self.plan.fragments_of(sid)
            if owned.size == 0 or (routable and not bits[owned].any()):
                continue
            ts = time.perf_counter()
            if sid in degraded:
                partials.append(self._degraded_partial(sid, q0, reg, bits))
            else:
                try:
                    partials.append(self._shard_call(
                        sid, "partial",
                        functools.partial(self.shards[sid].partial, q0, key,
                                          ranges, bits)))
                except ShardUnavailableError:
                    degraded.add(sid)
                    partials.append(self._degraded_partial(sid, q0, reg, bits))
            per_shard_s[sid] = time.perf_counter() - ts
        tm = time.perf_counter()
        # One HAVING-independent merge per entry; each member pays only its
        # own group-level tail (mirroring the fused path's shared launch).
        state = merge_partials_state(tuple(q0.groupby), partials)
        for i, q, entry, tp in members:
            tq = time.perf_counter()
            res = _result_from_state(q, state)
            out[i] = (res, RunInfo(
                reused=True, created=False, attr=ranges.attr,
                strategy=self.engine.strategy,
                selectivity=entry.sketch.selectivity,
                t_probe=tp, t_execute=time.perf_counter() - tq,
                repaired=applied > 0,
                shards_contacted=len(per_shard_s),
                shards_skipped=self.n_shards - len(per_shard_s),
                degraded=bool(degraded),
            ))
        return (tuple(per_shard_s), dict(per_shard_s),
                time.perf_counter() - tm, len(members))


def merge_partials_state(
    attrs: Tuple[str, ...],
    partials: List[Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]],
) -> Optional[Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]]:
    """Re-key per-shard per-group partials into merged group state.

    Partial sums/counts are re-keyed on group *values* (shard-local group
    numbering is arbitrary) and accumulated in float64; returns
    ``(group_values, sums, counts)``, or ``None`` when no shard contributed
    a group.  HAVING-independent, so one merge serves every query behind the
    same index entry.
    """
    if not attrs:
        s = float(sum(p[1].sum() for p in partials))
        c = float(sum(p[2].sum() for p in partials))
        return {}, np.array([s], dtype=np.float64), np.array([c], dtype=np.float64)
    keys, sums, counts = [], [], []
    for gv, s, c in partials:
        if s.shape[0] == 0:
            continue
        keys.append(np.stack([np.asarray(gv[a]) for a in attrs], axis=1))
        sums.append(s.astype(np.float64))
        counts.append(c.astype(np.float64))
    if not keys:
        return None
    all_keys = np.concatenate(keys)
    uniq, inv = np.unique(all_keys, axis=0, return_inverse=True)
    sums_m = np.zeros(uniq.shape[0], dtype=np.float64)
    counts_m = np.zeros(uniq.shape[0], dtype=np.float64)
    np.add.at(sums_m, inv, np.concatenate(sums))
    np.add.at(counts_m, inv, np.concatenate(counts))
    group_values = {a: uniq[:, i] for i, a in enumerate(attrs)}
    return group_values, sums_m, counts_m


def _result_from_state(
    q: Query,
    state: Optional[Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]],
) -> QueryResult:
    """Finish one query from merged group state: under the integral
    exactness envelope the float32 cast in ``_finalize`` reproduces the
    single-node kernel's per-group values bit-for-bit, and the shared
    ``result_from_group_state`` finishes HAVING chains and outer blocks
    identically to single-node execution."""
    if state is None:
        return QueryResult(
            group_values={a: np.empty(0) for a in
                          (q.outer_groupby if q.outer_groupby else q.groupby)},
            values=np.empty(0))
    group_values, sums_m, counts_m = state
    agg = _finalize(q.agg.fn, sums_m, counts_m)
    return result_from_group_state(q, group_values, agg, counts_m > 0)


def _merge_partials(
    q: Query,
    partials: List[Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]],
) -> QueryResult:
    """Merge per-shard per-group partials into one query's final result."""
    return _result_from_state(q, merge_partials_state(tuple(q.groupby), partials))


def _finalize(fn: str, sums: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """float32 finalization mirroring the executor's kernel arithmetic."""
    sums32 = sums.astype(np.float32)
    counts32 = counts.astype(np.float32)
    if fn == "count":
        return counts32
    if fn == "sum":
        return sums32
    if fn == "avg":
        return sums32 / np.maximum(counts32, np.float32(1.0))
    raise ValueError(f"unknown aggregate {fn!r}")
