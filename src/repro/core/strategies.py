"""Candidate-attribute selection strategies (Sec. 9 / Sec. 11.1.3).

Random baselines: RAND-ALL, RAND-REL-ALL, RAND-GB, RAND-PK, RAND-AGG.
Cost-based:       CB-OPT (all safe attrs), CB-OPT-REL (query-relevant),
                  CB-OPT-GB (group-by attrs only — the paper's winner).
Oracles:          OPT (exact capture of every candidate), NO-PS.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.aqp.sampling import AQRCache, SampleCache, SampleSet
from repro.aqp.size_estimation import (
    EstimationConfig,
    SizeEstimate,
    approximate_query_result,
    estimate_size_batched,
    satisfied_groups,
)
from repro.core.catalog import Catalog, default_catalog
from repro.core.queries import Query
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.safety import prefilter_candidates, safe_attributes
from repro.core.sketch import actual_size
from repro.core.table import Database

RANDOM_STRATEGIES = ("RAND-ALL", "RAND-REL-ALL", "RAND-GB", "RAND-PK", "RAND-AGG")
COST_STRATEGIES = ("CB-OPT", "CB-OPT-REL", "CB-OPT-GB")
ALL_STRATEGIES = RANDOM_STRATEGIES + COST_STRATEGIES + ("OPT",)


@dataclasses.dataclass
class SelectionResult:
    strategy: str
    attr: Optional[str]  # chosen attribute (None => no viable candidate)
    candidates: Tuple[str, ...]
    estimates: Dict[str, SizeEstimate]  # filled for cost-based strategies
    topk: Tuple[str, ...] = ()  # ranking, best first (cost-based only)


def candidate_pool(
    strategy: str, q: Query, db: Database, n_ranges: int,
    catalog: Optional[Catalog] = None,
) -> Tuple[str, ...]:
    """The strategy-specific candidate set, safety-checked and pre-filtered."""
    catalog = catalog or default_catalog()
    fact = db[q.table]
    safe = set(safe_attributes(q, db, catalog=catalog))
    if strategy in ("RAND-ALL", "CB-OPT", "OPT"):
        pool = tuple(sorted(safe))
    elif strategy in ("RAND-REL-ALL", "CB-OPT-REL"):
        pool = tuple(a for a in q.relevant_attrs if a in safe and fact.has(a))
    elif strategy in ("RAND-GB", "CB-OPT-GB"):
        pool = tuple(a for a in q.groupby if a in safe and fact.has(a))
    elif strategy == "RAND-PK":
        pool = tuple(a for a in fact.primary_key if a in safe)
    elif strategy == "RAND-AGG":
        pool = tuple([q.agg.attr] if q.agg.attr and q.agg.attr in safe else [])
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return prefilter_candidates(q, db, pool, n_ranges, catalog=catalog)


def select_attribute(
    strategy: str,
    key: jax.Array,
    q: Query,
    db: Database,
    n_ranges: int,
    sample_cache: Optional[SampleCache] = None,
    theta: float = 0.05,
    cfg: EstimationConfig = EstimationConfig(),
    ranges_for: Optional[Callable[[str], RangeSet]] = None,
    topk: int = 1,
    catalog: Optional[Catalog] = None,
    aqr_cache: Optional[AQRCache] = None,
) -> SelectionResult:
    catalog = catalog or default_catalog()
    cands = candidate_pool(strategy, q, db, n_ranges, catalog=catalog)
    if not cands:
        return SelectionResult(strategy, None, cands, {})
    ranges_for = ranges_for or (lambda a: equi_depth_ranges(db[q.table], a, n_ranges))

    if strategy in RANDOM_STRATEGIES:
        i = int(jax.random.randint(key, (), 0, len(cands)))
        return SelectionResult(strategy, cands[i], cands, {})

    if strategy == "OPT":
        sizes = {a: actual_size(q, db, ranges_for(a)) for a in cands}
        best = min(sizes, key=sizes.get)
        ranking = tuple(sorted(sizes, key=sizes.get))
        return SelectionResult(strategy, best, cands, {}, topk=ranking[:topk])

    # Cost-based: one shared AQR pass, then all candidates' fragment
    # incidence in a single vmapped device pass (Sec. 8).  Both the sample
    # and the estimate pass are cross-query caches: concurrent queries that
    # differ only in thresholds reuse them wholesale.
    sample_cache = sample_cache or SampleCache()
    k_s, k_e = jax.random.split(key)
    samples = sample_cache.get_or_create(k_s, db[q.table], q.groupby_on_fact(db), theta)
    if aqr_cache is not None:
        est, sampled = aqr_cache.get_or_compute(k_e, q, db, samples, theta, cfg)
        aqr = (est, satisfied_groups(q, est, sampled))
    else:
        aqr = approximate_query_result(k_e, q, db, samples, cfg)
    estimates: Dict[str, SizeEstimate] = estimate_size_batched(
        k_e, q, db, {a: ranges_for(a) for a in cands}, samples, cfg,
        aqr=aqr, catalog=catalog,
    )
    ranking = tuple(sorted(estimates, key=lambda a: estimates[a].est_rows))
    return SelectionResult(strategy, ranking[0], cands, estimates, topk=ranking[:topk])
