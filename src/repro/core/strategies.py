"""Candidate-attribute selection strategies (Sec. 9 / Sec. 11.1.3).

Random baselines: RAND-ALL, RAND-REL-ALL, RAND-GB, RAND-PK, RAND-AGG.
Cost-based:       CB-OPT (all safe attrs), CB-OPT-REL (query-relevant),
                  CB-OPT-GB (group-by attrs only — the paper's winner).
Oracles:          OPT (exact capture of every candidate), NO-PS.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.aqp.sampling import AQRCache, SampleCache, SampleSet
from repro.aqp.size_estimation import (
    EstimationConfig,
    SizeEstimate,
    approximate_query_result,
    estimate_size_batched,
    satisfied_groups,
)
from repro.core.catalog import Catalog, default_catalog
from repro.core.queries import Query
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.safety import prefilter_candidates, safe_attributes, stats_prefilter
from repro.core.sketch import actual_size
from repro.core.table import Database

RANDOM_STRATEGIES = ("RAND-ALL", "RAND-REL-ALL", "RAND-GB", "RAND-PK", "RAND-AGG")
COST_STRATEGIES = ("CB-OPT", "CB-OPT-REL", "CB-OPT-GB")
ALL_STRATEGIES = RANDOM_STRATEGIES + COST_STRATEGIES + ("OPT",)


@dataclasses.dataclass(frozen=True)
class SelectionConfig:
    """Knobs for the selection critical path (all engine-default ON).

    ``stats_prefilter``
        Dominance-prune candidates from catalog summary statistics alone
        (``safety.stats_prefilter``) before any sampling/AQR work.
    ``skip_single_candidate``
        A pool of one candidate has nothing to rank: skip the sample + AQR +
        estimate pass entirely and admit it estimate-free (like the random
        strategies, whose single pick never pays estimation either).
    ``reuse_aware`` / ``reuse_window`` / ``reuse_weight``
        Fold expected future index hits into the worth-it rule: each query a
        candidate sketch subsumes in the recent miss window
        (``WorkloadLog.reach``, self-inclusive so reach >= 1) discounts its
        estimated coverage by ``reuse_weight``.  The default weight (0.12)
        deliberately tips first-miss admission to *create* even for
        full-coverage sketches: a declined miss re-pays selection on every
        repeat, while even a skip-nothing sketch turns repeats into probe
        hits that skip selection wholesale — this is exactly how CB-OPT-GB
        stops losing the index-hit race to RAND-GB.  Lower the weight (or
        raise ``min_selectivity_gain``'s bite by lowering it) to restore
        coverage-based declining; reach then still lifts the bar for
        templates the window shows recurring.
    ``cache``
        Memoize whole selection passes per (strategy, table version, theta,
        n_ranges, HAVING ops, inner-block signature) so repeat templates pay
        ~zero (``SelectionCache``).  Threshold *values* are deliberately not
        part of the key — like the AQR cache, a repeat template differing
        only in thresholds reuses the first pass's ranking (documented
        approximation; estimates are exact for the query that computed them).
    """

    stats_prefilter: bool = True
    skip_single_candidate: bool = True
    reuse_aware: bool = True
    reuse_window: int = 256
    reuse_weight: float = 0.12
    cache: bool = True

    @classmethod
    def paper_faithful(cls) -> "SelectionConfig":
        """Sec. 8-9 selection exactly as the paper (and the seed) ran it:
        every safe candidate is sampled and estimated, admission is decided
        by estimated coverage alone, nothing is memoized across queries
        beyond the sample/AQR caches."""
        return cls(stats_prefilter=False, skip_single_candidate=False,
                   reuse_aware=False, cache=False)


PAPER_FAITHFUL = SelectionConfig.paper_faithful()


@dataclasses.dataclass
class SelectionResult:
    strategy: str
    attr: Optional[str]  # chosen attribute (None => no viable candidate)
    candidates: Tuple[str, ...]
    estimates: Dict[str, SizeEstimate]  # filled for cost-based strategies
    topk: Tuple[str, ...] = ()  # ranking, best first (cost-based only)


def selection_cache_key(
    strategy: str, q: Query, table: "object", theta: float, n_ranges: int
) -> Tuple:
    """Identity of one memoized selection pass.

    Keyed on everything the pass consumes besides threshold values: the
    candidate pool depends on the inner-block signature plus the HAVING
    *ops* (safety's upward-monotone check reads them), the estimates on the
    table version / theta / n_ranges.  Mutations invalidate by version
    mismatch, exactly like ``aqr_cache_key``.
    """
    ops = (q.having.op if q.having else None,
           q.outer_having.op if q.outer_having else None)
    return ((strategy, table.uid, table.version, theta, n_ranges, ops)
            + q.inner_signature())


class SelectionCache:
    """Memoized selection passes: repeat templates pay ~zero.

    The last tier of the Sec. 7.1 reuse stack (samples -> AQR passes ->
    whole selection results).  Bounded FIFO like the catalog maps; the
    sequential engine and the batched admission planner consult the same
    instance, which is what keeps ``run`` and ``run_batch`` choosing
    identical attributes on identical histories.
    """

    def __init__(self, max_entries: int = 512):
        self._cache: Dict[Tuple, SelectionResult] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple) -> Optional[SelectionResult]:
        hit = self._cache.get(key)
        if hit is not None:
            self.hits += 1
            return hit
        self.misses += 1
        return None

    def put(self, key: Tuple, result: SelectionResult) -> None:
        if len(self._cache) >= self.max_entries:
            self._cache.pop(next(iter(self._cache)))
        self._cache[key] = result

    def invalidate(self, table_name: str) -> None:
        # Key layout: (strategy, uid, version, theta, n_ranges, ops) +
        # inner_signature, whose first element is the table name.
        for ck in [ck for ck in self._cache if ck[6] == table_name]:
            del self._cache[ck]

    def __len__(self) -> int:
        return len(self._cache)


def candidate_pool(
    strategy: str, q: Query, db: Database, n_ranges: int,
    catalog: Optional[Catalog] = None,
) -> Tuple[str, ...]:
    """The strategy-specific candidate set, safety-checked and pre-filtered."""
    catalog = catalog or default_catalog()
    fact = db[q.table]
    safe = set(safe_attributes(q, db, catalog=catalog))
    if strategy in ("RAND-ALL", "CB-OPT", "OPT"):
        pool = tuple(sorted(safe))
    elif strategy in ("RAND-REL-ALL", "CB-OPT-REL"):
        pool = tuple(a for a in q.relevant_attrs if a in safe and fact.has(a))
    elif strategy in ("RAND-GB", "CB-OPT-GB"):
        pool = tuple(a for a in q.groupby if a in safe and fact.has(a))
    elif strategy == "RAND-PK":
        pool = tuple(a for a in fact.primary_key if a in safe)
    elif strategy == "RAND-AGG":
        pool = tuple([q.agg.attr] if q.agg.attr and q.agg.attr in safe else [])
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return prefilter_candidates(q, db, pool, n_ranges, catalog=catalog)


def select_attribute(
    strategy: str,
    key: jax.Array,
    q: Query,
    db: Database,
    n_ranges: int,
    sample_cache: Optional[SampleCache] = None,
    theta: float = 0.05,
    cfg: EstimationConfig = EstimationConfig(),
    ranges_for: Optional[Callable[[str], RangeSet]] = None,
    topk: int = 1,
    catalog: Optional[Catalog] = None,
    aqr_cache: Optional[AQRCache] = None,
    selection: Optional[SelectionConfig] = None,
    selection_cache: Optional[SelectionCache] = None,
) -> SelectionResult:
    """Pick the partition attribute for ``q`` under ``strategy``.

    ``selection=None`` (the default) is exactly the paper-faithful pass:
    every safe candidate is estimated, nothing is pruned or memoized.  The
    engine threads its :class:`SelectionConfig` (everything ON by default)
    plus a shared :class:`SelectionCache`; only the cost-based strategies
    consult either.
    """
    catalog = catalog or default_catalog()
    sel_cfg = selection if selection is not None else PAPER_FAITHFUL
    cost_based = strategy in COST_STRATEGIES
    ck = None
    if cost_based and sel_cfg.cache and selection_cache is not None:
        ck = selection_cache_key(strategy, q, db[q.table], theta, n_ranges)
        hit = selection_cache.get(ck)
        if hit is not None:
            return hit

    def done(result: SelectionResult) -> SelectionResult:
        if ck is not None:
            selection_cache.put(ck, result)
        return result

    cands = candidate_pool(strategy, q, db, n_ranges, catalog=catalog)
    ranges_for = ranges_for or (lambda a: equi_depth_ranges(db[q.table], a, n_ranges))
    if cost_based and sel_cfg.stats_prefilter:
        cands = stats_prefilter(q, db, cands, ranges_for, catalog=catalog)
    if not cands:
        return done(SelectionResult(strategy, None, cands, {}))

    if strategy in RANDOM_STRATEGIES:
        i = int(jax.random.randint(key, (), 0, len(cands)))  # analyze: waive[SYNC01]: deliberate merge: RANDOM strategies draw one scalar index per selection
        return SelectionResult(strategy, cands[i], cands, {})

    if strategy == "OPT":
        sizes = {a: actual_size(q, db, ranges_for(a)) for a in cands}
        best = min(sizes, key=lambda a: (sizes[a], a))
        ranking = tuple(sorted(sizes, key=lambda a: (sizes[a], a)))
        return SelectionResult(strategy, best, cands, {}, topk=ranking[:topk])

    if cost_based and sel_cfg.skip_single_candidate and len(cands) == 1:
        # Nothing to rank: admit the lone survivor estimate-free (the random
        # strategies never estimate their single pick either).  Skips the
        # sample + AQR + incidence launch entirely — the big first-miss
        # selection-cost lever for single-group-by templates.
        return done(SelectionResult(strategy, cands[0], cands, {}, topk=cands))

    # Cost-based: one shared AQR pass, then all candidates' fragment
    # incidence in a single vmapped device pass (Sec. 8).  Both the sample
    # and the estimate pass are cross-query caches: concurrent queries that
    # differ only in thresholds reuse them wholesale.
    sample_cache = sample_cache or SampleCache()
    k_s, k_e = jax.random.split(key)
    samples = sample_cache.get_or_create(k_s, db[q.table], q.groupby_on_fact(db), theta)
    if aqr_cache is not None:
        est, sampled = aqr_cache.get_or_compute(k_e, q, db, samples, theta, cfg)
        aqr = (est, satisfied_groups(q, est, sampled))
    else:
        aqr = approximate_query_result(k_e, q, db, samples, cfg)
    # The estimate stage draws from its own key: reusing ``k_e`` would
    # correlate its randomness with the AQR pass's whenever the AQR cache
    # misses.  (With a precomputed ``aqr`` the estimator is deterministic and
    # never consumes the key, so cached and uncached AQR paths still rank
    # candidates identically — pinned by tests/test_selection.py.)
    estimates: Dict[str, SizeEstimate] = estimate_size_batched(
        jax.random.fold_in(k_e, 1), q, db, {a: ranges_for(a) for a in cands},
        samples, cfg, aqr=aqr, catalog=catalog,
    )
    # Tuple tie-break, mirrored by the batched path in admission.py: equal
    # estimates resolve by attribute name, never by dict insertion order.
    ranking = tuple(sorted(estimates, key=lambda a: (estimates[a].est_rows, a)))
    return done(SelectionResult(strategy, ranking[0], cands, estimates,
                                topk=ranking[:topk]))
