"""Coordinator failover: a warm standby that takes over an active cluster.

:class:`FailoverCoordinator` owns the *role* of "the coordinator" so the
process playing it can die.  It wraps an active ``ShardedEngine``, keeps a
metadata replica attached (``core/replication``), and — when the chaos
harness injects a coordinator fault — promotes a standby:

1. **Snapshot** the replica's folded :class:`~repro.core.replication.MetadataStore`
   (for a subprocess replica, the standby process survived the coordinator
   and hands its store back over the socket).
2. **Promote** via ``ShardedEngine.from_replica`` with a bumped epoch: the
   clustered table replays from the replicated mutation log, placement /
   partition / delta logs are adopted, the sketch index rebuilds by local
   counting under its replicated ``reg_id``s, and the *live* shard
   transports are re-wrapped (``clone_for_takeover``) — no shard state
   moves, no re-capture, no full-table reship.
3. **Fence** the old coordinator out: the promoted engine's first catch-up
   round stamps the new epoch on every reachable shard, after which any op
   the old coordinator still issues raises ``StaleEpochError``
   (``coord_partition`` keeps the zombie around precisely so tests can
   prove that).
4. **Re-arm**: a fresh replica attaches to the promoted coordinator, so
   takeovers chain — coordinator #3 can die just like #1 did.

Fault kinds (``runtime.chaos.COORD_FAULT_KINDS``):

* ``coord_kill`` — the coordinator object is discarded outright (its
  clients are NOT closed: the shard servers keep running and the promoted
  engine adopts their sockets).  This is the failover analogue of a shard
  SIGKILL: nothing of the old coordinator survives but what it replicated.
* ``coord_partition`` — the old engine is kept as a live *zombie* that
  still believes it is the coordinator; the epoch fence is the only thing
  keeping its writes out, which is exactly what the chaos differential
  needs to witness.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.replication import InProcessReplica, SubprocessReplica
from repro.core.shard import ShardedEngine

#: Coordinator-level fault kinds this wrapper understands (mirrors
#: ``runtime.chaos.COORD_FAULT_KINDS`` without importing it — chaos imports
#: nothing from here, and this module must not depend on the harness).
COORD_FAULT_KINDS = ("coord_kill", "coord_partition")


def replica_factory(kind: str) -> Callable[[], object]:
    """``"loopback"`` -> in-process replica, ``"subprocess"`` -> a warm
    standby process that survives the coordinator object's death."""
    if kind == "loopback":
        return InProcessReplica
    if kind == "subprocess":
        return SubprocessReplica
    raise ValueError(f"unknown replica kind {kind!r}")


class FailoverCoordinator:
    """The failover-capable coordinator role around one ``ShardedEngine``.

    Delegates the entire serving surface (``run``/``run_batch``/mutations/
    introspection) to the currently-active engine, so it drops into every
    place a ``ShardedEngine`` goes — including ``runtime.chaos.run_ops``
    and the differential gate.  ``inject_coord`` is the chaos surface.
    """

    def __init__(self, engine: ShardedEngine,
                 make_replica: Optional[Callable[[], object]] = None):
        self._engine = engine
        self._make_replica = make_replica or InProcessReplica
        self.replica = self._make_replica()
        engine.attach_replica(self.replica)
        self.takeovers = 0
        #: The fenced-out old engine after a ``coord_partition`` (None after
        #: a ``coord_kill`` — a killed coordinator leaves no object behind).
        self.zombie: Optional[ShardedEngine] = None

    # -- delegation ------------------------------------------------------------
    @property
    def engine(self) -> ShardedEngine:
        """The currently-active coordinator engine."""
        return self._engine

    def __getattr__(self, name):
        if name.startswith("_"):
            raise AttributeError(name)
        return getattr(self._engine, name)

    # -- chaos surface ---------------------------------------------------------
    def inject_coord(self, kind: str) -> ShardedEngine:
        """Fail the active coordinator and promote a standby (see module
        docstring).  Returns the promoted engine."""
        if kind not in COORD_FAULT_KINDS:
            raise ValueError(f"unknown coordinator fault kind {kind!r}")
        old = self._engine
        store = self.replica.snapshot()
        promoted = ShardedEngine.from_replica(
            store, epoch=old.epoch + 1, attach=old.shards)
        self.replica.close_replica()
        # The zombie is NEVER shut down: its clients share live shard
        # server processes with the promoted engine (close_client would
        # hand shared servers back to the pool out from under it).  A
        # killed coordinator just loses every reference; a partitioned one
        # stays alive so the epoch fence can be witnessed rejecting it.
        self.zombie = old if kind == "coord_partition" else None
        self._engine = promoted
        self.takeovers += 1
        # Stamp the new epoch on every reachable shard NOW — from this
        # point the old coordinator is provably fenced out, not merely
        # superseded — and recover any shard that needs it.
        promoted._catch_up_all()
        # Re-arm with a fresh standby so the next takeover works too.
        self.replica = self._make_replica()
        promoted.attach_replica(self.replica)
        return promoted

    # -- lifecycle -------------------------------------------------------------
    def shutdown(self) -> None:
        """Shut down the active engine and the standby; the zombie (if any)
        is dropped without shutdown — its shard servers belong to the
        active engine now."""
        self.zombie = None
        try:
            self.replica.close_replica()
        except Exception:
            pass
        self._engine.shutdown()
