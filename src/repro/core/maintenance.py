"""Incremental maintenance of provenance sketches under appends/deletes.

The engine's premise — a captured sketch keeps paying off across queries —
breaks the moment a table mutates: without maintenance every insert or delete
would silently invalidate every sketch, and the only recovery is a full
re-capture (provenance recomputation over the whole table).  Following the
counter-based scheme of "In-memory Incremental Maintenance of Provenance
Sketches" (PAPERS.md), a ``SketchMaintainer`` keeps just enough per-sketch
state to repair the bits with *delta-sized* work:

  * the group dictionary of the captured query's GROUP BY (a private copy,
    so catalog evictions cannot invalidate it),
  * per-group aggregate state: float64 sums and int64 WHERE-passing counts,
    updated from the delta rows alone,
  * per-(group, fragment) incidence counters over WHERE-passing rows, and a
    per-fragment provenance counter ``frag_prov`` — a bit is set iff its
    counter is positive, so a delete clears a bit only when the count of
    provenance rows in that fragment hits zero,
  * the surviving-group vector, recomputed exactly from the maintained
    aggregates via ``queries.provenance_group_keep`` — the *same* group-level
    code a from-scratch capture runs, so maintained bits equal re-captured
    bits whenever the aggregate arithmetic is exact (integer-valued columns
    within float32 range; the differential tests pin this).

Group flips (a group entering/leaving the HAVING-surviving set) touch only
that group's incidence row.  For monotone-*unsafe* aggregates (AVG, or
non-upward-monotone HAVING ops per ``safety.monotone_safe``) a flip to
"not surviving" does NOT clear bits — the conservative keep-bit fallback —
because a wrongly cleared bit would make the sketch unsafe, while a stale set
bit merely skips less.  ``repair()`` re-derives ``frag_prov`` from the exact
counters and restores bit-exactness.

Join templates are maintained for mutations of the *fact* table (the delta
batch is joined against the dimension table — delta-sized work); a mutated
dimension table raises ``MaintenanceError`` and the engine falls back to
re-capture.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.catalog import (
    Catalog,
    default_catalog,
    extend_group_values,
    join_rows,
    map_group_keys,
)
from repro.core.queries import Query, provenance_group_keep
from repro.core.ranges import RangeSet
from repro.core.safety import monotone_safe
from repro.core.sketch import ProvenanceSketch
from repro.core.table import ColumnTable, Database, TableDelta


class MaintenanceError(RuntimeError):
    """Raised when a delta cannot be maintained; callers re-capture."""


def _predicate_mask(q: Query, cols: Dict[str, np.ndarray], n: int) -> np.ndarray:
    if q.where is None:
        return np.ones(n, dtype=bool)
    from repro.core.queries import _OPS

    return np.asarray(_OPS[q.where.op](cols[q.where.attr], q.where.value))


class SketchMaintainer:
    """Delta-maintained state for one (query, range partition) sketch."""

    def __init__(self, q: Query, db: Database, ranges: RangeSet,
                 catalog: Optional[Catalog] = None):
        if hasattr(ranges, "parts") or not hasattr(ranges, "attr"):
            # Raised (not AttributeError'd later) so repair_sketch's re-capture
            # fallback catches it.
            raise MaintenanceError("only single-attribute RangeSet partitions "
                                   "are maintainable; composite sketches re-capture")
        catalog = catalog or default_catalog()
        self.q = q
        self.ranges = ranges
        fact = db[q.table]
        self.table_uid = fact.uid
        self.version = fact.version
        self.exact = monotone_safe(q, db, catalog)
        self.conservative = False
        self.right = db[q.join.right] if q.join is not None else None

        if q.join is not None:
            flat, fact_idx = catalog.join(fact, self.right, q.join.left_key,
                                          q.join.right_key)
        else:
            flat, fact_idx = fact, None
        enc = catalog.groups(flat, q.groupby)
        bucket = np.asarray(catalog.bucketize(fact, ranges))
        frag = bucket if fact_idx is None else bucket[fact_idx]
        where = np.asarray(_predicate_mask(
            q, {a: np.asarray(flat[a]) for a in ([q.where.attr] if q.where else [])},
            flat.num_rows))
        if q.agg.fn == "count":
            values = np.ones(flat.num_rows, dtype=np.float64)
            self._values_integral = True
        else:
            values = np.asarray(flat[q.agg.attr], dtype=np.float64)
            self._values_integral = np.issubdtype(
                np.dtype(flat[q.agg.attr].dtype), np.integer)

        # Private copies: the maintainer must outlive catalog evictions.
        self.n_groups = enc.n_groups
        self.key_index: Dict[Tuple, int] = dict(enc.key_index(q.groupby))
        self.group_values = {a: v.copy() for a, v in enc.group_values.items()}
        self.sums = np.zeros(self.n_groups, dtype=np.float64)
        np.add.at(self.sums, enc.gid[where], values[where])
        self.counts = np.bincount(enc.gid[where], minlength=self.n_groups).astype(np.int64)
        # incidence[g] = {fragment: count of WHERE-passing rows}.  Dict-of-dict
        # so group flips touch one row; the build loop is over *deduped*
        # (group, fragment) pairs, bounded by n_groups x n_fragments.
        self.incidence: List[Dict[int, int]] = [dict() for _ in range(self.n_groups)]
        # All rows start owned; ``clone_for`` flips rows to shared (copy-on-
        # write) so a batch of same-signature maintainers does not duplicate
        # O(groups) dictionaries per query.
        self._row_owned = np.ones(self.n_groups, dtype=bool)
        pairs, cnts = np.unique(
            np.stack([enc.gid[where], frag[where]], axis=1), axis=0, return_counts=True
        ) if where.any() else (np.empty((0, 2), dtype=np.int64), np.empty(0, dtype=np.int64))
        for (g, f), c in zip(pairs, cnts):
            self.incidence[int(g)][int(f)] = int(c)
        self.passing = provenance_group_keep(
            q, self._agg_f32(), self.group_values, self.n_groups)
        # counted[g]: g's incidence row is currently folded into frag_prov.
        self.counted = self.passing.copy()
        sel = self.counted[pairs[:, 0]] if len(pairs) else np.zeros(0, dtype=bool)
        self.frag_prov = np.bincount(
            pairs[sel, 1], weights=cnts[sel], minlength=ranges.n_ranges
        ).astype(np.int64)

    def clone_for(self, q: Query, db: Database,
                  catalog: Optional[Catalog] = None) -> "SketchMaintainer":
        """A maintainer for ``q`` sharing this one's threshold-independent
        state.

        The counting state (per-group sums/WHERE-passing counts and the
        (group, fragment) incidence) depends only on the inner-block
        signature and the partition — not on the HAVING chain — so a batch of
        admitted queries differing in thresholds builds it ONCE and clones.
        The threshold-dependent pieces (surviving set, folded ``frag_prov``,
        monotone-safety) are re-derived per query exactly as a fresh build
        would, so a clone is bit-equal to ``SketchMaintainer(q, ...)``.
        """
        m = object.__new__(SketchMaintainer)
        m.q = q
        m.ranges = self.ranges
        m.table_uid = self.table_uid
        m.version = self.version
        m.exact = monotone_safe(q, db, catalog or default_catalog())
        m.conservative = False
        m.right = self.right
        m._values_integral = self._values_integral
        m.n_groups = self.n_groups
        m.key_index = dict(self.key_index)
        m.group_values = self.group_values  # replaced on growth, never mutated
        m.sums = self.sums.copy()
        m.counts = self.counts.copy()
        # Copy-on-write incidence: clones share the row dicts (a pointer-list
        # copy) and ``_own_row`` copies a row only when a delta touches it —
        # cloning stays O(groups) pointers instead of O(groups) dict copies.
        m.incidence = list(self.incidence)
        m._row_owned = np.zeros(self.n_groups, dtype=bool)
        self._row_owned[:] = False
        m.passing = provenance_group_keep(q, m._agg_f32(), m.group_values, m.n_groups)
        m.counted = m.passing.copy()
        m.frag_prov = np.zeros_like(self.frag_prov)
        for g in np.nonzero(m.counted)[0]:
            for f, c in m.incidence[int(g)].items():
                m.frag_prov[f] += c
        return m

    # -- replication -----------------------------------------------------------
    def state_dict(self) -> dict:
        """Portable counter state for coordinator replication.

        Everything a standby needs to resurrect this maintainer without the
        O(n log n) group re-encode of a fresh build: per-group aggregates,
        the deduped (group, fragment) incidence and the threshold products,
        pinned to the fact table's (uid, version) — and the join dimension's,
        when there is one — so a restore can delta-replay forward with
        ``apply``.  ``key_index`` is derivable from ``group_values`` and is
        rebuilt on restore rather than shipped.
        """
        gs: List[int] = []
        fs: List[int] = []
        cs: List[int] = []
        for g, row in enumerate(self.incidence):
            for f, c in row.items():
                gs.append(g)
                fs.append(f)
                cs.append(c)
        return {
            "table_uid": self.table_uid,
            "version": self.version,
            "exact": bool(self.exact),
            "conservative": bool(self.conservative),
            "values_integral": bool(self._values_integral),
            "right_uid": None if self.right is None else self.right.uid,
            "right_version": None if self.right is None else self.right.version,
            "n_groups": int(self.n_groups),
            "group_values": {a: v.copy() for a, v in self.group_values.items()},
            "sums": self.sums.copy(),
            "counts": self.counts.copy(),
            "incidence": (np.asarray(gs, dtype=np.int64),
                          np.asarray(fs, dtype=np.int64),
                          np.asarray(cs, dtype=np.int64)),
            "passing": self.passing.copy(),
            "counted": self.counted.copy(),
            "frag_prov": self.frag_prov.copy(),
        }

    @classmethod
    def from_state(cls, q: Query, db: Database, ranges: RangeSet,
                   state: dict) -> "SketchMaintainer":
        """Resurrect a maintainer from ``state_dict`` output.

        Counters restore verbatim and ``key_index`` re-derives from the
        shipped ``group_values`` (the same lazy derivation ``GroupEncoding``
        uses), so the result matches the maintainer that produced the state.
        Raises ``MaintenanceError`` when the state cannot be trusted under
        the current database — wrong fact-table lineage, or a join dimension
        at a different version than the counters were folded against — so
        callers fall back to an eager rebuild.
        """
        if hasattr(ranges, "parts") or not hasattr(ranges, "attr"):
            raise MaintenanceError("only single-attribute RangeSet partitions "
                                   "are maintainable; composite sketches re-capture")
        fact = db[q.table]
        if state["table_uid"] != fact.uid:
            raise MaintenanceError(
                f"replicated maintainer is for table uid {state['table_uid']}, "
                f"not {fact.uid}")
        m = object.__new__(cls)
        m.q = q
        m.ranges = ranges
        m.table_uid = state["table_uid"]
        m.version = int(state["version"])
        m.exact = bool(state["exact"])
        m.conservative = bool(state["conservative"])
        m._values_integral = bool(state["values_integral"])
        if q.join is not None:
            right = db[q.join.right]
            if (right.uid != state["right_uid"]
                    or right.version != state["right_version"]):
                raise MaintenanceError("join dimension table moved since the "
                                       "state was replicated; re-capture")
            m.right = right
        else:
            m.right = None
        m.n_groups = int(state["n_groups"])
        m.group_values = {a: np.asarray(v).copy()
                          for a, v in state["group_values"].items()}
        cols = [m.group_values[a].tolist() for a in q.groupby]
        m.key_index = ({key: g for g, key in enumerate(zip(*cols))}
                       if cols else {(): 0})
        m.sums = np.asarray(state["sums"], dtype=np.float64).copy()
        m.counts = np.asarray(state["counts"], dtype=np.int64).copy()
        m.incidence = [dict() for _ in range(m.n_groups)]
        gs, fs, cs = state["incidence"]
        for g, f, c in zip(gs.tolist(), fs.tolist(), cs.tolist()):
            m.incidence[g][f] = c
        m._row_owned = np.ones(m.n_groups, dtype=bool)
        m.passing = np.asarray(state["passing"], dtype=bool).copy()
        m.counted = np.asarray(state["counted"], dtype=bool).copy()
        m.frag_prov = np.asarray(state["frag_prov"], dtype=np.int64).copy()
        return m

    # -- group-aggregate bookkeeping ------------------------------------------
    def _agg_f32(self) -> np.ndarray:
        """Per-group aggregate values with the executor's float32 semantics."""
        sums = self.sums.astype(np.float32)
        counts = self.counts.astype(np.float32)
        if self.q.agg.fn == "count":
            return counts
        if self.q.agg.fn == "sum":
            return sums
        return sums / np.maximum(counts, np.float32(1.0))

    def _own_row(self, g: int) -> Dict[int, int]:
        """The group's incidence row, copied first if shared with a clone."""
        row = self.incidence[g]
        if not self._row_owned[g]:
            row = dict(row)
            self.incidence[g] = row
            self._row_owned[g] = True
        return row

    def _grow_groups(self, new_keys: np.ndarray, n_groups: int) -> None:
        """Extend per-group state for freshly assigned gids (appends only)."""
        n_new = n_groups - self.n_groups
        if not n_new:
            return
        self.n_groups = n_groups
        self.incidence.extend(dict() for _ in range(n_new))
        self._row_owned = np.concatenate(
            [self._row_owned, np.ones(n_new, dtype=bool)])
        self.sums = np.concatenate([self.sums, np.zeros(n_new)])
        self.counts = np.concatenate([self.counts, np.zeros(n_new, dtype=np.int64)])
        self.passing = np.concatenate([self.passing, np.zeros(n_new, dtype=bool)])
        self.counted = np.concatenate([self.counted, np.zeros(n_new, dtype=bool)])
        self.group_values = extend_group_values(self.group_values, self.q.groupby,
                                                new_keys)

    def _delta_products(
        self, cols: Dict[str, np.ndarray], grow: bool
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(gid, where, values) for one delta batch's flat rows."""
        n = len(next(iter(cols.values()))) if cols else 0
        where = _predicate_mask(self.q, cols, n)
        if self.q.agg.fn == "count":
            values = np.ones(n, dtype=np.float64)
        else:
            values = np.asarray(cols[self.q.agg.attr], dtype=np.float64)
        if not self.q.groupby:
            return np.zeros(n, dtype=np.int64), where, values
        stacked = np.stack([np.asarray(cols[a]) for a in self.q.groupby], axis=1)
        try:
            gid, new_keys, n_groups = map_group_keys(
                stacked, self.key_index, self.n_groups, grow=grow)
        except KeyError as e:  # pragma: no cover - state corruption guard
            raise MaintenanceError(f"unknown group key in delta: {e}") from None
        if grow:
            self._grow_groups(new_keys, n_groups)
        return gid, where, values

    def _flat_delta_cols(self, batch: ColumnTable) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Join-aware flat columns of a delta batch + its fact-side fragment ids.

        Returns the flat (possibly joined) columns restricted to rows that
        contribute (all rows without a join; matched rows with one) plus the
        per-flat-row fragment id of the underlying *fact* row.
        """
        fact_frag = np.asarray(self.ranges.bucketize(np.asarray(batch[self.ranges.attr])))
        if self.q.join is None:
            return {a: np.asarray(batch[a]) for a in batch.schema}, fact_frag
        cols, b_idx, _ = join_rows(
            {a: np.asarray(batch[a]) for a in batch.schema},
            self.right, self.q.join.left_key, self.q.join.right_key)
        return {a: np.asarray(v) for a, v in cols.items()}, fact_frag[b_idx]

    # -- delta application -----------------------------------------------------
    def _update_rows(self, gid: np.ndarray, frag: np.ndarray, where: np.ndarray,
                     values: np.ndarray, sign: int) -> None:
        """Fold one batch of flat rows into the counters (sign=+1/-1)."""
        g_w, f_w, v_w = gid[where], frag[where], values[where]
        np.add.at(self.sums, g_w, sign * v_w)
        np.add.at(self.counts, g_w, sign)
        if g_w.size:
            pairs, cnts = np.unique(np.stack([g_w, f_w], axis=1), axis=0,
                                    return_counts=True)
            for (g, f), c in zip(pairs, cnts):
                g, f, c = int(g), int(f), int(c) * sign
                row = self._own_row(g)
                row[f] = row.get(f, 0) + c
                if row[f] == 0:
                    del row[f]
                if self.counted[g]:
                    self.frag_prov[f] += c

    def _clears_trustworthy(self) -> bool:
        """May a group flip to "not surviving" clear its fragments' bits?

        Only when the maintained float64 aggregates provably reproduce the
        executor's float32 kernel arithmetic bit-for-bit: monotone-safe query,
        integer-valued aggregation column, and every sum the executor forms
        staying under 2**24 (so each f32 partial sum of non-negative integers
        is exactly representable).  Outside that envelope a clear could drop
        rows of a group the executor still considers passing — an unsafe
        subset sketch — so we keep bits instead (slack, never wrong).
        """
        if not (self.exact and self._values_integral):
            return False
        limit = 2.0 ** 24
        if self.counts.size and float(self.counts.max()) >= limit:
            return False
        if self.q.agg.fn != "count" and self.sums.size \
                and float(np.abs(self.sums).max()) >= limit:
            return False
        if self.q.outer_groupby is not None:
            # Outer sums accumulate the inner values; bound their total.
            inner_mag = self.counts if self.q.agg.fn == "count" else np.abs(self.sums)
            if float(inner_mag.sum()) >= limit:
                return False
        return True

    def _reconcile_passing(self) -> None:
        """Recompute the surviving-group set and fold flips into frag_prov."""
        passing = provenance_group_keep(
            self.q, self._agg_f32(), self.group_values, self.n_groups)
        trust_clears = self._clears_trustworthy()
        for g in np.nonzero(passing != self.counted)[0]:
            g = int(g)
            if passing[g]:
                for f, c in self.incidence[g].items():
                    self.frag_prov[f] += c
                self.counted[g] = True
            elif trust_clears:
                for f, c in self.incidence[g].items():
                    self.frag_prov[f] -= c
                self.counted[g] = False
            else:
                # Conservative keep-bit fallback: clearing on the word of a
                # maintained (possibly rounding-divergent) aggregate could
                # yield an unsafe subset sketch; a stale bit is merely slack.
                self.conservative = True
        self.passing = passing

    def _apply_one(self, delta: TableDelta) -> None:
        if delta.kind == "append":
            cols, frag = self._flat_delta_cols(delta.appended)
            gid, where, values = self._delta_products(cols, grow=True)
            self._update_rows(gid, frag, where, values, +1)
        else:
            parent = delta.parent
            idx = delta.deleted_idx
            batch = ColumnTable(parent.name, {
                a: np.asarray(parent[a])[idx] for a in parent.schema})
            cols, frag = self._flat_delta_cols(batch)
            gid, where, values = self._delta_products(cols, grow=False)
            self._update_rows(gid, frag, where, values, -1)
        self._reconcile_passing()

    def apply(self, table: ColumnTable, db: Database) -> None:
        """Advance the maintained state to ``table``'s version via its deltas."""
        if table.uid != self.table_uid:
            raise MaintenanceError(
                f"table lineage changed (uid {table.uid} != {self.table_uid})")
        if self.q.join is not None and db[self.q.join.right] is not self.right:
            raise MaintenanceError("join dimension table mutated; re-capture")
        chain: List[TableDelta] = []
        t = table
        while t.version > self.version:
            if t.delta is None:
                raise MaintenanceError(
                    f"no delta chain from v{self.version} to v{t.version}")
            chain.append(t.delta)
            t = t.delta.parent
        for delta in reversed(chain):
            self._apply_one(delta)
        self.version = table.version

    # -- products --------------------------------------------------------------
    def repair(self) -> None:
        """Re-derive frag_prov exactly from the counters (drops conservatism)."""
        for g in np.nonzero(self.counted & ~self.passing)[0]:
            g = int(g)
            for f, c in self.incidence[g].items():
                self.frag_prov[f] -= c
            self.counted[g] = False
        self.conservative = False

    def bits(self) -> np.ndarray:
        return self.frag_prov > 0

    def to_sketch(self, table: ColumnTable,
                  catalog: Optional[Catalog] = None) -> ProvenanceSketch:
        """Materialize the maintained state as a sketch for ``table``."""
        if table.version != self.version or table.uid != self.table_uid:
            raise MaintenanceError("maintainer not at the table's version")
        catalog = catalog or default_catalog()
        bits = self.bits()
        sizes = catalog.fragment_sizes(table, self.ranges)
        return ProvenanceSketch(
            table=self.q.table, ranges=self.ranges, bits=bits,
            size_rows=int(sizes[bits].sum()), total_rows=table.num_rows,
            table_uid=table.uid, table_version=table.version,
        )


def build_maintainer(q: Query, db: Database, ranges: RangeSet,
                     catalog: Optional[Catalog] = None) -> SketchMaintainer:
    """Build maintenance state for a just-captured sketch (cached products)."""
    return SketchMaintainer(q, db, ranges, catalog)


def maintainer_for(
    q: Query,
    db: Database,
    ranges: RangeSet,
    catalog: Optional[Catalog],
    pool: List["SketchMaintainer"],
) -> SketchMaintainer:
    """A maintainer for ``q``, cloning counting state from a pool-mate.

    A batch of sketches sharing one inner-block signature and partition (the
    common case in admitted waves, and in shard recovery re-registering a
    whole registration set at once) differs only in HAVING thresholds — the
    expensive counting pass is threshold-independent, so the first build pays
    it and the rest ``clone_for``.  Falls back to a fresh build when no
    pool-mate matches (different signature, partition, or table version).
    """
    fact = db[q.table]
    sig = q.inner_signature()
    for m in pool:
        if (m.q.inner_signature() == sig
                and m.ranges.key() == ranges.key()
                and m.table_uid == fact.uid and m.version == fact.version):
            return m.clone_for(q, db, catalog)
    return SketchMaintainer(q, db, ranges, catalog)


@dataclasses.dataclass
class RepairResult:
    sketch: ProvenanceSketch
    maintained: bool  # False => fell back to full re-capture


def repair_sketch(
    q: Query,
    db: Database,
    sketch: ProvenanceSketch,
    maintainer: Optional[SketchMaintainer],
    catalog: Optional[Catalog] = None,
) -> Tuple[RepairResult, Optional[SketchMaintainer]]:
    """Bring a stale sketch up to the current table version.

    Tries delta maintenance first; on any ``MaintenanceError`` falls back to a
    full re-capture (and rebuilds the maintainer so the *next* mutation is
    cheap again).  ``q`` must be the query the sketch was captured for.
    """
    from repro.core.sketch import capture_sketch

    catalog = catalog or default_catalog()
    table = db[q.table]
    try:
        if maintainer is None:
            raise MaintenanceError("no maintainer")
        maintainer.apply(table, db)
        sk = maintainer.to_sketch(table, catalog)
        catalog.stats["sketch_maintained"] += 1
        return RepairResult(sk, True), maintainer
    except MaintenanceError:
        sk = capture_sketch(q, db, sketch.ranges, catalog=catalog)
        catalog.stats["sketch_recaptured"] += 1
        try:
            maintainer = build_maintainer(q, db, sketch.ranges, catalog)
        except Exception:  # pragma: no cover - maintainer is best-effort
            maintainer = None
        return RepairResult(sk, False), maintainer
