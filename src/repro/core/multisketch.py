"""Multi-attribute (composite) provenance sketches.

The paper (Sec. 4.2, fn. 3) notes a sketch may be built on a partition over
*multiple* attributes but evaluates single-attribute candidates for ease of
exposition.  This module implements the composite case as a first-class
beyond-paper feature: the fragment id is the cross product of per-attribute
range buckets (row-major), the sketch is a bitset over n_a x n_b x ...
fragments, and the cost model extends naturally — the CB-OPT-GB2 strategy
estimates all 2-subsets of group-by attributes and picks the best of the
singles and pairs.

Composite sketches can only be *smaller* (finer fragments subset the coarse
ones), at the price of more ranges to store and a weaker match to physical
clustering — exactly the trade the cost model is for.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.catalog import Catalog, default_catalog
from repro.core.queries import Query, QueryResult, execute, provenance_mask
from repro.core.ranges import RangeSet, equi_depth_ranges
from repro.core.table import ColumnTable, Database

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompositeRanges:
    """Cross-product range partition over >= 1 attributes."""

    parts: Tuple[RangeSet, ...]

    @property
    def attrs(self) -> Tuple[str, ...]:
        return tuple(r.attr for r in self.parts)

    @property
    def n_ranges(self) -> int:
        n = 1
        for r in self.parts:
            n *= r.n_ranges
        return n

    def bucketize(self, table: ColumnTable) -> Array:
        """Row-major composite fragment id."""
        bucket = None
        for r in self.parts:
            b = r.bucketize(table[r.attr])
            bucket = b if bucket is None else bucket * r.n_ranges + b
        return bucket

    def key(self) -> Tuple:
        """Hashable identity, catalog-compatible with ``RangeSet.key``."""
        return ("composite",) + tuple(r.key() for r in self.parts)


@dataclasses.dataclass(frozen=True)
class CompositeSketch:
    table: str
    ranges: CompositeRanges
    bits: np.ndarray
    size_rows: int
    total_rows: int

    @property
    def selectivity(self) -> float:
        return self.size_rows / max(self.total_rows, 1)


def composite_ranges(
    table: ColumnTable, attrs: Sequence[str], n_ranges_total: int
) -> CompositeRanges:
    """Split the range budget evenly (geometric mean) across attributes."""
    k = len(attrs)
    per = max(2, int(round(n_ranges_total ** (1.0 / k))))
    return CompositeRanges(tuple(equi_depth_ranges(table, a, per) for a in attrs))


def capture_composite(
    q: Query, db: Database, ranges: CompositeRanges,
    prov: Optional[np.ndarray] = None,
    catalog: Optional[Catalog] = None,
) -> CompositeSketch:
    """Capture over a composite partition, through the catalog's caches.

    The composite bucketization and fragment sizes are cached exactly like
    single-attribute ones (``CompositeRanges.key`` is catalog-compatible), so
    repeated captures/applications over the same partition pay the
    cross-product bucketize once — the fused-path parity the single-attribute
    strategies already have.
    """
    catalog = catalog or default_catalog()
    table = db[q.table]
    if prov is None:
        prov = provenance_mask(q, db, catalog=catalog)
    bucket = catalog.bucketize(table, ranges)
    hits = jax.ops.segment_max(
        jnp.asarray(prov).astype(jnp.int32), bucket, num_segments=ranges.n_ranges
    )
    bits = np.asarray(hits > 0)
    sizes = catalog.fragment_sizes(table, ranges)
    return CompositeSketch(
        table=q.table, ranges=ranges, bits=bits,
        size_rows=int(sizes[bits].sum()), total_rows=table.num_rows,
    )


def apply_composite(
    sketch: CompositeSketch, db: Database, catalog: Optional[Catalog] = None
) -> Database:
    catalog = catalog or default_catalog()
    table = db[sketch.table]
    instance = catalog.get_instance(sketch, table)
    if instance is None:
        bucket = catalog.bucketize(table, sketch.ranges)
        keep = jnp.asarray(sketch.bits)[bucket]
        instance = table.select(keep)
        catalog.put_instance(sketch, table, instance)
    return db.with_table(instance)


def execute_with_composite(
    q: Query, db: Database, sk: CompositeSketch, catalog: Optional[Catalog] = None
) -> QueryResult:
    return execute(q, apply_composite(sk, db, catalog=catalog), catalog=catalog)


def select_composite_gb(
    key: jax.Array,
    q: Query,
    db: Database,
    n_ranges: int,
    theta: float = 0.05,
    max_pair_candidates: int = 3,
    catalog: Optional[Catalog] = None,
) -> Tuple[Tuple[str, ...], "CompositeRanges", Dict[Tuple[str, ...], float]]:
    """CB-OPT-GB2: cost-based choice over GB singles and GB pairs.

    One shared AQR pass, then every candidate — singles and composite pairs
    alike — goes through ``estimate_size_batched``'s single vmapped
    fragment-incidence pass.  For GB candidates the group key pins the
    (composite) fragment exactly, so the estimated size equals the exact
    per-candidate computation given the satisfied-group set — without the
    per-candidate full-table membership scan the previous loop paid.
    """
    from repro.aqp.sampling import stratified_reservoir_sample
    from repro.aqp.size_estimation import (
        approximate_query_result,
        estimate_size_batched,
    )

    catalog = catalog or default_catalog()
    fact = db[q.table]
    gb = [a for a in q.groupby if fact.has(a)]
    # Distinct keys per random pass (the PR 7 select_attribute fix): sampling
    # and the AQR drawing from one key correlates their randomness.
    k_s, k_e = jax.random.split(key)
    samples = stratified_reservoir_sample(k_s, fact, tuple(gb), theta)
    aqr = approximate_query_result(k_e, q, db, samples)

    cands: List[Tuple[str, ...]] = [(a,) for a in gb]
    cands += [tuple(sorted(p)) for p in itertools.combinations(gb, 2)][:max_pair_candidates]
    ranges_by = {attrs: composite_ranges(fact, attrs, n_ranges) for attrs in cands}

    total = max(fact.num_rows, 1)
    ests = estimate_size_batched(jax.random.fold_in(k_e, 1), q, db, ranges_by,
                                 samples, aqr=aqr, catalog=catalog)
    sizes: Dict[Tuple[str, ...], float] = {
        attrs: ests[attrs].est_rows / total for attrs in cands}

    # Tuple tie-break: equal estimates fall back to the lexically smallest
    # candidate, not dict insertion order.
    best = min(sizes, key=lambda attrs: (sizes[attrs], attrs))
    return best, composite_ranges(fact, best, n_ranges), sizes
