"""Query IR + executor for the paper's supported templates (Sec. 6.1).

Templates:
  Q-AGH    aggregation-groupby-having          (optional WHERE / HAVING)
  Q-AJGH   aggregation-join-groupby-having
  Q-AAGH   nested aggregation-aggregation-groupby-having
  Q-AAJGH  nested variant with a join in the inner block

The executor is a vectorized bag-semantics evaluator over ``ColumnTable``:
group-by keys are dictionary-encoded on the host (catalog work), per-row
aggregation runs on device via segment ops — on the optimized path through the
``segment_aggregate`` Pallas kernel (one-hot MXU matmuls).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import ColumnTable, Database, encode_groups

Array = jax.Array

_OPS = {
    ">": lambda x, v: x > v,
    ">=": lambda x, v: x >= v,
    "<": lambda x, v: x < v,
    "<=": lambda x, v: x <= v,
    "=": lambda x, v: x == v,
}


@dataclasses.dataclass(frozen=True)
class Predicate:
    """Row-level WHERE predicate ``attr op value``."""

    attr: str
    op: str
    value: float

    def mask(self, table: ColumnTable) -> Array:
        return _OPS[self.op](table[self.attr], self.value)


@dataclasses.dataclass(frozen=True)
class Having:
    op: str
    value: float

    def mask(self, agg_values: Array) -> Array:
        return _OPS[self.op](agg_values, self.value)


@dataclasses.dataclass(frozen=True)
class Aggregate:
    fn: str  # 'sum' | 'avg' | 'count'
    attr: Optional[str] = None  # None for count(*)


@dataclasses.dataclass(frozen=True)
class JoinSpec:
    """Equi-join ``fact.left_key = right.right_key`` (right key unique)."""

    right: str
    left_key: str
    right_key: str


@dataclasses.dataclass(frozen=True)
class Query:
    table: str
    groupby: Tuple[str, ...]
    agg: Aggregate
    where: Optional[Predicate] = None
    having: Optional[Having] = None
    join: Optional[JoinSpec] = None
    # Nested templates (Q-AAGH / Q-AAJGH): outer block over the inner result.
    outer_groupby: Optional[Tuple[str, ...]] = None
    outer_agg: Optional[Aggregate] = None
    outer_having: Optional[Having] = None

    @property
    def template(self) -> str:
        nested = self.outer_groupby is not None
        joined = self.join is not None
        if nested and joined:
            return "Q-AAJGH"
        if nested:
            return "Q-AAGH"
        if joined:
            return "Q-AJGH"
        return "Q-AGH"

    @property
    def relevant_attrs(self) -> Tuple[str, ...]:
        """Attributes the query 'touches' (for RAND-REL-ALL / CB-OPT-REL)."""
        attrs = list(self.groupby)
        if self.agg.attr:
            attrs.append(self.agg.attr)
        if self.where is not None:
            attrs.append(self.where.attr)
        if self.join is not None:
            attrs.append(self.join.left_key)
        if self.outer_groupby:
            attrs.extend(self.outer_groupby)
        seen, out = set(), []
        for a in attrs:
            if a not in seen:
                seen.add(a)
                out.append(a)
        return tuple(out)

    def groupby_on_fact(self, db: "Database") -> Tuple[str, ...]:
        """Group-by attributes that live on the sketched (fact) relation."""
        fact = db[self.table]
        return tuple(a for a in self.groupby if fact.has(a))

    def signature(self) -> Tuple:
        """Hashable identity used by the sketch index."""
        return (
            self.table,
            self.groupby,
            (self.agg.fn, self.agg.attr),
            dataclasses.astuple(self.where) if self.where else None,
            dataclasses.astuple(self.having) if self.having else None,
            dataclasses.astuple(self.join) if self.join else None,
            self.outer_groupby,
            (self.outer_agg.fn, self.outer_agg.attr) if self.outer_agg else None,
            dataclasses.astuple(self.outer_having) if self.outer_having else None,
        )


@dataclasses.dataclass(frozen=True)
class QueryResult:
    group_values: Dict[str, np.ndarray]  # per surviving group
    values: np.ndarray  # aggregate per surviving group

    def canonical(self) -> Tuple[Tuple, ...]:
        """Order-independent representation for result-equality tests."""
        attrs = sorted(self.group_values)
        rows = []
        for i in range(len(self.values)):
            rows.append(
                tuple(float(self.group_values[a][i]) for a in attrs)
                + (round(float(self.values[i]), 6),)
            )
        return tuple(sorted(rows))


# ---------------------------------------------------------------------------
# Aggregation primitives
# ---------------------------------------------------------------------------


def segment_aggregate(
    values: Array, gid: Array, n_groups: int, fn: str, weights: Optional[Array] = None
) -> Array:
    """Per-group aggregate; ``weights`` is the row inclusion mask (WHERE)."""
    w = jnp.ones_like(values, dtype=jnp.float32) if weights is None else weights.astype(jnp.float32)
    v = values.astype(jnp.float32)
    if fn == "count":
        return jax.ops.segment_sum(w, gid, num_segments=n_groups)
    sums = jax.ops.segment_sum(v * w, gid, num_segments=n_groups)
    if fn == "sum":
        return sums
    if fn == "avg":
        cnt = jax.ops.segment_sum(w, gid, num_segments=n_groups)
        return sums / jnp.maximum(cnt, 1.0)
    raise ValueError(f"unknown aggregate {fn!r}")


# ---------------------------------------------------------------------------
# Join materialization (right key unique, e.g. orders.orderkey, part.partkey)
# ---------------------------------------------------------------------------


def materialize_join(db: Database, q: Query) -> Tuple[ColumnTable, np.ndarray]:
    """Return the joined flat table and, per joined row, the fact-row index.

    Fact rows with no partner are dropped (inner join).  Right-side columns
    are prefixed with ``<right>.`` unless the name is free in the fact table.
    """
    fact = db[q.table]
    right = db[q.join.right]
    lk = np.asarray(fact[q.join.left_key])
    rk = np.asarray(right[q.join.right_key])
    order = np.argsort(rk, kind="stable")
    rk_sorted = rk[order]
    pos = np.searchsorted(rk_sorted, lk)
    pos_clip = np.minimum(pos, len(rk_sorted) - 1)
    matched = rk_sorted[pos_clip] == lk
    fact_idx = np.nonzero(matched)[0]
    right_idx = order[pos_clip[fact_idx]]

    cols: Dict[str, Array] = {}
    for a in fact.schema:
        cols[a] = jnp.asarray(np.asarray(fact[a])[fact_idx])
    for a in right.schema:
        name = a if a not in cols else f"{right.name}.{a}"
        cols[name] = jnp.asarray(np.asarray(right[a])[right_idx])
    joined = ColumnTable(f"{fact.name}_join_{right.name}", cols, fact.primary_key)
    return joined, fact_idx


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


def _inner_block(db: Database, q: Query):
    """Evaluate FROM/WHERE/GROUP BY/agg of the inner block.

    Returns (flat_table, fact_idx, gid, n_groups, group_values, agg_values,
    where_mask).  ``fact_idx`` maps flat rows back to fact-table rows.
    """
    if q.join is not None:
        flat, fact_idx = materialize_join(db, q)
    else:
        flat = db[q.table]
        fact_idx = np.arange(flat.num_rows)
    where_mask = (
        q.where.mask(flat) if q.where is not None else jnp.ones(flat.num_rows, dtype=bool)
    )
    gid, n_groups, group_values = encode_groups(flat, q.groupby)
    gid_dev = jnp.asarray(gid)
    if q.agg.fn == "count":
        vals = jnp.ones(flat.num_rows, dtype=jnp.float32)
    else:
        vals = flat[q.agg.attr]
    agg_values = segment_aggregate(vals, gid_dev, n_groups, q.agg.fn, weights=where_mask)
    return flat, fact_idx, gid, n_groups, group_values, agg_values, where_mask


def execute(q: Query, db: Database) -> QueryResult:
    flat, fact_idx, gid, n_groups, group_values, agg_values, where_mask = _inner_block(db, q)
    agg_np = np.asarray(agg_values)
    # Groups that actually exist post-WHERE (a group whose every row fails the
    # WHERE does not appear in the result).
    present = np.asarray(
        jax.ops.segment_sum(where_mask.astype(jnp.int32), jnp.asarray(gid), num_segments=n_groups)
    ) > 0

    if q.outer_groupby is None:
        keep = present
        if q.having is not None:
            keep &= np.asarray(q.having.mask(jnp.asarray(agg_np)))
        idx = np.nonzero(keep)[0]
        return QueryResult(
            group_values={a: v[idx] for a, v in group_values.items()},
            values=agg_np[idx],
        )

    # Nested templates: inner HAVING filters inner groups, then the outer
    # block aggregates result1 over outer_groupby (subset of inner groupby).
    inner_keep = present
    if q.having is not None:
        inner_keep &= np.asarray(q.having.mask(jnp.asarray(agg_np)))
    inner_idx = np.nonzero(inner_keep)[0]
    inner_vals = agg_np[inner_idx]
    inner_gv = {a: v[inner_idx] for a, v in group_values.items()}

    stacked = np.stack([inner_gv[a] for a in q.outer_groupby], axis=1)
    if stacked.shape[0] == 0:
        return QueryResult(group_values={a: np.empty(0) for a in q.outer_groupby}, values=np.empty(0))
    uniq, ogid = np.unique(stacked, axis=0, return_inverse=True)
    n_outer = uniq.shape[0]
    outer_vals = segment_aggregate(
        jnp.asarray(inner_vals),
        jnp.asarray(ogid.astype(np.int32)),
        n_outer,
        q.outer_agg.fn if q.outer_agg else "sum",
    )
    outer_np = np.asarray(outer_vals)
    keep = np.ones(n_outer, dtype=bool)
    if q.outer_having is not None:
        keep &= np.asarray(q.outer_having.mask(jnp.asarray(outer_np)))
    idx = np.nonzero(keep)[0]
    return QueryResult(
        group_values={a: uniq[:, i][idx] for i, a in enumerate(q.outer_groupby)},
        values=outer_np[idx],
    )


def provenance_mask(q: Query, db: Database) -> np.ndarray:
    """Lineage P(Q, D) as a boolean mask over the *fact table* rows.

    A fact row is in the provenance iff it contributes to some result tuple:
    it satisfies WHERE, joins (for join templates), and its group survives the
    HAVING chain.  This is the sufficiency-preserving lineage of Sec. 2.2.
    """
    flat, fact_idx, gid, n_groups, group_values, agg_values, where_mask = _inner_block(db, q)
    agg_np = np.asarray(agg_values)
    inner_keep = np.ones(n_groups, dtype=bool)
    if q.having is not None:
        inner_keep &= np.asarray(q.having.mask(jnp.asarray(agg_np)))

    if q.outer_groupby is not None:
        inner_idx = np.nonzero(inner_keep)[0]
        if inner_idx.shape[0]:
            stacked = np.stack(
                [group_values[a][inner_idx] for a in q.outer_groupby], axis=1
            )
            uniq, ogid = np.unique(stacked, axis=0, return_inverse=True)
            outer_vals = np.asarray(
                segment_aggregate(
                    jnp.asarray(agg_np[inner_idx]),
                    jnp.asarray(ogid.astype(np.int32)),
                    uniq.shape[0],
                    q.outer_agg.fn if q.outer_agg else "sum",
                )
            )
            outer_keep = np.ones(uniq.shape[0], dtype=bool)
            if q.outer_having is not None:
                outer_keep &= np.asarray(q.outer_having.mask(jnp.asarray(outer_vals)))
            surviving_inner = np.zeros(n_groups, dtype=bool)
            surviving_inner[inner_idx] = outer_keep[ogid]
            inner_keep = surviving_inner
        else:
            inner_keep = np.zeros(n_groups, dtype=bool)

    row_keep = inner_keep[gid] & np.asarray(where_mask)
    mask = np.zeros(db[q.table].num_rows, dtype=bool)
    np.add.at(mask, fact_idx[row_keep], True)
    return mask
