"""Query IR + executor for the paper's supported templates (Sec. 6.1).

Templates:
  Q-AGH    aggregation-groupby-having          (optional WHERE / HAVING)
  Q-AJGH   aggregation-join-groupby-having
  Q-AAGH   nested aggregation-aggregation-groupby-having
  Q-AAJGH  nested variant with a join in the inner block

The executor is a vectorized bag-semantics evaluator over ``ColumnTable``.
Group-by dictionary encodings, join layouts and bucketizations are *catalog*
state (``repro.core.catalog``) built once and reused across queries; per-row
aggregation runs on device through ``repro.kernels.ops.segment_aggregate``
(the one-hot MXU Pallas kernel on TPU, the ``jax.ops.segment_sum`` reference
path elsewhere).  The inner FROM/WHERE/GROUP BY/agg block is evaluated once
per query and its products are shared between result construction and
provenance derivation (``execute_and_provenance``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.catalog import Catalog, default_catalog
from repro.core.table import PAD_VALID, ColumnTable, Database
from repro.runtime.guards import hot_path

Array = jax.Array

_OPS = {
    ">": lambda x, v: x > v,
    ">=": lambda x, v: x >= v,
    "<": lambda x, v: x < v,
    "<=": lambda x, v: x <= v,
    "=": lambda x, v: x == v,
}


@dataclasses.dataclass(frozen=True)
class Predicate:
    """Row-level WHERE predicate ``attr op value``."""

    attr: str
    op: str
    value: float

    def mask(self, table: ColumnTable) -> Array:
        return _OPS[self.op](table[self.attr], self.value)


@dataclasses.dataclass(frozen=True)
class Having:
    op: str
    value: float

    def mask(self, agg_values: Array) -> Array:
        return _OPS[self.op](agg_values, self.value)


@dataclasses.dataclass(frozen=True)
class Aggregate:
    fn: str  # 'sum' | 'avg' | 'count'
    attr: Optional[str] = None  # None for count(*)


@dataclasses.dataclass(frozen=True)
class JoinSpec:
    """Equi-join ``fact.left_key = right.right_key`` (right key unique)."""

    right: str
    left_key: str
    right_key: str


@dataclasses.dataclass(frozen=True)
class Query:
    table: str
    groupby: Tuple[str, ...]
    agg: Aggregate
    where: Optional[Predicate] = None
    having: Optional[Having] = None
    join: Optional[JoinSpec] = None
    # Nested templates (Q-AAGH / Q-AAJGH): outer block over the inner result.
    outer_groupby: Optional[Tuple[str, ...]] = None
    outer_agg: Optional[Aggregate] = None
    outer_having: Optional[Having] = None

    @property
    def template(self) -> str:
        nested = self.outer_groupby is not None
        joined = self.join is not None
        if nested and joined:
            return "Q-AAJGH"
        if nested:
            return "Q-AAGH"
        if joined:
            return "Q-AJGH"
        return "Q-AGH"

    @property
    def relevant_attrs(self) -> Tuple[str, ...]:
        """Attributes the query 'touches' (for RAND-REL-ALL / CB-OPT-REL)."""
        attrs = list(self.groupby)
        if self.agg.attr:
            attrs.append(self.agg.attr)
        if self.where is not None:
            attrs.append(self.where.attr)
        if self.join is not None:
            attrs.append(self.join.left_key)
        if self.outer_groupby:
            attrs.extend(self.outer_groupby)
        seen, out = set(), []
        for a in attrs:
            if a not in seen:
                seen.add(a)
                out.append(a)
        return tuple(out)

    def groupby_on_fact(self, db: "Database") -> Tuple[str, ...]:
        """Group-by attributes that live on the sketched (fact) relation."""
        fact = db[self.table]
        return tuple(a for a in self.groupby if fact.has(a))

    def inner_signature(self) -> Tuple:
        """Hashable identity of the inner block (FROM/WHERE/GROUP BY/agg) —
        everything the HAVING chain does *not* affect.  Queries with equal
        inner signatures share samples, AQR estimate passes, inner-block
        evaluations and maintainer counting state; the batched admission
        pipeline and the AQR cache both key on this one helper so the
        sharing assumptions cannot drift apart."""
        return (
            self.table,
            self.groupby,
            (self.agg.fn, self.agg.attr),
            dataclasses.astuple(self.where) if self.where else None,
            dataclasses.astuple(self.join) if self.join else None,
        )

    def signature(self) -> Tuple:
        """Hashable identity used by the sketch index."""
        return (
            self.table,
            self.groupby,
            (self.agg.fn, self.agg.attr),
            dataclasses.astuple(self.where) if self.where else None,
            dataclasses.astuple(self.having) if self.having else None,
            dataclasses.astuple(self.join) if self.join else None,
            self.outer_groupby,
            (self.outer_agg.fn, self.outer_agg.attr) if self.outer_agg else None,
            dataclasses.astuple(self.outer_having) if self.outer_having else None,
        )


@dataclasses.dataclass(frozen=True)
class QueryResult:
    group_values: Dict[str, np.ndarray]  # per surviving group
    values: np.ndarray  # aggregate per surviving group

    def canonical(self) -> Tuple[Tuple, ...]:
        """Order-independent representation for result-equality tests."""
        attrs = sorted(self.group_values)
        rows = []
        for i in range(len(self.values)):
            rows.append(
                tuple(float(self.group_values[a][i]) for a in attrs)
                + (round(float(self.values[i]), 6),)
            )
        return tuple(sorted(rows))


# ---------------------------------------------------------------------------
# Aggregation primitives
# ---------------------------------------------------------------------------


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def segment_sums_counts(
    values: Array, gid: Array, n_groups: int, weights: Optional[Array] = None
) -> Tuple[Array, Array]:
    """(per-group sums, per-group counts) via the segment-aggregate kernel.

    Dispatches the Pallas one-hot MXU kernel on TPU and the segment-sum
    reference path elsewhere (see ``repro.kernels.ops``).  Row and group
    dimensions are padded to powers of two so the jitted kernel wrapper
    compiles once per size class instead of once per query shape.
    """
    from repro.kernels import ops as kops

    n = int(values.shape[0])
    n_pad = _next_pow2(max(n, 1))
    g_pad = _next_pow2(max(n_groups, 1))
    w = jnp.ones(n, dtype=jnp.float32) if weights is None else weights.astype(jnp.float32)
    if n_pad != n:
        # Padded rows carry weight 0 into group 0: they contribute nothing.
        values = jnp.pad(values.astype(jnp.float32), (0, n_pad - n))
        gid = jnp.pad(gid.astype(jnp.int32), (0, n_pad - n))
        w = jnp.pad(w, (0, n_pad - n))
    sums, counts = kops.segment_aggregate(values, gid, g_pad, w)
    return sums[:n_groups], counts[:n_groups]


def _finalize_aggregate(fn: str, sums: Array, counts: Array) -> Array:
    if fn == "count":
        return counts
    if fn == "sum":
        return sums
    if fn == "avg":
        return sums / jnp.maximum(counts, 1.0)
    raise ValueError(f"unknown aggregate {fn!r}")


@hot_path
def segment_aggregate(
    values: Array, gid: Array, n_groups: int, fn: str, weights: Optional[Array] = None
) -> Array:
    """Per-group aggregate; ``weights`` is the row inclusion mask (WHERE)."""
    sums, counts = segment_sums_counts(values, gid, n_groups, weights)
    return _finalize_aggregate(fn, sums, counts)


# ---------------------------------------------------------------------------
# Join materialization (right key unique, e.g. orders.orderkey, part.partkey)
# ---------------------------------------------------------------------------


def materialize_join(
    db: Database, q: Query, catalog: Optional[Catalog] = None
) -> Tuple[ColumnTable, np.ndarray]:
    """Return the joined flat table and, per joined row, the fact-row index.

    The layout is built once per (fact, right, keys) in the catalog and
    reused by every subsequent query over the same join spec.
    """
    catalog = catalog or default_catalog()
    return catalog.join(db[q.table], db[q.join.right], q.join.left_key, q.join.right_key)


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class InnerBlock:
    """Products of the FROM/WHERE/GROUP BY/agg inner block, computed once.

    ``fact_idx`` maps flat rows back to fact-table rows (``None`` means the
    identity — no join).  ``present[g]`` is True iff group ``g`` has at least
    one row passing WHERE.
    """

    flat: ColumnTable
    fact_idx: Optional[np.ndarray]
    gid: np.ndarray
    n_groups: int
    group_values: Dict[str, np.ndarray]
    agg_np: np.ndarray
    present: np.ndarray
    where_np: np.ndarray


def inner_block_arrays(q: Query, flat: ColumnTable, catalog: Catalog):
    """The per-row inputs of the inner block's aggregation over one (already
    joined) flat table: ``(enc, where_mask, vals)``.

    The single source of truth for mask derivation (WHERE ∧ pad-validity),
    group encoding and aggregate value selection.  Single-node execution
    feeds the arrays straight into ``segment_sums_counts``; the fragment-
    sharded stacked launch (``repro.core.shard``) pads and stacks them on a
    shard axis first — either way the aggregation semantics come from here,
    which is what makes routed partials mergeable into bit-identical results.
    """
    where_mask = (
        catalog.where_mask(flat, q.where)
        if q.where is not None
        else jnp.ones(flat.num_rows, dtype=bool)
    )
    if flat.has(PAD_VALID):
        # Pow2-padded sketch instance: the tail rows exist only to pin the
        # compiled shape and must contribute nothing (weight 0 everywhere).
        where_mask = where_mask & flat[PAD_VALID]
    enc = catalog.groups(flat, q.groupby)
    if q.agg.fn == "count":
        vals = jnp.ones(flat.num_rows, dtype=jnp.float32)
    else:
        vals = flat[q.agg.attr]
    return enc, where_mask, vals


def inner_group_partials(
    q: Query, flat: ColumnTable, catalog: Catalog
):
    """WHERE mask + group encoding + fused per-group sums/counts over one
    (already joined) flat table.  Returns ``(enc, where_mask, sums, counts)``.
    """
    enc, where_mask, vals = inner_block_arrays(q, flat, catalog)
    sums, counts = segment_sums_counts(vals, enc.gid_dev, enc.n_groups, weights=where_mask)
    return enc, where_mask, sums, counts


def _inner_block(db: Database, q: Query, catalog: Optional[Catalog] = None) -> InnerBlock:
    """Evaluate the inner block once; one fused segment pass yields both the
    aggregate values and group presence."""
    catalog = catalog or default_catalog()
    if q.join is not None:
        flat, fact_idx = materialize_join(db, q, catalog)
    else:
        flat, fact_idx = db[q.table], None
    enc, where_mask, sums, counts = inner_group_partials(q, flat, catalog)
    agg = _finalize_aggregate(q.agg.fn, sums, counts)
    counts_np = np.asarray(counts)
    return InnerBlock(
        flat=flat,
        fact_idx=fact_idx,
        gid=enc.gid,
        n_groups=enc.n_groups,
        group_values=enc.group_values,
        agg_np=np.asarray(agg),
        # Groups whose every row fails the WHERE do not appear in the result.
        present=counts_np > 0,
        where_np=np.asarray(where_mask),
    )


def result_from_group_state(
    q: Query,
    group_values: Dict[str, np.ndarray],
    agg_np: np.ndarray,
    present: np.ndarray,
) -> QueryResult:
    """Finish a query from per-group state alone (HAVING chain + outer block).

    This is the group-level tail of the executor, factored out so the
    fragment-sharded coordinator (``repro.core.shard``) can run it over
    *merged* per-shard partial aggregates: given equal per-group values and
    presence, the result matches single-node execution exactly.
    """
    if q.outer_groupby is None:
        keep = present.copy()
        if q.having is not None:
            keep &= np.asarray(q.having.mask(agg_np))
        idx = np.nonzero(keep)[0]
        return QueryResult(
            group_values={a: v[idx] for a, v in group_values.items()},
            values=agg_np[idx],
        )

    # Nested templates: inner HAVING filters inner groups, then the outer
    # block aggregates result1 over outer_groupby (subset of inner groupby).
    inner_keep = present.copy()
    if q.having is not None:
        inner_keep &= np.asarray(q.having.mask(agg_np))
    inner_idx = np.nonzero(inner_keep)[0]
    inner_vals = agg_np[inner_idx]
    inner_gv = {a: v[inner_idx] for a, v in group_values.items()}

    stacked = np.stack([inner_gv[a] for a in q.outer_groupby], axis=1)
    if stacked.shape[0] == 0:
        return QueryResult(group_values={a: np.empty(0) for a in q.outer_groupby}, values=np.empty(0))
    uniq, ogid = np.unique(stacked, axis=0, return_inverse=True)
    n_outer = uniq.shape[0]
    outer_vals = segment_aggregate(
        jnp.asarray(inner_vals),
        jnp.asarray(ogid.astype(np.int32)),
        n_outer,
        q.outer_agg.fn if q.outer_agg else "sum",
    )
    outer_np = np.asarray(outer_vals)  # analyze: waive[SYNC01]: deliberate merge: outer-query HAVING filters on host, once per query result
    keep = np.ones(n_outer, dtype=bool)
    if q.outer_having is not None:
        keep &= np.asarray(q.outer_having.mask(outer_np))
    idx = np.nonzero(keep)[0]
    return QueryResult(
        group_values={a: uniq[:, i][idx] for i, a in enumerate(q.outer_groupby)},
        values=outer_np[idx],
    )


def _result_from_inner(q: Query, ib: InnerBlock) -> QueryResult:
    return result_from_group_state(q, ib.group_values, ib.agg_np, ib.present)


def provenance_group_keep(
    q: Query,
    agg_np: np.ndarray,
    group_values: Dict[str, np.ndarray],
    n_groups: int,
) -> np.ndarray:
    """Which (inner) groups survive the HAVING chain, per-group-state only.

    This is the group-level half of provenance derivation, factored out so the
    incremental maintenance path (``repro.core.maintenance``) can replay it
    bit-for-bit from *maintained* per-group aggregates: given equal ``agg_np``
    and group key values, the surviving-group set — and hence the sketch bits
    — matches a from-scratch capture exactly.  Group *numbering* may differ
    between callers; the outer block re-keys on group values, so the result
    is numbering-covariant.
    """
    inner_keep = np.ones(n_groups, dtype=bool)
    if q.having is not None:
        inner_keep &= np.asarray(q.having.mask(agg_np))

    if q.outer_groupby is not None:
        inner_idx = np.nonzero(inner_keep)[0]
        if inner_idx.shape[0]:
            stacked = np.stack(
                [group_values[a][inner_idx] for a in q.outer_groupby], axis=1
            )
            uniq, ogid = np.unique(stacked, axis=0, return_inverse=True)
            outer_vals = np.asarray(  # analyze: waive[SYNC01]: deliberate merge: nested-aggregate outer pass filters on host, once per query result
                segment_aggregate(
                    jnp.asarray(agg_np[inner_idx]),
                    jnp.asarray(ogid.astype(np.int32)),
                    uniq.shape[0],
                    q.outer_agg.fn if q.outer_agg else "sum",
                )
            )
            outer_keep = np.ones(uniq.shape[0], dtype=bool)
            if q.outer_having is not None:
                outer_keep &= np.asarray(q.outer_having.mask(outer_vals))
            surviving_inner = np.zeros(n_groups, dtype=bool)
            surviving_inner[inner_idx] = outer_keep[ogid]
            inner_keep = surviving_inner
        else:
            inner_keep = np.zeros(n_groups, dtype=bool)
    return inner_keep


def _provenance_from_inner(q: Query, ib: InnerBlock, n_fact_rows: int) -> np.ndarray:
    inner_keep = provenance_group_keep(q, ib.agg_np, ib.group_values, ib.n_groups)
    row_keep = inner_keep[ib.gid] & ib.where_np
    if ib.fact_idx is None:
        return row_keep
    mask = np.zeros(n_fact_rows, dtype=bool)
    mask[ib.fact_idx[row_keep]] = True
    return mask


# Public names for the inner-block products: the batched admission pipeline
# (``repro.core.admission``) evaluates the shared FROM/WHERE/GROUP BY/agg
# block once per signature group and derives every member query's result and
# provenance from the same ``InnerBlock`` — the group-level tails are pure
# functions of it, so sharing is bit-exact.
inner_block = _inner_block
result_from_inner = _result_from_inner
provenance_from_inner = _provenance_from_inner


@hot_path
def execute(q: Query, db: Database, catalog: Optional[Catalog] = None) -> QueryResult:
    return _result_from_inner(q, _inner_block(db, q, catalog))


def provenance_mask(q: Query, db: Database, catalog: Optional[Catalog] = None) -> np.ndarray:
    """Lineage P(Q, D) as a boolean mask over the *fact table* rows.

    A fact row is in the provenance iff it contributes to some result tuple:
    it satisfies WHERE, joins (for join templates), and its group survives the
    HAVING chain.  This is the sufficiency-preserving lineage of Sec. 2.2.
    """
    ib = _inner_block(db, q, catalog)
    return _provenance_from_inner(q, ib, db[q.table].num_rows)


@hot_path
def execute_and_provenance(
    q: Query, db: Database, catalog: Optional[Catalog] = None
) -> Tuple[QueryResult, np.ndarray]:
    """Fused capture+execute path: one inner-block evaluation yields both the
    query result and the provenance mask (the seed ran the block twice)."""
    ib = _inner_block(db, q, catalog)
    return _result_from_inner(q, ib), _provenance_from_inner(q, ib, db[q.table].num_rows)
