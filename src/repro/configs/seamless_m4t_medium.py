"""seamless-m4t-medium [audio]: enc-dec, 12L encoder + 12L decoder,
d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  [arXiv:2308.11596; hf]

The audio frontend is a STUB per the brief: input_specs() provides
precomputed frame embeddings (B, S_enc, 1024) consumed by the encoder's
input projection.  Vocab 256206 is padded to 256208 for even 16-way TP
sharding (padded logits masked to -inf; excluded from MODEL_FLOPS).
Decode shapes run the decoder with a cross-attention cache over the encoder
states; `long_500k` is skipped (pure full attention).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    padded_vocab=256208,
    pattern=(("attn", "mlp"),),
    n_periods=12,
    n_encoder_layers=12,
    frontend="audio",
    frontend_dim=1024,
    rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=250,
    padded_vocab=256,
    pattern=(("attn", "mlp"),),
    n_periods=2,
    n_encoder_layers=2,
    frontend="audio",
    frontend_dim=32,
    loss_chunk=16,
    attn_chunk=16,
)
