"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

_MODULES: Dict[str, str] = {
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "stablelm-1.6b": "repro.configs.stablelm_16b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "jamba-1.5-large-398b": "repro.configs.jamba_15_large_398b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_is_runnable(arch: str, shape: str) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell, else the skip reason."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if shape == "long_500k" and not cfg.supports_long_context():
        return False, "pure full-attention arch: 500k dense KV unsupported (DESIGN.md §5)"
    return True, ""


def runnable_cells():
    for a in ARCHS:
        for s in SHAPES:
            ok, why = cell_is_runnable(a, s)
            yield a, s, ok, why
