"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544.  [arXiv:2403.17297; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    pattern=(("attn", "mlp"),),
    n_periods=48,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="internlm2-20b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    pattern=(("attn", "mlp"),),
    n_periods=2,
    loss_chunk=16,
    attn_chunk=16,
)
