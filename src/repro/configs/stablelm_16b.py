"""stablelm-1.6b [dense]: 24L d_model=2048 32H (MHA, kv=32) d_ff=5632
vocab=100352.  [hf:stabilityai/stablelm-2-1_6b; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    pattern=(("attn", "mlp"),),
    n_periods=24,
    rope_theta=1e4,
)

SMOKE_CONFIG = ModelConfig(
    name="stablelm-1.6b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    pattern=(("attn", "mlp"),),
    n_periods=2,
    loss_chunk=16,
    attn_chunk=16,
)
