"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]

40 heads don't divide the 16-way model axis: heads are padded to 48 (3 per
device).  Padding heads are regular parameters (extra capacity when training
from scratch) but are excluded from MODEL_FLOPS, so the §Roofline
useful-compute ratio stays honest.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    pattern=(("attn", "mlp"),),
    n_periods=64,
    qkv_bias=True,
    padded_heads=48,
    padded_kv_heads=48,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen1.5-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=60,
    n_heads=5,
    n_kv_heads=5,
    d_ff=128,
    vocab_size=256,
    head_dim=12,
    pattern=(("attn", "mlp"),),
    n_periods=2,
    qkv_bias=True,
    padded_heads=6,
    padded_kv_heads=6,
    loss_chunk=16,
    attn_chunk=16,
)
