"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) expert d_ff=1408,
vocab=151936, MoE 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

The 4 shared (always-on) experts are modelled as one dense SwiGLU of width
4*1408 = 5632 alongside the routed experts.  60 routed experts don't divide
the 16-way model axis, so the expert dim is padded to 64 (router logits for
padding experts are masked to -inf; they are excluded from MODEL_FLOPS).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    pattern=(("attn", "moe"),),
    n_periods=24,
    n_experts=60,
    experts_per_token=4,
    moe_d_ff=1408,
    shared_d_ff=5632,
    padded_experts=64,
    rope_theta=1e6,
    qkv_bias=True,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=256,
    pattern=(("attn", "moe"),),
    n_periods=2,
    n_experts=6,
    experts_per_token=2,
    moe_d_ff=96,
    shared_d_ff=128,
    padded_experts=8,
    qkv_bias=True,
    loss_chunk=16,
    attn_chunk=16,
)
