"""xlstm-350m [ssm]: 24L d_model=1024 4H vocab=50304, alternating sLSTM +
mLSTM blocks with post-up-projection (d_ff=0: blocks carry their own
projections).  [arXiv:2405.04517; unverified]

Pure recurrent state => `long_500k` decode is O(1) per token; the parallel
(quadratic, gated-attention-like) mLSTM form is used for training/prefill.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=(("mlstm", "none"), ("slstm", "none")),
    n_periods=12,
    xlstm_proj_factor=2.0,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-350m-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    pattern=(("mlstm", "none"), ("slstm", "none")),
    n_periods=2,
    xlstm_proj_factor=2.0,
    loss_chunk=16,
    attn_chunk=16,
)
