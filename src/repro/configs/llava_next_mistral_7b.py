"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres vision stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The anyres vision tower is a STUB per the brief: input_specs() provides 576
precomputed patch embeddings (B, 576, 4096) which the backbone projects and
prepends to the text tokens.  KV heads (8) don't divide the 16-way model axis
and are replicated (q heads shard 32/16=2) — see DESIGN.md §6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    pattern=(("attn", "mlp"),),
    n_periods=32,
    rope_theta=1e6,
    frontend="vision",
    n_frontend_tokens=576,
    frontend_dim=4096,
)

SMOKE_CONFIG = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    pattern=(("attn", "mlp"),),
    n_periods=2,
    frontend="vision",
    n_frontend_tokens=8,
    frontend_dim=32,
    loss_chunk=16,
    attn_chunk=16,
)
