"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768,
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]

Every layer is (attention, MoE); there is no dense FFN.  Experts shard 128/16
= 8 per device over the model axis (EP); kv=4 heads replicate.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    pattern=(("attn", "moe"),),
    n_periods=48,
    n_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    pattern=(("attn", "moe"),),
    n_periods=2,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=96,
    loss_chunk=16,
    attn_chunk=16,
)
