"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

Pattern period = 5 sliding-window layers (window 1024) + 1 global layer;
62 = 10 periods * 6 + 2 remainder local layers (run unrolled post-scan).
Local layers keep only a 1024-slot ring-buffer KV cache, which is what makes
`long_500k` decode feasible: only ~1/6 of layers hold full-length KV.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    pattern=(("swa", "mlp"),) * 5 + (("attn", "mlp"),),
    n_periods=10,
    remainder=(("swa", "mlp"),) * 2,
    sliding_window=1024,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-27b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    pattern=(("swa", "mlp"),) * 2 + (("attn", "mlp"),),
    n_periods=1,
    remainder=(("swa", "mlp"),),
    sliding_window=8,
    loss_chunk=16,
    attn_chunk=16,
)
