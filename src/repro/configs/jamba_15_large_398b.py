"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, Mamba:attention 7:1 interleave, MoE (16 experts top-2) on every
other layer.  [arXiv:2403.19887; hf]

Period of 8 = [attn+MoE, (mamba+MLP, mamba+MoE) * 3, mamba+MLP], scanned 9x.
The 398B scale is the FSDP/ZeRO stress test: bf16 params alone are 796 GB,
so every parameter's embed dim shards over ('pod','data') in addition to TP
over 'model' (see parallel/sharding.py).
"""
from repro.models.config import ModelConfig

_PERIOD = (
    ("attn", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PERIOD,
    n_periods=9,
    n_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    ssm_state=16,
    ssm_expand=2,
    ssm_dt_rank=256,
    rope_theta=1e6,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-1.5-large-398b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    pattern=(("attn", "moe"), ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp")),
    n_periods=1,
    n_experts=4,
    experts_per_token=2,
    moe_d_ff=128,
    ssm_state=4,
    ssm_dt_rank=8,
    loss_chunk=16,
    attn_chunk=16,
)
