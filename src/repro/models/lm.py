"""Model assembly: decoder-only LMs (dense / MoE / SSM / hybrid patterns) and
encoder-decoder backbones, with scan-over-periods layer stacking.

The repeating layer pattern (cfg.pattern) is the scan unit: parameters for
one period are stacked over ``n_periods`` and consumed by ``lax.scan``, which
keeps HLO size O(period) instead of O(layers) — essential for compiling 62-72
layer models quickly, and the idiom XLA pipelines FSDP all-gathers around.
Pattern remainders (e.g. gemma3's 62 = 10*6 + 2) run unrolled after the scan.

Three entry points per model:
  loss_fn(params, batch)                 -- training loss (+ MoE aux)
  prefill(params, tokens, ...)           -- full-seq forward -> (logits, cache)
  decode_step(params, cache, token, pos) -- one token with O(1)/O(T) state
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import Block, ModelConfig
from repro.models.params import P, abstract, init_params

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter tree construction
# ---------------------------------------------------------------------------


def _mixer_params(cfg: ModelConfig, mixer: str) -> Dict[str, Any]:
    if mixer in ("attn", "swa"):
        return L.attn_params(cfg)
    if mixer == "mamba":
        return S.mamba_params(cfg)
    if mixer == "mlstm":
        return S.mlstm_params(cfg)
    if mixer == "slstm":
        return S.slstm_params(cfg)
    raise ValueError(mixer)


def _ffn_params(cfg: ModelConfig, ffn: str) -> Optional[Dict[str, Any]]:
    if ffn == "mlp":
        return L.mlp_params(cfg)
    if ffn == "moe":
        return L.moe_params(cfg)
    if ffn == "none":
        return None
    raise ValueError(ffn)


def _block_params(cfg: ModelConfig, block: Block, decoder_cross: bool = False) -> Dict[str, Any]:
    mixer, ffn = block
    p: Dict[str, Any] = {"mixer": _mixer_params(cfg, mixer)}
    f = _ffn_params(cfg, ffn)
    if f is not None:
        p["ffn"] = f
    if decoder_cross:
        p["xattn"] = L.attn_params(cfg, cross=True)
    return p


def build_param_spec(cfg: ModelConfig) -> Dict[str, Any]:
    d, vp = cfg.d_model, cfg.vocab_p
    spec: Dict[str, Any] = {
        "embed": P((vp, d), ("vocab", "embed"), init="embed"),
        "final_norm": L.norm_params(d),
        "lm_head": P((d, vp), ("embed", "vocab")),
    }
    if cfg.frontend:
        spec["frontend"] = {"proj": P((cfg.frontend_dim, d), (None, "embed"))}
    cross = cfg.is_encdec
    period = {
        f"b{j}": _block_params(cfg, blk, decoder_cross=cross)
        for j, blk in enumerate(cfg.pattern)
    }
    from repro.models.params import stack

    spec["periods"] = stack(period, cfg.n_periods)
    if cfg.remainder:
        spec["rem"] = {
            f"r{j}": _block_params(cfg, blk, decoder_cross=cross)
            for j, blk in enumerate(cfg.remainder)
        }
    if cfg.is_encdec:
        spec["encoder"] = {
            "in_proj": P((cfg.frontend_dim or d, d), (None, "embed")),
            "layers": stack(
                {"b0": _block_params(cfg, ("attn", "mlp"))}, cfg.n_encoder_layers
            ),
            "norm": L.norm_params(d),
        }
    return spec


def abstract_params(cfg: ModelConfig):
    return abstract(build_param_spec(cfg), jnp.dtype(cfg.dtype))


def concrete_params(key: jax.Array, cfg: ModelConfig):
    return init_params(key, build_param_spec(cfg), jnp.dtype(cfg.dtype))


# ---------------------------------------------------------------------------
# Block application (train / full-sequence)
# ---------------------------------------------------------------------------


def _apply_block_train(
    cfg: ModelConfig, block: Block, p, h: Array, enc: Optional[Array]
) -> Tuple[Array, Array]:
    mixer, ffn = block
    aux = jnp.zeros((), jnp.float32)
    if mixer == "attn":
        h = L.attention_train(p["mixer"], cfg, h, causal=not cfg.is_encdec or True)
    elif mixer == "swa":
        h = L.attention_train(p["mixer"], cfg, h, window=cfg.sliding_window)
    elif mixer == "mamba":
        h = S.mamba_train(p["mixer"], cfg, h)
    elif mixer == "mlstm":
        h = S.mlstm_train(p["mixer"], cfg, h)
    elif mixer == "slstm":
        h = S.slstm_train(p["mixer"], cfg, h)
    if "xattn" in p and enc is not None:
        h = L.attention_train(p["xattn"], cfg, h, enc=enc)
    if ffn == "mlp":
        h = L.mlp(p["ffn"], cfg, h)
    elif ffn == "moe":
        h, aux = L.moe(p["ffn"], cfg, h)
    return h, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _run_stack(cfg: ModelConfig, params, h: Array, enc: Optional[Array] = None) -> Tuple[Array, Array]:
    """Scan the periods, then run the remainder blocks."""
    from repro.parallel.context import constrain_batch, constrain_params

    def period_body(carry, pparams):
        hh, aux = carry
        hh = constrain_batch(hh)  # keep the residual stream DP-sharded
        for j, blk in enumerate(cfg.pattern):
            bp = constrain_params(("periods", f"b{j}"), pparams[f"b{j}"])  # ZeRO-3 gather
            hh, a = _apply_block_train(cfg, blk, bp, hh, enc)
            aux = aux + a
        return (hh, aux), None

    body = _remat(period_body, cfg)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["periods"])
    for j, blk in enumerate(cfg.remainder):
        rp = constrain_params(("rem", f"r{j}"), params["rem"][f"r{j}"])
        h, a = _apply_block_train(cfg, blk, rp, h, enc)
        aux = aux + a
    return h, aux


def _run_encoder(cfg: ModelConfig, params, frames: Array) -> Array:
    enc_p = params["encoder"]
    h = jnp.einsum("bsf,fd->bsd", frames.astype(jnp.dtype(cfg.dtype)), enc_p["in_proj"])

    from repro.parallel.context import constrain_batch, constrain_params

    def body(hh, lp):
        hh = constrain_batch(hh)
        lp = constrain_params("encoder_layers", lp)
        hh = L.attention_train(lp["b0"]["mixer"], cfg, hh, causal=False)
        hh = L.mlp(lp["b0"]["ffn"], cfg, hh)
        return hh, None

    h, _ = jax.lax.scan(_remat(body, cfg), h, enc_p["layers"])
    return L.rmsnorm(enc_p["norm"], h)


# ---------------------------------------------------------------------------
# Embedding / loss
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, tokens: Array) -> Array:
    from repro.parallel.context import constrain_batch, constrain_params

    table = constrain_params("embed", params["embed"])
    emb = jnp.take(table, tokens, axis=0)
    # NB: scale by a *weak-typed* python float — a numpy f32 scalar would
    # promote the whole residual stream to f32 (2x activation memory + comm).
    return constrain_batch(emb * float(np.sqrt(cfg.d_model)))


def chunked_xent(
    cfg: ModelConfig, h: Array, head: Array, labels: Array, mask: Array
) -> Array:
    """Cross-entropy with the vocab projection applied in sequence chunks, so
    the (B, S, V) logits tensor never exists; V can be 262k."""
    b, s, d = h.shape
    chunk = min(cfg.loss_chunk, s)
    pad = -s % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (s + pad) // chunk
    hc = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    def body(carry, inp):
        hh, ll, mm = inp
        logits = jnp.einsum("bsd,dv->bsv", hh, head).astype(jnp.float32)
        if cfg.vocab_p > cfg.vocab_size:
            pad_v = jnp.arange(cfg.vocab_p) >= cfg.vocab_size
            logits = jnp.where(pad_v[None, None], -1e30, logits)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        loss = ((lse - gold) * mm).sum()
        return (carry[0] + loss, carry[1] + mm.sum()), None

    (loss_sum, n), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hc, lc, mc))
    return loss_sum / jnp.maximum(n, 1.0)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array]) -> Array:
    """batch: tokens (B, S_text) [+ 'frontend' (B, F, fdim)] [+ 'frames']."""
    tokens = batch["tokens"]
    h = _embed(cfg, params, tokens)
    n_front = 0
    if cfg.frontend and "frontend" in batch:
        fe = jnp.einsum(
            "bsf,fd->bsd", batch["frontend"].astype(h.dtype), params["frontend"]["proj"]
        )
        h = jnp.concatenate([fe, h], axis=1)
        n_front = fe.shape[1]
    enc = None
    if cfg.is_encdec:
        enc = _run_encoder(cfg, params, batch["frames"])
    h, aux = _run_stack(cfg, params, h, enc)
    from repro.parallel.context import constrain_batch

    h = constrain_batch(L.rmsnorm(params["final_norm"], h))
    # Next-token prediction over the text region only.
    h_text = h[:, n_front:, :]
    labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(
        jnp.ones_like(tokens[:, 1:], jnp.float32), ((0, 0), (0, 1))
    )
    from repro.parallel.context import constrain_params

    head = constrain_params("lm_head", params["lm_head"])
    xent = chunked_xent(cfg, h_text, head, labels, mask)
    return xent + 0.01 * aux


# -- caches -----------------------------------------------------------------


def _block_cache(cfg: ModelConfig, block: Block, batch: int, length: int, dtype, cross_len: int = 0):
    mixer, _ = block
    c: Dict[str, Any] = {}
    if mixer == "attn":
        c["kv"] = L.init_attn_cache(cfg, batch, length, 0, dtype)
    elif mixer == "swa":
        c["kv"] = L.init_attn_cache(cfg, batch, length, cfg.sliding_window, dtype)
    elif mixer == "mamba":
        c["ssm"] = S.init_mamba_cache(cfg, batch, dtype)
    elif mixer == "mlstm":
        c["ml"] = S.init_mlstm_cache(cfg, batch)
    elif mixer == "slstm":
        c["sl"] = S.init_slstm_cache(cfg, batch)
    if cfg.is_encdec and cross_len:
        c["xkv"] = {
            "k": jnp.zeros((batch, cross_len, cfg.kv_heads_p, cfg.hd), dtype),
            "v": jnp.zeros((batch, cross_len, cfg.kv_heads_p, cfg.hd), dtype),
        }
    return c


def init_cache(cfg: ModelConfig, batch: int, length: int, cross_len: int = 0):
    """Decode cache pytree; period leaves stacked over n_periods."""
    dtype = jnp.dtype(cfg.kv_dtype or cfg.dtype)
    period = {
        f"b{j}": _block_cache(cfg, blk, batch, length, dtype, cross_len)
        for j, blk in enumerate(cfg.pattern)
    }
    cache = {
        "periods": jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), period
        )
    }
    if cfg.remainder:
        cache["rem"] = {
            f"r{j}": _block_cache(cfg, blk, batch, length, dtype, cross_len)
            for j, blk in enumerate(cfg.remainder)
        }
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, length: int, cross_len: int = 0):
    return jax.eval_shape(lambda: init_cache(cfg, batch, length, cross_len))


# -- decode -------------------------------------------------------------------


def _apply_block_decode(
    cfg: ModelConfig, block: Block, p, c, h: Array, pos: Array
) -> Tuple[Array, Any]:
    mixer, ffn = block
    if mixer == "attn":
        h, kv = L.attention_decode(p["mixer"], cfg, h, c["kv"], pos)
        c = {**c, "kv": kv}
    elif mixer == "swa":
        h, kv = L.attention_decode(p["mixer"], cfg, h, c["kv"], pos, window=cfg.sliding_window)
        c = {**c, "kv": kv}
    elif mixer == "mamba":
        h, st = S.mamba_decode(p["mixer"], cfg, h, c["ssm"])
        c = {**c, "ssm": st}
    elif mixer == "mlstm":
        h, st = S.mlstm_decode(p["mixer"], cfg, h, c["ml"])
        c = {**c, "ml": st}
    elif mixer == "slstm":
        h, st = S.slstm_decode(p["mixer"], cfg, h, c["sl"])
        c = {**c, "sl": st}
    if "xattn" in p and "xkv" in c:
        # Cross-attention against the precomputed encoder KV (static).
        h = _cross_decode(p["xattn"], cfg, h, c["xkv"])
    if ffn == "mlp":
        h = L.mlp(p["ffn"], cfg, h)
    elif ffn == "moe":
        h, _ = L.moe(p["ffn"], cfg, h)
    return h, c


def _cross_decode(p, cfg: ModelConfig, x: Array, xkv) -> Array:
    h = L.rmsnorm(p["ln"], x)
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt))
    out = L.gqa_chunked(q, xkv["k"], xkv["v"], causal=False, chunk=cfg.attn_chunk)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def decode_step(params, cfg: ModelConfig, cache, token: Array, pos: Array):
    """token (B,) int32, pos () int32 -> (logits (B, vocab_p), new cache)."""
    h = _embed(cfg, params, token[:, None])

    from repro.parallel.context import constrain_batch, constrain_params

    # Cache travels in the scan CARRY (not xs/ys): the per-period
    # dynamic_update_index on the carry is done in place by XLA, so decode
    # holds ONE cache buffer instead of double-buffering a stacked ys copy —
    # at 32k x 128-batch MHA that's ~13 GiB/device saved.
    def body(carry, xs):
        hh, cache_st = carry
        hh = constrain_batch(hh)
        pparams, idx = xs
        pcache = jax.tree_util.tree_map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0, keepdims=False), cache_st
        )
        for j, blk in enumerate(cfg.pattern):
            bp = constrain_params(("periods", f"b{j}"), pparams[f"b{j}"])
            hh, newc = _apply_block_decode(cfg, blk, bp, pcache[f"b{j}"], hh, pos)
            pcache = {**pcache, f"b{j}": newc}
        cache_st = jax.tree_util.tree_map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(c, n.astype(c.dtype), idx, 0),
            cache_st,
            pcache,
        )
        return (hh, cache_st), None

    (h, new_pcache), _ = jax.lax.scan(
        body,
        (h, cache["periods"]),
        (params["periods"], jnp.arange(cfg.n_periods)),
    )
    new_cache = {"periods": new_pcache}
    if cfg.remainder:
        rem = {}
        for j, blk in enumerate(cfg.remainder):
            h, newc = _apply_block_decode(cfg, blk, params["rem"][f"r{j}"], cache["rem"][f"r{j}"], h, pos)
            rem[f"r{j}"] = newc
        new_cache["rem"] = rem
    h = L.rmsnorm(params["final_norm"], h)
    head = constrain_params("lm_head", params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, head).astype(jnp.float32)[:, 0]
    if cfg.vocab_p > cfg.vocab_size:
        logits = jnp.where(jnp.arange(cfg.vocab_p) >= cfg.vocab_size, -1e30, logits)
    return logits, new_cache


# -- prefill ------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, batch: Dict[str, Array]):
    """Full-sequence forward returning last-position logits.

    The dry-run lowers this as the prefill cost proxy: it contains the same
    attention/FFN work as cache-building prefill; per-layer KV emission is
    covered by the decode path's cache signature.
    """
    tokens = batch["tokens"]
    h = _embed(cfg, params, tokens)
    if cfg.frontend and "frontend" in batch:
        fe = jnp.einsum(
            "bsf,fd->bsd", batch["frontend"].astype(h.dtype), params["frontend"]["proj"]
        )
        h = jnp.concatenate([fe, h], axis=1)
    enc = _run_encoder(cfg, params, batch["frames"]) if cfg.is_encdec else None
    h, _ = _run_stack(cfg, params, h, enc)
    h = L.rmsnorm(params["final_norm"], h)
    from repro.parallel.context import constrain_params

    head = constrain_params("lm_head", params["lm_head"])
    logits = jnp.einsum("bd,dv->bv", h[:, -1], head).astype(jnp.float32)
    return logits
