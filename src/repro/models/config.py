"""Model + shape configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

# A block is (mixer, ffn).  Mixers: 'attn' (full), 'swa' (sliding-window),
# 'mamba', 'mlstm', 'slstm'.  FFNs: 'mlp', 'moe', 'none'.
Block = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[Block, ...]  # one period of the repeating layer pattern
    n_periods: int
    remainder: Tuple[Block, ...] = ()  # layers after the scanned periods
    head_dim: int = 0  # 0 => d_model // n_heads
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0  # qwen2-moe shared experts (always-on)
    capacity_factor: float = 1.25
    # attention details
    sliding_window: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1e6
    # SSM (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 256
    # xLSTM
    xlstm_proj_factor: float = 2.0
    # encoder-decoder
    n_encoder_layers: int = 0
    # modality frontend stub ('vision' | 'audio' | None): input_specs() feeds
    # precomputed embeddings; the backbone prepends them to token embeddings.
    frontend: Optional[str] = None
    n_frontend_tokens: int = 0
    frontend_dim: int = 0
    # numerics / fitting knobs (hillclimbable)
    dtype: str = "bfloat16"
    kv_dtype: str = ""  # KV-cache storage dtype ('' => dtype); f8 halves MHA caches
    remat: str = "full"  # 'none' | 'full' | 'dots'
    loss_chunk: int = 512  # sequence chunk for the vocab projection + xent
    attn_chunk: int = 1024  # kv-block size for chunked (flash-in-XLA) attention
    # padded sizes for even TP sharding (see DESIGN.md §6); 0 => no padding
    padded_heads: int = 0
    padded_kv_heads: int = 0
    padded_vocab: int = 0
    padded_experts: int = 0

    # -- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def heads_p(self) -> int:
        return self.padded_heads or self.n_heads

    @property
    def kv_heads_p(self) -> int:
        return self.padded_kv_heads or self.n_kv_heads

    @property
    def vocab_p(self) -> int:
        return self.padded_vocab or self.vocab_size

    @property
    def experts_p(self) -> int:
        return self.padded_experts or self.n_experts

    @property
    def all_blocks(self) -> Tuple[Block, ...]:
        return self.pattern * self.n_periods + self.remainder

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def supports_long_context(self) -> bool:
        """Sub-quadratic state growth: SSM / hybrid / mostly-local attention."""
        kinds = [m for m, _ in self.all_blocks]
        n_full = sum(1 for k in kinds if k == "attn")
        return n_full == 0 or (n_full / len(kinds)) <= 0.25

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (unpadded, for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        n = 2 * self.vocab_size * d  # embedding + untied lm head
        for mixer, ffn in self.all_blocks:
            if mixer in ("attn", "swa"):
                n += d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                n += (self.n_heads * hd) * d + d  # wo + ln
            elif mixer == "mamba":
                di = self.ssm_expand * d
                n += d * 2 * di + self.ssm_conv * di + 3 * di * self.ssm_state
                n += di * self.ssm_dt_rank * 2 + 2 * di + di * d + d
            elif mixer == "mlstm":
                f = int(self.xlstm_proj_factor * d)
                n += d * 2 * f + 3 * f * f + 3 * f + f * d + d
            elif mixer == "slstm":
                u = d
                n += d * 4 * u + 4 * u * (u // max(self.n_heads, 1)) + 4 * u + d
            if ffn == "mlp":
                n += 3 * d * self.d_ff + d
            elif ffn == "moe":
                k = self.experts_per_token if active_only else self.n_experts
                n += k * 3 * d * self.moe_d_ff + d * self.n_experts + d
                if self.shared_d_ff:
                    n += 3 * d * self.shared_d_ff
        if self.is_encdec:
            for _ in range(self.n_encoder_layers):
                n += 4 * d * (self.n_heads * hd) + 3 * d * self.d_ff + 2 * d
            # decoder cross-attention
            n += len(self.all_blocks) * (4 * d * (self.n_heads * hd) + d)
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def dense(n: int) -> Tuple[Block, ...]:
    return (("attn", "mlp"),) * n
