"""Transformer building blocks: norms, RoPE, GQA attention (full / sliding /
cross, train & decode paths), SwiGLU MLP, and capacity-based MoE.

All forwards are pure functions over ``P``-spec param trees (see params.py).
Attention over long sequences uses an online-softmax *chunked* formulation
(a flash-attention schedule expressed in XLA: lax.scan over KV blocks) so the
S x T score matrix is never materialized; the Pallas kernel in
``repro/kernels/flash_attention.py`` is the TPU-native version of the same
schedule and is swappable via ``attn_impl``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.params import P

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms & RoPE
# ---------------------------------------------------------------------------


def norm_params(d: int) -> Dict[str, P]:
    return {"scale": P((d,), ("embed",), init="ones")}


def rmsnorm(p: Dict[str, Array], x: Array) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, H, hd); positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attn_params(cfg: ModelConfig, cross: bool = False) -> Dict[str, Any]:
    d, hq, hkv, hd = cfg.d_model, cfg.heads_p, cfg.kv_heads_p, cfg.hd
    p: Dict[str, Any] = {
        "ln": norm_params(d),
        "wq": P((d, hq, hd), ("embed", "q_heads", "head_dim")),
        "wk": P((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, hkv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((hq, hd, d), ("q_heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = P((hq, hd), ("q_heads", "head_dim"), init="zeros")
        p["bk"] = P((hkv, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = P((hkv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cross:
        p["ln_kv"] = norm_params(d)
    return p


def _qkv(p, cfg: ModelConfig, x: Array, kv_src: Optional[Array] = None):
    dt = x.dtype
    src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def gqa_chunked(
    q: Array,  # (B, S, Hq, hd)
    k: Array,  # (B, T, Hkv, hd)
    v: Array,
    *,
    causal: bool,
    window: int = 0,
    q_positions: Optional[Array] = None,  # absolute positions of q rows (S,)
    k_valid: Optional[Array] = None,  # (B, T) bool extra mask (cache validity)
    k_positions: Optional[Array] = None,  # absolute positions of k slots (T,)
    chunk: int = 1024,
) -> Array:
    """Online-softmax GQA; never materializes (S, T)."""
    b, s, hq, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    qg = (q * scale).reshape(b, s, hkv, g, hd)

    if q_positions is None:
        q_positions = jnp.arange(s) + (t - s)
    if k_positions is None:
        k_positions = jnp.arange(t)

    chunk = min(chunk, t)
    pad = -t % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad), constant_values=-1)
        k_valid = jnp.ones((b, t), bool) if k_valid is None else k_valid
        k_valid = jnp.pad(k_valid, ((0, 0), (0, pad)))
    n_chunks = (t + pad) // chunk
    kc = k.reshape(b, n_chunks, chunk, hkv, hd)
    vc = v.reshape(b, n_chunks, chunk, hkv, hd)
    pc = k_positions.reshape(n_chunks, chunk)
    valc = None if k_valid is None else k_valid.reshape(b, n_chunks, chunk)

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, pci, vali = inp
        logits = jnp.einsum(
            "bskgd,btkd->bskgt", qg.astype(jnp.float32), kci.astype(jnp.float32)
        )  # (B,S,Hkv,G,chunk)
        mask = (pci >= 0)[None, None, :]
        if vali is not None:
            mask = mask & vali[:, None, :]
        mask = mask[:, :, None, None, :]  # (B,S,1,1,chunk)
        rel = q_positions[None, :, None] - pci[None, None, :]  # (1,S,chunk)
        if causal:
            mask = mask & (rel >= 0)[:, :, None, None, :]
        if window and window > 0:
            mask = mask & (rel < window)[:, :, None, None, :]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        probs = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + probs.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", probs, vci.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, s, hkv, g, hd), jnp.float32)
    xs = (
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        pc,
        None if valc is None else jnp.moveaxis(valc, 1, 0),
    )
    if valc is None:
        (m, l, acc), _ = jax.lax.scan(
            lambda c, i: body(c, (i[0], i[1], i[2], None)), (m0, l0, a0), xs[:3]
        )
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, hq, hd).astype(q.dtype)


def attention_train(
    p, cfg: ModelConfig, x: Array, *, causal: bool = True, window: int = 0,
    enc: Optional[Array] = None, return_kv: bool = False,
):
    """Full-sequence attention (training / encoder / prefill compute)."""
    h = rmsnorm(p["ln"], x)
    kv_src = rmsnorm(p["ln_kv"], enc) if enc is not None else None
    q, k, v = _qkv(p, cfg, h, kv_src)
    if enc is None:
        s = x.shape[1]
        pos = jnp.arange(s)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    out = gqa_chunked(q, k, v, causal=causal and enc is None, window=window,
                      chunk=cfg.attn_chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return x + y, (k, v)
    return x + y


def init_attn_cache(cfg: ModelConfig, batch: int, length: int, window: int, dtype) -> Dict[str, Any]:
    t = min(length, window) if window else length
    shape = (batch, t, cfg.kv_heads_p, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode(
    p, cfg: ModelConfig, x: Array, cache: Dict[str, Array], pos: Array,
    *, window: int = 0,
) -> Tuple[Array, Dict[str, Array]]:
    """One decode step. x (B, 1, d); cache k/v (B, T, Hkv, hd); pos scalar."""
    h = rmsnorm(p["ln"], x)
    q, k_new, v_new = _qkv(p, cfg, h)
    q = rope(q, pos[None], cfg.rope_theta)
    k_new = rope(k_new, pos[None], cfg.rope_theta)

    t = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % jnp.maximum(window, 1), pos) if window else pos
    slot = jnp.minimum(slot, t - 1)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, 1)

    idx = jnp.arange(t)
    if window:
        # Ring buffer: slot s holds absolute position pos - ((pos - s) mod W).
        abs_pos = pos - jnp.mod(pos - idx, window)
        valid = abs_pos >= 0
    else:
        abs_pos = idx
        valid = idx <= pos

    b, hq = q.shape[0], q.shape[2]
    hkv = k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(cfg.hd)
    # Keys in the cache were stored *with* RoPE already applied at their
    # absolute positions, so no re-rotation is needed here.  The contraction
    # reads the (possibly f8) cache in the compute dtype with f32
    # accumulation — no f32 materialization of the cache.
    cdt = x.dtype
    logits = jnp.einsum(
        "bkgd,btkd->bkgt",
        (q[:, 0] * scale).reshape(b, hkv, g, cfg.hd).astype(cdt),
        k.astype(cdt),
        preferred_element_type=jnp.float32,
    )
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgt,btkd->bkgd",
        probs.astype(cdt),
        v.astype(cdt),
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, 1, hq, cfg.hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# FFN: dense SwiGLU + capacity-based MoE
# ---------------------------------------------------------------------------


def mlp_params(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    return {
        "ln": norm_params(d),
        "wg": P((d, ff), ("embed", "ffn")),
        "wi": P((d, ff), ("embed", "ffn")),
        "wo": P((ff, d), ("ffn", "embed")),
    }


def mlp(p, cfg: ModelConfig, x: Array, residual: bool = True) -> Array:
    h = rmsnorm(p["ln"], x)
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", h, p["wg"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", h, p["wi"].astype(dt))
    y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["wo"].astype(dt))
    return x + y if residual else y


def moe_params(cfg: ModelConfig) -> Dict[str, Any]:
    d, e, ffe = cfg.d_model, cfg.experts_p, cfg.moe_d_ff
    p: Dict[str, Any] = {
        "ln": norm_params(d),
        "router": P((d, e), ("embed", "experts")),
        "wg": P((e, d, ffe), ("experts", "embed", "moe_ffn")),
        "wi": P((e, d, ffe), ("experts", "embed", "moe_ffn")),
        "wo": P((e, ffe, d), ("experts", "moe_ffn", "embed")),
    }
    if cfg.shared_d_ff:
        p["shared"] = {
            "wg": P((d, cfg.shared_d_ff), ("embed", "ffn")),
            "wi": P((d, cfg.shared_d_ff), ("embed", "ffn")),
            "wo": P((cfg.shared_d_ff, d), ("ffn", "embed")),
        }
    return p


def moe(p, cfg: ModelConfig, x: Array, group_size: int = 4096) -> Tuple[Array, Array]:
    """GShard-style top-k dispatch with capacity groups.

    Tokens are split into (batch x sequence-chunk) groups of <= group_size;
    each group gets its own expert capacity C = ceil(gs*k/E*cf).  The
    dispatch/combine one-hots are (B, G, gs, E, C) and shard over
    ('data', None, None, 'model', None); grouping keeps them linear (not
    quadratic) in sequence length.  Returns (output, aux_load_balance_loss).
    """
    b, s, d = x.shape
    e, k = cfg.experts_p, cfg.experts_per_token
    gs = min(group_size, s)
    pad = -s % gs
    h = rmsnorm(p["ln"], x)
    dt = x.dtype
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0))) if pad else h
    ng = (s + pad) // gs
    hg = hp.reshape(b, ng, gs, d)
    cap = max(1, int(np.ceil(gs * k / e * cfg.capacity_factor)))

    logits = jnp.einsum("bgsd,de->bgse", hg.astype(jnp.float32), p["router"].astype(jnp.float32))
    if cfg.padded_experts and cfg.padded_experts > cfg.n_experts:
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None, None], NEG_INF, logits)
    if pad:  # padded positions route nowhere
        valid = (jnp.arange(s + pad) < s).reshape(1, ng, gs, 1)
        logits = jnp.where(valid, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, k)  # (B,G,gs,k)
    gates = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    sel = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (B,G,gs,k,E)
    mask = sel.sum(3)  # (B,G,gs,E)
    gate_e = jnp.einsum("bgske,bgsk->bgse", sel, gates)

    pos_in_e = jnp.cumsum(mask, axis=2) - mask  # position within the group
    keep = (pos_in_e < cap) * mask
    dispatch = jax.nn.one_hot(pos_in_e, cap, dtype=dt) * keep[..., None].astype(dt)
    combine = dispatch * gate_e[..., None].astype(dt)  # (B,G,gs,E,C)

    xin = jnp.einsum("bgsec,bgsd->bgecd", dispatch, hg)  # (B,G,E,C,d)
    gsw = jax.nn.silu(jnp.einsum("bgecd,edf->bgecf", xin, p["wg"].astype(dt)))
    up = jnp.einsum("bgecd,edf->bgecf", xin, p["wi"].astype(dt))
    out_e = jnp.einsum("bgecf,efd->bgecd", gsw * up, p["wo"].astype(dt))
    y = jnp.einsum("bgsec,bgecd->bgsd", combine, out_e)
    y = y.reshape(b, s + pad, d)[:, :s]

    if "shared" in p:
        sh = p["shared"]
        g2 = jnp.einsum("bsd,df->bsf", h, sh["wg"].astype(dt))
        u2 = jnp.einsum("bsd,df->bsf", h, sh["wi"].astype(dt))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(g2) * u2, sh["wo"].astype(dt))

    # Switch-style load-balance aux loss over the *real* experts.
    e_real = cfg.n_experts
    f_e = mask[..., :e_real].mean(axis=(0, 1, 2))
    p_e = probs[..., :e_real].mean(axis=(0, 1, 2))
    aux = e_real * jnp.sum(f_e * p_e)
    return x + y, aux
