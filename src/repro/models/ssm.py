"""State-space & recurrent mixers: Mamba (selective SSM), xLSTM's mLSTM and
sLSTM blocks.  Each provides a parallel/full-sequence form for training and an
O(1)-state recurrent form for decode — the property that makes `long_500k`
runnable for the ssm/hybrid architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import norm_params, rmsnorm
from repro.models.params import P

Array = jax.Array


# ---------------------------------------------------------------------------
# Mamba (selective SSM, Mamba-1 style)
# ---------------------------------------------------------------------------


def mamba_params(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    r = cfg.ssm_dt_rank
    return {
        "ln": norm_params(d),
        "in_proj": P((d, 2 * di), ("embed", "ssm_inner")),
        "conv": P((cfg.ssm_conv, di), (None, "ssm_inner")),
        "wb": P((di, n), ("ssm_inner", None)),
        "wc": P((di, n), ("ssm_inner", None)),
        "wdt_lo": P((di, r), ("ssm_inner", None)),
        "wdt_hi": P((r, di), (None, "ssm_inner")),
        "dt_bias": P((di,), ("ssm_inner",), init="zeros"),
        "a_log": P((di, n), ("ssm_inner", None), init="ones"),
        "dd": P((di,), ("ssm_inner",), init="ones"),
        "out_proj": P((di, d), ("ssm_inner", "embed")),
    }


def _mamba_gates(p, x1: Array):
    """B, C, dt from the post-conv activations. x1 (..., di)."""
    f32 = jnp.float32
    bmat = jnp.einsum("...i,in->...n", x1.astype(f32), p["wb"].astype(f32))
    cmat = jnp.einsum("...i,in->...n", x1.astype(f32), p["wc"].astype(f32))
    dt = jnp.einsum("...i,ir->...r", x1.astype(f32), p["wdt_lo"].astype(f32))
    dt = jnp.einsum("...r,ri->...i", dt, p["wdt_hi"].astype(f32))
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(f32))
    a = -jnp.exp(p["a_log"].astype(f32))  # (di, n)
    return bmat, cmat, dt, a


def mamba_train(p, cfg: ModelConfig, x: Array, chunk: int = 1024) -> Array:
    """Chunked selective scan. x (B, S, d).

    The (B,S,di,n) decay/drive tensors and the state history never exist at
    full sequence length: an outer scan walks S/chunk chunks (carrying the
    (B,di,n) state), the inner scan walks steps within a chunk and emits y_t
    directly (contracted with C_t), so the live set is one chunk's tensors —
    the TPU-native equivalent of mamba's chunked CUDA kernel.
    """
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    h = rmsnorm(p["ln"], x)
    dt_ = x.dtype
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(dt_))
    x1, z = jnp.split(xz, 2, axis=-1)  # (B,S,di)
    # Causal depthwise conv along S.
    k = cfg.ssm_conv
    xpad = jnp.pad(x1, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(
        xpad[:, i : i + s] * p["conv"][i].astype(dt_) for i in range(k)
    )
    x1 = jax.nn.silu(conv)

    c = min(chunk, s)
    pad = -s % c
    x1p = jnp.pad(x1, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // c
    xc = jnp.moveaxis(x1p.reshape(b, nc, c, di), 1, 0)  # (nc,B,c,di)

    def chunk_step(hst, x_chunk):
        bmat, cmat, dtv, a = _mamba_gates(p, x_chunk)  # (B,c,di,n)-ish
        decay = jnp.exp(dtv[..., None] * a)  # (B,c,di,n)
        drive = (dtv * x_chunk.astype(jnp.float32))[..., None] * bmat[..., None, :]

        def step(hh, inp):
            dec, drv, cm = inp
            hh = hh * dec + drv
            y = jnp.einsum("bin,bn->bi", hh, cm)
            return hh, y

        hst, ys = jax.lax.scan(
            step,
            hst,
            (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(drive, 1, 0), jnp.moveaxis(cmat, 1, 0)),
        )  # ys (c,B,di)
        return hst, jnp.moveaxis(ys, 0, 1)  # (B,c,di)

    from repro.parallel.context import constrain_state

    h0 = constrain_state(jnp.zeros((b, di, cfg.ssm_state), jnp.float32))
    _, ychunks = jax.lax.scan(chunk_step, h0, xc)  # (nc,B,c,di)
    y = jnp.moveaxis(ychunks, 0, 1).reshape(b, s + pad, di)[:, :s]
    y = y + p["dd"].astype(jnp.float32) * x1.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    return x + jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(dt_))


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    di = cfg.ssm_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
    }


def mamba_decode(p, cfg: ModelConfig, x: Array, cache: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
    """One step. x (B, 1, d); cache: ssm state + conv tail."""
    dt_ = x.dtype
    h = rmsnorm(p["ln"], x)
    xz = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(dt_))
    x1, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    hist = jnp.concatenate([cache["conv"], x1], axis=1)  # (B,k,di)
    conv = jnp.einsum("bki,ki->bi", hist.astype(jnp.float32), p["conv"].astype(jnp.float32))
    x1s = jax.nn.silu(conv)  # (B,di)
    bmat, cmat, dtv, a = _mamba_gates(p, x1s)
    hstate = cache["h"] * jnp.exp(dtv[..., None] * a) + (dtv * x1s)[..., None] * bmat[..., None, :]
    y = jnp.einsum("bin,bn->bi", hstate, cmat) + p["dd"].astype(jnp.float32) * x1s
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(dt_)
    out = x + jnp.einsum("bi,id->bd", y, p["out_proj"].astype(dt_))[:, None]
    return out, {"h": hstate, "conv": hist[:, 1:].astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) & sLSTM (scalar memory, block-diag recurrence)
# ---------------------------------------------------------------------------


def mlstm_params(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    f = int(cfg.xlstm_proj_factor * d)
    return {
        "ln": norm_params(d),
        "up": P((d, 2 * f), ("embed", "xl_inner")),
        "wq": P((f, f), ("xl_inner", None)),
        "wk": P((f, f), ("xl_inner", None)),
        "wv": P((f, f), ("xl_inner", None)),
        "wif": P((f, 2), ("xl_inner", None)),  # input & forget gate pre-acts
        "wog": P((f, f), ("xl_inner", None)),
        "down": P((f, d), ("xl_inner", "embed")),
    }


def mlstm_train(p, cfg: ModelConfig, x: Array, chunk: int = 1024) -> Array:
    """Chunk-recurrent mLSTM (xLSTM's parallel form, tiled).

    The naive parallel form materializes (B,H,S,S) decay/score matrices —
    34 GiB at 32k context.  Here an outer scan carries the (C, n, m) matrix-
    memory state across chunks; within a chunk the quadratic form runs on a
    (chunk x chunk) tile, and the inter-chunk contribution comes from the
    carried state (exactly the recurrence mlstm_decode implements).  Memory
    is O(chunk^2), matching the chunkwise formulation of the xLSTM kernels.
    """
    b, s, d = x.shape
    hh = cfg.n_heads
    f = int(cfg.xlstm_proj_factor * d)
    dh = f // hh
    dt_ = x.dtype
    f32 = jnp.float32
    hin = rmsnorm(p["ln"], x)
    u = jnp.einsum("bsd,de->bse", hin, p["up"].astype(dt_))
    xm, z = jnp.split(u, 2, axis=-1)  # (B,S,f)

    def heads(w):
        return jnp.einsum("bsf,fg->bsg", xm, w.astype(dt_)).reshape(b, s, hh, dh)

    q, k, v = heads(p["wq"]), heads(p["wk"]), heads(p["wv"])
    gates = jnp.einsum("bsf,fg->bsg", xm.astype(f32), p["wif"].astype(f32))  # (B,S,2)
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])  # (B,S)
    scale = 1.0 / np.sqrt(dh)

    c = min(chunk, s)
    pad = -s % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad)))
    nc = (s + pad) // c

    def to_chunks(t, extra_dims):
        return jnp.moveaxis(t.reshape((b, nc, c) + extra_dims), 1, 0)

    qc = to_chunks(q.astype(f32), (hh, dh))
    kc = to_chunks(k.astype(f32), (hh, dh))
    vc = to_chunks(v.astype(f32), (hh, dh))
    lic = to_chunks(logi, ())
    lfc = to_chunks(logf, ())

    def chunk_step(state, inp):
        C, n, m0 = state  # (B,H,dh,dh), (B,H,dh), (B,H)
        qq, kk, vv, li, lf = inp  # (B,c,H,dh) / (B,c)
        lf_cum = jnp.cumsum(lf, axis=1)  # (B,c) local sum of log f
        # intra-chunk log decay: lf_cum[t] - lf_cum[s] + li[s], s <= t
        logd = lf_cum[:, :, None] - lf_cum[:, None, :] + li[:, None, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        logd = jnp.where(tri[None], logd, -1e30)
        m_intra = logd.max(axis=-1)  # (B,c)
        m_inter = m0[:, None, :] + 0.0  # (B,1,H) -> broadcast below
        # per-step stabilizer across heads: gates are shared across heads.
        m_t = jnp.maximum(m_intra[..., None], m0[:, None, :] + lf_cum[..., None])  # (B,c,H)
        dmat = jnp.exp(logd[:, :, None, :] - m_t[..., None])  # (B,c,H,c)
        sqk = jnp.einsum("bthd,bshd->bths", qq * scale, kk)  # (B,c,H,c)
        w = sqk * dmat
        inter_scale = jnp.exp(m0[:, None, :] + lf_cum[..., None] - m_t)  # (B,c,H)
        h_inter = jnp.einsum("bthd,bhde->bthe", qq * scale, C) * inter_scale[..., None]
        n_inter = jnp.einsum("bthd,bhd->bth", qq * scale, n) * inter_scale
        num = jnp.einsum("bths,bshd->bthd", w, vv) + h_inter
        den = jnp.maximum(jnp.abs(w.sum(-1) + n_inter), jnp.exp(-m_t))
        hout = num / den[..., None]  # (B,c,H,dh)
        # ---- state update to end of chunk ----
        lf_tot = lf_cum[:, -1]  # (B,)
        decay_s = lf_tot[:, None] - lf_cum + li  # (B,c) log weight of each s
        m_new = jnp.maximum(m0 + lf_tot[:, None], decay_s.max(1)[:, None])  # (B,H)
        w_s = jnp.exp(decay_s[:, :, None] - m_new[:, None, :])  # (B,c,H)
        C_new = C * jnp.exp(m0 + lf_tot[:, None] - m_new)[..., None, None] + jnp.einsum(
            "bsh,bshd,bshe->bhde", w_s, kk, vv
        )
        n_new = n * jnp.exp(m0 + lf_tot[:, None] - m_new)[..., None] + jnp.einsum(
            "bsh,bshd->bhd", w_s, kk
        )
        return (C_new, n_new, m_new), hout

    from repro.parallel.context import constrain_state

    C0 = constrain_state(jnp.zeros((b, hh, dh, dh), f32))
    n0 = constrain_state(jnp.zeros((b, hh, dh), f32))
    m0 = constrain_state(jnp.full((b, hh), -1e30, f32))
    _, houts = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    hout = jnp.moveaxis(houts, 0, 1).reshape(b, s + pad, f)[:, :s]
    og = jax.nn.sigmoid(jnp.einsum("bsf,fg->bsg", xm.astype(f32), p["wog"].astype(f32)))
    y = (hout * og * jax.nn.silu(z.astype(f32))).astype(dt_)
    return x + jnp.einsum("bsf,fd->bsd", y, p["down"].astype(dt_))


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    hh = cfg.n_heads
    f = int(cfg.xlstm_proj_factor * cfg.d_model)
    dh = f // hh
    return {
        "c": jnp.zeros((batch, hh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, hh, dh), jnp.float32),
        "m": jnp.full((batch, hh), -1e30, jnp.float32),
    }


def mlstm_decode(p, cfg: ModelConfig, x: Array, cache: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
    b, _, d = x.shape
    hh = cfg.n_heads
    f = int(cfg.xlstm_proj_factor * d)
    dh = f // hh
    dt_ = x.dtype
    f32 = jnp.float32
    hin = rmsnorm(p["ln"], x)
    u = jnp.einsum("bsd,de->bse", hin, p["up"].astype(dt_))[:, 0]
    xm, z = jnp.split(u, 2, axis=-1)  # (B,f)

    def heads(w):
        return jnp.einsum("bf,fg->bg", xm, w.astype(dt_)).reshape(b, hh, dh).astype(f32)

    q, k, v = heads(p["wq"]), heads(p["wk"]), heads(p["wv"])
    gates = jnp.einsum("bf,fg->bg", xm.astype(f32), p["wif"].astype(f32))
    logi, logf = gates[..., 0:1], jax.nn.log_sigmoid(gates[..., 1:2])  # (B,1)
    # Broadcast the scalar gates across heads.
    logi_h = jnp.repeat(logi, hh, axis=1)  # (B,H)
    logf_h = jnp.repeat(logf, hh, axis=1)
    m_new = jnp.maximum(logf_h + cache["m"], logi_h)
    i_p = jnp.exp(logi_h - m_new)[..., None]  # (B,H,1)
    f_p = jnp.exp(logf_h + cache["m"] - m_new)[..., None]
    scale = 1.0 / np.sqrt(dh)
    c = cache["c"] * f_p[..., None] + i_p[..., None] * jnp.einsum("bhd,bhe->bhde", v, k * scale)
    n = cache["n"] * f_p + i_p * (k * scale)
    num = jnp.einsum("bhde,bhe->bhd", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), jnp.exp(-m_new))[..., None]
    hout = (num / den).reshape(b, f)
    og = jax.nn.sigmoid(jnp.einsum("bf,fg->bg", xm.astype(f32), p["wog"].astype(f32)))
    y = (hout * og * jax.nn.silu(z.astype(f32))).astype(dt_)
    out = x + jnp.einsum("bf,fd->bd", y, p["down"].astype(dt_))[:, None]
    return out, {"c": c, "n": n, "m": m_new}


def slstm_params(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    hh = cfg.n_heads
    uh = d // hh
    return {
        "ln": norm_params(d),
        "wx": P((d, 4 * d), ("embed", "units")),
        "wr": P((hh, uh, 4 * uh), (None, None, "units")),
        "bias": P((4 * d,), ("units",), init="zeros"),
        "out": P((d, d), ("units", "embed")),
    }


def _slstm_step(p, cfg: ModelConfig, xproj_t: Array, state):
    """xproj_t (B, 4d); state (h, c, n, m) each (B, H, uh)."""
    b = xproj_t.shape[0]
    d = cfg.d_model
    hh = cfg.n_heads
    uh = d // hh
    h, c, n, m = state
    rec = jnp.einsum("bhu,hug->bhg", h, p["wr"].astype(jnp.float32))  # (B,H,4uh)
    pre = xproj_t.reshape(b, hh, 4 * uh).astype(jnp.float32) + rec + p["bias"].reshape(hh, 4 * uh).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)  # (B,H,uh)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(zt)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def _slstm_cell(xt: Array, hprev: Array, state, wr_b: Array, bias: Array, hh: int, uh: int):
    """One sLSTM step with PER-BATCH recurrent weights wr_b (B,H,uh,4uh).

    The per-batch broadcast of wr is the point: its cotangent is per-batch
    too, so the backward scan can accumulate weight gradients *locally*
    (batch-sharded) and cross-device reduction happens once after the loop —
    not once per timestep (see EXPERIMENTS.md §Perf, xlstm iterations 3-5).
    """
    b = xt.shape[0]
    c, n, m = state
    rec = jnp.einsum("bhu,bhug->bhg", hprev, wr_b)
    pre = xt.reshape(b, hh, 4 * uh).astype(jnp.float32) + rec + bias.reshape(hh, 4 * uh).astype(jnp.float32)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(zt)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, (c_new, n_new, m_new)


def _slstm_scan_fwd_impl(xproj, wr, bias, hh, uh):
    from repro.parallel.context import constrain_state

    b, s, _ = xproj.shape
    wr_b = jnp.broadcast_to(wr.astype(jnp.float32)[None], (b,) + wr.shape)
    z = constrain_state(jnp.zeros((b, hh, uh), jnp.float32))
    m0 = constrain_state(jnp.full((b, hh, uh), -1e30, jnp.float32))

    def step(carry, xt):
        h, st = carry
        h_new, st_new = _slstm_cell(xt, h, st, wr_b, bias, hh, uh)
        return (h_new, st_new), (h_new, h, st)

    (_, _), (hs, hs_prev, states_prev) = jax.lax.scan(
        step, (z, (z, z, m0)), jnp.moveaxis(xproj, 1, 0)
    )
    return jnp.moveaxis(hs, 0, 1), (xproj, wr, bias, hs_prev, states_prev)


def _slstm_scan_bwd(hh, uh, res, dhs):
    xproj, wr, bias, hs_prev, states_prev = res
    b, s, _ = xproj.shape
    wr_b = jnp.broadcast_to(wr.astype(jnp.float32)[None], (b,) + wr.shape)
    dhs_rev = jnp.moveaxis(dhs, 1, 0)[::-1]
    xs_rev = jnp.moveaxis(xproj, 1, 0)[::-1]
    hsp_rev = hs_prev[::-1]
    stp_rev = jax.tree_util.tree_map(lambda t: t[::-1], states_prev)

    def cell_for_vjp(xt, hprev, st, wrb, bi):
        return _slstm_cell(xt, hprev, st, wrb, bi, hh, uh)

    def step(carry, inp):
        dh_next, dst_next, dwr_acc, dbias_acc = carry
        dh_out, xt, hprev, st = inp
        _, pullback = jax.vjp(cell_for_vjp, xt, hprev, st, wr_b, bias)
        dxt, dhprev, dst, dwrb, dbi = pullback((dh_next + dh_out, dst_next))
        # dwrb is PER-BATCH (B,H,uh,4uh): accumulate locally in the carry.
        return (dhprev, dst, dwr_acc + dwrb, dbias_acc + dbi), dxt

    zst = jax.tree_util.tree_map(jnp.zeros_like, stp_rev)
    zst0 = jax.tree_util.tree_map(lambda t: t[0] * 0.0, stp_rev)
    dh0 = jnp.zeros((b, hh, uh), jnp.float32)
    dwr0 = jnp.zeros((b,) + wr.shape, jnp.float32)
    dbias0 = jnp.zeros_like(bias, dtype=jnp.float32)
    (dh_last, _, dwr_b, dbias), dxs = jax.lax.scan(
        step, (dh0, zst0, dwr0, dbias0), (dhs_rev, xs_rev, hsp_rev, stp_rev)
    )
    dxproj = jnp.moveaxis(dxs[::-1], 0, 1)
    # ONE reduction over the (sharded) batch — outside the loop.
    dwr = dwr_b.sum(0).astype(wr.dtype)
    return dxproj, dwr, dbias.astype(bias.dtype)


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _slstm_scan_p(xproj: Array, wr: Array, bias: Array, hh: int, uh: int) -> Array:
    return _slstm_scan_fwd_impl(xproj, wr, bias, hh, uh)[0]


_slstm_scan_p.defvjp(_slstm_scan_fwd_impl, _slstm_scan_bwd)


def slstm_train(p, cfg: ModelConfig, x: Array) -> Array:
    b, s, d = x.shape
    hh = cfg.n_heads
    uh = d // hh
    dt_ = x.dtype
    hin = rmsnorm(p["ln"], x)
    xproj = jnp.einsum("bsd,dg->bsg", hin, p["wx"].astype(dt_))  # (B,S,4d)
    hs = _slstm_scan_p(xproj, p["wr"], p["bias"], hh, uh)  # (B,S,H,uh)
    hs = hs.reshape(b, s, d).astype(dt_)
    return x + jnp.einsum("bsd,dg->bsg", hs, p["out"].astype(dt_))


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Tuple[Array, ...]:
    hh = cfg.n_heads
    uh = cfg.d_model // hh
    z = jnp.zeros((batch, hh, uh), jnp.float32)
    return (z, z, z, jnp.full((batch, hh, uh), -1e30, jnp.float32))


def slstm_decode(p, cfg: ModelConfig, x: Array, cache) -> Tuple[Array, Any]:
    dt_ = x.dtype
    hin = rmsnorm(p["ln"], x)
    xproj = jnp.einsum("bsd,dg->bsg", hin, p["wx"].astype(dt_))[:, 0]
    h, c, n, m = _slstm_step(p, cfg, xproj, cache)
    b = x.shape[0]
    y = h.reshape(b, cfg.d_model).astype(dt_)
    out = x + jnp.einsum("bd,dg->bg", y, p["out"].astype(dt_))[:, None]
    return out, (h, c, n, m)
