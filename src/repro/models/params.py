"""Parameter specs: one tree describing shapes, logical axes, and init.

Every model builds a tree of ``P`` leaves.  From it we derive
  - abstract params (ShapeDtypeStruct) for the dry-run (never allocated),
  - concrete params for smoke tests / the real trainer,
  - PartitionSpecs via the logical-axis rules in ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class P:
    """A parameter leaf spec."""

    shape: Tuple[int, ...]
    axes: Axes  # logical axis names per dim (None = replicated dim)
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leaf(x: Any) -> bool:
    return isinstance(x, P)


def tree_map_p(fn: Callable[[P], Any], tree: Any) -> Any:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_leaf)


def stack(tree: Any, n: int, axis_name: str = "layers") -> Any:
    """Add a leading stacked-layers dim to every leaf (for scan-over-periods)."""
    return tree_map_p(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale), tree
    )


def abstract(tree: Any, dtype: jnp.dtype) -> Any:
    return tree_map_p(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), tree)


def n_params(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_leaf)
    return sum(int(np.prod(p.shape)) for p in leaves)


def init_params(key: jax.Array, tree: Any, dtype: jnp.dtype) -> Any:
    """Concrete initialization (smoke tests / real training)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_leaf)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, p in zip(keys, leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
            scale = p.scale if p.init == "embed" else 1.0 / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, p.shape, jnp.float32) * scale).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
