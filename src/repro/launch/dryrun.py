import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build abstract inputs (ShapeDtypeStruct only — nothing is
allocated), attach NamedShardings from the logical-axis rules, and run
``jax.jit(step).lower(...).compile()`` against the production mesh.  The
compiled artifact yields:
  - memory_analysis(): per-device bytes (proves the cell fits),
  - cost_analysis(): HLO FLOPs / bytes for the roofline terms,
  - as_text(): optimized HLO, parsed for collective bytes.

Results append to a JSON cache (benchmarks/dryrun_results.json by default)
keyed by (arch, shape, mesh, tag) so reruns skip green cells and the §Perf
hillclimb records variants under distinct tags.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ARCHS, cell_is_runnable, get_config
from repro.launch.hlo_stats import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim.adamw import OptConfig
from repro.parallel import sharding as shd
from repro.train.step import TrainSpec, abstract_train_state, make_decode_step, make_prefill_step, make_train_step

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "dryrun_results.json")


# --------------------------------------------------------------------------
# Per-(arch, shape) fitting knobs.  Defaults first; overrides below are part
# of the §Perf iteration log (EXPERIMENTS.md references these by tag).
# --------------------------------------------------------------------------

def tuning_for(arch: str, shape: str, mesh_kind: str = "single") -> TrainSpec:
    n_micro = {"train_4k": 4}.get(shape, 1)
    opt = OptConfig()
    acc = "float32"
    if arch == "jamba-1.5-large-398b":
        # 398B: bf16 moments + master-less updates + bf16 grad accumulator.
        # DP extent doubles multi-pod: microbatch must stay shardable (>=dp).
        n_micro = 8 if mesh_kind == "multi" else 16
        opt = OptConfig(opt_dtype="bfloat16", use_master=False)
        acc = "bfloat16"
    if arch == "qwen1.5-32b":
        n_micro = 16
    if arch == "internlm2-20b" and shape == "train_4k":
        n_micro = 16
    if arch == "gemma3-27b" and shape == "train_4k":
        n_micro = 16
    return TrainSpec(microbatch=n_micro, opt=opt, acc_dtype=acc)


# Per-cell config overrides (part of the baseline fitting story; see
# EXPERIMENTS.md §Dry-run).  f8 KV cache: 32k ctx x batch 128 x 48-head MHA
# is a 6.6 TB cache in bf16 — f8 storage is the production fix.
CFG_OVERRIDES: Dict[Tuple[str, str], Dict[str, Any]] = {
    ("qwen1.5-32b", "decode_32k"): {"kv_dtype": "float8_e4m3fn"},
}


# --------------------------------------------------------------------------
# Abstract inputs
# --------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, sh: ShapeConfig, spec: TrainSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = sh.global_batch, sh.seq_len
    if sh.kind == "train":
        nm = spec.microbatch
        mb = b // nm
        if cfg.frontend == "vision":
            text = s - cfg.n_frontend_tokens
            return {
                "tokens": _sds((nm, mb, text), jnp.int32),
                "frontend": _sds((nm, mb, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16),
            }
        if cfg.is_encdec:
            return {
                "tokens": _sds((nm, mb, s // 2), jnp.int32),
                "frames": _sds((nm, mb, s // 2, cfg.frontend_dim), jnp.bfloat16),
            }
        return {"tokens": _sds((nm, mb, s), jnp.int32)}
    if sh.kind == "prefill":
        if cfg.frontend == "vision":
            text = s - cfg.n_frontend_tokens
            return {
                "tokens": _sds((b, text), jnp.int32),
                "frontend": _sds((b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16),
            }
        if cfg.is_encdec:
            return {
                "tokens": _sds((b, s // 2), jnp.int32),
                "frames": _sds((b, s // 2, cfg.frontend_dim), jnp.bfloat16),
            }
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token against a seq_len cache
    cross = s // 2 if cfg.is_encdec else 0
    cache = lm.abstract_cache(cfg, b, s, cross_len=cross)
    return {
        "cache": cache,
        "token": _sds((b,), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


# --------------------------------------------------------------------------
# Lower + compile one cell
# --------------------------------------------------------------------------


def lower_cell(arch: str, shape: str, mesh, spec: Optional[TrainSpec] = None,
               cfg_overrides: Optional[Dict[str, Any]] = None,
               variant: str = "baseline"):
    """variant: 'baseline' | 'dponly' (no TP, DP over the whole mesh) |
    'seqpar' (Megatron sequence parallelism on the residual stream) |
    'rematdots' (save matmul outputs instead of full recompute)."""
    cfg = get_config(arch)
    if variant == "rematdots":
        cfg = dataclasses.replace(cfg, remat="dots")
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    sh = SHAPES[shape]
    spec = spec or tuning_for(arch, shape)
    if variant == "dponly" and sh.kind == "train":
        # pure DP over the whole mesh: the microbatch must divide by ALL chips
        spec = dataclasses.replace(spec, microbatch=1)
    param_spec_tree = lm.build_param_spec(cfg)
    rest_rules = shd.DP_ONLY_RULES if variant == "dponly" else shd.DEFAULT_RULES
    pspec_tree = shd.param_pspecs(param_spec_tree, mesh, rules=rest_rules)
    params_sh = shd.to_shardings(mesh, pspec_tree)
    abs_params = lm.abstract_params(cfg)
    ins = input_specs(cfg, sh, spec)

    # Compute-time (ZeRO-3 gather-point) specs, looked up by subtree inside
    # the model via the activation-sharding context.
    if variant == "dponly":
        # fully gathered at compute (pure DP), ZeRO-3 at rest
        from repro.models.params import tree_map_p

        gather_all = {k: () for k in shd.DEFAULT_RULES}

        def leaf(p):
            s = shd.spec_for(p, mesh, gather_all)
            if p.axes and p.axes[0] == "layers":
                return PartitionSpec(*tuple(s)[1:])
            return s

        cps = tree_map_p(leaf, param_spec_tree)
    elif variant in ("moe2d", "all2d"):
        from repro.models.params import tree_map_p

        rules2 = shd.MOE2D_COMPUTE_RULES if variant == "moe2d" else shd.ALL2D_COMPUTE_RULES

        def leaf2(p):
            s = shd.spec_for(p, mesh, rules2)
            if p.axes and p.axes[0] == "layers":
                return PartitionSpec(*tuple(s)[1:])
            return s

        cps = tree_map_p(leaf2, param_spec_tree)
    else:
        cps = shd.compute_pspecs(param_spec_tree, mesh)
    compute_specs = {
        "periods": cps["periods"],
        "embed": cps["embed"],
        "lm_head": cps["lm_head"],
    }
    if "rem" in cps:
        compute_specs["rem"] = cps["rem"]
    if cfg.is_encdec:
        compute_specs["encoder_layers"] = cps["encoder"]["layers"]

    from repro.parallel.context import activation_sharding

    seq_axis = "model" if variant == "seqpar" else None

    if sh.kind == "train":
        state = abstract_train_state(cfg, spec)
        opt_sh = {
            "m": params_sh, "v": params_sh,
            "step": NamedSharding(mesh, PartitionSpec()),
        }
        if "master" in state["opt"]:
            opt_sh["master"] = params_sh
        state_sh = {"params": params_sh, "opt": opt_sh}
        mb = sh.global_batch // spec.microbatch
        ba = shd.dp_batch_axes(mesh, mb) if variant == "dponly" else shd.batch_axes(mesh, mb)
        batch_sh = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, PartitionSpec(None, ba, *([None] * (x.ndim - 2)))),
            ins,
        )
        fn = make_train_step(cfg, spec)
        jitted = jax.jit(
            fn,
            in_shardings=(state_sh, batch_sh),
            donate_argnums=(0,),
        )
        with activation_sharding(mesh, ba, compute_specs, seq_axis=seq_axis):
            return jitted.lower(state, ins), cfg, spec

    if sh.kind == "prefill":
        # Serving path: weights live in the serving layout (no FSDP, hidden
        # dims take every axis) and never move; tokens/partials move instead.
        serve_params_sh = shd.to_shardings(
            mesh, shd.param_pspecs(param_spec_tree, mesh, rules=shd.SERVING_RULES)
        )
        rps = shd.resident_pspecs(param_spec_tree, mesh)
        serve_specs = {"periods": rps["periods"], "embed": rps["embed"], "lm_head": rps["lm_head"]}
        if "rem" in rps:
            serve_specs["rem"] = rps["rem"]
        if cfg.is_encdec:
            serve_specs["encoder_layers"] = rps["encoder"]["layers"]
        ba = shd.batch_axes(mesh, sh.global_batch)
        batch_sh = jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, PartitionSpec(ba, *([None] * (x.ndim - 1)))), ins
        )
        fn = make_prefill_step(cfg)
        jitted = jax.jit(fn, in_shardings=(serve_params_sh, batch_sh))
        with activation_sharding(mesh, ba, serve_specs):
            return jitted.lower(abs_params, ins), cfg, spec

    # decode: weights live in the serving layout and never move (a decode
    # step has no reuse to amortize gathers; tiny activation partial-sums
    # cross the ICI instead).
    serve_params_sh = shd.to_shardings(
        mesh, shd.param_pspecs(param_spec_tree, mesh, rules=shd.SERVING_RULES)
    )
    rps = shd.resident_pspecs(param_spec_tree, mesh)
    compute_specs = {"periods": rps["periods"], "embed": rps["embed"], "lm_head": rps["lm_head"]}
    if "rem" in rps:
        compute_specs["rem"] = rps["rem"]
    if cfg.is_encdec:
        compute_specs["encoder_layers"] = rps["encoder"]["layers"]
    ba = shd.batch_axes(mesh, sh.global_batch)
    cache_ps = shd.cache_pspecs(cfg, mesh, ins["cache"], sh.global_batch)
    cache_sh = shd.to_shardings(mesh, cache_ps)
    tok_sh = NamedSharding(mesh, PartitionSpec(ba))
    pos_sh = NamedSharding(mesh, PartitionSpec())
    fn = make_decode_step(cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(serve_params_sh, cache_sh, tok_sh, pos_sh),
        donate_argnums=(1,),
    )
    with activation_sharding(mesh, ba, compute_specs):
        return jitted.lower(abs_params, ins["cache"], ins["token"], ins["pos"]), cfg, spec


def analyze(lowered, mesh) -> Dict[str, Any]:
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo)
    n_dev = int(np.prod(mesh.devices.shape))
    out = {
        "compile_s": round(t_compile, 1),
        "n_devices": n_dev,
        # Per-device numbers (post-SPMD HLO), loop-multiplier-aware.
        "flops_per_device": float(stats["dot_flops"]),
        "collective_bytes_per_device": float(stats["collective_bytes"]),
        "collective_by_kind": {k: float(v) for k, v in stats["collective_by_kind"].items()},
        "n_dot_sites": int(stats["n_dot_sites"]),
        "while_trips": stats["while_trips"],
        # Entry-computation-only numbers from XLA (for cross-checking).
        "xla_entry_flops": float(cost.get("flops", 0.0)),
        "xla_entry_bytes": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "hlo_bytes": len(hlo),
    }
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, tag: str = "baseline",
             cfg_overrides: Optional[Dict[str, Any]] = None,
             spec: Optional[TrainSpec] = None,
             variant: str = "baseline") -> Dict[str, Any]:
    ok, why = cell_is_runnable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        spec = spec or tuning_for(arch, shape, mesh_kind)
        if cfg_overrides is None:
            cfg_overrides = CFG_OVERRIDES.get((arch, shape))
        lowered, cfg, spec = lower_cell(arch, shape, mesh, spec=spec,
                                        cfg_overrides=cfg_overrides, variant=variant)
        res = analyze(lowered, mesh)
        res.update(
            arch=arch, shape=shape, mesh=mesh_kind, tag=tag, status="ok",
            n_params=cfg.param_count(),
            n_params_active=cfg.param_count(active_only=True),
        )
        # memory_analysis() reports the per-device executable already.
        mem = res["memory"]
        per_dev = mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"] - mem["alias_bytes"]
        res["bytes_per_device"] = per_dev
        print(f"[dryrun] {arch} x {shape} x {mesh_kind} ({tag}): OK "
              f"compile={res['compile_s']}s flops/dev={res['flops_per_device']:.3e} "
              f"bytes/dev={per_dev/2**30:.2f}GiB coll/dev={res['collective_bytes_per_device']:.3e}B")
        return res
    except Exception as e:  # noqa: BLE001 - record the failure in the cache
        print(f"[dryrun] {arch} x {shape} x {mesh_kind} ({tag}): FAIL {e}")
        return {"arch": arch, "shape": shape, "mesh": mesh_kind, "tag": tag,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_result(path: str, res: Dict[str, Any]) -> None:
    all_res = load_results(path)
    key = f"{res['arch']}|{res['shape']}|{res['mesh']}|{res['tag']}"
    all_res[key] = res
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(tmp, "w") as f:
        json.dump(all_res, f, indent=1)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "dponly", "seqpar", "rematdots", "moe2d", "all2d"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(RESULTS))
    args = ap.parse_args()

    cells = (
        [(a, s) for a in ARCHS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    existing = load_results(args.out)
    for arch, shape in cells:
        key = f"{arch}|{shape}|{args.mesh}|{args.tag}"
        prev = existing.get(key)
        if prev and prev.get("status") in ("ok", "skipped") and not args.force:
            print(f"[dryrun] {key}: cached ({prev['status']})")
            continue
        res = run_cell(arch, shape, args.mesh, tag=args.tag, variant=args.variant)
        save_result(args.out, res)


if __name__ == "__main__":
    main()
